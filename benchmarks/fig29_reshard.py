"""Fig. 29 (repo extension) — elastic online resharding of the CSSD array.

ROADMAP item 3 closes here: the array grows, shrinks, and re-places its
hottest vertex classes LIVE — batched reads keep flowing (and must stay
bit-identical to the single-device store at every migration chunk
boundary) while only the pages that change owner move shard-to-shard
over the peer links.  Three drills:

  * **grow 4 -> 5** — a fresh endpoint attaches mid-serve; the planner
    refines ``vid % 4`` to 20 classes and the new shard steals 4 of
    them, so migration must ship ~1/5 of the data set and NOT re-ship
    the rest (**byte accounting asserted**: shipped bytes within
    (5%, 35%) of the bulk-load page bytes).  Closed-loop reader threads
    hammer ``sample_batch`` throughout — **zero failed requests** and
    every result bit-identical to the single-device reference; an
    ``on_progress`` probe re-checks embedding + adjacency bit-identity
    at **every chunk boundary**;
  * **shrink 4 -> 3** — the highest shard drains out under the same
    traffic + probes (12 classes, 3 move: byte window (10%, 45%));
  * **heat rebalance, R in {1, 2}** — fig24's skewed mix (a hot
    community clustered in two residue classes) on a 4-shard array.  At
    R=1 hash placement pins the hot pages onto two shards (balance
    ~0.36, the hole fig24 leaves open); ``reshard(rebalance=True)``
    refines the map x4 and moves the hottest classes off the loaded
    shards using the measured read heat — **acceptance: R=1 min/max
    read balance >= 0.8**, results bit-identical before/after.  At R=2
    the same rebalance must coexist with replica spreading
    (bit-identity asserted; spreading already balances, the map move
    must not break it).

  PYTHONPATH=src:. python -m benchmarks.fig29_reshard [--smoke]
"""
from __future__ import annotations

import threading
import time

import numpy as np

from . import common as C
from .fig24_replicated import (HUB_CLASSES, N_SHARDS, _balance,
                               skewed_workload, target_stream)
from repro.store import (GraphStore, ReplicatedGraphStore,
                         ShardedGraphStore, sample_batch)
from repro.store.blockdev import BlockDevice
from repro.store.endpoint import LocalShardEndpoint

H_THRESHOLD = 32
DEV_PAGES = 1 << 15


def _devs(n):
    return [BlockDevice(DEV_PAGES) for _ in range(n)]


def _ref_results(ref, batches, fanouts):
    return [sample_batch(ref, t, list(fanouts),
                         rng=np.random.default_rng(1000 + b), pad_to=64)
            for b, t in enumerate(batches)]


def _same(a, b) -> bool:
    if not np.array_equal(a.node_vids, b.node_vids):
        return False
    if not np.array_equal(a.embeddings, b.embeddings):
        return False
    return all(np.array_equal(la.nbr, lb.nbr) and
               np.array_equal(la.mask, lb.mask)
               for la, lb in zip(a.layers, b.layers))


def _elastic_drill(store, ref, batches, fanouts, probe_vids, *,
                   reshard_kw, n_readers=2):
    """Run ``store.reshard(**reshard_kw)`` under closed-loop traffic.

    Reader threads replay the seeded batch stream against the array and
    compare every result to the single-device reference until the
    migration finishes; an ``on_progress`` hook re-checks a probe set
    bit-identically at every adjacency/embedding chunk boundary.
    Returns (report, probes, completed, errors)."""
    ref_res = _ref_results(ref, batches, fanouts)
    ref_emb = ref.get_embeds(probe_vids)
    ref_adj = [ref.get_neighbors(int(v)) for v in probe_vids[:2]]
    stop = threading.Event()
    errors: list[str] = []
    done = [0]
    lock = threading.Lock()

    def reader(tid):
        b = tid
        while not stop.is_set():
            try:
                got = sample_batch(store, batches[b % len(batches)],
                                   list(fanouts),
                                   rng=np.random.default_rng(
                                       1000 + b % len(batches)),
                                   pad_to=64)
                if not _same(ref_res[b % len(batches)], got):
                    raise AssertionError("mid-migration batch diverged")
            except Exception as e:  # noqa: BLE001 — surfaced by the caller
                with lock:
                    errors.append(f"reader {tid}: {type(e).__name__}: {e}")
                return
            with lock:
                done[0] += 1
            b += n_readers

    probes = [0]

    def on_progress(ev):
        if ev["event"] not in ("chunk", "emb_chunk"):
            return
        if not np.array_equal(store.get_embeds(probe_vids), ref_emb):
            errors.append(f"probe at {ev}: embeddings diverged")
        for v, want in zip(probe_vids[:2], ref_adj):
            if not np.array_equal(store.get_neighbors(int(v)), want):
                errors.append(f"probe at {ev}: adjacency of {v} diverged")
        probes[0] += 1

    threads = [threading.Thread(target=reader, args=(t,))
               for t in range(n_readers)]
    for t in threads:
        t.start()
    try:
        report = store.reshard(on_progress=on_progress, **reshard_kw)
    finally:
        stop.set()
        for t in threads:
            t.join()
    if errors:
        raise AssertionError(f"{len(errors)} failures; first: {errors[0]}")
    assert probes[0] > 0, "migration produced no chunk boundaries to probe"
    assert done[0] > 0, "no closed-loop traffic completed mid-migration"
    # post-move: the full stream must still be bit-identical
    for want, t, b in zip(ref_res, batches, range(len(batches))):
        got = sample_batch(store, t, list(fanouts),
                           rng=np.random.default_rng(1000 + b), pad_to=64)
        assert _same(want, got), "post-reshard batch diverged"
    return report, probes[0], done[0], errors


def _load_bytes(store) -> int:
    return sum(d.stats.written_bytes for d in
               (ep.local_store.dev for ep in store.endpoints))


def _measure_reads(store, batches, fanouts):
    devs = [ep.local_store.dev for ep in store.endpoints]
    reads0 = [d.stats.read_pages for d in devs]
    res = _ref_results(store, batches, fanouts)
    reads = [d.stats.read_pages - r0 for d, r0 in zip(devs, reads0)]
    return reads, res


def run(smoke: bool = False):
    lines: list[str] = []
    if smoke:
        n, e, feat, n_warm = 16000, 144000, 64, 1600
        batch, n_batches, fanouts = 64, 3, [10, 10]
        chunk_pages, reps = 64, (1,)
    else:
        n, e, feat, n_warm = 48000, 432000, 128, 4800
        batch, n_batches, fanouts = 96, 4, [12, 12]
        chunk_pages, reps = 128, (1, 2)
    edges, emb, warm, cold_pool = skewed_workload(n, e, feat, n_warm)
    batches = target_stream(warm, cold_pool, batch, n_batches)
    rng = np.random.default_rng(7)
    probe_vids = rng.integers(0, n, 64).astype(np.int64)

    ref = GraphStore(BlockDevice(DEV_PAGES * N_SHARDS),
                     h_threshold=H_THRESHOLD)
    ref.update_graph(edges, emb)

    # ---------------------------------------------------- grow 4 -> 5 live
    store = ShardedGraphStore(devs=_devs(N_SHARDS), h_threshold=H_THRESHOLD)
    store.update_graph(edges, emb)
    loaded = _load_bytes(store)
    new_ep = LocalShardEndpoint(dev=BlockDevice(DEV_PAGES),
                                h_threshold=H_THRESHOLD, feature_dim=feat)
    t0 = time.perf_counter()
    rep, probes, served, _ = _elastic_drill(
        store, ref, batches, fanouts, probe_vids,
        reshard_kw=dict(add=[new_ep], chunk_pages=chunk_pages))
    grow_s = time.perf_counter() - t0
    ratio = rep["bytes_shipped"] / loaded
    # refine 4 -> 20 classes, the new shard steals 4: ~20% of the data
    # moves; anything near 100% would mean we re-shipped unmoved pages
    assert 0.05 < ratio < 0.35, \
        f"grow shipped {ratio:.2f}x of the loaded bytes (want ~0.2)"
    assert store.n_shards == N_SHARDS + 1
    lines.append(C.csv_line(
        "fig29.grow.4to5", grow_s,
        f"classes_moved={rep['classes_moved']};"
        f"bytes_shipped={rep['bytes_shipped']};byte_ratio={ratio:.3f};"
        f"chunk_probes={probes};mid_migration_batches={served};errors=0"))

    # -------------------------------------------------- shrink 4 -> 3 live
    store = ShardedGraphStore(devs=_devs(N_SHARDS), h_threshold=H_THRESHOLD)
    store.update_graph(edges, emb)
    loaded = _load_bytes(store)
    t0 = time.perf_counter()
    rep, probes, served, _ = _elastic_drill(
        store, ref, batches, fanouts, probe_vids,
        reshard_kw=dict(remove=[N_SHARDS - 1], chunk_pages=chunk_pages))
    shrink_s = time.perf_counter() - t0
    ratio = rep["bytes_shipped"] / loaded
    # refine 4 -> 12 classes, the removed shard's 3 move: ~25%
    assert 0.10 < ratio < 0.45, \
        f"shrink shipped {ratio:.2f}x of the loaded bytes (want ~0.25)"
    assert store.n_shards == N_SHARDS - 1
    lines.append(C.csv_line(
        "fig29.shrink.4to3", shrink_s,
        f"classes_moved={rep['classes_moved']};"
        f"bytes_shipped={rep['bytes_shipped']};byte_ratio={ratio:.3f};"
        f"chunk_probes={probes};mid_migration_batches={served};errors=0"))

    # ------------------------------------- heat rebalance at R in {1, 2}
    for r in reps:
        store = ReplicatedGraphStore(devs=_devs(N_SHARDS), replication=r,
                                     h_threshold=H_THRESHOLD)
        store.update_graph(edges, emb)
        _measure_reads(store, batches[:1], fanouts)              # warm
        reads_before, res_before = _measure_reads(store, batches, fanouts)
        bal_before = _balance(reads_before)
        t0 = time.perf_counter()
        rep, probes, served, _ = _elastic_drill(
            store, ref, batches, fanouts, probe_vids,
            reshard_kw=dict(rebalance=True, refine=4,
                            chunk_pages=chunk_pages))
        reb_s = time.perf_counter() - t0
        reads_after, res_after = _measure_reads(store, batches, fanouts)
        bal_after = _balance(reads_after)
        for want, got in zip(res_before, res_after):
            assert _same(want, got), f"R={r} rebalance changed results"
        if r == 1:
            # THE acceptance number: hash placement pins the hot
            # community onto 2 of 4 shards (~0.36); the heat-weighted
            # map must spread the hot classes themselves
            assert bal_after >= 0.8, \
                f"R=1 rebalanced balance {bal_after:.3f} < 0.8"
            assert bal_before < bal_after, (bal_before, bal_after)
        lines.append(C.csv_line(
            f"fig29.rebalance.r{r}", reb_s,
            f"balance_before={bal_before:.3f};"
            f"balance_after={bal_after:.3f};"
            f"classes_moved={rep['classes_moved']};"
            f"bytes_shipped={rep['bytes_shipped']};"
            f"chunk_probes={probes};mid_migration_batches={served}"))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for ln in run(smoke=args.smoke):
        print(ln)
