"""Fig. 22 (repo extension) — closed-loop multi-client serving benchmark.

Serial per-request baseline (one synchronous doorbell: every client queues
behind the single in-flight command, the paper's pre-multi-queue situation)
vs the concurrent serving runtime (multi-queue RoP + continuous batcher +
device-DRAM embedding cache), warm and cold cache.

Each of N clients runs a closed loop: submit one Run(DFG, batch), wait for
its completion, repeat.  Both sides register the model once device-side
(``put_weights``) and run in steady state — shape-bucket jit compiles are
warmed untimed, as the paper's GPU baselines run precompiled kernels.
Reported per mode: mean/percentile request latency and aggregate
throughput; the headline number is the scheduler's throughput speedup over
the serial doorbell at the same client count (acceptance target: >= 3x at
16 clients).

  PYTHONPATH=src:. python -m benchmarks.fig22_serving [--smoke]
"""
from __future__ import annotations

import threading
import time

import numpy as np

from . import common as C
from repro.core import gnn
from repro.core.service import HolisticGNNService, make_service_dfg
from repro.rpc import RPCServer, RPCClient
from repro.serve import ServingRuntime

WEIGHTS_REF = "fig22-gcn"


def _workload(n, e, feat, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _service(edges, emb, weights, *, cache_pages):
    svc = HolisticGNNService(h_threshold=64, pad_to=64,
                             dev=C.storage_device(),
                             cache_pages=cache_pages)
    svc.store.update_graph(edges, emb)
    svc.put_weights(WEIGHTS_REF, weights)
    return svc


def _requests(n, clients, per_client, batch):
    """Deterministic per-client request streams (targets, seed)."""
    streams = []
    for c in range(clients):
        rng = np.random.default_rng(1000 + c)
        streams.append([(rng.integers(0, n, batch).tolist(), c * 10000 + r)
                        for r in range(per_client)])
    return streams


def _closed_loop(issue_fn, streams):
    """Run every client's stream concurrently; returns per-request latencies
    (seconds) and the aggregate wall time."""
    lat: list[float] = []
    lock = threading.Lock()

    def client_loop(cid):
        mine = []
        for targets, seed in streams[cid]:
            t0 = time.perf_counter()
            issue_fn(cid, targets, seed)
            mine.append(time.perf_counter() - t0)
        with lock:
            lat.extend(mine)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client_loop, args=(c,))
               for c in range(len(streams))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return np.array(lat), time.perf_counter() - t0


def _measure(issue_fn, streams, passes=2):
    """Best-of-N timed passes (steady state; container stalls land on
    single passes — same best-of methodology as common.timeit)."""
    best = None
    for _ in range(passes):
        lat, wall = _closed_loop(issue_fn, streams)
        if best is None or wall < best[1]:
            best = (lat, wall)
    return best


def _report(name, lat, wall, n_req, extra=""):
    rps = n_req / wall
    derived = (f"rps={rps:.1f};p50ms={np.percentile(lat, 50) * 1e3:.1f};"
               f"p95ms={np.percentile(lat, 95) * 1e3:.1f};"
               f"p99ms={np.percentile(lat, 99) * 1e3:.1f}")
    if extra:
        derived += ";" + extra
    return C.csv_line(name, float(lat.mean()), derived), rps


def run(smoke=False, clients=16, per_client=12, batch=8):
    import sys
    if smoke:
        clients, per_client = 4, 3
        n, e, feat = 3000, 20000, 64
    else:
        n, e, feat = 20000, 100000, 128
    # finer GIL quantum for the many-client closed loops (both modes);
    # restored before returning
    old_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.001)
    try:
        return _run(clients, per_client, batch, n, e, feat)
    finally:
        sys.setswitchinterval(old_switch)


def _run(clients, per_client, batch, n, e, feat):
    edges, emb = _workload(n, e, feat)
    params = gnn.init_params("gcn", [feat, 64, 32], seed=1)
    dfg = make_service_dfg("gcn", 2, [10, 10]).save()
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    streams = _requests(n, clients, per_client, batch)
    n_req = clients * per_client
    lines = []

    # ---- serial baseline: one synchronous doorbell, no cache
    svc_s = _service(edges, emb, weights, cache_pages=None)
    rpc = RPCClient(RPCServer(svc_s))
    door = threading.Lock()                   # the single in-flight command

    def serial_issue(cid, targets, seed):
        with door:
            rpc.call("run", dfg=dfg, batch=targets,
                     weights_ref=WEIGHTS_REF, seed=seed)

    # one untimed pass over the full streams (jit signature compiles):
    # both sides are measured in steady state
    _closed_loop(serial_issue, streams)
    lat, wall = _measure(serial_issue, streams)
    line, rps_serial = _report(f"fig22.serial.{clients}c", lat, wall, n_req)
    lines.append(line)

    # ---- scheduled runtime: multi-queue RoP + batcher + page cache
    svc = _service(edges, emb, weights, cache_pages=8192)
    rng = np.random.default_rng(7)
    for g in (1, 2, 3, 4, 6, 8, 10, 12, 14, 16):   # warm group-size buckets
        if g <= clients:
            svc.run_batch(dfg, [{"targets":
                                 rng.integers(0, n, batch).tolist(),
                                 "seed": 1} for _ in range(g)],
                          weights_ref=WEIGHTS_REF)
    rt = ServingRuntime(svc, n_queues=min(clients, 16), queue_depth=64,
                        max_group=16, max_pending=512)
    stubs = [rt.client() for _ in range(clients)]

    def sched_issue(cid, targets, seed):
        stubs[cid].call("run", dfg=dfg, batch=targets,
                        weights_ref=WEIGHTS_REF, seed=seed, timeout=600)

    rt.start()
    try:
        _closed_loop(sched_issue, streams)                     # untimed
        lat, wall = _measure(sched_issue, streams)             # warm cache
        qos = rt.qos_snapshot()
        hr = svc.store.cache.stats.hit_rate
        line, rps_warm = _report(
            f"fig22.sched_warm.{clients}c", lat, wall, n_req,
            extra=(f"hit_rate={hr:.2f};"
                   f"avg_group={qos['avg_group_size']:.1f}"))
        lines.append(line)

        # cold-cache passes: drop the cache each time, keep jit warm
        best = None
        for _ in range(2):
            svc.store.cache.clear()
            got = _closed_loop(sched_issue, streams)
            if best is None or got[1] < best[1]:
                best = got
        lat, wall = best
        line, rps_cold = _report(f"fig22.sched_cold.{clients}c", lat, wall,
                                 n_req)
        lines.append(line)
    finally:
        rt.stop()

    lines.append(C.csv_line(
        "fig22.speedup", 0.0,
        f"warm={rps_warm / rps_serial:.1f}x;cold={rps_cold / rps_serial:.1f}x"
        f";serial_rps={rps_serial:.1f}"))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--per-client", type=int, default=12)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    for ln in run(smoke=args.smoke, clients=args.clients,
                  per_client=args.per_client, batch=args.batch):
        print(ln)
