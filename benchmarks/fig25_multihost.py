"""Fig. 25 (repo extension) — multi-host CSSD array over ShardEndpoints.

The paper's interface claim is "RPC over PCIe": hosts program against the
graph semantic library with no knowledge of the storage configuration
(§3.3).  This benchmark drives the array coordinator against shards that
sit behind REAL message boundaries (``RopShardEndpoint``: per-shard
MultiQueueRoP SQ/CQ pair + PCIeChannel + host poll thread) and shows the
fetch/compute split survives the hop:

  * **RPC amortisation** — per-shard RPC count per batched read stays
    O(1) while the pages served per read grow ~10x: the whole frontier is
    ONE ``fetch`` command per shard, never one round-trip per page (the
    scale-out restatement of the paper's batched-RoP argument, Fig. 19);
  * **scale-out prep throughput** — the fig23 feature-heavy workload at
    QLC-class flash latencies, swept over 1/2/4 REMOTE shards: the
    coordinator submits to every shard and awaits them together, so the
    array still pays max(shard costs) and throughput scales (acceptance:
    >= 2x at 4 shards, asserted in full mode);
  * **shard-to-shard rebuild** — fail + rebuild on a replicated remote
    array: survivor pages stream over the endpoints' peer links in
    bounded chunks, and the coordinator's own RoP link moves only
    metadata (asserted: coordinator bytes during rebuild are a tiny
    fraction of the page data the replacement shard writes).

  PYTHONPATH=src:. python -m benchmarks.fig25_multihost [--smoke]
"""
from __future__ import annotations

import numpy as np

from . import common as C
from repro.store import (PAGE_BYTES, ReplicatedGraphStore,
                         ShardedGraphStore, make_rop_endpoints,
                         sample_batch)
from repro.store.blockdev import BlockDevice

# Array-scale device profile, one notch below fig23's: archival/dense-QLC
# page latency on a cost-optimized 4-channel device (125 us effective per
# page, ~32 MB/s random each) — the per-device-bandwidth-starved regime that
# motivates buying MORE devices rather than better ones, i.e. exactly
# where a multi-host array earns its keep.  As everywhere in this repo,
# the scale-out claim rides the ANALYTIC device-time model (the array
# pays max over shards of the deferred flash time); host-side compute is
# a container-bound constant the model deliberately prices apart.
PAGE_READ_US = 500.0
PAGE_WRITE_US = 600.0
CMD_LATENCY_US = 20.0
DEV_CHANNELS = 4


def _flash_devs(n: int) -> list[BlockDevice]:
    devs = [BlockDevice(1 << 15, simulate_latency=True,
                        page_read_us=PAGE_READ_US,
                        page_write_us=PAGE_WRITE_US,
                        command_latency_us=CMD_LATENCY_US)
            for _ in range(n)]
    for d in devs:
        d.channels = DEV_CHANNELS
    return devs


def _workload(n, e, feat, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.35, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


# ------------------------------------------------------ A: RPC amortisation
def _rpc_amortisation(lines, *, replicated: bool, batches=(16, 64, 256)):
    """Per-shard RPCs per batched read vs pages served: O(1), not O(pages).

    The replicated variant adds the per-class ``plan_info`` calls and the
    gossip ``counters`` pull, so its constant is higher — but still a
    constant (and the gossip amortises under ``stats_staleness_s``).
    """
    n_shards = 2
    edges, emb = _workload(4000, 24000, 64)
    eps = make_rop_endpoints(n_shards, h_threshold=64)
    if replicated:
        store = ReplicatedGraphStore(endpoints=eps, replication=2,
                                     h_threshold=64)
    else:
        store = ShardedGraphStore(endpoints=eps, h_threshold=64)
    store.update_graph(edges, emb)
    tag = "rep" if replicated else "sharded"
    worst_rpcs = 0.0
    for b in batches:
        vids = np.random.default_rng(1).integers(0, 4000, b)
        reads0 = [s["device"]["read_pages"] for s in store.shard_stats()]
        calls0 = [ep.rpc_calls() for ep in store.endpoints]
        repeat = 4
        for r in range(repeat):
            store.get_neighbors_batch(vids)
            store.get_embeds(vids)
        calls1 = [ep.rpc_calls() for ep in store.endpoints]
        reads1 = [s["device"]["read_pages"] for s in store.shard_stats()]
        # 2 batched reads per round (adjacency + embeds)
        rpcs = max(c1 - c0 for c0, c1 in zip(calls0, calls1)) \
            / (2.0 * repeat)
        pages = sum(r1 - r0 for r0, r1 in zip(reads0, reads1)) \
            / (2.0 * repeat)
        worst_rpcs = max(worst_rpcs, rpcs)
        lines.append(C.csv_line(
            f"fig25.rpc.{tag}.b{b}", 0.0,
            f"rpcs_per_shard_per_read={rpcs:.1f};"
            f"pages_per_read={pages:.1f}"))
    # O(1) acceptance: the per-shard command count per batched read must
    # not scale with the page count (bound covers fetch + plan_info +
    # gossip for the replicated array)
    bound = 4.5 if replicated else 1.5
    assert worst_rpcs <= bound, \
        f"per-read RPC count {worst_rpcs} exceeds O(1) bound {bound}"
    store.close()
    return lines


# --------------------------------------------------- B: scale-out prep
def _prep_sweep(lines, shard_counts, w, batch, fanouts, repeat,
                assert_speedup):
    n, e, feat = (3000, 16000, 256) if w == "small" else (40000, 120000, 1024)
    edges, emb = _workload(n, e, feat)
    targets = np.random.default_rng(0).integers(0, n, batch)
    base_tp = None
    speedups = {}
    for ns in shard_counts:
        store = ShardedGraphStore(
            endpoints=make_rop_endpoints(ns, devs=_flash_devs(ns),
                                         h_threshold=64),
            h_threshold=64)
        store.update_graph(edges, emb)

        def prep():
            return sample_batch(store, targets, list(fanouts),
                                rng=np.random.default_rng(0), pad_to=64)

        prep()                                          # warm
        t, _ = C.timeit(prep, repeat=repeat)
        tp = 1.0 / t
        if base_tp is None:
            base_tp = tp
        speedups[ns] = tp / base_tp
        lines.append(C.csv_line(
            f"fig25.prep.{w}.{ns}remote", t,
            f"batches_per_s={tp:.1f};speedup={tp / base_tp:.2f}x"))
        store.close()
    if assert_speedup and 4 in speedups:
        assert speedups[4] >= 2.0, \
            f"remote 4-shard prep speedup {speedups[4]:.2f}x < 2x"
    return lines


# -------------------------------------------------- C: rebuild streaming
def _rebuild_streaming(lines):
    """Coordinator link bytes during rebuild vs page data streamed peer to
    peer — the endpoint-to-endpoint claim, measured."""
    edges, emb = _workload(6000, 40000, 128)
    eps = make_rop_endpoints(3, h_threshold=64)
    store = ReplicatedGraphStore(endpoints=eps, replication=2,
                                 h_threshold=64)
    store.update_graph(edges, emb)
    victim = 1
    store.fail_shard(victim)
    coord0 = store.endpoints[victim].channel_bytes()
    info = store.rebuild_shard(victim)
    coord_bytes = store.endpoints[victim].channel_bytes() - coord0
    page_bytes = int(info["pages_written"]) * PAGE_BYTES
    lines.append(C.csv_line(
        "fig25.rebuild.stream", info["seconds"],
        f"pages_written={info['pages_written']};"
        f"coordinator_bytes={coord_bytes};"
        f"peer_page_bytes={page_bytes};"
        f"coord_frac={coord_bytes / max(page_bytes, 1):.4f}"))
    # the coordinator carries plan + summary, never the survivor pages
    assert coord_bytes < 65536, \
        f"rebuild moved {coord_bytes} bytes through the coordinator link"
    assert page_bytes > 10 * coord_bytes, (coord_bytes, page_bytes)
    store.close()
    return lines


def run(smoke: bool = False):
    lines: list[str] = []
    if smoke:
        _rpc_amortisation(lines, replicated=False, batches=(16, 128))
        _rpc_amortisation(lines, replicated=True, batches=(16, 128))
        _prep_sweep(lines, (1, 2), "small", 32, [10, 10], 2,
                    assert_speedup=False)
        _rebuild_streaming(lines)
    else:
        _rpc_amortisation(lines, replicated=False)
        _rpc_amortisation(lines, replicated=True)
        _prep_sweep(lines, (1, 2, 4), "large", 128, [15, 10], 3,
                    assert_speedup=True)
        _rebuild_streaming(lines)
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for ln in run(smoke=args.smoke):
        print(ln)
