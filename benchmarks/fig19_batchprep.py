"""Fig. 19 — batch preprocessing (GetNeighbors + GetEmbed) latency for the
first and subsequent batches: GPU-enabled host (must preprocess the raw
graph before batch 1) vs CSSD GraphStore (adjacency ready at update time)."""
from __future__ import annotations

import time

import numpy as np

from . import common as C
from repro.store.sampler import sample_batch, sample_batch_ref


def run(workloads=("chmleon", "youtube")):
    lines = []
    for w in workloads:
        edges, emb, _ = C.make_workload(w)
        rng = np.random.default_rng(0)
        targets = rng.integers(0, emb.shape[0], 8)

        # host: first batch pays graph load + preprocess + embedding load
        host = C.HostPipeline(edges, emb)
        t0 = time.perf_counter()
        host.batch_preprocess(targets, [10, 10])
        t_host_first = time.perf_counter() - t0
        t_host_next, _ = C.timeit(host.batch_preprocess, targets, [10, 10],
                                  repeat=5)

        # near-storage: adjacency already page-resident from ingest
        svc, _ = C.hgnn_service(edges, emb)
        t0 = time.perf_counter()
        sample_batch(svc.store, targets, [10, 10],
                     rng=np.random.default_rng(0), pad_to=32)
        t_gs_first = time.perf_counter() - t0
        t_gs_next, _ = C.timeit(
            lambda: sample_batch(svc.store, targets, [10, 10],
                                 rng=np.random.default_rng(0), pad_to=32),
            repeat=5)

        lines.append(C.csv_line(f"fig19.{w}.host_first", t_host_first, ""))
        lines.append(C.csv_line(
            f"fig19.{w}.gs_first", t_gs_first,
            f"speedup={t_host_first/t_gs_first:.1f}x;"
            f"paper={'1.7x' if w == 'chmleon' else '114.5x'}"))
        # the per-vertex-loop seed sampler, for the fast-path speedup claim
        t_gs_ref, _ = C.timeit(
            lambda: sample_batch_ref(svc.store, targets, [10, 10],
                                     rng=np.random.default_rng(0), pad_to=32),
            repeat=5)

        lines.append(C.csv_line(f"fig19.{w}.host_next", t_host_next, ""))
        lines.append(C.csv_line(
            f"fig19.{w}.gs_next", t_gs_next,
            f"fastpath_speedup={t_gs_ref/t_gs_next:.1f}x"))
        lines.append(C.csv_line(f"fig19.{w}.gs_next_ref", t_gs_ref, ""))
    return lines
