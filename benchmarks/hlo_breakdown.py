"""Attribution: which ops/computations dominate the loop-expanded bytes and
flops of a recorded dry-run cell — the §Perf profiling view.

  PYTHONPATH=src:. python -m benchmarks.hlo_breakdown results/dryrun/<cell>.hlo.gz
"""
from __future__ import annotations

import gzip
import re
import sys
from collections import defaultdict

from .hlo_analysis import (parse_hlo, _while_trip_count, _operand_names,
                           _called_comps, _dot_flops, shape_bytes, COLLECTIVES)


def breakdown(text: str):
    comps, entry = parse_hlo(text)
    by_op_bytes = defaultdict(float)
    by_comp_bytes = defaultdict(float)
    by_comp_flops = defaultdict(float)
    coll = defaultdict(float)

    from . import hlo_analysis as H
    # reuse the exact byte model by monkey-walking with local accumulation
    def visit(comp_name, mult, depth):
        comp = comps.get(comp_name)
        if comp is None or depth > 16:
            return
        for ins in comp.instrs:
            if ins.op in COLLECTIVES:
                b = 0
                for on in _operand_names(ins.args):
                    src = comp.by_name.get(on)
                    if src is not None:
                        b += shape_bytes(src.type_str)
                b = b or shape_bytes(ins.type_str)
                coll[f"{ins.op}@{comp_name[:36]}"] += b * mult
                by_op_bytes[ins.op] += b * mult
                by_comp_bytes[comp_name] += b * mult
            elif ins.op == "while":
                cond = re.search(r"condition=%?([\w\.\-]+)", ins.args)
                body = re.search(r"body=%?([\w\.\-]+)", ins.args)
                tc = _while_trip_count(comps, cond.group(1)) if cond else None
                tc = tc if tc and tc > 0 else 1
                if body:
                    visit(body.group(1), mult * tc, depth + 1)
            else:
                ea = H.expanded_analysis.__wrapped__ if False else None
                # replicate the single-op byte model
                b = _op_bytes_model(comps, comp, ins)
                if b:
                    by_op_bytes[ins.op] += b * mult
                    by_comp_bytes[comp_name] += b * mult
                if ins.op in ("dot", "convolution"):
                    by_comp_flops[comp_name] += _dot_flops(comp, ins) * mult
                if ins.op == "fusion":
                    for cn in _called_comps(ins.args):
                        fc = comps.get(cn)
                        if fc:
                            for fi in fc.instrs:
                                if fi.op == "dot":
                                    by_comp_flops[comp_name] += \
                                        _dot_flops(fc, fi) * mult
                if ins.op in ("call", "conditional", "custom-call"):
                    for cn in _called_comps(ins.args):
                        visit(cn, mult, depth + 1)

    def _op_bytes_model(comps, comp, ins):
        import benchmarks.hlo_analysis as H2
        # mirror expanded_analysis op handling
        skip = H2._SKIP_BYTES_OPS
        if ins.op in skip or ins.op in COLLECTIVES or ins.op == "while":
            return 0.0
        if ins.op == "fusion":
            # same fusion model
            called = _called_comps(ins.args)
            fc = comps.get(called[0]) if called else None
            if fc is None:
                return shape_bytes(ins.type_str)
            total = 0.0
            uses = {}
            for node in fc.instrs:
                for on in _operand_names(node.args):
                    uses.setdefault(on, []).append(node)
            for node in fc.instrs:
                if node.op != "parameter":
                    continue
                u = uses.get(node.name, [])
                if u and all(x.op in ("dynamic-slice", "gather") for x in u):
                    total += sum(shape_bytes(x.type_str) for x in u)
                else:
                    total += shape_bytes(node.type_str)
            root = next((x for x in fc.instrs if x.is_root), None)
            if root is not None and root.op == "tuple":
                for on in _operand_names(root.args):
                    nd = fc.by_name.get(on)
                    total += _w(fc, nd)
            else:
                total += _w(fc, root)
            return total
        if ins.op in ("dynamic-slice", "slice", "gather"):
            return 2.0 * shape_bytes(ins.type_str)
        if ins.op in ("dynamic-update-slice", "scatter"):
            cand = [shape_bytes(comp.by_name[on].type_str)
                    for on in _operand_names(ins.args)
                    if on in comp.by_name]
            return 2.0 * min(cand) if cand else 0.0
        if ins.op == "broadcast":
            return shape_bytes(ins.type_str)
        b = shape_bytes(ins.type_str)
        for on in _operand_names(ins.args):
            src = comp.by_name.get(on)
            if src is not None:
                b += shape_bytes(src.type_str)
        return b

    def _w(fc, node):
        if node is None:
            return 0.0
        if node.op == "dynamic-update-slice":
            cand = [shape_bytes(fc.by_name[on].type_str)
                    for on in _operand_names(node.args) if on in fc.by_name]
            return float(min(cand)) if cand else shape_bytes(node.type_str)
        return float(shape_bytes(node.type_str))

    visit(entry, 1.0, 0)
    return by_op_bytes, by_comp_bytes, by_comp_flops, coll


def main():
    path = sys.argv[1]
    with gzip.open(path, "rt") as f:
        txt = f.read()
    ob, cb, cf, coll = breakdown(txt)
    print("== bytes by op kind ==")
    for k, v in sorted(ob.items(), key=lambda kv: -kv[1])[:14]:
        print(f"  {k:28s} {v/1e9:12.2f} GB")
    print("== bytes by computation ==")
    for k, v in sorted(cb.items(), key=lambda kv: -kv[1])[:12]:
        print(f"  {k[:52]:52s} {v/1e9:12.2f} GB")
    print("== flops by computation ==")
    for k, v in sorted(cf.items(), key=lambda kv: -kv[1])[:8]:
        print(f"  {k[:52]:52s} {v/1e12:12.2f} TF")
    print("== collectives ==")
    for k, v in sorted(coll.items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {k:64s} {v/1e9:10.2f} GB")


if __name__ == "__main__":
    main()
