"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src:. python -m benchmarks.run [--only fig3,fig14,...] [--smoke]

Prints ``name,us_per_call,derived`` CSV (scaffold contract).  ``--smoke``
runs a CI-sized subset (fig19 batch-prep + fig21 fast-path + fig22 serving
+ fig23 sharding + fig24 replication + fig25 multi-host + fig27 ingest on
the small workloads) so sampler/engine/scale-out perf regressions surface
at PR time.  The
roofline table (LM archs) reads the dry-run artifacts; run
``python -m repro.launch.dryrun --all --both-meshes`` first for §Roofline.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def _parse_line(line: str, suite: str) -> dict:
    """``name,us_per_call,derived`` CSV line -> JSON-able record."""
    name, us, derived = (line.split(",", 2) + ["", ""])[:3]
    try:
        us_f = float(us)
    except ValueError:
        us_f = None
    return {"suite": suite, "name": name, "us_per_call": us_f,
            "derived": derived}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: fig19 + fig21 on the small workload")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON list (CI uploads "
                         "benchmarks/*.json as workflow artifacts)")
    ap.add_argument("--history", action="store_true",
                    help="append this run's records (timestamped) to "
                         "benchmarks/BENCH_history.json so perf drift is "
                         "trackable across CI runs")
    args = ap.parse_args(argv)

    # fig28's mesh equivalence needs a multi-device host pool; the flag
    # only takes effect if set before jax initializes, i.e. before the
    # fig-module imports below pull in jax via benchmarks.common
    if "jax" not in sys.modules:
        _flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in _flags:
            os.environ["XLA_FLAGS"] = (
                _flags + " --xla_force_host_platform_device_count=8").strip()

    from . import (fig3_breakdown, fig14_end2end, fig15_energy,
                   fig16_pure_inference, fig17_opbreakdown, fig18_bulk,
                   fig19_batchprep, fig20_mutable, fig21_fastpath,
                   fig22_serving, fig23_sharded, fig24_replicated,
                   fig25_multihost, fig26_autonomic, fig27_ingest,
                   fig28_spmd, fig29_reshard, table5_datasets)
    suites = {
        "table5": table5_datasets.run,
        "fig3": fig3_breakdown.run,
        "fig14": fig14_end2end.run,
        "fig15": fig15_energy.run,
        "fig16": fig16_pure_inference.run,
        "fig17": fig17_opbreakdown.run,
        "fig18": fig18_bulk.run,
        "fig19": fig19_batchprep.run,
        "fig20": fig20_mutable.run,
        "fig21": fig21_fastpath.run,
        "fig22": fig22_serving.run,
        "fig23": fig23_sharded.run,
        "fig24": fig24_replicated.run,
        "fig25": fig25_multihost.run,
        "fig26": fig26_autonomic.run,
        "fig27": fig27_ingest.run,
        "fig28": fig28_spmd.run,
        "fig29": fig29_reshard.run,
    }
    if args.smoke:
        suites = {
            "fig19": lambda: fig19_batchprep.run(workloads=("chmleon",)),
            "fig21": lambda: fig21_fastpath.run(smoke=True),
            "fig22": lambda: fig22_serving.run(smoke=True),
            "fig23": lambda: fig23_sharded.run(smoke=True),
            "fig24": lambda: fig24_replicated.run(smoke=True),
            "fig25": lambda: fig25_multihost.run(smoke=True),
            "fig26": lambda: fig26_autonomic.run(smoke=True),
            "fig27": lambda: fig27_ingest.run(smoke=True),
            "fig28": lambda: fig28_spmd.run(smoke=True),
            "fig29": lambda: fig29_reshard.run(smoke=True),
        }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    records: list[dict] = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            for line in fn():
                print(line)
                records.append(_parse_line(line, name))
            wall = f"{name}.suite_wall,{(time.perf_counter()-t0)*1e6:.0f},ok"
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures += 1
            wall = f"{name}.suite_wall,0,FAILED"
        print(wall)
        records.append(_parse_line(wall, name))
    if args.json:
        import json
        with open(args.json, "w") as fh:
            json.dump(records, fh, indent=1)
        print(f"# wrote {len(records)} records to {args.json}",
              file=sys.stderr)
    if args.history:
        import json
        path = os.path.join(os.path.dirname(__file__), "BENCH_history.json")
        try:
            with open(path) as fh:
                history = json.load(fh)
            assert isinstance(history, list)
        except (FileNotFoundError, ValueError, AssertionError):
            history = []
        history.append({
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "smoke": bool(args.smoke),
            "only": args.only,
            "failures": failures,
            "records": records,
        })
        with open(path, "w") as fh:
            json.dump(history, fh, indent=1)
        print(f"# appended run ({len(records)} records) to {path} "
              f"({len(history)} runs)", file=sys.stderr)
    # roofline summary (if dry-run artifacts exist)
    try:
        from .roofline import load_records, table
        recs = load_records(os.path.join(os.path.dirname(__file__), "..",
                                         "results", "dryrun"))
        if recs:
            rows = table(recs, mesh_filter="16x16")
            for r in rows:
                print(f"roofline.{r['arch']}.{r['shape']},"
                      f"{r['bound_s']*1e6:.0f},"
                      f"bound={r['bound']};frac={r['roofline_fraction']:.3f};"
                      f"useful={r['useful_flops_ratio']:.2f}")
    except Exception:  # noqa: BLE001
        traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
