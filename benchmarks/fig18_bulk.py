"""Fig. 18 — GraphStore bulk operations: (a) update bandwidth vs the
host-storage-stack path, (b) graph-preprocessing overlap with the embedding
write, (c) time-series of the cs workload ingest."""
from __future__ import annotations

import time

import numpy as np

from . import common as C
from repro.store.graphstore import GraphStore


_SYSCALL_US = 15.0          # per-write syscall + fs-journal overhead
_SYSCALL_BYTES = 128 << 10  # write(2) chunking through the storage stack


def _host_stack_write(edges, emb):
    """Host path: user buffer -> page-cache copy -> chunked write(2)
    syscalls through the filesystem (the storage-stack tax GraphStore's
    direct in-CSSD write avoids — paper Fig. 18a, ~1.3x)."""
    dev = C.storage_device()
    t0 = time.perf_counter()
    for flat, tag in ((edges.astype(np.int32).reshape(-1), "graph"),
                      (emb.reshape(-1).view(np.int32), "embed")):
        base = dev.alloc_back(-(-flat.size // 1024))
        step = _SYSCALL_BYTES // 4
        off = 0
        while off < flat.size:
            chunk = flat[off: off + step].copy()     # user -> page cache
            dev.write_span(base + off // 1024, chunk, tag=tag)
            time.sleep(_SYSCALL_US * 1e-6)           # syscall + journal
            off += step
    return time.perf_counter() - t0


def run(workloads=("cs", "physics", "road-tx")):
    lines = []
    for w in workloads:
        edges, emb, _ = C.make_workload(w)
        nbytes = edges.nbytes // 2 + emb.nbytes
        t_host = _host_stack_write(edges, emb)
        gs = GraphStore(C.storage_device(), h_threshold=64)
        tl = gs.update_graph(edges, emb)
        bw_host = nbytes / t_host / 1e9
        bw_gs = nbytes / tl.user_visible / 1e9
        lines.append(C.csv_line(f"fig18a.{w}.host_stack", t_host,
                                f"GBps={bw_host:.2f}"))
        lines.append(C.csv_line(
            f"fig18a.{w}.graphstore", tl.user_visible,
            f"GBps={bw_gs:.2f};gain={bw_gs/bw_host:.2f}x;paper=1.3x"))
        # (b) overlap: prep hidden inside the feature write?
        g0, g1 = tl.graph_pre
        f0, f1 = tl.write_feature
        hidden = min(g1, f1) - max(g0, f0)
        lines.append(C.csv_line(
            f"fig18b.{w}.graph_pre", g1 - g0,
            f"overlapped_frac={max(0.0, hidden)/max(g1-g0, 1e-9):.2f}"))
    # (c) cs time-series from device events
    edges, emb, _ = C.make_workload("cs")
    gs = GraphStore(C.storage_device(full_trace=True), h_threshold=64)
    tl = gs.update_graph(edges, emb)
    ev = gs.dev.stats.events
    emb_w = [e for e in ev if e.kind == "write" and e.tag == "embed"]
    g_w = [e for e in ev if e.kind == "write" and e.tag == "graph"]
    if emb_w and g_w:
        lines.append(C.csv_line(
            "fig18c.cs.write_feature_span", emb_w[-1].t - emb_w[0].t,
            f"graph_flush_after_feature={g_w[0].t >= emb_w[-1].t - 0.05}"))
    return lines
