"""Post-partitioning HLO analysis for the roofline.

``compiled.cost_analysis()`` on this backend (a) does NOT multiply while-loop
bodies by their trip counts (verified: scan(2) and scan(8) report identical
flops) and (b) reports per-device numbers post-SPMD.  Our models scan over
layer periods and over time (mamba/xlstm), so naive cost_analysis
undercounts by 10-4000x.  This module walks the optimized HLO text and
computes **loop-expanded, per-device**:

  * ``flops``    — 2 * prod(result_dims) * prod(contracting_dims) per dot
                   (+ cost_analysis cross-check),
  * ``bytes``    — per top-level instruction: operand + result bytes
                   (fusions count only their boundary operands/results —
                   exactly one kernel's HBM traffic),
  * ``collective bytes`` — operand bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute.

While-loop trip counts are recovered from the canonical scan pattern
(condition ``compare(gte(iv), constant), direction=LT``).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+([\w\-]+)\((.*)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)(?:\.clone)?\s+\(")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "copy-start", "copy-done", "while",
    "conditional", "call", "after-all", "partition-id", "replica-id",
    "get-dimension-size", "iota",
}


def shape_dims(type_str: str):
    """[(dtype, [dims...]), ...] for a (possibly tuple) HLO type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    args: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def parse_hlo(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if "{" in line and ("(" in line and "->" in line):
            mc = _COMP_RE.match(line)
            if mc:
                cur = Computation(mc.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            ins = Instr(mi.group(1), mi.group(2), mi.group(3), mi.group(4),
                        is_root=line.lstrip().startswith("ROOT"))
            cur.instrs.append(ins)
            cur.by_name[ins.name] = ins
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _while_trip_count(comps, cond_name: str) -> int | None:
    cond = comps.get(cond_name)
    if cond is None:
        return None
    consts = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.match(r"(-?\d+)\)?", ins.args.strip())
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.op == "compare" and "direction=LT" in ins.args:
            for on in _operand_names(ins.args):
                if on in consts:
                    return consts[on]
    if consts:
        return max(consts.values())
    return None


def _operand_names(args: str) -> list[str]:
    out = []
    for tok in re.split(r",\s*", args):
        tok = tok.strip()
        head = tok.split("(")[0]
        if "=" in head and not tok.startswith("%"):
            break
        m = re.match(r"(?:[a-z0-9]+\[[0-9,]*\]\S*\s+)?%?([\w\.\-]+)",
                     tok)
        if m:
            out.append(m.group(1))
    return out


def _called_comps(args: str) -> list[str]:
    out = []
    for key in ("calls", "to_apply", "body", "condition",
                "branch_computations"):
        for m in re.finditer(key + r"=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)",
                             args):
            for nm in re.split(r",\s*%?", m.group(1)):
                out.append(nm.strip("% "))
    return out


def _dot_flops(comp: Computation, ins: Instr) -> float:
    res = 1
    for _, dims in shape_dims(ins.type_str):
        for d in dims:
            res *= d
        break
    lhs_names = _operand_names(ins.args)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.args)
    if m and lhs_names:
        src = comp.by_name.get(lhs_names[0])
        if src is not None:
            sd = shape_dims(src.type_str)
            if sd:
                dims = sd[0][1]
                for i in m.group(1).split(","):
                    if i != "" and int(i) < len(dims):
                        contract *= dims[int(i)]
    return 2.0 * res * contract


def expanded_analysis(text: str) -> dict:
    """Loop-expanded per-device flops / bytes / collective bytes."""
    comps, entry = parse_hlo(text)
    coll_bytes = defaultdict(float)
    coll_count = defaultdict(int)
    flops = 0.0
    bytes_accessed = 0.0
    unknown_loops = 0

    def op_bytes(comp, ins) -> float:
        # sliced/gathered accesses touch only the slice, not the operand
        # buffer (XLA emits in-place/windowed reads) — count result-sized
        # read + write.  Everything else: operands + result.
        if ins.op in ("dynamic-slice", "slice", "gather"):
            return 2.0 * shape_bytes(ins.type_str)
        if ins.op in ("dynamic-update-slice", "scatter"):
            upd = 0
            ops = _operand_names(ins.args)
            # update operand is the 2nd for DUS, 3rd group for scatter;
            # take the smallest non-index operand as the touched window
            cand = []
            for on in ops:
                src = comp.by_name.get(on)
                if src is not None:
                    cand.append(shape_bytes(src.type_str))
            if cand:
                upd = min(cand)
            return 2.0 * upd
        if ins.op == "broadcast":
            return shape_bytes(ins.type_str)
        b = shape_bytes(ins.type_str)
        for on in _operand_names(ins.args):
            src = comp.by_name.get(on)
            if src is not None:
                b += shape_bytes(src.type_str)
        return b

    def visit(comp_name: str, mult: float, depth: int):
        nonlocal flops, bytes_accessed, unknown_loops
        comp = comps.get(comp_name)
        if comp is None or depth > 16:
            return
        for ins in comp.instrs:
            if ins.op in COLLECTIVES:
                ops = _operand_names(ins.args)
                b = 0
                for on in ops:
                    src = comp.by_name.get(on)
                    if src is not None:
                        b += shape_bytes(src.type_str)
                if b == 0:
                    b = shape_bytes(ins.type_str)
                coll_bytes[ins.op] += b * mult
                coll_count[ins.op] += 1
                bytes_accessed += b * mult
            elif ins.op == "while":
                cond = re.search(r"condition=%?([\w\.\-]+)", ins.args)
                body = re.search(r"body=%?([\w\.\-]+)", ins.args)
                tc = _while_trip_count(comps, cond.group(1)) if cond else None
                if tc is None or tc <= 0:
                    tc = 1
                    unknown_loops += 1
                if body:
                    visit(body.group(1), mult * tc, depth + 1)
            elif ins.op == "fusion":
                bytes_accessed += fusion_bytes(comp, ins) * mult
                for cn in _called_comps(ins.args):
                    visit_flops_only(cn, mult, depth + 1)
            elif ins.op in ("dot", "convolution"):
                flops += _dot_flops(comp, ins) * mult
                bytes_accessed += op_bytes(comp, ins) * mult
            elif ins.op in ("call", "conditional", "custom-call", "map",
                            "sort", "reduce", "scatter", "reduce-window",
                            "select-and-scatter"):
                if ins.op not in ("reduce", "scatter", "sort"):
                    for cn in _called_comps(ins.args):
                        visit(cn, mult, depth + 1)
                if ins.op not in ("call", "conditional"):
                    bytes_accessed += op_bytes(comp, ins) * mult
            elif ins.op not in _SKIP_BYTES_OPS:
                bytes_accessed += op_bytes(comp, ins) * mult

    def _write_bytes_of(fc, node) -> float:
        """Write traffic of a fusion root node: a DUS writes only the
        update window; anything else writes its full result."""
        if node is None:
            return 0.0
        if node.op == "dynamic-update-slice":
            cand = [shape_bytes(fc.by_name[on].type_str)
                    for on in _operand_names(node.args) if on in fc.by_name]
            return float(min(cand)) if cand else shape_bytes(node.type_str)
        return float(shape_bytes(node.type_str))

    def fusion_bytes(comp, ins) -> float:
        """HBM traffic of one fused kernel: parameter reads (sliced reads
        count only the slice) + root writes (DUS counts only the window)."""
        called = _called_comps(ins.args)
        fc = comps.get(called[0]) if called else None
        if fc is None:
            return op_bytes(comp, ins)
        total = 0.0
        # ---- reads: per fused parameter
        uses = {}
        for node in fc.instrs:
            for on in _operand_names(node.args):
                uses.setdefault(on, []).append(node)
        for node in fc.instrs:
            if node.op != "parameter":
                continue
            u = uses.get(node.name, [])
            if u and all(x.op in ("dynamic-slice", "gather",
                                  "dynamic-update-slice", "scatter")
                         for x in u):
                for x in u:
                    if x.op in ("dynamic-update-slice", "scatter"):
                        # the buffer is only written through a window; the
                        # window write is counted at the root — param read 0
                        continue
                    total += shape_bytes(x.type_str)
            else:
                total += shape_bytes(node.type_str)
        reads = total
        # ---- writes: root (possibly a tuple of outputs)
        writes = 0.0
        root = next((x for x in fc.instrs if x.is_root), None)
        if root is not None and root.op == "tuple":
            for on in _operand_names(root.args):
                writes += _write_bytes_of(fc, fc.by_name.get(on))
        else:
            writes += _write_bytes_of(fc, root)
        # ---- CPU-backend dtype-promotion artifact: a fusion that only
        # converts/relays bytes (convert/bitcast/copy/reshape/broadcast)
        # exists because XLA:CPU upcasts bf16 to f32 at use; a TPU compile
        # keeps bf16 native.  Count one pass-through at the narrow width.
        body_ops = {x.op for x in fc.instrs} - {"parameter", "tuple"}
        if body_ops and body_ops <= {"convert", "bitcast", "copy",
                                     "reshape", "broadcast"}:
            return 2.0 * min(reads, writes)
        return reads + writes

    def visit_flops_only(comp_name: str, mult: float, depth: int):
        nonlocal flops
        comp = comps.get(comp_name)
        if comp is None or depth > 16:
            return
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                flops += _dot_flops(comp, ins) * mult
            elif ins.op == "fusion":
                for cn in _called_comps(ins.args):
                    visit_flops_only(cn, mult, depth + 1)

    visit(entry, 1.0, 0)
    return {
        "flops": flops,
        "bytes": bytes_accessed,
        "collectives": {"bytes_by_kind": dict(coll_bytes),
                        "count_by_kind": dict(coll_count),
                        "total_bytes": float(sum(coll_bytes.values()))},
        "unknown_loops": unknown_loops,
    }


def collective_bytes(text: str) -> dict:
    return expanded_analysis(text)["collectives"]
