"""Fig. 21 (repo extension) — fast-path before/after microbenchmarks.

Isolates each layer of the vectorized batch-preprocessing + fused-execution
pipeline against its seed implementation on the same store:

  * ``neighbors``  — per-vid ``get_neighbors`` loop vs ``get_neighbors_batch``
  * ``embeds``     — row-wise ``get_embed`` loop vs coalesced ``get_embeds``
  * ``sampler``    — ``sample_batch_ref`` vs the vectorized ``sample_batch``
  * ``engine``     — eager per-node dispatch vs the whole-DFG jit with the
                     fused aggregate-combine kernel (steady state, hetero)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import common as C
from repro.core import gnn
from repro.kernels.ops import program_config
from repro.store.sampler import sample_batch, sample_batch_ref


def run(workload="youtube", smoke=False):
    if smoke:
        workload = "chmleon"
    edges, emb, _ = C.make_workload(workload)
    svc, _ = C.hgnn_service(edges, emb)
    store = svc.store
    rng = np.random.default_rng(0)
    lines = []

    # ---- neighbors: one batched request vs a per-vid page walk
    b = sample_batch(store, rng.integers(0, emb.shape[0], 8), [10, 10],
                     rng=np.random.default_rng(0))
    vids = b.node_vids
    t_loop, _ = C.timeit(
        lambda: [store.get_neighbors(int(v)) for v in vids], repeat=5)
    t_batch, _ = C.timeit(lambda: store.get_neighbors_batch(vids), repeat=5)
    lines.append(C.csv_line(f"fig21.{workload}.neighbors_loop", t_loop, ""))
    lines.append(C.csv_line(f"fig21.{workload}.neighbors_batch", t_batch,
                            f"speedup={t_loop/t_batch:.1f}x"))

    # ---- embeddings: row-wise page reads vs coalesced span reads
    t_rows, _ = C.timeit(
        lambda: np.stack([store.get_embed(int(v)) for v in vids]), repeat=5)
    t_coal, _ = C.timeit(lambda: store.get_embeds(vids), repeat=5)
    lines.append(C.csv_line(f"fig21.{workload}.embeds_rowwise", t_rows, ""))
    lines.append(C.csv_line(f"fig21.{workload}.embeds_coalesced", t_coal,
                            f"speedup={t_rows/t_coal:.1f}x"))

    # ---- full sampler
    targets = rng.integers(0, emb.shape[0], 8)
    t_ref, _ = C.timeit(
        lambda: sample_batch_ref(store, targets, [10, 10],
                                 rng=np.random.default_rng(0), pad_to=32),
        repeat=5)
    t_vec, _ = C.timeit(
        lambda: sample_batch(store, targets, [10, 10],
                             rng=np.random.default_rng(0), pad_to=32),
        repeat=5)
    lines.append(C.csv_line(f"fig21.{workload}.sampler_ref", t_ref, ""))
    lines.append(C.csv_line(f"fig21.{workload}.sampler_vec", t_vec,
                            f"speedup={t_ref/t_vec:.1f}x"))

    # ---- engine: eager per-node dispatch vs cached whole-DFG jit (+fusion)
    program_config(svc.xbuilder, "hetero")
    params = gnn.init_params("gcn", [emb.shape[1], 128, 64], seed=0)
    dfg = gnn.BUILD_DFG["gcn"](2)
    bb = sample_batch(store, targets, [10, 10],
                      rng=np.random.default_rng(0), pad_to=64)
    feeds = gnn.dfg_feeds(
        "gcn", params, jnp.asarray(bb.embeddings),
        [(jnp.asarray(x.nbr), jnp.asarray(x.mask)) for x in bb.layers])
    svc.engine.run(dfg, feeds, jit=False, fuse=False)          # warm
    t_eager, _ = C.timeit(
        lambda: svc.engine.run(dfg, feeds, jit=False, fuse=False), repeat=5)
    svc.engine.run(dfg, feeds, jit=True)                       # warm + trace
    t_jit, _ = C.timeit(
        lambda: svc.engine.run(dfg, feeds, jit=True), repeat=5)
    lines.append(C.csv_line(f"fig21.{workload}.engine_eager", t_eager, ""))
    lines.append(C.csv_line(f"fig21.{workload}.engine_jit_fused", t_jit,
                            f"speedup={t_eager/t_jit:.1f}x"))
    return lines
