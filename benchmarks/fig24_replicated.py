"""Fig. 24 (repo extension) — replicated CSSD array under skewed reads.

PR 3's array leaves two holes the ROADMAP calls out: a lost device loses
its partition, and hash placement concentrates hot data (fig23's balance
0.8-0.95).  This sweep drives a ``ReplicatedGraphStore`` over 4 simulated
devices with R ∈ {1, 2, 3} on a *skewed* read mix: a hot co-engagement
community whose vertex ids cluster in two residue classes (the
clustered-id cohort a partition-unaware ingest produces — the
adversarial-but-realistic case hash placement cannot fix), over a uniform
cold background.  Reported:

  * **batched-read latency** (``sample_batch``: per-hop adjacency
    scatter-reads + striped embedding gather).  The array's deferred
    latency is max over shards, so replica-spreading the per-page load is
    a direct wall-clock win — acceptance: R=2 cuts skewed-mix latency
    >= 1.3x vs R=1;
  * **per-shard read balance** min/max over the measured window —
    acceptance: R=2 >= 0.97 (vs hash placement's ~0.5 on this mix);
  * **degraded mode**: the hottest shard is failed mid-sweep; the same
    seeded batches must come back **bit-identical** from the survivors
    (asserted), at the reported degraded latency;
  * **rebuild**: ``rebuild_shard`` re-materialises the lost partition
    from the survivors; redundancy is verified through the per-shard page
    counters (fresh device's written pages + restored mapping tables).

  PYTHONPATH=src:. python -m benchmarks.fig24_replicated [--smoke]
"""
from __future__ import annotations

import time

import numpy as np

from . import common as C
from repro.store import ReplicatedGraphStore, sample_batch
from repro.store.blockdev import BlockDevice

# Same array-scale QLC-class profile as fig23: per-page flash time
# dominant — the regime where spreading pages across devices buys latency.
PAGE_READ_US = 200.0
PAGE_WRITE_US = 250.0
CMD_LATENCY_US = 20.0

N_SHARDS = 4
HUB_CLASSES = (1, 2)   # the residue classes the hot hub ids cluster into


def shard_devices(n: int) -> list[BlockDevice]:
    return [BlockDevice(1 << 15, simulate_latency=True,
                        page_read_us=PAGE_READ_US,
                        page_write_us=PAGE_WRITE_US,
                        command_latency_us=CMD_LATENCY_US)
            for _ in range(n)]


def _balance(reads: list[int]) -> float:
    lo, hi = min(reads), max(reads)
    return lo / hi if hi else 1.0


def skewed_workload(n: int, e: int, feat: int, n_warm: int, seed: int = 0):
    """Power-law serving graph with a HOT COMMUNITY whose vertex ids sit
    in two adjacent residue classes.

    The warm set (think: this week's trending items) has ids of the form
    ``N_SHARDS * k + c`` for c in ``HUB_CLASSES`` — the clustered-id
    layout a partition-unaware ingest assigns a new cohort — scattered
    across the id range, so its adjacency pages and embedding rows are
    many distinct pages that ``vid % N`` placement pins onto two of the
    four shards.  Warm vertices link mostly to each other (co-engagement
    community), so a batch seeded in the warm set STAYS hot through every
    sampling hop; a uniform cold background over the full vertex space
    supplies the scattered traffic the spread can balance against.
    """
    rng = np.random.default_rng(seed)
    per = -(-n_warm // len(HUB_CLASSES))
    ks = rng.choice(n // N_SHARDS, size=per, replace=False)
    warm = np.sort(np.concatenate(
        [N_SHARDS * ks + c for c in HUB_CLASSES])[:n_warm])
    cold_pool = np.setdiff1d(np.arange(n), warm)
    e_w = e // 2
    ww = warm[rng.integers(0, len(warm), (e_w, 2))]
    cc = cold_pool[rng.integers(0, len(cold_pool), (e - e_w, 2))]
    edges = np.concatenate([ww, cc]).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb, warm, cold_pool


def target_stream(warm, cold_pool, batch, n_batches, seed=100):
    """Skewed read mix: half of every batch targets the warm community
    (whose sampling hops then stay inside it), the rest is uniform cold
    traffic."""
    rng = np.random.default_rng(seed)
    n_hot = batch // 2
    out = []
    for _ in range(n_batches):
        hot = warm[rng.integers(0, len(warm), n_hot)]
        cold = cold_pool[rng.integers(0, len(cold_pool), batch - n_hot)]
        out.append(np.concatenate([hot, cold]))
    return out


def _measure(store, batches, fanouts):
    """Seeded sweep -> (mean array-IO seconds, mean wall seconds,
    per-shard read deltas, results).

    The headline latency is the store's deferred array wait (max over
    shards per fetch — the device model's own output); wall-clock is
    reported alongside but includes host scheduler oversleep noise the
    simulated array would not have.
    """
    reads0 = [d.stats.read_pages for d in store.devs]
    io0 = store.io_wait_us
    results = []
    t0 = time.perf_counter()
    for b, targets in enumerate(batches):
        results.append(sample_batch(store, targets, list(fanouts),
                                    rng=np.random.default_rng(1000 + b),
                                    pad_to=64))
    wall = (time.perf_counter() - t0) / len(batches)
    io_s = (store.io_wait_us - io0) * 1e-6 / len(batches)
    reads = [d.stats.read_pages - r0 for d, r0 in zip(store.devs, reads0)]
    return io_s, wall, reads, results


def _assert_identical(want, got, ctx):
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a.node_vids, b.node_vids, err_msg=ctx)
        np.testing.assert_array_equal(a.embeddings, b.embeddings,
                                      err_msg=ctx)
        for la, lb in zip(a.layers, b.layers):
            np.testing.assert_array_equal(la.nbr, lb.nbr, err_msg=ctx)


def run(smoke: bool = False, reps=(1, 2, 3)):
    lines: list[str] = []
    if smoke:
        reps = (1, 2)
        n, e, feat, n_warm = 80000, 720000, 256, 8000
        batch, n_batches, fanouts = 96, 4, [12, 12]
    else:
        n, e, feat, n_warm = 160000, 1440000, 256, 16000
        batch, n_batches, fanouts = 128, 10, [12, 12]
    edges, emb, warm, cold_pool = skewed_workload(n, e, feat, n_warm)
    batches = target_stream(warm, cold_pool, batch, n_batches)

    base_io = None
    healthy_ref = None
    for rep in reps:
        store = ReplicatedGraphStore(devs=shard_devices(N_SHARDS),
                                     replication=rep, h_threshold=32)
        store.update_graph(edges, emb)
        _measure(store, batches[:1], fanouts)            # warm
        io_s, wall, reads, results = _measure(store, batches, fanouts)
        if base_io is None:
            base_io = io_s
        if healthy_ref is None:
            healthy_ref = results
        else:
            _assert_identical(healthy_ref, results, f"healthy R={rep}")
        bal = _balance(reads)
        lines.append(C.csv_line(
            f"fig24.read.r{rep}.{N_SHARDS}shard", io_s,
            f"io_speedup={base_io / io_s:.2f}x;balance={bal:.3f};"
            f"wall_ms={wall * 1e3:.1f};"
            f"shard_reads={'/'.join(str(r) for r in reads)}"))
        if not smoke and rep == 2:
            assert bal >= 0.97, f"R=2 balance {bal:.3f} < 0.97"
            assert base_io / io_s >= 1.3, \
                f"R=2 array-IO speedup {base_io / io_s:.2f}x < 1.3x"

        if rep != 2:
            continue
        # ---- degraded mode: fail the hottest shard, results must not move
        victim = int(np.argmax(reads))
        store.fail_shard(victim)
        dio, dwall, dreads, dresults = _measure(store, batches, fanouts)
        _assert_identical(healthy_ref, dresults, "degraded R=2")
        assert dreads[victim] == 0
        live = [r for i, r in enumerate(dreads) if i != victim]
        lines.append(C.csv_line(
            f"fig24.degraded.r2.kill{victim}", dio,
            f"io_vs_healthy={dio / io_s:.2f}x;"
            f"balance_live={_balance(live):.3f}"))
        # ---- rebuild: fresh device re-materialised from survivors
        info = store.rebuild_shard(victim)
        sh = store.shards[victim]
        assert sh.dev.stats.written_pages == info["pages_written"] > 0
        assert sh.stats.pages_l + sh.stats.pages_h > 0
        assert not any(store.failed_shards)
        rio, rwall, rreads, rresults = _measure(store, batches, fanouts)
        _assert_identical(healthy_ref, rresults, "rebuilt R=2")
        assert rreads[victim] > 0                  # back in rotation
        lines.append(C.csv_line(
            f"fig24.rebuild.r2.shard{victim}", info["seconds"],
            f"vertices={info['vertices']};"
            f"pages_written={info['pages_written']};"
            f"post_rebuild_io_vs_healthy={rio / io_s:.2f}x"))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for ln in run(smoke=args.smoke):
        print(ln)
