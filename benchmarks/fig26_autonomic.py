"""Fig. 26 (repo extension) — autonomic array runtime under chaos.

PRs 4-5 gave the replicated CSSD array a fault PATH (drain + streaming
rebuild) driven by operator RPCs; this PR closes the LOOP.  Three phases
drive the ``ShardSupervisor`` + end-to-end flow control:

  * **chaos** — the hottest shard's DEVICE is killed mid-sweep with NO
    operator call; the supervisor must detect (probe + error mapping),
    auto-drain, and auto-rebuild while every completed batch stays
    **bit-identical** to the healthy reference (asserted).  Reported:
    wall detection latency, restore time, degraded/healed latency;
  * **paced rebuild** — serving p99 is measured degraded-without-rebuild
    (rebuild off) and again WHILE a chunk-paced rebuild streams from the
    survivors (rebuild on); pacing is asserted from the rebuild info
    (``chunks * pace_s`` is a floor on the stream time) and the
    during-rebuild p99 must stay within a bounded factor of rebuild-off;
    the unpaced stream is reported for contrast;
  * **overload** — reader threads hammer a multi-host (RoP) array sized
    to saturate (1-deep in-flight windows, shallow SQs): sustained
    overload must shed as typed ``BackpressureError`` with a reason —
    never a raw ``QueueFullError`` escape, a wedged SQ, or a wrong
    answer — and the array must serve bit-identically after the storm.

  PYTHONPATH=src:. python -m benchmarks.fig26_autonomic [--smoke]
"""
from __future__ import annotations

import threading
import time

import numpy as np

from . import common as C
from repro.rpc.queues import BackpressureError
from repro.serve import HealthPolicy, ShardSupervisor
from repro.store import (ReplicatedGraphStore, ShardedGraphStore,
                         make_rop_endpoints, sample_batch)
from repro.store.blockdev import BlockDevice
from repro.store.sharded import FlowControl

# fig23/fig24's array-scale QLC-class profile: per-page flash time
# dominant — the regime where a rebuild stream visibly contends with
# serving reads and pacing visibly helps.
PAGE_READ_US = 200.0
PAGE_WRITE_US = 250.0
CMD_LATENCY_US = 20.0

N_SHARDS = 4


def shard_devices(n: int) -> list[BlockDevice]:
    return [BlockDevice(1 << 15, simulate_latency=True,
                        page_read_us=PAGE_READ_US,
                        page_write_us=PAGE_WRITE_US,
                        command_latency_us=CMD_LATENCY_US)
            for _ in range(n)]


def _workload(n, e, feat, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _batches(n, batch, n_batches, seed=100):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, n, batch) for _ in range(n_batches)]


def _serve(store, targets, b, fanouts):
    return sample_batch(store, targets, list(fanouts),
                        rng=np.random.default_rng(1000 + b), pad_to=64)


def _assert_identical(want, got, ctx):
    np.testing.assert_array_equal(want.node_vids, got.node_vids, err_msg=ctx)
    np.testing.assert_array_equal(want.embeddings, got.embeddings,
                                  err_msg=ctx)
    for la, lb in zip(want.layers, got.layers):
        np.testing.assert_array_equal(la.nbr, lb.nbr, err_msg=ctx)


def _p99(lat_s: list) -> float:
    return float(np.percentile(np.array(lat_s), 99)) if lat_s else 0.0


# ------------------------------------------------------------------ phase A
def phase_chaos(smoke: bool) -> list[str]:
    n, e, feat = (8000, 60000, 32) if smoke else (40000, 300000, 64)
    batch, n_batches, fanouts = (48, 6, [8, 8]) if smoke \
        else (96, 12, [10, 10])
    edges, emb = _workload(n, e, feat)
    batches = _batches(n, batch, n_batches)
    store = ReplicatedGraphStore(devs=shard_devices(N_SHARDS),
                                 replication=2, h_threshold=32)
    store.update_graph(edges, emb)
    ref = [_serve(store, t, b, fanouts) for b, t in enumerate(batches)]
    reads = [d.stats.read_pages for d in store.devs]
    victim = int(np.argmax(reads))

    sup = ShardSupervisor(store, HealthPolicy(
        probe_interval_s=0.01, rebuild_retry_s=0.1)).start()
    try:
        # ---- kill the device directly: no fail_shard, no operator
        t_kill = time.perf_counter()
        store.devs[victim].fail()
        t_detect = None
        lat_degraded: list[float] = []
        for b, t in enumerate(batches):
            t0 = time.perf_counter()
            out = _serve(store, t, b, fanouts)
            lat_degraded.append(time.perf_counter() - t0)
            _assert_identical(ref[b], out, f"chaos batch {b}")
            if t_detect is None and store.failed_shards[victim]:
                t_detect = time.perf_counter()
        # ---- the array must return to full redundancy on its own
        t_end = time.monotonic() + 60.0
        while time.monotonic() < t_end:
            if t_detect is None and store.failed_shards[victim]:
                t_detect = time.perf_counter()
            snap = sup.snapshot()
            if (snap["incidents"] and not any(store.failed_shards)
                    and all(s == "healthy" for s in snap["states"])):
                break
            time.sleep(0.01)
        else:
            raise AssertionError(f"array did not heal: {sup.snapshot()}")
        assert t_detect is not None and t_detect - t_kill <= 10.0
        inc = snap["last_incident"]
        assert inc["shard"] == victim and inc["drained"] is True
        assert inc["cause"] in ("probe", "error_burst", "observed_drained")
        reads1 = store.devs[victim].stats.read_pages
        lat_healed: list[float] = []
        for b, t in enumerate(batches):
            t0 = time.perf_counter()
            out = _serve(store, t, b, fanouts)
            lat_healed.append(time.perf_counter() - t0)
            _assert_identical(ref[b], out, f"healed batch {b}")
        assert store.devs[victim].stats.read_pages > reads1   # back in rotation
        return [C.csv_line(
            f"fig26.chaos.kill{victim}", t_detect - t_kill,
            f"cause={inc['cause']};restore_s={inc.get('restore_s', 0):.3f};"
            f"degraded_p99_ms={_p99(lat_degraded) * 1e3:.1f};"
            f"healed_p99_ms={_p99(lat_healed) * 1e3:.1f};"
            f"batches_identical={len(batches) * 2};operator_calls=0")]
    finally:
        sup.stop()
        store.close()


# ------------------------------------------------------------------ phase B
def phase_paced_rebuild(smoke: bool) -> list[str]:
    n, e, feat = (12000, 80000, 128) if smoke else (48000, 320000, 192)
    batch, fanouts = (48, [8, 8]) if smoke else (96, [10, 10])
    pace_s = 0.02 if smoke else 0.04
    min_off, min_on = (6, 3) if smoke else (12, 5)
    edges, emb = _workload(n, e, feat)
    store = ReplicatedGraphStore(devs=shard_devices(N_SHARDS),
                                 replication=2, h_threshold=32)
    store.update_graph(edges, emb)
    batches = _batches(n, batch, 64)
    _serve(store, batches[0], 0, fanouts)                      # warm
    store.fail_shard(0)

    def measure(n_min, alive=None):
        lat = []
        for b, t in enumerate(batches):
            t0 = time.perf_counter()
            _serve(store, t, b, fanouts)
            lat.append(time.perf_counter() - t0)
            if len(lat) >= n_min and (alive is None or not alive()):
                break
        return lat

    lat_off = measure(min_off)                 # degraded, rebuild off
    out = {}

    def run_rebuild(pacing):
        out["info"] = store.rebuild_shard(0, pacing_s=pacing)

    th = threading.Thread(target=run_rebuild, args=(pace_s,))
    th.start()
    lat_on = measure(min_on, alive=th.is_alive)  # during the paced stream
    th.join(timeout=600.0)
    info = out["info"]
    assert info["chunks"] > 0 and info["pace_s"] == pace_s
    assert info["seconds"] >= info["chunks"] * pace_s          # pacing real
    p_off, p_on = _p99(lat_off), _p99(lat_on)
    factor = p_on / p_off if p_off else 1.0
    if not smoke:
        assert factor <= 4.0, \
            f"paced-rebuild p99 {p_on * 1e3:.1f}ms is {factor:.2f}x " \
            f"rebuild-off {p_off * 1e3:.1f}ms (> 4.0x)"
    lines = [C.csv_line(
        "fig26.rebuild.paced", p_on,
        f"rebuild_off_p99_ms={p_off * 1e3:.1f};factor={factor:.2f};"
        f"chunks={info['chunks']};pace_ms={pace_s * 1e3:.0f};"
        f"stream_s={info['seconds']:.2f};overlap_batches={len(lat_on)}")]
    # ---- unpaced contrast: same fault, pace 0
    store.fail_shard(0)
    th = threading.Thread(target=run_rebuild, args=(0.0,))
    th.start()
    lat_raw = measure(1, alive=th.is_alive)
    th.join(timeout=600.0)
    lines.append(C.csv_line(
        "fig26.rebuild.unpaced", _p99(lat_raw),
        f"stream_s={out['info']['seconds']:.2f};"
        f"overlap_batches={len(lat_raw)}"))
    store.close()
    return lines


# ------------------------------------------------------------------ phase C
def phase_overload(smoke: bool) -> list[str]:
    n, e, feat = (6000, 40000, 64) if smoke else (20000, 140000, 64)
    n_threads, per_thread = (8, 6) if smoke else (16, 10)
    edges, emb = _workload(n, e, feat)
    flow = FlowControl(max_inflight_per_shard=1, window_timeout_s=0.001,
                       submit_retries=1, backoff_base_s=1e-4,
                       backoff_max_s=5e-4)
    store = ShardedGraphStore(
        endpoints=make_rop_endpoints(3, h_threshold=32, n_queues=1,
                                     queue_depth=2),
        h_threshold=32, flow=flow)
    store.update_graph(edges, emb)
    probe = np.arange(200)
    ref = store.get_embeds(probe)

    rng = np.random.default_rng(7)
    work = [rng.integers(0, n, 4096) for _ in range(n_threads)]
    counts = {"ok": 0, "shed": 0}
    foreign: list[str] = []
    lock = threading.Lock()

    def hammer(tid):
        for _ in range(per_thread):
            try:
                store.get_embeds(work[tid])
                with lock:
                    counts["ok"] += 1
            except BackpressureError as bp:
                src = bp.reason.get("source")
                with lock:
                    counts["shed"] += 1
                if src not in ("inflight_window", "queue_full"):
                    foreign.append(f"unreasoned shed: {bp.reason}")
            except Exception as exc:  # noqa: BLE001 — must never happen
                foreign.append(f"{type(exc).__name__}: {exc}")

    t0 = time.perf_counter()
    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=600.0)
    wall = time.perf_counter() - t0
    assert not foreign, foreign[:5]
    assert counts["ok"] + counts["shed"] == n_threads * per_thread
    assert counts["ok"] > 0
    assert counts["shed"] > 0, "overload storm never shed — not saturated"
    assert store.backpressure_events == counts["shed"]
    # no wedge: the array serves bit-identically after the storm
    np.testing.assert_array_equal(ref, store.get_embeds(probe),
                                  err_msg="post-storm")
    lines = [C.csv_line(
        "fig26.overload.shed", wall / (n_threads * per_thread),
        f"ok={counts['ok']};shed={counts['shed']};"
        f"retries={store.backpressure_retries};"
        f"threads={n_threads};sq_depth=2;window=1")]
    store.close()
    return lines


def run(smoke: bool = False):
    lines = []
    lines += phase_chaos(smoke)
    lines += phase_paced_rebuild(smoke)
    lines += phase_overload(smoke)
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for ln in run(smoke=args.smoke):
        print(ln)
