"""Fig. 15 — modeled energy: host-system W x host time vs CSSD-system W x
HGNN time (the paper's own W-times-seconds method; clearly modeled, not
measured)."""
from __future__ import annotations

import numpy as np

from . import common as C
from . import fig14_end2end as F14
from repro.core import gnn


def run(workloads=("citeseer", "cs", "physics", "road-tx")):
    lines = []
    ratios = []
    for w in workloads:
        edges, emb, _ = C.make_workload(w)
        params = gnn.init_params("gcn", [emb.shape[1], 128, 64], seed=0)
        targets = np.random.default_rng(0).integers(0, emb.shape[0], 8)
        th = F14._host_end2end(edges, emb, params, targets)
        tg = F14._hgnn_end2end(edges, emb, params, targets)
        e_host = th * C.POWER["gtx1060_system"]
        e_hgnn = tg * C.POWER["cssd_system"]
        ratios.append(e_host / e_hgnn)
        lines.append(C.csv_line(f"fig15.{w}.host_J", e_host / 1e6, "modeled"))
        lines.append(C.csv_line(f"fig15.{w}.hgnn_J", e_hgnn / 1e6,
                                f"ratio={e_host/e_hgnn:.1f}x"))
    lines.append(C.csv_line("fig15.geomean_energy_ratio",
                            float(np.exp(np.mean(np.log(ratios)))),
                            "paper_claims=33.2x_vs_rtx3090"))
    return lines
