"""Fig. 20 — mutable graph support: a DBLP-like historical stream (daily
vertex/edge adds + deletes) against GraphStore unit operations; per-day
accumulated latency."""
from __future__ import annotations

import time

import numpy as np

from . import common as C
from repro.store.graphstore import GraphStore


def run(days=23, seed=0):
    rng = np.random.default_rng(seed)
    gs = GraphStore(C.storage_device(), h_threshold=64)
    gs.update_graph(np.array([[0, 1], [1, 2]], np.int64))
    next_vid = 3
    per_day = []
    total_adds = total_dels = 0
    for day in range(days):
        # paper's averages: 365 new nodes, 8.8K new edges, 16 del nodes,
        # 713 del edges per day — scaled /10 for this container
        n_v, n_e = 36, 880
        n_dv, n_de = 2, 71
        t0 = time.perf_counter()
        new_vids = list(range(next_vid, next_vid + n_v))
        for v in new_vids:
            gs.add_vertex(v)
        next_vid += n_v
        hi = next_vid
        for _ in range(n_e):
            gs.add_edge(int(rng.integers(0, hi)), int(rng.integers(0, hi)))
        for _ in range(n_de):
            v = int(rng.integers(0, hi))
            nb = gs.get_neighbors(v)
            nb = nb[nb != v]
            if len(nb):
                gs.delete_edge(v, int(nb[0]))
        for _ in range(n_dv):
            gs.delete_vertex(int(rng.integers(0, hi)))
        per_day.append(time.perf_counter() - t0)
        total_adds += n_v + n_e
        total_dels += n_dv + n_de
    worst = max(per_day)
    mean = float(np.mean(per_day))
    return [
        C.csv_line("fig20.per_day_mean", mean,
                   f"paper=970ms_per_day_unscaled;ops_per_day={36+880+2+71}"),
        C.csv_line("fig20.per_day_worst", worst,
                   f"paper_worst=8.4s;l_splits={gs.stats.l_evictions}"),
        C.csv_line("fig20.total_ops", (total_adds + total_dels) / 1e6,
                   "unit=Mops"),
    ]
