"""Re-derive flops/bytes/collectives for recorded dry-run cells from their
saved HLO (results/dryrun/*.hlo.gz) without recompiling.

  PYTHONPATH=src:. python -m benchmarks.reanalyze [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from .hlo_analysis import expanded_analysis


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args(argv)
    n = 0
    for jf in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        hf = jf[:-5] + ".hlo.gz"
        if not os.path.exists(hf):
            continue
        with gzip.open(hf, "rt") as f:
            txt = f.read()
        ea = expanded_analysis(txt)
        with open(jf) as f:
            rec = json.load(f)
        rec["hlo_flops"] = ea["flops"]
        rec["hlo_bytes"] = ea["bytes"]
        rec["collectives"] = ea["collectives"]
        rec["unknown_loops"] = ea["unknown_loops"]
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
