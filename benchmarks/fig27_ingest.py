"""Fig. 27 (repo extension) — distributed device-side ingest.

The paper's G-1..G-4 UpdateGraph pipeline lives on the device because
host-side graph preprocessing is the bottleneck at scale.  Through PR 6
the array coordinator still ran that pipeline globally and shipped every
shard a monolithic preprocessed CSR; this figure measures the PR 7
distributed path (``update_graph_chunked`` + ``MutationFirehose``):

  * **A: bulk-load scale-out** — chunked ingest wall time at QLC-class
    flash latencies over 1/2/4 shards: every shard sorts and packs its
    partition locally and in parallel, so the load accelerates with the
    array (acceptance: >= 1.5x from 1 -> 4 shards, asserted in full
    mode);
  * **B: coordinator raw chunks only** — over REAL RoP links the chunked
    coordinator ships raw edge chunks + embedding stripes and issues ZERO
    preprocessed ``write_adjacency``/``write_embedding_table`` commands
    (asserted), moving fewer bytes than the monolithic load on an
    indptr-heavy graph (each monolithic shard write carries the full
    global indptr);
  * **C: mutation firehose under mixed read/write** — windowed
    device-side mutation batches between closed-loop batched reads: reads
    keep flowing (bounded p99 inflation vs an idle-array baseline,
    asserted in full mode) and the final graph is bit-identical to serial
    unit-mutation replay (always asserted).

  PYTHONPATH=src:. python -m benchmarks.fig27_ingest [--smoke]
"""
from __future__ import annotations

import threading
import time

import numpy as np

from . import common as C
from repro.store import ShardedGraphStore, make_local_endpoints, \
    make_rop_endpoints
from repro.store.blockdev import BlockDevice

# fig25's array-scale device profile: archival/dense-QLC page latency on a
# cost-optimized 4-channel device — the per-device-bandwidth-starved
# regime where an array of MORE devices is the answer, i.e. exactly the
# regime distributed ingest targets (bulk loads are page-write bound).
PAGE_READ_US = 500.0
PAGE_WRITE_US = 600.0
CMD_LATENCY_US = 20.0
DEV_CHANNELS = 4


def _flash_devs(n: int) -> list[BlockDevice]:
    devs = [BlockDevice(1 << 15, simulate_latency=True,
                        page_read_us=PAGE_READ_US,
                        page_write_us=PAGE_WRITE_US,
                        command_latency_us=CMD_LATENCY_US)
            for _ in range(n)]
    for d in devs:
        d.channels = DEV_CHANNELS
    return devs


def _workload(n, e, feat, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.35, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


# ------------------------------------------------------ A: bulk scale-out
def _bulk_scaleout(lines, shard_counts, *, n, e, feat, assert_speedup):
    """Chunked bulk-load wall time vs shard count at flash latencies.

    Each shard's commit (device-side sort + L/H pack + embedding stripe
    burst) runs on its own device concurrently; the coordinator only
    streams raw chunks.  1 shard pays every page write serially — the
    array splits them.
    """
    edges, emb = _workload(n, e, feat)
    base = None
    speedups = {}
    for ns in shard_counts:
        store = ShardedGraphStore(
            endpoints=make_local_endpoints(ns, devs=_flash_devs(ns),
                                           h_threshold=64),
            h_threshold=64)
        t0 = time.perf_counter()
        tl = store.update_graph_chunked(edges, emb)
        t = time.perf_counter() - t0
        if base is None:
            base = t
        speedups[ns] = base / t
        lines.append(C.csv_line(
            f"fig27.bulk.{ns}shard", t,
            f"speedup={base / t:.2f}x;transfer_s={tl.transfer[1]:.3f};"
            f"graph_pre_s={tl.graph_pre[1] - tl.graph_pre[0]:.3f};"
            f"user_visible_s={tl.user_visible:.3f}"))
        store.close()
    if assert_speedup and 4 in speedups:
        assert speedups[4] >= 1.5, \
            f"4-shard chunked bulk load speedup {speedups[4]:.2f}x < 1.5x"
    return lines


# ------------------------------------------- B: coordinator raw-chunks-only
def _coordinator_bytes(lines, *, n, e, feat):
    """Monolithic vs chunked coordinator link bytes over real RoP
    endpoints, same graph, 2 shards.  The chunked coordinator must issue
    zero preprocessed page-image commands — its whole contribution is raw
    edge chunks and embedding stripes."""
    edges, emb = _workload(n, e, feat)
    totals = {}
    for mode in ("monolithic", "chunked"):
        eps = make_rop_endpoints(2, h_threshold=64)
        try:
            store = ShardedGraphStore(endpoints=eps, h_threshold=64)
            if mode == "chunked":
                store.update_graph_chunked(edges, emb)
            else:
                store.update_graph(edges, emb)
            totals[mode] = sum(ep.channel_bytes() for ep in eps)
            if mode == "chunked":
                for ep in eps:
                    sent = ep.method_stats
                    assert "write_adjacency" not in sent, sorted(sent)
                    assert "write_embedding_table" not in sent, sorted(sent)
        finally:
            for ep in eps:
                ep.close()
    ratio = totals["chunked"] / totals["monolithic"]
    lines.append(C.csv_line(
        "fig27.coord_bytes", 0.0,
        f"monolithic_bytes={totals['monolithic']};"
        f"chunked_bytes={totals['chunked']};ratio={ratio:.3f};"
        f"preprocessed_cmds=0"))
    assert totals["chunked"] < totals["monolithic"], totals
    return lines


# --------------------------------------------- C: firehose mixed read/write
def _firehose_mixed(lines, *, n, e, feat, n_ops, assert_p99):
    """Closed-loop batched reads against an array absorbing a mutation
    firehose; read p99 vs the idle baseline, plus final bit-identity with
    serial unit-mutation replay."""
    edges, emb = _workload(n, e, feat)
    rng = np.random.default_rng(1)

    def read_loop(store, count=200, batch=64):
        lat = []
        for _ in range(count):
            vids = rng.integers(0, n, batch)
            t0 = time.perf_counter()
            store.get_neighbors_batch(vids)
            store.get_embeds(vids)
            lat.append(time.perf_counter() - t0)
        return np.percentile(np.asarray(lat), [50, 99])

    store = ShardedGraphStore(n_shards=2, h_threshold=64)
    store.update_graph(edges, emb)
    p50_idle, p99_idle = read_loop(store)

    twin = ShardedGraphStore(n_shards=2, h_threshold=64)
    twin.update_graph(edges, emb)

    ops = []
    opr = np.random.default_rng(2)
    for _ in range(n_ops):
        k = int(opr.integers(0, 3))
        if k == 0:
            ops.append(("add_edge", int(opr.integers(0, n)),
                        int(opr.integers(0, n))))
        elif k == 1:
            ops.append(("delete_edge", int(opr.integers(0, n)),
                        int(opr.integers(0, n))))
        else:
            ops.append(("update_embed", int(opr.integers(0, n)),
                        opr.standard_normal(feat).astype(np.float32)))

    fh = store.firehose(window_s=0.002, max_window_ops=256).start()
    done = threading.Event()

    def writer():
        for op in ops:
            getattr(fh, op[0])(*op[1:])
        done.set()

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    p50_mixed, p99_mixed = read_loop(store)
    th.join(timeout=30.0)
    snap = fh.close()
    assert done.is_set() and snap["applied"] == n_ops, snap

    for op in ops:                      # serial unit-mutation replay
        getattr(twin, op[0])(*op[1:])
    assert twin.to_adjacency() == store.to_adjacency()
    vids = np.arange(0, n, max(1, n // 256))
    for va, vb in zip(twin.get_neighbors_batch(vids),
                      store.get_neighbors_batch(vids)):
        np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(twin.get_embeds(vids),
                                  store.get_embeds(vids))

    factor = p99_mixed / max(p99_idle, 1e-9)
    lines.append(C.csv_line(
        "fig27.firehose.mixed", p99_mixed,
        f"read_p50_idle_us={p50_idle * 1e6:.0f};"
        f"read_p99_idle_us={p99_idle * 1e6:.0f};"
        f"read_p50_mixed_us={p50_mixed * 1e6:.0f};"
        f"read_p99_mixed_us={p99_mixed * 1e6:.0f};"
        f"p99_factor={factor:.2f};windows={snap['windows']};"
        f"bit_identical=1"))
    if assert_p99:
        assert factor <= 25.0, \
            f"firehose inflated read p99 by {factor:.1f}x"
    store.close()
    twin.close()
    return lines


def run(smoke: bool = False):
    lines: list[str] = []
    if smoke:
        _bulk_scaleout(lines, (1, 2), n=4000, e=12000, feat=64,
                       assert_speedup=False)
        _coordinator_bytes(lines, n=6000, e=10000, feat=8)
        _firehose_mixed(lines, n=2000, e=8000, feat=16, n_ops=300,
                        assert_p99=False)
    else:
        _bulk_scaleout(lines, (1, 2, 4), n=20000, e=60000, feat=256,
                       assert_speedup=True)
        _coordinator_bytes(lines, n=20000, e=30000, feat=8)
        _firehose_mixed(lines, n=6000, e=30000, feat=32, n_ops=2000,
                        assert_p99=True)
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for ln in run(smoke=args.smoke):
        print(ln)
