"""Fig. 28 (repo extension) — SPMD model-parallel engine execution.

PR 8 shards the engine's fused compute plane across a (data, model)
device mesh (``core/spmd.py``): hidden/embedding dims striped over the
``model`` axis (Megatron-style row-parallel GEMM with a psum at the
combine boundary), super-batch rows over ``data``.  This figure checks
the two claims that matter:

  * **A: numerics** — the sharded program is allclose (fp32) to the
    single-device program for GCN/GIN/NGCF at mesh shapes 1x1 / 1x2 /
    2x2 / 1x4 over real forced-host devices, odd (padded) hidden dims
    included — always asserted, in smoke mode too;
  * **B: compute-phase scaling** — this container has ONE physical core,
    so forced-host "devices" time-slice it and a wall-clock mesh speedup
    is unmeasurable here.  Following the repo convention (the array pays
    max over shard costs; host compute priced apart), the compute phase
    is priced from *measured* per-slice kernel wall times plus an
    alpha-beta model of the psum at the combine boundary: a slice of the
    wide-hidden layer body is really executed at slice shapes and timed.
    Acceptance (full mode): >= 1.5x priced compute-phase speedup at
    4-way model parallelism in the wide-hidden regime;
  * **sampling unchanged** — BatchPre runs eagerly ahead of the sharded
    suffix, so near-storage sampling is bit-identical whatever the mesh
    (asserted on the composed super-batch).

  PYTHONPATH=src:. python -m benchmarks.fig28_spmd [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

# standalone runs: force the 8-device host pool before jax initializes
# (benchmarks.run does the same thing for harness runs)
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np
import jax
import jax.numpy as jnp

from . import common as C
from repro.core.dfg import Engine
from repro.core.registry import KernelRegistry
from repro.core.xbuilder import XBuilder, SHELL_DEVICE
from repro.core import gnn
from repro.launch.mesh import make_host_mesh

MESH_SHAPES = ((1, 1), (1, 2), (2, 2), (1, 4))

# alpha-beta interconnect model for the combine-boundary psum: a modest
# accelerator-interconnect ring (per-hop launch latency + link bandwidth).
ALPHA_US = 5.0
BETA_GBPS = 50.0


def _ring_allreduce_s(bytes_: int, m: int) -> float:
    """Ring all-reduce cost of a ``bytes_`` payload over ``m`` slices."""
    if m <= 1:
        return 0.0
    return 2.0 * (m - 1) / m * bytes_ / (BETA_GBPS * 1e9) \
        + 2.0 * (m - 1) * ALPHA_US * 1e-6


def _engine(mesh=None):
    reg = KernelRegistry()
    XBuilder(reg)
    for name, fn in gnn.extra_shell_kernels().items():
        reg.register_op(name, SHELL_DEVICE, fn)
    return Engine(reg, mesh=mesh)


# ------------------------------------------------------------- A: numerics
def _equivalence(lines, *, models, shapes, dims_odd):
    rng = np.random.default_rng(0)
    n, k, rows = 120, 5, [48, 24]

    def blocks():
        out, prev = [], n
        for d in rows:
            nbr = jnp.asarray(rng.integers(0, prev, (d, k)), jnp.int32)
            mask = jnp.asarray((rng.random((d, k)) < 0.8).astype(np.float32))
            out.append((nbr, mask))
            prev = d
        return out

    avail = len(jax.devices())
    for model, dims in models:
        params = gnn.init_params(model, dims, seed=1)
        emb = jnp.asarray(rng.standard_normal((n, dims[0])).astype(np.float32))
        dfg = gnn.BUILD_DFG[model](len(dims) - 1)
        feeds = gnn.dfg_feeds(model, params, emb, blocks())
        ref = _engine().run(dfg, dict(feeds), jit=True)
        for shape in shapes:
            need = shape[0] * shape[1]
            if need > avail:
                lines.append(C.csv_line(
                    f"fig28.equiv.{model}.{shape[0]}x{shape[1]}", 0.0,
                    f"SKIPPED=need_{need}_devices_have_{avail}"))
                continue
            mesh = make_host_mesh(need, shape=shape)
            t0 = time.perf_counter()
            out = _engine(mesh).run(dfg, dict(feeds), jit=True)
            t = time.perf_counter() - t0
            diffs = [float(np.abs(np.asarray(ref[key]) -
                                  np.asarray(out[key])).max())
                     for key in ref]
            for key in ref:
                np.testing.assert_allclose(ref[key], out[key],
                                           rtol=2e-5, atol=2e-5)
            lines.append(C.csv_line(
                f"fig28.equiv.{model}.{shape[0]}x{shape[1]}", t,
                f"allclose=true;maxdiff={max(diffs):.2e};"
                f"dims={'x'.join(map(str, dims))}"))
    # odd hidden dims: padding to mesh divisibility must be invisible
    if dims_odd and avail >= 8:
        params = gnn.init_params("gcn", dims_odd, seed=2)
        emb = jnp.asarray(rng.standard_normal(
            (n, dims_odd[0])).astype(np.float32))
        dfg = gnn.BUILD_DFG["gcn"](len(dims_odd) - 1)
        feeds = gnn.dfg_feeds("gcn", params, emb, blocks())
        ref = _engine().run(dfg, dict(feeds), jit=True)
        out = _engine(make_host_mesh(8, shape=(2, 4))).run(
            dfg, dict(feeds), jit=True)
        np.testing.assert_allclose(ref["Result"], out["Result"],
                                   rtol=2e-5, atol=2e-5)
        lines.append(C.csv_line(
            "fig28.equiv.gcn_odd_dims.2x4", 0.0,
            f"allclose=true;dims={'x'.join(map(str, dims_odd))};padded=true"))
    return lines


# ------------------------------------------------- sampling is mesh-blind
def _sampling_unchanged(lines):
    """The composed super-batch is bit-identical whatever the mesh: the
    sampler never sees the mesh (BatchPre runs in the eager prefix)."""
    from repro.core.service import HolisticGNNService
    from repro.serve.batcher import sample_group
    rng = np.random.default_rng(7)
    n, e = 2000, 12000
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, 32)).astype(np.float32)
    batches = []
    for mp in (None, 4):
        svc = HolisticGNNService(h_threshold=16, pad_to=32,
                                 model_parallel=mp)
        svc.store.update_graph(edges, emb)
        b, _ = sample_group(svc.store, [list(range(16)), [3, 5, 8]],
                            [11, 12], [5, 5])
        batches.append(b)
        svc.close()
    ref, meshed = batches
    np.testing.assert_array_equal(ref.node_vids, meshed.node_vids)
    np.testing.assert_array_equal(ref.embeddings, meshed.embeddings)
    for a, b in zip(ref.layers, meshed.layers):
        np.testing.assert_array_equal(a.nbr, b.nbr)
        np.testing.assert_array_equal(a.mask, b.mask)
    lines.append(C.csv_line("fig28.sampling", 0.0,
                            "bit_identical_across_meshes=true"))
    return lines


# ---------------------------------------- B: priced compute-phase scaling
def _layer_body(h, nbr, mask, w, b):
    """One wide GCN layer: mean-aggregate + combine + bias + relu."""
    g = jnp.take(h, nbr, axis=0) * mask[..., None]
    s = g.sum(axis=1) / jnp.maximum(mask.sum(axis=1), 1.0)[:, None]
    z = jnp.dot(s, w, preferred_element_type=jnp.float32) + b
    return jnp.maximum(z, 0.0)


def _slice_body(h_s, nbr, mask, w_s):
    """The same layer at model-slice shapes: feature-sliced aggregate +
    row-sharded GEMM partial product (the psum is priced, not run)."""
    g = jnp.take(h_s, nbr, axis=0) * mask[..., None]
    s = g.sum(axis=1) / jnp.maximum(mask.sum(axis=1), 1.0)[:, None]
    return jnp.dot(s, w_s, preferred_element_type=jnp.float32)


def _measure(fn, *args, repeat=5):
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))          # compile outside the clock
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _compute_scaling(lines, *, n, d, k, f, o, assert_speedup):
    """Priced compute-phase speedup of m-way model parallelism in the
    wide-hidden regime.  Per-slice work is MEASURED at slice shapes on
    the real kernel body; the combine-boundary psum is priced alpha-beta.
    The mesh pays max over slices == the (homogeneous) slice wall."""
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((n, f)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, n, (d, k)), jnp.int32)
    mask = jnp.asarray((rng.random((d, k)) < 0.9).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((f, o)).astype(np.float32) * 0.05)
    b = jnp.zeros((o,), jnp.float32)

    t_full = _measure(_layer_body, h, nbr, mask, w, b)
    lines.append(C.csv_line(
        "fig28.compute.m1", t_full,
        f"D={d};F={f};O={o};measured=single_device_layer"))
    speedups = {}
    for m in (2, 4):
        t_slice = _measure(_slice_body, h[:, : f // m], nbr, mask,
                           w[: f // m])
        t_psum = _ring_allreduce_s(d * o * 4, m)
        t_par = t_slice + t_psum
        speedups[m] = t_full / t_par
        lines.append(C.csv_line(
            f"fig28.compute.m{m}", t_par,
            f"slice_wall_s={t_slice:.5f};psum_s={t_psum:.6f};"
            f"speedup={speedups[m]:.2f}x;"
            f"alpha_us={ALPHA_US};beta_gbps={BETA_GBPS}"))
    if assert_speedup:
        assert speedups[4] >= 1.5, \
            (f"4-way model-parallel priced compute speedup "
             f"{speedups[4]:.2f}x < 1.5x in wide-hidden regime")
    return lines


def run(smoke: bool = False):
    lines: list[str] = []
    if smoke:
        _equivalence(lines,
                     models=[("gcn", [13, 17, 7])],
                     shapes=((1, 1), (1, 2), (2, 2), (1, 4)),
                     dims_odd=[5, 9, 3])
        _sampling_unchanged(lines)
        # scaling assertion is full-mode only (smoke-exempt): timing on a
        # shared CI core is too noisy to gate merges on
        _compute_scaling(lines, n=2048, d=512, k=8, f=512, o=512,
                         assert_speedup=False)
    else:
        _equivalence(lines,
                     models=[("gcn", [13, 17, 7]), ("gin", [13, 17, 7]),
                             ("ngcf", [13, 13, 13])],
                     shapes=MESH_SHAPES,
                     dims_odd=[5, 9, 3])
        _sampling_unchanged(lines)
        _compute_scaling(lines, n=8192, d=2048, k=10, f=2048, o=2048,
                         assert_speedup=True)
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for ln in run(smoke=args.smoke):
        print(ln)
