"""Roofline derivation from the dry-run artifacts (results/dryrun/*.json).

Three terms per (arch x shape x mesh), all PER-DEVICE (the dry-run records
post-SPMD per-device quantities, loop-expanded):

    compute    = flops_dev / PEAK_FLOPS
    memory     = bytes_dev / HBM_BW
    collective = coll_bytes_dev / LINK_BW

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.  The dominant term is the step-time lower bound;
roofline fraction = compute / max(all terms) (how close the cell is to
being compute-bound at peak).

Usage:  PYTHONPATH=src:. python -m benchmarks.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link


def load_records(d: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def derive(rec: dict) -> dict:
    chips = rec["chips"]
    t_comp = rec["hlo_flops"] / PEAK_FLOPS
    t_mem = rec["hlo_bytes"] / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / LINK_BW
    bound = max(("compute", t_comp), ("memory", t_mem),
                ("collective", t_coll), key=lambda kv: kv[1])
    # useful fraction: model flops (global) vs compiled flops (global)
    global_flops = rec["hlo_flops"] * chips
    useful = rec["model_flops"] / global_flops if global_flops else 0.0
    # roofline fraction: how much of the bound is doing peak-rate compute
    frac = t_comp / bound[1] if bound[1] > 0 else 0.0
    # step-time lower bound & achievable MFU at that bound
    mfu_bound = (rec["model_flops"] / chips / PEAK_FLOPS) / bound[1] \
        if bound[1] > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec.get("multi_pod") else "16x16",
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bound": bound[0], "bound_s": bound[1],
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "mfu_bound": mfu_bound,
        "coll_by_kind": rec["collectives"]["bytes_by_kind"],
        "mem_args_gb": rec.get("memory", {}).get(
            "argument_size_in_bytes", 0) / 1e9,
        "mem_temp_gb": rec.get("memory", {}).get(
            "temp_size_in_bytes", 0) / 1e9,
    }


def advise(row: dict) -> str:
    """One sentence: what moves the dominant term down."""
    if row["bound"] == "collective":
        big = max(row["coll_by_kind"].items(), key=lambda kv: kv[1])[0] \
            if row["coll_by_kind"] else "?"
        return (f"cut {big} volume: reshard to keep the contracting dim "
                f"local (or overlap via async collective scheduling)")
    if row["bound"] == "memory":
        if row["shape"].startswith(("decode", "long")):
            return ("decode is cache-bandwidth-bound by nature: shrink cache "
                    "reads (paged/ring caches, kv in bf16/int8, GQA/MLA)")
        return ("reduce HBM traffic: less remat recompute, fuse norms/rope, "
                "larger per-step tiles")
    return ("compute-bound (good): raise MFU by removing redundant flops "
            "(remat policy) and feeding the MXU bigger contractions")


def table(recs: list[dict], *, mesh_filter=None) -> list[dict]:
    rows = [derive(r) for r in recs]
    if mesh_filter:
        rows = [r for r in rows if r["mesh"] == mesh_filter]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def fmt_row(r: dict) -> str:
    return (f"{r['arch'][:24]:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute_s']:.3e} {r['t_memory_s']:.3e} "
            f"{r['t_collective_s']:.3e}  {r['bound'][:4]:4s} "
            f"{r['roofline_fraction']:5.1%} {r['useful_flops_ratio']:5.2f} "
            f"{r['mfu_bound']:6.1%}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    rows = table(load_records(args.dir), mesh_filter=args.mesh)
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'t_comp':9s} "
           f"{'t_mem':9s} {'t_coll':9s}  {'bnd':4s} {'frac':5s} "
           f"{'use':5s} {'mfu@b':6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(fmt_row(r))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
