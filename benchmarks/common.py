"""Shared benchmark substrate: the Table-5 workload suite (scaled to this
CPU container, preserving the paper's shape characteristics — embedding
tables 100-700x larger than edge arrays), the host-stack baseline pipeline
(the paper's DGL/GPU path), and the energy model.

Wall-clock numbers on this container are *relative* comparisons between
code paths, mirroring the paper's relative claims (its absolute numbers
come from FPGA/GPU hardware we do not have).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.store.blockdev import BlockDevice, PAGE_BYTES
from repro.store.graphstore import GraphStore, preprocess_edges
from repro.store.sampler import sample_batch
from repro.core import gnn

# ------------------------------------------------------- workload suite
# name: (vertices, edges, feature_dim, bucket) — scaled from paper Table 5
WORKLOADS = {
    "chmleon":  (2_300,  16_000, 256, "small"),
    "citeseer": (2_100,   4_500, 384, "small"),
    "coraml":   (3_000,   9_000, 288, "small"),
    "dblpfull": (8_000,  30_000, 160, "small"),
    "cs":       (9_000,  45_000, 384, "small"),
    "physics":  (12_000, 90_000, 420, "small"),
    "road-tx":  (60_000, 160_000, 220, "large"),
    "youtube":  (50_000, 130_000, 220, "large"),
    "wikitalk": (80_000, 170_000, 220, "large"),
}

# paper's system-level power constants (W)
POWER = {"gtx1060_system": 447.0, "rtx3090_system": 214.0,
         "cssd_system": 111.0, "cssd_fpga": 16.3}

# simulated SSD page latencies (2 GB/s sequential-ish) plus the fixed
# per-command round-trip a 4 KB random NVMe access pays (~80 us) — batched
# commands amortise the latter, which is the near-storage batching argument
PAGE_READ_US = PAGE_BYTES / (2e9) * 1e6
PAGE_WRITE_US = PAGE_BYTES / (1.2e9) * 1e6
CMD_LATENCY_US = 80.0


def make_workload(name: str, seed: int = 0):
    n, e, f, bucket = WORKLOADS[name]
    rng = np.random.default_rng(seed + hash(name) % 1000)
    src = rng.zipf(1.35, e) % n                       # power-law degrees
    dst = rng.integers(0, n, e)
    edges = np.stack([dst, src], axis=1).astype(np.int64)
    emb = rng.standard_normal((n, f)).astype(np.float32)
    return edges, emb, bucket


def storage_device(*, full_trace: bool = False):
    return BlockDevice(1 << 14, simulate_latency=True,
                       page_read_us=PAGE_READ_US,
                       page_write_us=PAGE_WRITE_US,
                       command_latency_us=CMD_LATENCY_US,
                       trace_events=full_trace)


# --------------------------------------------------- host-stack baseline
@dataclass
class HostTimes:
    graph_io: float = 0.0
    graph_prep: float = 0.0
    batch_io: float = 0.0
    batch_prep: float = 0.0
    pure_infer: float = 0.0

    @property
    def total(self):
        return (self.graph_io + self.graph_prep + self.batch_io
                + self.batch_prep + self.pure_infer)


class HostPipeline:
    """The paper's baseline: storage -> host RAM -> preprocess -> GPU.

    The raw edge array and embedding table live on the simulated SSD; every
    stage's storage traffic goes through the page device so GraphI/O and
    BatchI/O are honest relative measurements (Fig. 2 / Fig. 3 path).
    """

    def __init__(self, edges: np.ndarray, emb: np.ndarray):
        self.dev = storage_device()
        t0 = time.perf_counter()
        # raw-format data written to storage (edge text file + features)
        flat_e = edges.astype(np.int32).reshape(-1)
        self.e_pages = -(-flat_e.size // (PAGE_BYTES // 4))
        self.e_base = self.dev.alloc_back(self.e_pages)
        self.dev.write_span(self.e_base, flat_e, tag="graph")
        flat_f = emb.reshape(-1).view(np.int32)
        self.f_pages = -(-flat_f.size // (PAGE_BYTES // 4))
        self.f_base = self.dev.alloc_back(self.f_pages)
        self.dev.write_span(self.f_base, flat_f, tag="embed")
        self.n, self.f_dim = emb.shape
        self.e_size = flat_e.size
        self.write_time = time.perf_counter() - t0   # raw-data ingest
        self.times = HostTimes()
        self._csr = None
        self._emb = None
        self._jits = {}

    def graph_preprocess(self):
        t0 = time.perf_counter()                      # [G-1] load edge array
        flat = self.dev.read_span(self.e_base, self.e_pages, tag="graph")
        edges = flat[: self.e_size].reshape(-1, 2).astype(np.int64)
        self.times.graph_io += time.perf_counter() - t0
        t0 = time.perf_counter()                      # [G-2..4] undirect+sort
        self._csr = preprocess_edges(edges)
        self.times.graph_prep += time.perf_counter() - t0

    def load_embeddings(self):
        """[B-3] global embedding load (the OOM-prone host step)."""
        t0 = time.perf_counter()
        flat = self.dev.read_span(self.f_base, self.f_pages, tag="embed")
        self._emb = flat[: self.n * self.f_dim].view(np.float32).reshape(
            self.n, self.f_dim).copy()
        self.times.batch_io += time.perf_counter() - t0

    def batch_preprocess(self, targets, fanouts, seed=0):
        if self._csr is None:
            self.graph_preprocess()
        if self._emb is None:
            self.load_embeddings()
        t0 = time.perf_counter()
        batch = sample_batch(_CSRView(self._csr, self._emb), targets,
                             fanouts, rng=np.random.default_rng(seed),
                             pad_to=32)
        self.times.batch_prep += time.perf_counter() - t0
        return batch

    def infer(self, model, params, batch):
        """Steady-state inference (paper's PureInfer): the jit compile is
        warmed untimed — the paper's GPUs run compiled CUDA kernels."""
        blocks = [(jnp.asarray(b.nbr), jnp.asarray(b.mask))
                  for b in batch.layers]
        emb = jnp.asarray(batch.embeddings)
        fwd = self._jits.setdefault(model, jax.jit(gnn.FORWARD[model]))
        jax.block_until_ready(fwd(params, emb, blocks))      # warm
        t0 = time.perf_counter()
        out = jax.block_until_ready(fwd(params, emb, blocks))
        self.times.pure_infer += time.perf_counter() - t0
        return out


class _CSRView:
    """In-memory adjacency view with the GraphStore sampler interface."""

    def __init__(self, csr, emb):
        self.indptr, self.indices = csr
        self.emb = emb
        self.feature_dim = emb.shape[1] if emb is not None else 0

    def get_neighbors(self, v):
        return self.indices[self.indptr[v]: self.indptr[v + 1]]

    def get_embeds(self, vids):
        return self.emb[np.asarray(vids)]


# ------------------------------------------------ near-storage (HGNN) path
def hgnn_service(edges, emb, *, h_threshold=64, pad_to=32):
    from repro.core.service import HolisticGNNService
    svc = HolisticGNNService(h_threshold=h_threshold, pad_to=pad_to,
                             dev=storage_device())
    tl = svc.store.update_graph(edges, emb)
    return svc, tl


def timeit(fn, *args, repeat=3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def csv_line(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
