"""Fig. 3a — end-to-end GCN inference latency breakdown on the host path:
GraphPrep / BatchPrep / PureInfer / GraphI/O / BatchI/O per workload.
Reproduces the paper's claim that PureInfer is a tiny fraction and
BatchI/O dominates as graphs grow."""
from __future__ import annotations

import numpy as np

from . import common as C
from repro.core import gnn


def run(workloads=("citeseer", "chmleon", "cs", "physics", "road-tx",
                   "youtube")):
    lines = []
    fractions = {}
    for w in workloads:
        edges, emb, bucket = C.make_workload(w)
        host = C.HostPipeline(edges, emb)
        params = gnn.init_params("gcn", [emb.shape[1], 128, 64], seed=0)
        rng = np.random.default_rng(0)
        targets = rng.integers(0, emb.shape[0], 8)
        batch = host.batch_preprocess(targets, [10, 10])
        host.infer("gcn", params, batch)
        t = host.times
        tot = t.total
        lines.append(C.csv_line(
            f"fig3.{w}.total", tot,
            f"graphio={t.graph_io/tot:.2f};graphprep={t.graph_prep/tot:.2f};"
            f"batchio={t.batch_io/tot:.2f};batchprep={t.batch_prep/tot:.2f};"
            f"pureinfer={t.pure_infer/tot:.2f};bucket={bucket}"))
        fractions[w] = t.pure_infer / tot
    mean_pi = float(np.mean(list(fractions.values())))
    lines.append(C.csv_line("fig3.pure_infer_fraction_mean", mean_pi,
                            "paper_claims=0.02"))
    return lines
