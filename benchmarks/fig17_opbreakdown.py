"""Fig. 17 — inference latency decomposition into aggregation (SIMD-class
C-operations: SpMM/SDDMM/Reduce/elementwise) vs transformation (GEMM-class)
per User-logic configuration, on the 'physics' workload."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import common as C
from repro.core import gnn
from repro.core.service import HolisticGNNService
from repro.kernels.ops import program_config
from repro.store.sampler import sample_batch

GEMM_OPS = {"GEMM"}


def run(workload="physics", model="gcn"):
    edges, emb, _ = C.make_workload(workload)
    svc = HolisticGNNService(h_threshold=64, pad_to=64)
    svc.store.update_graph(edges, emb)
    b = sample_batch(svc.store, np.arange(16), [10, 10],
                     rng=np.random.default_rng(0), pad_to=64)
    params = gnn.init_params(model, [emb.shape[1], 128, 64], seed=0)
    dfg = gnn.BUILD_DFG[model](2)
    feeds = gnn.dfg_feeds(
        model, params, jnp.asarray(b.embeddings),
        [(jnp.asarray(x.nbr), jnp.asarray(x.mask)) for x in b.layers])
    lines = []
    for cfg in ("octa", "lsap", "hetero"):
        program_config(svc.xbuilder, cfg)
        # fuse=False: the decomposition needs the unfused per-op timings
        svc.engine.run(dfg, feeds, fuse=False)      # warm
        svc.engine.run(dfg, feeds, fuse=False)
        gemm_t = sum(dt for op, _, dt in svc.engine.timings
                     if op in GEMM_OPS)
        simd_t = sum(dt for op, _, dt in svc.engine.timings
                     if op not in GEMM_OPS)
        tot = gemm_t + simd_t
        lines.append(C.csv_line(
            f"fig17.{model}.{cfg}", tot,
            f"gemm_frac={gemm_t/tot:.2f};simd_frac={simd_t/tot:.2f}"))
    return lines
