"""Fig. 23 (repo extension) — CSSD-array scale-out sweep.

The paper's §8 scale-out story is an array of CSSDs; this benchmark sweeps
a ``ShardedGraphStore`` over 1/2/4/8 simulated devices and reports:

  * **batch-preprocessing throughput** (the Fig. 19 workload shape): one
    ``sample_batch`` per measurement — per hop, ONE queued scatter-read per
    shard issued concurrently, plus the striped embedding gather.  The
    sweep uses array-scale flash latencies (per-page flash time dominant,
    the regime a hundred-billion-edge device actually operates in) so the
    channel-parallel argument shows up as wall-clock speedup;
  * **serving throughput** (the Fig. 22 closed-loop shape): N clients in a
    closed loop against a ServingRuntime whose fused groups sample across
    the array;
  * **per-shard IO balance**: min/max read-page ratio across shards — the
    hash partition should keep the array within a few percent of even.

  PYTHONPATH=src:. python -m benchmarks.fig23_sharded [--smoke]
"""
from __future__ import annotations

import threading
import time

import numpy as np

from . import common as C
from repro.core import gnn
from repro.core.service import HolisticGNNService, make_service_dfg
from repro.serve import ServingRuntime
from repro.store import ShardedGraphStore, sample_batch
from repro.store.blockdev import BlockDevice

# Array-scale device profile: a QLC-class 4 KB random read (200 us raw,
# 25 us effective across 8 channels ~ 160 MB/s random per device).  Unlike
# the fig19/fig22 profile (command-latency dominated, the batching
# argument), here the per-page flash time dominates — that is the regime
# where adding devices, like adding channels, buys bandwidth.
PAGE_READ_US = 200.0
PAGE_WRITE_US = 250.0
CMD_LATENCY_US = 20.0


def shard_devices(n: int) -> list[BlockDevice]:
    return [BlockDevice(1 << 15, simulate_latency=True,
                        page_read_us=PAGE_READ_US,
                        page_write_us=PAGE_WRITE_US,
                        command_latency_us=CMD_LATENCY_US)
            for _ in range(n)]


def _balance(reads: list[int]) -> str:
    lo, hi = min(reads), max(reads)
    return f"balance={lo / hi:.2f}" if hi else "balance=1.00"


# ------------------------------------------------- A: batch preprocessing
def _prep_workload(n, e, feat, seed=0):
    """Paper-shaped scale-out workload: power-law edges and a FEATURE-HEAVY
    embedding table (Table 5: embedding tables are 100-700x the edge
    array), so batch preprocessing is embedding-gather bound — the regime
    the array actually buys bandwidth in."""
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.35, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _prep_sweep(lines, shard_counts, w, batch, fanouts, repeat):
    n, e, feat = (3000, 16000, 256) if w == "small" else (40000, 120000, 1024)
    edges, emb = _prep_workload(n, e, feat)
    targets = np.random.default_rng(0).integers(0, n, batch)
    base_tp = None
    for ns in shard_counts:
        store = ShardedGraphStore(devs=shard_devices(ns), h_threshold=64)
        store.update_graph(edges, emb)
        reads0 = [d.stats.read_pages for d in store.devs]

        def prep():
            return sample_batch(store, targets, list(fanouts),
                                rng=np.random.default_rng(0), pad_to=64)

        prep()                                          # warm
        t, _ = C.timeit(prep, repeat=repeat)
        tp = 1.0 / t                                    # batches / s
        if base_tp is None:
            base_tp = tp
        reads = [d.stats.read_pages - r0
                 for d, r0 in zip(store.devs, reads0)]
        lines.append(C.csv_line(
            f"fig23.prep.{w}.{ns}shard", t,
            f"batches_per_s={tp:.1f};speedup={tp / base_tp:.2f}x;"
            + _balance(reads)))
    return lines


# ----------------------------------------------------------- B: serving
def _serve_sweep(lines, shard_counts, clients, per_client, batch, feat):
    n, e = 12000, 70000
    rng = np.random.default_rng(0)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    params = gnn.init_params("gcn", [feat, 32, 16], seed=1)
    dfg = make_service_dfg("gcn", 2, [10, 10]).save()
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    streams = [[(np.random.default_rng(1000 + c)
                 .integers(0, n, batch).tolist(), c * 10000 + r)
                for r in range(per_client)] for c in range(clients)]
    n_req = clients * per_client
    base_rps = None
    for ns in shard_counts:
        svc = HolisticGNNService(h_threshold=64, pad_to=64,
                                 devs=shard_devices(ns))
        svc.store.update_graph(edges, emb)
        svc.put_weights("fig23", weights)
        for g in (1, 2, 4, clients):                   # warm jit buckets
            svc.run_batch(dfg, [{"targets": streams[0][0][0], "seed": 1}
                                for _ in range(g)], weights_ref="fig23")
        rt = ServingRuntime(svc, n_queues=min(clients, 8),
                            max_group=clients, max_pending=256)
        stubs = [rt.client() for _ in range(clients)]
        lat: list[float] = []
        lock = threading.Lock()

        def loop(cid):
            mine = []
            for targets, seed in streams[cid]:
                t0 = time.perf_counter()
                stubs[cid].call("run", dfg=dfg, batch=targets,
                                weights_ref="fig23", seed=seed, timeout=600)
                mine.append(time.perf_counter() - t0)
            with lock:
                lat.extend(mine)

        rt.start()
        try:
            t0 = time.perf_counter()
            ths = [threading.Thread(target=loop, args=(c,))
                   for c in range(clients)]
            for t in ths:
                t.start()
            for t in ths:
                t.join()
            wall = time.perf_counter() - t0
        finally:
            rt.stop()
        rps = n_req / wall
        if base_rps is None:
            base_rps = rps
        reads = [d.stats.read_pages for d in svc.store.devs]
        lines.append(C.csv_line(
            f"fig23.serve.{clients}c.{ns}shard",
            float(np.mean(lat)),
            f"rps={rps:.1f};speedup={rps / base_rps:.2f}x;"
            f"p95ms={np.percentile(lat, 95) * 1e3:.1f};" + _balance(reads)))
    return lines


def run(smoke: bool = False, shard_counts=(1, 2, 4, 8)):
    lines: list[str] = []
    if smoke:
        shard_counts = (1, 2)
        prep_args = ("small", 32, [10, 10], 2)
        serve_args = (4, 3, 8, 64)
    else:
        prep_args = ("large", 128, [15, 10], 3)
        serve_args = (8, 6, 8, 128)
    _prep_sweep(lines, shard_counts, *prep_args)
    _serve_sweep(lines, shard_counts, *serve_args)
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for ln in run(smoke=args.smoke):
        print(ln)
