"""Table 5 — workload suite characteristics (original vs sampled graph),
including the paper's embedding-vs-edge-array size ratio (Fig. 3b)."""
from __future__ import annotations

import numpy as np

from . import common as C
from repro.core.service import HolisticGNNService
from repro.store.sampler import sample_batch


def run():
    lines = []
    ratios = []
    for w, (n, e, f, bucket) in C.WORKLOADS.items():
        edges, emb, _ = C.make_workload(w)
        ratio = emb.nbytes / (edges.nbytes // 2)
        ratios.append(ratio)
        svc = HolisticGNNService(h_threshold=64, pad_to=32)
        svc.store.update_graph(edges, emb)
        b = sample_batch(svc.store, np.arange(8), [10, 10],
                         rng=np.random.default_rng(0))
        lines.append(C.csv_line(
            f"table5.{w}", 0.0,
            f"V={n};E={e};featdim={f};bucket={bucket};"
            f"emb_over_edges={ratio:.0f}x;"
            f"sampled_V={b.num_nodes};sampled_deg={b.layers[0].nbr.shape[1]}"))
    lines.append(C.csv_line("fig3b.mean_emb_over_edges",
                            float(np.mean(ratios)),
                            "paper=285.7x_small_728.1x_large"))
    return lines
