"""Fig. 14 — end-to-end inference latency: host-stack baseline vs
HolisticGNN near-storage, per workload (GCN).  The HGNN path counts bulk
ingest user-visible time + near-storage batch prep + inference; the host
path counts raw load + preprocess + batch prep + inference."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from . import common as C
from repro.core import gnn
from repro.store.sampler import sample_batch


def _host_end2end(edges, emb, params, targets):
    # end-to-end includes writing the raw data to storage (the HGNN side
    # counts its UpdateGraph ingest too — paper Fig. 14 semantics)
    host = C.HostPipeline(edges, emb)
    batch = host.batch_preprocess(targets, [10, 10])
    host.infer("gcn", params, batch)
    return host.write_time + host.times.total


_FWD = {}


def _hgnn_end2end(edges, emb, params, targets):
    t0 = time.perf_counter()
    svc, tl = C.hgnn_service(edges, emb)
    b = sample_batch(svc.store, targets, [10, 10],
                     rng=np.random.default_rng(0), pad_to=32)
    blocks = [(jnp.asarray(x.nbr), jnp.asarray(x.mask)) for x in b.layers]
    embj = jnp.asarray(b.embeddings)
    t_pre = time.perf_counter() - t0
    fwd = _FWD.setdefault("gcn", jax.jit(gnn.FORWARD["gcn"]))
    jax.block_until_ready(fwd(params, embj, blocks))          # warm, untimed
    t0 = time.perf_counter()
    jax.block_until_ready(fwd(params, embj, blocks))
    # user-visible: overlapped ingest + batch prep + steady inference
    return (t_pre + (time.perf_counter() - t0)
            - (tl.total - tl.user_visible))


def run(workloads=("citeseer", "chmleon", "cs", "physics", "road-tx",
                   "youtube")):
    lines = []
    speedups = []
    for w in workloads:
        edges, emb, bucket = C.make_workload(w)
        params = gnn.init_params("gcn", [emb.shape[1], 128, 64], seed=0)
        rng = np.random.default_rng(0)
        targets = rng.integers(0, emb.shape[0], 8)
        t_host = _host_end2end(edges, emb, params, targets)
        t_hgnn = _hgnn_end2end(edges, emb, params, targets)
        speedups.append(t_host / t_hgnn)
        lines.append(C.csv_line(f"fig14.{w}.host", t_host, f"bucket={bucket}"))
        lines.append(C.csv_line(f"fig14.{w}.hgnn", t_hgnn,
                                f"speedup={t_host/t_hgnn:.2f}x"))
    lines.append(C.csv_line("fig14.geomean_speedup",
                            float(np.exp(np.mean(np.log(speedups)))),
                            "paper_claims=7.1x_vs_gpu"))
    return lines
