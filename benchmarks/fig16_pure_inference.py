"""Fig. 16 — pure inference latency across User-logic configurations
(Octa software-only / Lsap systolic-only / Hetero vector+systolic) for
GCN / GIN / NGCF.  Reproduces the paper's routing result: systolic-only
loses on irregular aggregation; Hetero routes SpMM->vector, GEMM->systolic.

On this container "systolic" = Pallas GEMM (interpret), "vector" = Pallas
SpMM/SDDMM (interpret), "software" = jnp Shell — relative routing effects,
not TPU wall-clock.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import common as C
from repro.core.registry import KernelRegistry
from repro.core.xbuilder import XBuilder
from repro.core.dfg import Engine
from repro.core import gnn
from repro.core.service import HolisticGNNService
from repro.kernels.ops import program_config
from repro.store.sampler import sample_batch


def run(workload="cs", models=("gcn", "gin", "ngcf"),
        configs=("octa", "lsap", "hetero")):
    edges, emb, _ = C.make_workload(workload)
    svc = HolisticGNNService(h_threshold=64, pad_to=64)
    svc.store.update_graph(edges, emb)
    b = sample_batch(svc.store, np.arange(16), [10, 10],
                     rng=np.random.default_rng(0), pad_to=64)
    lines = []
    for model in models:
        params = gnn.init_params(model, [emb.shape[1], 128, 64], seed=0)
        dfg = gnn.BUILD_DFG[model](2)
        feeds = gnn.dfg_feeds(
            model, params, jnp.asarray(b.embeddings),
            [(jnp.asarray(x.nbr), jnp.asarray(x.mask)) for x in b.layers])
        times = {}
        for cfgname in configs:
            program_config(svc.xbuilder, cfgname)
            eng = svc.engine
            eng.run(dfg, feeds)                      # warm (compile)
            dt, _ = C.timeit(eng.run, dfg, feeds, repeat=3)
            times[cfgname] = dt
            lines.append(C.csv_line(f"fig16.{model}.{cfgname}", dt, ""))
        lines.append(C.csv_line(
            f"fig16.{model}.hetero_vs_lsap",
            times["lsap"] / max(times["hetero"], 1e-9),
            "paper: hetero 14.2x faster than lsap (avg all models)"))
    return lines
