"""Fused RMSNorm Pallas kernel (row blocks, full feature dim in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .config import CompilerParams, resolve_interpret


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w_ref[...]).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6, br: int = 256,
            interpret: bool | None = None) -> jax.Array:
    return _rmsnorm(x, w, eps=eps, br=br,
                    interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def _rmsnorm(x: jax.Array, w: jax.Array, *, eps: float, br: int,
             interpret: bool) -> jax.Array:
    orig_shape = x.shape
    f = orig_shape[-1]
    x2 = x.reshape(-1, f)
    b = x2.shape[0]
    br = min(br, max(8, b))
    bp = -(-b // br) * br
    xp = jnp.pad(x2, ((0, bp - b), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(bp // br,),
        in_specs=[
            pl.BlockSpec((br, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, f), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp, w)
    return out[:b].reshape(orig_shape)
