"""Paged decode attention — GraphStore's VID->LPN mapping as a KV page table.

This is the paper's storage technique landed in the serving hot loop: the KV
cache lives in fixed-size *pages* (the paper's 4 KB flash pages; here
``page_size`` KV slots), and a per-sequence **page table** (logical page ->
physical page, exactly the H-type VID->LPN chain flattened) tells the kernel
where each logical block of the sequence physically resides.

The page table and sequence lengths ride in **scalar-prefetch** (SMEM), so
the BlockSpec index_map itself performs the translation — the DMA engine
fetches physical page ``pt[b, p]`` while the MXU/VPU works on the previous
page: near-data gather with zero host involvement, the CSSD insight on TPU.

Grid (B, Hkv, PP): one token's attention per (batch, kv-head), online
softmax across that sequence's pages; GQA handled by grouping Hq/Hkv query
heads into the sublane dimension of a single (G, D) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .config import CompilerParams, resolve_interpret

_LANES = 128
NEG_INF = -1e30


def _decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, ps: int, n_p: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(p * ps < length)                     # skip fully-past-end pages
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)       # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)    # (ps, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pexp = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = alpha * l_ref[:, :1] + pexp.sum(axis=1, keepdims=True)
        m_ref[:, :1] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            pexp, v, preferred_element_type=jnp.float32)

    @pl.when(p == n_p - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     page_table: jax.Array, lengths: jax.Array, *,
                     interpret: bool | None = None) -> jax.Array:
    """q (B,Hq,D); pages (P,ps,Hkv,D); page_table (B,PP); lengths (B,)."""
    return _decode_attention(q, k_pages, v_pages, page_table, lengths,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                      page_table: jax.Array, lengths: jax.Array, *,
                      interpret: bool) -> jax.Array:
    b, hq, d = q.shape
    p_num, ps, hkv, _ = k_pages.shape
    pp = page_table.shape[1]
    g = hq // hkv
    scale = float(1.0 / (d ** 0.5))
    qg = q.reshape(b, hkv, g, d)
    # physical pages laid out (P, ps, Hkv, D) -> kernel reads (ps, 1, D) tiles
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, ps=ps, n_p=pp),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hkv, pp),
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bi, h, p, pt, ln: (bi, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, d),
                             lambda bi, h, p, pt, ln: (pt[bi, p], 0, h, 0)),
                pl.BlockSpec((1, ps, 1, d),
                             lambda bi, h, p, pt, ln: (pt[bi, p], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, h, p, pt, ln: (bi, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, _LANES), jnp.float32),
                pltpu.VMEM((g, _LANES), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
