"""SDDMM Pallas kernel — per-edge elementwise products (NGCF similarity term).

out[i,k,:] = h[nbr[i,k],:] * h[i,:] * mask[i,k]   over (D,K,F).
Same VMEM-slab strategy as SpMM; output is a 3D block (bd,K,bf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .config import CompilerParams, resolve_interpret


def _sddmm_kernel(h_ref, nbr_ref, mask_ref, o_ref):
    nbr = nbr_ref[...]
    mask = mask_ref[...]
    bd, kk = nbr.shape
    h = h_ref[...]
    g = jnp.take(h, nbr.reshape(-1), axis=0).reshape(bd, kk, -1)
    i0 = pl.program_id(0) * bd
    dst = jax.lax.dynamic_slice_in_dim(h, i0, bd, axis=0)
    o_ref[...] = (g * dst[:, None, :] * mask[..., None]).astype(o_ref.dtype)


def sddmm(h: jax.Array, nbr: jax.Array, mask: jax.Array, *, bd: int = 64,
          bf: int = 128, interpret: bool | None = None) -> jax.Array:
    return _sddmm(h, nbr, mask, bd=bd, bf=bf,
                  interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bd", "bf", "interpret"))
def _sddmm(h: jax.Array, nbr: jax.Array, mask: jax.Array, *, bd: int,
           bf: int, interpret: bool) -> jax.Array:
    n, f = h.shape
    d, k = nbr.shape
    bd = min(bd, max(8, d))
    bf = min(bf, max(128, f))
    dp = -(-d // bd) * bd
    fp = -(-f // bf) * bf
    # the dst rows (prefix of h) must cover the padded dst range
    npad = max(n, dp)
    hp = jnp.pad(h, ((0, npad - n), (0, fp - f)))
    nbrp = jnp.pad(nbr, ((0, dp - d), (0, 0)))
    maskp = jnp.pad(mask, ((0, dp - d), (0, 0)))
    out = pl.pallas_call(
        _sddmm_kernel,
        grid=(dp // bd, fp // bf),
        in_specs=[
            pl.BlockSpec((npad, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bd, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bd, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bd, k, bf), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((dp, k, fp), h.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(hp, nbrp, maskp)
    return out[:d, :, :f]
