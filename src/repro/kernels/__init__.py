from . import ref
from .ops import (gemm, spmm, sddmm, rmsnorm, agg_combine, flash_attention,
                  decode_attention, set_interpret, get_interpret,
                  BITSTREAMS, program_config)

__all__ = ["ref", "gemm", "spmm", "sddmm", "rmsnorm", "agg_combine",
           "flash_attention", "decode_attention", "set_interpret",
           "get_interpret", "BITSTREAMS", "program_config"]
