"""Flash attention Pallas kernel (causal, online softmax) for train/prefill.

Grid (B*H, Tq/bq, Tk/bk); K is the innermost arbitrary dimension so each
query tile is revisited across KV tiles with running (m, l, acc) state in
VMEM scratch — the TPU analog of the paper's dense-compute path routed to
the systolic unit (QK^T and PV on the MXU, softmax on the VPU).
Causal tiles entirely above the diagonal are skipped via pl.when (compute
skip; the HLO cost model sees the saved FLOPs through the mask either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .config import CompilerParams, resolve_interpret

_LANES = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, n_k: int, bq: int, bk: int,
                  kv_len: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip tiles strictly above the diagonal
    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _update():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, :1] = alpha * l_prev + p.sum(axis=1, keepdims=True)
        m_ref[:, :1] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(j == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q (B,Hq,T,D); k,v (B,Hkv,S,D) with Hq % Hkv == 0 -> (B,Hq,T,D)."""
    return _flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, bq: int, bk: int,
                     interpret: bool) -> jax.Array:
    b, hq, t, d = q.shape
    hkv, s_len = k.shape[1], k.shape[2]
    if hq != hkv:                                     # GQA: broadcast KV heads
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = float(1.0 / (d ** 0.5))
    bq = min(bq, max(8, t))
    bk = min(bk, max(128, s_len))
    tp = -(-t // bq) * bq
    sp = -(-s_len // bk) * bk
    qf = jnp.pad(q.reshape(b * hq, t, d), ((0, 0), (0, tp - t), (0, 0)))
    kf = jnp.pad(k.reshape(b * hq, s_len, d), ((0, 0), (0, sp - s_len), (0, 0)))
    vf = jnp.pad(v.reshape(b * hq, s_len, d), ((0, 0), (0, sp - s_len), (0, 0)))
    n_k = sp // bk
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal, n_k=n_k,
                          bq=bq, bk=bk, kv_len=s_len),
        grid=(b * hq, tp // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, tp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :t].reshape(b, hq, t, d)
