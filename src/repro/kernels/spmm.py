"""ELL/page-format SpMM Pallas kernel — the "vector processor" aggregation.

Consumes GraphStore's page-shaped blocks directly: a (D,K) padded
neighbor-index matrix + mask against the sampled embedding table h (N,F).
TPU adaptation (vs. the paper's Hwacha vector loops): sampled subgraphs are
small (paper Table 5: <= ~6K nodes), so the *full node dimension* of h fits
VMEM when the feature dimension is tiled — the kernel keeps an (N, bf) slab
resident in VMEM and performs VPU row-gathers per destination block, never
touching HBM per edge.  Grid is (dst blocks, feature tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .config import CompilerParams, resolve_interpret


def _spmm_kernel(h_ref, nbr_ref, mask_ref, o_ref, *, mode: str):
    nbr = nbr_ref[...]                    # (bd, K) int32
    mask = mask_ref[...]                  # (bd, K) f32
    bd, kk = nbr.shape
    h = h_ref[...]                        # (N, bf) VMEM slab
    g = jnp.take(h, nbr.reshape(-1), axis=0).reshape(bd, kk, -1)
    g = g * mask[..., None]
    s = g.sum(axis=1)
    if mode == "mean":
        deg = jnp.maximum(mask.sum(axis=1), 1.0)
        s = s / deg[:, None]
    o_ref[...] = s.astype(o_ref.dtype)


def spmm(h: jax.Array, nbr: jax.Array, mask: jax.Array, *, mode: str = "mean",
         bd: int = 128, bf: int = 128,
         interpret: bool | None = None) -> jax.Array:
    return _spmm(h, nbr, mask, mode=mode, bd=bd, bf=bf,
                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("mode", "bd", "bf", "interpret"))
def _spmm(h: jax.Array, nbr: jax.Array, mask: jax.Array, *, mode: str,
          bd: int, bf: int, interpret: bool) -> jax.Array:
    n, f = h.shape
    d, k = nbr.shape
    bd = min(bd, max(8, d))
    bf = min(bf, max(128, f))
    dp = -(-d // bd) * bd
    fp = -(-f // bf) * bf
    hp = jnp.pad(h, ((0, 0), (0, fp - f)))
    nbrp = jnp.pad(nbr, ((0, dp - d), (0, 0)))
    maskp = jnp.pad(mask, ((0, dp - d), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, mode=mode),
        grid=(dp // bd, fp // bf),
        in_specs=[
            pl.BlockSpec((n, bf), lambda i, j: (0, j)),     # VMEM-resident slab
            pl.BlockSpec((bd, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bd, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bd, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, fp), h.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(hp, nbrp, maskp)
    return out[:d, :f]
