"""Global Pallas execution-mode switch.

Every kernel wrapper defaults ``interpret=None`` and resolves it here, so a
single ``set_interpret(False)`` flips the whole kernel library to native TPU
compilation — direct callers no longer bypass the toggle by picking up a
hardcoded per-kernel default.  Resolution happens *outside* the jitted
wrappers: ``interpret`` is a static argument, so the resolved boolean (not
``None``) must be what reaches the jit cache key.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; resolve
# whichever this installation provides so kernels work on both.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

_INTERPRET = True


def set_interpret(flag: bool) -> None:
    """Global toggle: False on real TPU."""
    global _INTERPRET
    _INTERPRET = bool(flag)


def get_interpret() -> bool:
    return _INTERPRET


def resolve_interpret(interpret: bool | None) -> bool:
    return _INTERPRET if interpret is None else bool(interpret)
