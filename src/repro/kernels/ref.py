"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function is the semantic ground truth; kernel tests sweep shapes and
dtypes and `assert_allclose` the pallas_call (interpret=True) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def spmm_ref(h: jax.Array, nbr: jax.Array, mask: jax.Array,
             *, mode: str = "mean") -> jax.Array:
    """ELL/page-format neighbor aggregation. h (N,F), nbr/mask (D,K) -> (D,F)."""
    g = jnp.take(h, nbr, axis=0) * mask[..., None]
    s = g.sum(axis=1)
    if mode == "sum":
        return s
    deg = jnp.maximum(mask.sum(axis=1), 1.0)
    return s / deg[:, None]


def sddmm_ref(h: jax.Array, nbr: jax.Array, mask: jax.Array) -> jax.Array:
    """Per-edge product with destination rows: (D,K,F)."""
    g = jnp.take(h, nbr, axis=0)
    d = h[: nbr.shape[0]]
    return g * d[:, None, :] * mask[..., None]


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps) * w).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None) -> jax.Array:
    """(B,H,T,D) x (B,Hkv,S,D) -> (B,H,T,D); GQA by head broadcast."""
    b, hq, t, d = q.shape
    hkv = k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        tt = jnp.arange(t)[:, None]
        ss = jnp.arange(k.shape[2])[None, :]
        s = jnp.where(tt >= ss, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_gather(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P,ps,Hkv,D) pages + (B,PP) table -> (B, PP*ps, Hkv, D) logical KV."""
    b, pp = page_table.shape
    sel = pages[page_table.reshape(-1)]                 # (B*PP, ps, Hkv, D)
    ps, hkv, d = sel.shape[1:]
    return sel.reshape(b, pp * ps, hkv, d)


def decode_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                         page_table: jax.Array, lengths: jax.Array,
                         *, scale: float | None = None) -> jax.Array:
    """Single-token paged decode attention.

    q (B,Hq,D); pages (P,ps,Hkv,D); page_table (B,PP); lengths (B,) -> (B,Hq,D)
    """
    b, hq, d = q.shape
    k = paged_gather(k_pages, page_table)               # (B,S,Hkv,D)
    v = paged_gather(v_pages, page_table)
    s_len = k.shape[1]
    hkv = k.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(s_len)[None, None, :]
    s = jnp.where(pos < lengths[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
