"""jit'd kernel wrappers + the paper's User-logic "bitstreams".

Three accelerator configurations mirror the paper's prototypes (Fig. 12):

  * **Octa-HGNN**  — software-only: every C-kernel is the Shell jnp path
    (registering Octa is a no-op bitstream; it exists so the Fig. 16
    comparison has the same dispatch machinery).
  * **Lsap-HGNN**  — a large systolic array only: GEMM goes to the Pallas
    MXU kernel, but the irregular aggregation (SpMM/SDDMM) has *no* vector
    unit and is forced through GEMM-style dense ops (one-hot matmul) — the
    paper's "systolic arrays cannot traverse graphs" effect.
  * **Hetero-HGNN** — vector + systolic: SpMM/SDDMM on the VPU kernels,
    GEMM on the MXU kernel (highest priority), the winning configuration.

On this CPU container Pallas kernels run in interpret mode; on TPU the same
``pallas_call``s compile natively (flip ``interpret=False`` via
set_interpret()).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.xbuilder import Bitstream
from .config import set_interpret, get_interpret
from .gemm import gemm
from .spmm import spmm
from .sddmm import sddmm
from .rmsnorm import rmsnorm
from .agg_combine import agg_combine, agg_combine_partial
from .flash_attention import flash_attention
from .decode_attention import decode_attention


def _i():
    return get_interpret()


# ----------------------------------------------------------- dense fallbacks
def _spmm_via_gemm(h, nbr, mask, *, mode: str = "mean"):
    """Lsap path: aggregation lowered onto the systolic array as a dense
    one-hot matmul — correct but wasteful (the paper's Fig. 16 point)."""
    n = h.shape[0]
    d, k = nbr.shape
    onehot = jax.nn.one_hot(nbr, n, dtype=h.dtype) * mask[..., None]  # (D,K,N)
    a = onehot.sum(axis=1)                                            # (D,N)
    if mode == "mean":
        deg = jnp.maximum(mask.sum(axis=1), 1.0)
        a = a / deg[:, None]
    return gemm(a, h, interpret=_i())


def _sddmm_via_gemm(h, nbr, mask):
    n = h.shape[0]
    d, k = nbr.shape
    onehot = jax.nn.one_hot(nbr.reshape(-1), n, dtype=h.dtype)        # (D*K,N)
    g = gemm(onehot, h, interpret=_i()).reshape(d, k, -1)
    return g * h[:d][:, None, :] * mask[..., None]


# ------------------------------------------------------------- bitstreams
def octa_bitstream() -> Bitstream:
    return Bitstream(device="octa-o3", priority=60, kernels={})


def lsap_bitstream() -> Bitstream:
    return Bitstream(device="systolic", priority=300, kernels={
        "GEMM": lambda a, b: gemm(a, b),
        "SpMM": functools.partial(_spmm_via_gemm),
        "SpMM_Mean": lambda h, n, m: _spmm_via_gemm(h, n, m, mode="mean"),
        "SpMM_Sum": lambda h, n, m: _spmm_via_gemm(h, n, m, mode="sum"),
        "SDDMM": _sddmm_via_gemm,
    })


def hetero_bitstream() -> Bitstream:
    bs = Bitstream(device="vector", priority=150, kernels={
        "SpMM": lambda h, n, m, mode="mean": spmm(h, n, m, mode=mode),
        "SpMM_Mean": lambda h, n, m: spmm(h, n, m, mode="mean"),
        "SpMM_Sum": lambda h, n, m: spmm(h, n, m, mode="sum"),
        "SDDMM": lambda h, n, m: sddmm(h, n, m),
        "RMSNorm": lambda x, w: rmsnorm(x, w),
        # fused aggregate-combine: one whole GCN layer per kernel launch —
        # the engine's fusion pass targets this C-operation when present.
        "AggCombine": lambda h, n, m, w, b: agg_combine(h, n, m, w, b,
                                                        mode="mean"),
        # slice-shaped SPMD entry: agg@w partial product, no epilogue —
        # the sharded engine psums this across the model axis before
        # applying bias+relu to the full sum.
        "AggCombinePartial": lambda h, n, m, w: agg_combine_partial(
            h, n, m, w, mode="mean"),
    })
    return bs


def hetero_gemm_bitstream() -> Bitstream:
    """The systolic half of Hetero (program both this and hetero_bitstream)."""
    return Bitstream(device="systolic", priority=300, kernels={
        "GEMM": lambda a, b: gemm(a, b),
    })


BITSTREAMS = {
    "octa": [octa_bitstream],
    "lsap": [lsap_bitstream],
    "hetero": [hetero_bitstream, hetero_gemm_bitstream],
}


def program_config(xbuilder, name: str) -> float:
    """Program a named accelerator configuration; returns reconfig seconds."""
    for dev in list(xbuilder.loaded):
        xbuilder.unprogram(dev)
    total = 0.0
    for mk in BITSTREAMS[name]:
        total += xbuilder.program(mk())
    return total


__all__ = ["gemm", "spmm", "sddmm", "rmsnorm", "agg_combine",
           "agg_combine_partial",
           "flash_attention", "decode_attention", "set_interpret",
           "get_interpret", "BITSTREAMS", "program_config",
           "octa_bitstream", "lsap_bitstream", "hetero_bitstream",
           "hetero_gemm_bitstream"]
