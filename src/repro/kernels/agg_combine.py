"""Fused aggregate-combine Pallas kernel — one GCN layer in one kernel.

Computes ``relu(spmm(h, nbr, mask, mode) @ w + b)`` without materialising the
aggregated features in HBM: the VPU gather/reduce (SpMM) lands in a VMEM
scratch slab that feeds the MXU matmul directly — the GNNHLS-style
aggregate/combine fusion on top of GraphStore's page-shaped ELL blocks.

Grid is (dst blocks, output-feature tiles) with the output dimension
innermost: the aggregation for a destination block runs once (at the first
output tile) and is reused from scratch across all output tiles, so the
expensive irregular gather is never recomputed per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .config import CompilerParams, resolve_interpret


def _agg_combine_kernel(h_ref, nbr_ref, mask_ref, w_ref, b_ref, o_ref,
                        agg_ref, *, mode: str, epilogue: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _aggregate():
        nbr = nbr_ref[...]                  # (bd, K) int32
        mask = mask_ref[...]                # (bd, K) f32
        bd, kk = nbr.shape
        h = h_ref[...]                      # (N, Fp) VMEM slab
        g = jnp.take(h, nbr.reshape(-1), axis=0).reshape(bd, kk, -1)
        g = g * mask[..., None]
        s = g.sum(axis=1)
        if mode == "mean":
            deg = jnp.maximum(mask.sum(axis=1), 1.0)
            s = s / deg[:, None]
        agg_ref[...] = s.astype(jnp.float32)

    z = jnp.dot(agg_ref[...], w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    if epilogue:
        z = z + b_ref[...].astype(jnp.float32)
        z = jnp.maximum(z, 0.0)
    o_ref[...] = z.astype(o_ref.dtype)


def agg_combine(h: jax.Array, nbr: jax.Array, mask: jax.Array,
                w: jax.Array, b: jax.Array, *, mode: str = "mean",
                bd: int = 128, bo: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """h (N,F); nbr,mask (D,K); w (F,O); b (O,) -> relu(agg@w+b) (D,O)."""
    return _agg_combine(h, nbr, mask, w, b, mode=mode, bd=bd, bo=bo,
                        epilogue=True, interpret=resolve_interpret(interpret))


def agg_combine_partial(h: jax.Array, nbr: jax.Array, mask: jax.Array,
                        w: jax.Array, *, mode: str = "mean",
                        bd: int = 128, bo: int = 128,
                        interpret: bool | None = None) -> jax.Array:
    """Slice-shaped SPMD entry point: ``agg @ w`` with NO bias/relu epilogue.

    The SPMD engine calls this per mesh slice with feature-sharded ``h``
    and row-sharded ``w``; the partial products are then ``psum``-reduced
    across the ``model`` axis and the bias+relu epilogue applied to the
    full sum (a nonlinearity cannot be applied to a partial sum).  Same
    fused Pallas kernel, epilogue compiled out.
    """
    b = jnp.zeros((w.shape[1],), jnp.float32)      # unused when epilogue=False
    return _agg_combine(h, nbr, mask, w, b, mode=mode, bd=bd, bo=bo,
                        epilogue=False, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("mode", "bd", "bo", "epilogue",
                                    "interpret"))
def _agg_combine(h, nbr, mask, w, b, *, mode, bd, bo, epilogue, interpret):
    n, f = h.shape
    d, k = nbr.shape
    o = w.shape[1]
    bd = min(bd, max(8, d))
    bo = min(bo, max(128, o))
    dp = -(-d // bd) * bd
    fp = -(-f // 128) * 128
    op = -(-o // bo) * bo
    npad = -(-max(n, 8) // 8) * 8
    hp = jnp.pad(h, ((0, npad - n), (0, fp - f)))
    nbrp = jnp.pad(nbr, ((0, dp - d), (0, 0)))
    maskp = jnp.pad(mask, ((0, dp - d), (0, 0)))
    wp = jnp.pad(w, ((0, fp - f), (0, op - o)))
    bp = jnp.pad(b.reshape(1, -1), ((0, 0), (0, op - o)))
    out = pl.pallas_call(
        functools.partial(_agg_combine_kernel, mode=mode, epilogue=epilogue),
        grid=(dp // bd, op // bo),
        in_specs=[
            pl.BlockSpec((npad, fp), lambda i, j: (0, 0)),   # VMEM h slab
            pl.BlockSpec((bd, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bd, k), lambda i, j: (i, 0)),
            pl.BlockSpec((fp, bo), lambda i, j: (0, j)),
            pl.BlockSpec((1, bo), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, op), h.dtype),
        scratch_shapes=[pltpu.VMEM((bd, fp), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(hp, nbrp, maskp, wp, bp)
    return out[:d, :o]
