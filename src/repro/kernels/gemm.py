"""Blocked GEMM Pallas kernel — the "systolic array" User logic on TPU.

MXU-aligned (128x128x128 default) accumulation over a 3D grid with an fp32
VMEM accumulator; K is the innermost ("arbitrary") dimension so each (i,j)
output tile is revisited across K steps — the canonical TPU matmul pipeline
(HBM -> VMEM double-buffered by pallas, MXU per tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .config import CompilerParams, resolve_interpret


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def gemm(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
         bk: int = 128, interpret: bool | None = None) -> jax.Array:
    """a (M,K) @ b (K,N) -> (M,N) in a's dtype (fp32 accumulate)."""
    return _gemm(a, b, bm=bm, bn=bn, bk=bk,
                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _gemm(a: jax.Array, b: jax.Array, *, bm: int, bn: int, bk: int,
          interpret: bool) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    ap = _pad_to(_pad_to(a, bm, 0), bk, 1)
    bp = _pad_to(_pad_to(b, bk, 0), bn, 1)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
