"""jax version compatibility shims.

The codebase targets the current jax API; these helpers keep it running on
older installations (e.g. 0.4.x) where ``jax.shard_map`` still lives in
``jax.experimental`` with the ``check_rep``/``auto`` spelling and
``jax.tree.flatten_with_path`` is only in ``jax.tree_util``.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check: bool = False):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    ``axis_names`` is the set of mesh axes over which ``f`` is manual (the
    new-API meaning); the remaining axes stay automatic.  ``check`` maps to
    ``check_vma`` (new) / ``check_rep`` (old).
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # the old partial-manual (``auto=``) path trips an XLA manual-subgroup
    # check inside jit on some versions; run fully manual instead — the
    # replicated in_specs keep the computation identical.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)


def tree_flatten_with_path(tree):
    fn = getattr(jax.tree, "flatten_with_path", None)
    if fn is None:                                   # pragma: no cover
        fn = jax.tree_util.tree_flatten_with_path
    return fn(tree)
