"""Host-side RPC stub: serialize -> mmap copy -> doorbell -> reply."""
from __future__ import annotations

import time

from .transport import PCIeChannel, serialize, deserialize, check_reply


class RPCClient:
    def __init__(self, server, *, tx: PCIeChannel | None = None,
                 rx: PCIeChannel | None = None):
        self.server = server
        self.tx = tx or PCIeChannel()
        self.rx = rx or PCIeChannel()

    def call(self, method: str, **kwargs):
        t0 = time.perf_counter()
        packet = serialize({"method": method, "kwargs": kwargs})
        self.tx.stats.serialize_secs += time.perf_counter() - t0

        self.tx.push(packet)
        reply = self.server.handle(self.tx.pull())
        self.rx.push(reply)

        t0 = time.perf_counter()
        resp = deserialize(self.rx.pull())
        self.rx.stats.serialize_secs += time.perf_counter() - t0
        return check_reply(resp, f"RPC {method}")
