"""Host-side RPC stub: serialize -> mmap copy -> doorbell -> reply.

Both host-side stubs (this synchronous one and the multi-queue
``AsyncRPCClient``) share one error/stats contract: every reply decodes
through ``check_reply`` (typed device errors, shipped tracebacks) and
every call records into a per-method ``MethodStats`` rolling window — so
a local array endpoint and a RoP array endpoint report identically in
``stats``."""
from __future__ import annotations

import time

from .server import MethodStats
from .transport import PCIeChannel, serialize, deserialize, check_reply


class ClientStats:
    """Host-side per-method call accounting shared by every RPC stub."""

    def __init__(self):
        self.method_stats: dict[str, MethodStats] = {}

    def record(self, method: str, secs: float, ok: bool) -> None:
        self.method_stats.setdefault(method, MethodStats()) \
            .record(secs, ok)

    def stats_snapshot(self) -> dict:
        return {m: s.snapshot() for m, s in sorted(self.method_stats.items())}


class RPCClient:
    def __init__(self, server, *, tx: PCIeChannel | None = None,
                 rx: PCIeChannel | None = None):
        self.server = server
        self.tx = tx or PCIeChannel()
        self.rx = rx or PCIeChannel()
        self._stats = ClientStats()

    @property
    def method_stats(self) -> dict:
        return self._stats.method_stats

    def stats_snapshot(self) -> dict:
        return self._stats.stats_snapshot()

    def call(self, method: str, **kwargs):
        t_call = time.perf_counter()
        packet = serialize({"method": method, "kwargs": kwargs})
        self.tx.stats.serialize_secs += time.perf_counter() - t_call

        self.tx.push(packet)
        reply = self.server.handle(self.tx.pull())
        self.rx.push(reply)

        t0 = time.perf_counter()
        resp = deserialize(self.rx.pull())
        self.rx.stats.serialize_secs += time.perf_counter() - t0
        self._stats.record(method, time.perf_counter() - t_call,
                           bool(resp.get("ok")))
        return check_reply(resp, f"RPC {method}")
