"""RPC-over-PCIe (RoP) transport simulation — paper §3.3, Fig. 5.

The paper tunnels gRPC through PCIe: the host driver exposes a pre-allocated
memory-mapped buffer; a PCIe command (opcode, buffer address, length) is
written to the FPGA's BAR ("doorbell"), and the device copies the packet out
of the mmap'd buffer into FPGA-internal memory.

We model exactly those mechanics in-process:

  * ``serialize``/``deserialize`` — the gRPC-core packet layer: a JSON
    metadata header plus zero-copy-concatenated raw ndarray payloads;
  * ``PCIeChannel`` — a pre-allocated bytearray "mmap buffer" per direction;
    ``push`` memcpy's the packet in (host->mmap), ``pull`` memcpy's it out
    (mmap->device SRAM), both sides record byte counts and copy times so the
    RoP overhead is measurable (benchmarks/fig19 uses it).

The format is self-contained (no pickle) and versioned.
"""
from __future__ import annotations

import json
import struct
import time
from dataclasses import dataclass, field

import numpy as np

_MAGIC = b"RoP1"


def _encode(obj, buffers: list[np.ndarray]):
    if isinstance(obj, np.ndarray):
        # harden the payload path: force contiguity (sliced / transposed /
        # negative-stride views) and ship the dtype as an unambiguous
        # byte-order-explicit string — ``str(dtype)`` of a native array is
        # a NAME ('float32'), of a byte-swapped one a SPEC ('>f4'), and
        # only ``dtype.str`` round-trips both through ``np.dtype(...)``.
        buffers.append(np.ascontiguousarray(obj))
        b = buffers[-1]
        # shape comes from the ORIGINAL array: ascontiguousarray promotes
        # 0-d arrays to 1-d, which would silently change the decoded rank
        return {"__nd__": len(buffers) - 1, "dtype": b.dtype.str,
                "shape": list(obj.shape)}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, dict):
        return {k: _encode(v, buffers) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v, buffers) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if hasattr(obj, "__array__"):                    # jax arrays etc.
        return _encode(np.asarray(obj), buffers)
    raise TypeError(f"unserializable type {type(obj)}")


def _decode(obj, buffers: list[np.ndarray]):
    if isinstance(obj, dict):
        if "__nd__" in obj:
            arr = buffers[obj["__nd__"]]
            return arr.view(np.dtype(obj["dtype"])).reshape(obj["shape"])
        return {k: _decode(v, buffers) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v, buffers) for v in obj]
    return obj


def serialize(obj) -> bytes:
    buffers: list[np.ndarray] = []
    meta = json.dumps(_encode(obj, buffers)).encode()
    parts: list = [_MAGIC, struct.pack("<II", len(meta), len(buffers)), meta]
    for b in buffers:
        parts.append(struct.pack("<Q", b.nbytes))
        # zero-copy handoff: join() reads straight out of the array
        # buffer — tobytes() would copy every payload twice.  memoryview
        # cannot cast zero-length shapes, so empty payloads ship as b"".
        parts.append(memoryview(b).cast("B") if b.nbytes else b"")
    return b"".join(parts)


def deserialize(data: bytes):
    assert data[:4] == _MAGIC, "bad RoP packet"
    meta_len, n_buf = struct.unpack_from("<II", data, 4)
    off = 12
    meta = json.loads(data[off: off + meta_len].decode())
    off += meta_len
    buffers = []
    for _ in range(n_buf):
        (ln,) = struct.unpack_from("<Q", data, off)
        off += 8
        buffers.append(np.frombuffer(data, dtype=np.uint8, count=ln, offset=off))
        off += ln
    return _decode(meta, buffers)


def check_reply(resp: dict, label: str = "RPC"):
    """Decode a reply dict: return the result, or raise with the
    device-side error (and its formatted traceback, when shipped).
    Shared by every host-side stub so the error contract lives here.
    The raised error carries the raw device error string as
    ``remote_error`` so callers that must re-raise a typed exception
    (e.g. ``DeviceFailedError`` for the array failover path) can map it
    without parsing the formatted message."""
    if resp.get("ok"):
        return resp.get("result")
    msg = f"{label} failed: {resp.get('error')}"
    if resp.get("traceback"):
        msg += "\n--- device traceback ---\n" + resp["traceback"]
    err = RuntimeError(msg)
    err.remote_error = str(resp.get("error") or "")
    raise err


@dataclass
class ChannelStats:
    packets: int = 0
    bytes_moved: int = 0
    copy_secs: float = 0.0
    serialize_secs: float = 0.0


@dataclass
class PCIeChannel:
    """One direction of the RoP link: mmap buffer + doorbell."""
    buf_size: int = 64 << 20
    stats: ChannelStats = field(default_factory=ChannelStats)

    def __post_init__(self):
        self._buf = bytearray(self.buf_size)          # pre-allocated mmap buffer
        self._len = 0
        self._doorbell = False

    def push(self, packet: bytes) -> None:
        """Host writes the packet into the mmap buffer + rings the doorbell."""
        if len(packet) > self.buf_size:
            self._buf = bytearray(len(packet))        # driver re-mmaps bigger buf
            self.buf_size = len(packet)
        t0 = time.perf_counter()
        self._buf[: len(packet)] = packet             # memcpy #1 (host->mmap)
        self._len = len(packet)
        self.stats.copy_secs += time.perf_counter() - t0
        self.stats.packets += 1
        self.stats.bytes_moved += len(packet)
        self._doorbell = True

    def pull(self) -> bytes:
        """Device parses the PCIe command and copies mmap->internal memory."""
        assert self._doorbell, "doorbell not rung"
        t0 = time.perf_counter()
        # bytes(memoryview) is ONE memcpy; bytes(bytearray[:n]) would cut
        # an intermediate bytearray first and copy the payload twice
        out = bytes(memoryview(self._buf)[: self._len])
        self.stats.copy_secs += time.perf_counter() - t0
        self._doorbell = False
        return out
