"""Multi-queue RPC-over-PCIe transport — the paper's RoP link (§3.3, Fig. 5)
generalised from one synchronous doorbell to N submission/completion queue
pairs, NVMe-style, so many logical clients can have commands in flight
against one CSSD at once.

Mechanics modeled:

  * ``QueuePair`` — one host-visible SQ/CQ ring pair: a bounded submission
    ring (full ring == backpressure, ``QueueFullError``) and a completion
    table keyed by command id (completions may land out of order — the
    scheduler reorders requests freely).  Each pair has its own condition
    variable, so a completion wakes only that pair's waiters — with many
    concurrent clients a shared doorbell would thrash every thread on every
    completion;
  * ``MultiQueueRoP`` — the device side: round-robin arbitration across
    submission queues (one firmware poll loop serves every queue, parked on
    a counting doorbell) plus an in-flight command table (cmd_id -> queue,
    method, submit time) so queue depth and per-command age are observable
    at any moment;
  * ``AsyncRPCClient`` — the host-side stub for one queue pair: ``submit``
    returns immediately with a command id, ``result`` blocks on the matching
    completion.  ``call`` is the synchronous convenience wrapper.

Packets are the same self-contained RoP byte format as the single-doorbell
path (``transport.serialize``); only the queueing discipline differs.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass

from ..concurrency import witness_condition, witness_lock
from .transport import serialize, deserialize, check_reply


class QueueFullError(RuntimeError):
    """Submission ring is full — backpressure surfaced to the submitter.

    Carries ``qid``/``depth`` so retry layers can report WHICH ring
    pushed back instead of a bare string."""

    def __init__(self, msg: str, *, qid: int | None = None,
                 depth: int | None = None):
        super().__init__(msg)
        self.qid = qid
        self.depth = depth


class BackpressureError(RuntimeError):
    """Typed end-to-end backpressure: a bounded submit window or ring
    stayed full through the configured retry budget.

    Raised by the array coordinator's flow control (never by the rings
    themselves — those raise ``QueueFullError`` per attempt) so the
    serving scheduler can shed load with a REASON (``.reason``: source,
    shard, attempts, queue depths) instead of letting a transport error
    crash the request path.  "Overloaded" stays distinguishable from
    "degraded array"."""

    def __init__(self, msg: str, *, reason: dict | None = None):
        super().__init__(msg)
        self.reason = dict(reason or {})


@dataclass
class QueuePairStats:
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    bytes_tx: int = 0          # host -> device (submission packets)
    bytes_rx: int = 0          # device -> host (completion packets)


class QueuePair:
    """One SQ/CQ ring pair with its own doorbell (condition variable)."""

    def __init__(self, qid: int, depth: int):
        self.qid = qid
        self.depth = int(depth)
        self.cv = witness_condition(           # guards sq + cq of THIS pair
            "queues.cv", threading.Condition())
        self.sq: deque = deque()               # (cmd_id, packet)
        self.cq: dict[int, bytes] = {}         # cmd_id -> reply packet
        self.abandoned: set[int] = set()       # waiters that timed out
        self.stats = QueuePairStats()


class MultiQueueRoP:
    """N queue pairs + in-flight command tracking over one device."""

    def __init__(self, n_queues: int = 4, depth: int = 64):
        if n_queues < 1:
            raise ValueError("need at least one queue pair")
        self.pairs = [QueuePair(q, depth) for q in range(n_queues)]
        # device-side doorbell: counts commands sitting in any SQ
        self._work = witness_condition("queues._work",
                                       threading.Condition())
        self._sq_count = 0
        self._next_cmd = 1
        self.inflight: dict[int, dict] = {}    # cmd_id -> {qid, method, t}
        self._rr = 0                           # round-robin arbitration cursor

    # ------------------------------------------------------------- host side
    def submit(self, qid: int, packet: bytes, *, method: str = "?") -> int:
        """Write a command into SQ ``qid``; returns its command id.

        Raises ``QueueFullError`` when the ring is full — the transport-level
        backpressure the serving scheduler's admission control builds on.
        """
        pair = self.pairs[qid]
        with pair.cv:
            if len(pair.sq) >= pair.depth:
                pair.stats.rejected += 1
                raise QueueFullError(
                    f"submission queue {qid} full (depth {pair.depth})")
            # in-flight registration + doorbell must precede SQ visibility:
            # a consumer already scanning may pop the command the instant it
            # appears, and its completion must find the tracking entry
            with self._work:
                cmd_id = self._next_cmd
                self._next_cmd += 1
                self.inflight[cmd_id] = {"qid": qid, "method": method,
                                         "t_submit": time.perf_counter()}
                self._sq_count += 1
                self._work.notify()
            pair.sq.append((cmd_id, packet))
            pair.stats.submitted += 1
            pair.stats.bytes_tx += len(packet)
        return cmd_id

    def wait_completion(self, qid: int, cmd_id: int, *,
                        timeout: float | None = None) -> bytes:
        """Block until command ``cmd_id`` completes on CQ ``qid``."""
        end = None if timeout is None else time.monotonic() + timeout
        pair = self.pairs[qid]
        with pair.cv:
            while cmd_id not in pair.cq:
                rem = None if end is None else end - time.monotonic()
                if rem is not None and rem <= 0:
                    # mark abandoned so the eventual completion is dropped
                    # instead of sitting in the CQ forever
                    pair.abandoned.add(cmd_id)
                    raise TimeoutError(f"command {cmd_id} not completed "
                                       f"within {timeout}s")
                pair.cv.wait(rem)
            return pair.cq.pop(cmd_id)

    def poll_completion(self, qid: int, cmd_id: int) -> bytes | None:
        """Non-blocking completion check (None while still in flight)."""
        pair = self.pairs[qid]
        with pair.cv:
            return pair.cq.pop(cmd_id, None)

    # ----------------------------------------------------------- device side
    def pop_submission(self, *, timeout: float | None = None):
        """Round-robin pop one command across every SQ (device poll loop).

        Returns ``(qid, cmd_id, packet)`` or None on timeout (``timeout=0``
        is a pure non-blocking poll).
        """
        end = None if timeout is None else time.monotonic() + timeout
        with self._work:
            while self._sq_count == 0:
                rem = None if end is None else end - time.monotonic()
                if rem is not None and rem <= 0:
                    return None
                self._work.wait(rem)
            self._sq_count -= 1       # one queued command is now reserved
        # a command is guaranteed present in some SQ (appends precede the
        # doorbell increment); scan from the arbitration cursor
        while True:
            n = len(self.pairs)
            for k in range(n):
                pair = self.pairs[(self._rr + k) % n]
                with pair.cv:
                    if pair.sq:
                        self._rr = (self._rr + k + 1) % n
                        cmd_id, packet = pair.sq.popleft()
                        return pair.qid, cmd_id, packet

    def post_completion(self, qid: int, cmd_id: int, packet: bytes) -> None:
        pair = self.pairs[qid]
        with pair.cv:
            pair.stats.completed += 1
            pair.stats.bytes_rx += len(packet)
            if cmd_id in pair.abandoned:       # waiter gave up: drop reply
                pair.abandoned.discard(cmd_id)
            else:
                pair.cq[cmd_id] = packet
                pair.cv.notify_all()  # wakes only this pair's waiters
        with self._work:
            self.inflight.pop(cmd_id, None)

    # -------------------------------------------------------------- telemetry
    @property
    def depth_in_flight(self) -> int:
        with self._work:
            return len(self.inflight)

    def stats_snapshot(self) -> dict:
        with self._work:
            now = time.perf_counter()
            oldest = max((now - c["t_submit"]
                          for c in self.inflight.values()), default=0.0)
            in_flight = len(self.inflight)
        return {
            "n_queues": len(self.pairs),
            "in_flight": in_flight,
            "oldest_in_flight_s": oldest,
            "queues": [{"qid": p.qid, "sq_depth": len(p.sq),
                        "submitted": p.stats.submitted,
                        "completed": p.stats.completed,
                        "rejected": p.stats.rejected,
                        "bytes_tx": p.stats.bytes_tx,
                        "bytes_rx": p.stats.bytes_rx}
                       for p in self.pairs],
        }


class AsyncRPCClient:
    """Host-side stub bound to one queue pair: submit many, reap any order.

    Shares the synchronous stub's error/stats contract (``check_reply`` +
    per-method ``MethodStats``), so whichever transport a shard endpoint
    uses, its host-side accounting looks the same.  An optional
    ``PCIeChannel`` pair models the RoP mmap-buffer copies per direction
    (byte/copy counters for the multi-host benchmarks); the channels are
    guarded by a client-local lock so several coordinator threads may
    share one stub.
    """

    def __init__(self, rop: MultiQueueRoP, qid: int, *, tx=None, rx=None):
        from .client import ClientStats           # shared accounting
        self.rop = rop
        self.qid = int(qid)
        self.tx = tx                              # host -> device channel
        self.rx = rx                              # device -> host channel
        self._stats = ClientStats()
        self._pending: dict[int, tuple[str, float]] = {}
        self._lock = witness_lock("rpcclient._lock", threading.Lock())

    @property
    def method_stats(self) -> dict:
        return self._stats.method_stats

    def stats_snapshot(self) -> dict:
        return self._stats.stats_snapshot()

    def submit(self, method: str, **kwargs) -> int:
        t0 = time.perf_counter()
        packet = serialize({"method": method, "kwargs": kwargs})
        if self.tx is not None:
            with self._lock:
                self.tx.stats.serialize_secs += time.perf_counter() - t0
                self.tx.push(packet)              # memcpy host -> mmap
                packet = self.tx.pull()           # memcpy mmap -> device
        cmd_id = self.rop.submit(self.qid, packet, method=method)
        with self._lock:
            self._pending[cmd_id] = (method, t0)
        return cmd_id

    def result(self, cmd_id: int, *, timeout: float | None = None):
        try:
            reply = self.rop.wait_completion(self.qid, cmd_id,
                                             timeout=timeout)
        except TimeoutError:
            # the ring marks the command abandoned (its completion will be
            # dropped); the host-side pending entry must go too, or
            # sustained timeouts grow it without bound
            with self._lock:
                method, t0 = self._pending.pop(cmd_id,
                                               ("?", time.perf_counter()))
            self._stats.record(method, time.perf_counter() - t0, False)
            raise
        with self._lock:
            method, t0 = self._pending.pop(cmd_id, ("?", time.perf_counter()))
            if self.rx is not None:
                self.rx.push(reply)               # memcpy device -> mmap
                reply = self.rx.pull()            # memcpy mmap -> host
        resp = deserialize(reply)
        self._stats.record(method, time.perf_counter() - t0,
                           bool(resp.get("ok")))
        return check_reply(resp, f"RPC {method}")

    def call(self, method: str, *, timeout: float | None = None, **kwargs):
        """Synchronous convenience: submit + wait."""
        return self.result(self.submit(method, **kwargs), timeout=timeout)
