"""CSSD-side RPC dispatcher: deserializes RoP packets, invokes service
handlers (Table 1), serializes the reply."""
from __future__ import annotations

import time

from .transport import serialize, deserialize


class RPCServer:
    def __init__(self, service):
        self.service = service
        self.call_log: list[tuple[str, float]] = []

    def handle(self, packet: bytes) -> bytes:
        req = deserialize(packet)
        method = req["method"]
        kwargs = req.get("kwargs", {})
        t0 = time.perf_counter()
        fn = getattr(self.service, method, None)
        if fn is None:
            resp = {"ok": False, "error": f"no such RPC {method!r}"}
        else:
            try:
                resp = {"ok": True, "result": fn(**kwargs)}
            except Exception as e:  # noqa: BLE001 — fault surfaced to client
                resp = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        self.call_log.append((method, time.perf_counter() - t0))
        return serialize(resp)
