"""CSSD-side RPC dispatcher: deserializes RoP packets, invokes service
handlers (Table 1), serializes the reply.

Only ``type: message`` data crosses RoP, so device-side faults ship a
formatted traceback string in the error reply (debuggability of
scheduler-side failures), and per-method accounting is a bounded rolling
window (``MethodStats``) instead of an unbounded log — sustained serving
traffic must not grow device memory.  The rolling stats are surfaced to
hosts through the ``stats`` RPC (injected into the service's reply dict).
"""
from __future__ import annotations

import time
import traceback
from collections import deque
from dataclasses import dataclass, field

from .transport import serialize, deserialize

_RECENT_WINDOW = 128            # per-method rolling sample count


@dataclass
class MethodStats:
    """Bounded per-method call accounting: totals + a recent-window sample."""
    calls: int = 0
    errors: int = 0
    total_s: float = 0.0
    recent_s: deque = field(
        default_factory=lambda: deque(maxlen=_RECENT_WINDOW))

    def record(self, secs: float, ok: bool) -> None:
        self.calls += 1
        self.total_s += secs
        self.recent_s.append(secs)
        if not ok:
            self.errors += 1

    def snapshot(self) -> dict:
        rec = list(self.recent_s)
        return {"calls": self.calls, "errors": self.errors,
                "total_s": self.total_s,
                "recent_n": len(rec),
                "recent_mean_s": sum(rec) / len(rec) if rec else 0.0,
                "recent_max_s": max(rec) if rec else 0.0}


class RPCServer:
    def __init__(self, service):
        self.service = service
        self.method_stats: dict[str, MethodStats] = {}

    def handle(self, packet: bytes) -> bytes:
        req = deserialize(packet)
        return serialize(self.dispatch(req["method"], req.get("kwargs", {})))

    def dispatch(self, method: str, kwargs: dict) -> dict:
        """Invoke a handler and build the reply dict.

        Shared with the serving runtime, which routes ``run`` commands into
        the continuous batcher instead but uses this path for everything
        else (mutations, unit queries, stats).
        """
        t0 = time.perf_counter()
        fn = getattr(self.service, method, None)
        if fn is None:
            resp = {"ok": False, "error": f"no such RPC {method!r}"}
        else:
            try:
                resp = {"ok": True, "result": fn(**kwargs)}
            except Exception as e:  # noqa: BLE001 — fault surfaced to client
                resp = {"ok": False, "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()}
                # typed shedding (BackpressureError / AdmissionError)
                # carries its reason dict to the client, so callers can
                # tell overload from fault without parsing the message
                reason = getattr(e, "reason", None)
                if isinstance(reason, dict):
                    resp["reason"] = dict(reason)
        self.method_stats.setdefault(method, MethodStats()) \
            .record(time.perf_counter() - t0, resp["ok"])
        if method == "stats" and resp["ok"] and isinstance(resp["result"], dict):
            resp["result"]["rpc"] = self.stats_snapshot()
        return resp

    def stats_snapshot(self) -> dict:
        return {m: s.snapshot() for m, s in sorted(self.method_stats.items())}
