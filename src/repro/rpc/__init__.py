from .transport import PCIeChannel, serialize, deserialize
from .server import RPCServer, MethodStats
from .client import RPCClient
from .queues import (MultiQueueRoP, QueuePair, AsyncRPCClient,
                     QueueFullError, BackpressureError)

__all__ = ["PCIeChannel", "serialize", "deserialize", "RPCServer",
           "MethodStats", "RPCClient", "MultiQueueRoP", "QueuePair",
           "AsyncRPCClient", "QueueFullError", "BackpressureError"]
