from .transport import PCIeChannel, serialize, deserialize
from .server import RPCServer
from .client import RPCClient

__all__ = ["PCIeChannel", "serialize", "deserialize", "RPCServer", "RPCClient"]
