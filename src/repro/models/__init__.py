from . import layers, transformer, mamba, xlstm, encdec, vlm
from .api import ModelAPI, build

__all__ = ["layers", "transformer", "mamba", "xlstm", "encdec", "vlm",
           "ModelAPI", "build"]
