"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with recurrent weights) in pure JAX.

Both blocks carry O(1)-size recurrent state, so xlstm-125m qualifies for
``long_500k`` decode.  Training runs ``lax.scan`` over time with exp-gating
stabilizer state m (the paper's numerically-stabilized formulation).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .layers import (ParamDef, norm_def, rms_norm, shard, DP, _div,
                     active_tp)


# ===================================================================== mLSTM
def mlstm_defs(cfg, tp: int):
    d = cfg.d_model
    di = 2 * d                                   # projected block dim
    nh = cfg.num_heads
    di_ax = "model" if _div(di, tp) else None
    return {
        "up_proj": ParamDef((d, 2 * di), (None, di_ax)),
        "wq": ParamDef((di, di), (None, di_ax)),
        "wk": ParamDef((di, di), (None, di_ax)),
        "wv": ParamDef((di, di), (None, di_ax)),
        "wi": ParamDef((di, nh), (None, None)),
        "wf": ParamDef((di, nh), (None, None)),
        "down_proj": ParamDef((di, d), (di_ax, None)),
        "ln": norm_def(d),
    }


def _mlstm_scan(q, k, v, i_g, f_g, nh):
    """q/k/v (B,T,NH,hd); i_g/f_g (B,T,NH) pre-activation gates."""
    b, t, _, hd = q.shape

    def step(carry, inp):
        C, n, m = carry                                # (B,NH,hd,hd) ...
        qt, kt, vt, it, ft = inp
        m_new = jnp.maximum(ft + m, it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + m - m_new)
        C = f[..., None, None] * C + i[..., None, None] \
            * (vt[..., :, None] * kt[..., None, :])    # v k^T
        n = f[..., None] * n + i[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    init = (jnp.zeros((b, nh, hd, hd), jnp.float32),
            jnp.zeros((b, nh, hd), jnp.float32),
            jnp.full((b, nh), -1e30, jnp.float32))
    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (q, k, v, i_g, f_g))
    carry, hs = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(hs, 0, 1), carry               # (B,T,NH,hd), state


def mlstm_apply(p, x, cfg, *, cache=None, cache_len=None):
    b, t, d = x.shape
    di = 2 * d
    nh = cfg.num_heads
    hd = di // nh
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    uz = jnp.einsum("btd,de->bte", xn, p["up_proj"].astype(xn.dtype))
    u, z = uz[..., :di], uz[..., di:]
    q = jnp.einsum("bte,ef->btf", u, p["wq"].astype(u.dtype)).reshape(b, t, nh, hd)
    k = jnp.einsum("bte,ef->btf", u, p["wk"].astype(u.dtype)).reshape(b, t, nh, hd)
    k = k / np.sqrt(hd)
    v = jnp.einsum("bte,ef->btf", u, p["wv"].astype(u.dtype)).reshape(b, t, nh, hd)
    i_g = jnp.einsum("bte,eh->bth", u, p["wi"].astype(u.dtype))
    f_g = jnp.einsum("bte,eh->bth", u, p["wf"].astype(u.dtype))

    if t > 1 or cache is None:
        h, state = _mlstm_scan(q, k, v, i_g, f_g, nh)
        new_cache = None
        if cache is not None:
            new_cache = {"C": state[0], "n": state[1], "m": state[2]}
    else:
        assert t == 1
        C, n, m = cache["C"], cache["n"], cache["m"]
        qt, kt, vt = (a[:, 0].astype(jnp.float32) for a in (q, k, v))
        it, ft = i_g[:, 0].astype(jnp.float32), f_g[:, 0].astype(jnp.float32)
        m_new = jnp.maximum(ft + m, it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(ft + m - m_new)
        C = f[..., None, None] * C + i[..., None, None] \
            * (vt[..., :, None] * kt[..., None, :])
        n = f[..., None] * n + i[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt)), 1.0)
        h = (num / den[..., None])[:, None]
        new_cache = {"C": C, "n": n, "m": m_new}

    h = h.astype(x.dtype).reshape(b, t, di)
    y = h * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["down_proj"].astype(y.dtype))
    return x + shard(out, DP, None, None), new_cache


def mlstm_cache_defs(cfg, batch: int, *, tp: int):
    di = 2 * cfg.d_model
    nh = cfg.num_heads
    hd = di // nh
    return {"C": ParamDef((batch, nh, hd, hd), (DP, None, None, None),
                          init="zeros", dtype="float32"),
            "n": ParamDef((batch, nh, hd), (DP, None, None), init="zeros",
                          dtype="float32"),
            "m": ParamDef((batch, nh), (DP, None), init="zeros",
                          dtype="float32")}


# ===================================================================== sLSTM
def slstm_defs(cfg, tp: int):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    return {
        "wz": ParamDef((d, d), (None, None)),
        "wi": ParamDef((d, d), (None, None)),
        "wf": ParamDef((d, d), (None, None)),
        "wo": ParamDef((d, d), (None, None)),
        "rz": ParamDef((nh, hd, hd), (None, None, None)),
        "ri": ParamDef((nh, hd, hd), (None, None, None)),
        "rf": ParamDef((nh, hd, hd), (None, None, None)),
        "ro": ParamDef((nh, hd, hd), (None, None, None)),
        "up_proj": ParamDef((d, 2 * d), (None, None)),
        "down_proj": ParamDef((d, d), (None, None)),
        "ln": norm_def(d),
    }


def _slstm_cell(p, xt, carry, nh, hd):
    """One sLSTM step.  xt (B,d) fp32; carry = (c,h,n,m) each (B,NH,hd)/(B,NH)."""
    c, h, n, m = carry
    hr = h.reshape(h.shape[0], nh, hd)

    def rec(w, r):
        return (xt @ w).reshape(-1, nh, hd) + jnp.einsum(
            "bhj,hij->bhi", hr, r)

    z = jnp.tanh(rec(p["wz"], p["rz"]))
    i_t = rec(p["wi"], p["ri"])
    f_t = rec(p["wf"], p["rf"])
    o = jax.nn.sigmoid(rec(p["wo"], p["ro"]))
    m_new = jnp.maximum(f_t + m, i_t)          # per-unit exp-gating stabilizer
    i = jnp.exp(i_t - m_new)
    f = jnp.exp(f_t + m - m_new)
    c = f * c + i * z
    n = jnp.maximum(f * n + i, 1e-6)
    h_new = o * (c / n)
    return (c, h_new.reshape(h.shape[0], -1), n, m_new)


def slstm_apply(p, x, cfg, *, cache=None, cache_len=None):
    b, t, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    xn = rms_norm(x, p["ln"], cfg.norm_eps).astype(jnp.float32)
    pf = {k: v.astype(jnp.float32) for k, v in p.items()
          if k in ("wz", "wi", "wf", "wo", "rz", "ri", "rf", "ro")}

    if t > 1 or cache is None:
        init = (jnp.zeros((b, nh, hd), jnp.float32),
                jnp.zeros((b, d), jnp.float32),
                jnp.full((b, nh, hd), 1e-6, jnp.float32),
                jnp.full((b, nh, hd), -1e30, jnp.float32))

        def step(carry, xt):
            new = _slstm_cell(pf, xt, carry, nh, hd)
            return new, new[1]

        carry, hs = jax.lax.scan(step, init, jnp.moveaxis(xn, 1, 0))
        h = jnp.moveaxis(hs, 0, 1)                        # (B,T,d)
        new_cache = None
        if cache is not None:
            new_cache = {"c": carry[0], "h": carry[1], "n": carry[2],
                         "m": carry[3]}
    else:
        assert t == 1
        carry = (cache["c"], cache["h"], cache["n"], cache["m"])
        carry = _slstm_cell(pf, xn[:, 0], carry, nh, hd)
        h = carry[1][:, None]
        new_cache = {"c": carry[0], "h": carry[1], "n": carry[2],
                     "m": carry[3]}

    h = h.astype(x.dtype)
    uz = jnp.einsum("btd,de->bte", h, p["up_proj"].astype(h.dtype))
    u, z = uz[..., :d], uz[..., d:]
    y = jnp.einsum("btd,de->bte", u * jax.nn.silu(z),
                   p["down_proj"].astype(h.dtype))
    return x + shard(y, DP, None, None), new_cache


def slstm_cache_defs(cfg, batch: int, *, tp: int):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    return {"c": ParamDef((batch, nh, hd), (DP, None, None), init="zeros",
                          dtype="float32"),
            "h": ParamDef((batch, d), (DP, None), init="zeros",
                          dtype="float32"),
            "n": ParamDef((batch, nh, hd), (DP, None, None), init="zeros",
                          dtype="float32"),
            "m": ParamDef((batch, nh, hd), (DP, None, None), init="zeros",
                          dtype="float32")}
