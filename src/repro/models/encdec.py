"""Encoder-decoder backbone (seamless-m4t): bidirectional encoder over
precomputed frame embeddings (the stubbed speech frontend), causal decoder
with per-layer cross-attention.  Both stacks are period-1 scans.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import chunked_xent


def encdec_param_defs(cfg, tp: int):
    enc_layer = {"attn": L.attn_defs(cfg, tp), "mlp": L.mlp_defs(cfg, tp)}
    dec_layer = {"self": L.attn_defs(cfg, tp),
                 "cross": L.attn_defs(cfg, tp),
                 "mlp": L.mlp_defs(cfg, tp)}
    return {
        "embed": L.embed_defs(cfg, tp),
        "enc": L.stack_defs(enc_layer, cfg.enc_layers),
        "enc_ln": L.norm_def(cfg.d_model),
        "dec": L.stack_defs(dec_layer, cfg.num_layers),
    }


def _cross_kv(p_cross, enc_out, cfg):
    """Per-layer projected encoder KV (B,S,KVH,hd)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["wv"].astype(enc_out.dtype))
    return k, v


def encode(params, cfg, frames, *, remat=False):
    """frames (B,S,D) -> encoder output (B,S,D)."""
    x = L.shard(frames.astype(jnp.dtype(cfg.dtype)), L.DP, None, None)

    def body(x, p):
        x, _ = L.attn_apply(p["attn"], x, cfg, causal=False)
        x = L.mlp_apply(p["mlp"], x, cfg)
        return x, None

    b = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(b, x, params["enc"])
    return L.rms_norm(x, params["enc_ln"], cfg.norm_eps)


def decode_stack(params, cfg, x, enc_out, *, caches=None, cache_len=None,
                 positions=None, enc_len=None, remat=False):
    """Decoder over x (B,T,D); caches = {"self": stacked attn caches}."""
    def body(carry, xs):
        x = carry
        if caches is not None:
            p, c = xs
        else:
            p, c = xs, None
        x, nc = L.attn_apply(p["self"], x, cfg, causal=True,
                             positions=positions, cache=c,
                             cache_len=cache_len)
        kv = _cross_kv(p["cross"], enc_out, cfg)
        x, _ = L.attn_apply(p["cross"], x, cfg, kv_override=kv,
                            kv_len=enc_len)
        x = L.mlp_apply(p["mlp"], x, cfg)
        return x, nc

    b = jax.checkpoint(body) if remat else body
    xs = params["dec"] if caches is None else (params["dec"], caches["self"])
    x, new_c = jax.lax.scan(b, x, xs)
    return x, (None if caches is None else {"self": new_c})


def encdec_train_loss(params, cfg, frames, tokens, labels):
    enc_out = encode(params, cfg, frames, remat=(cfg.remat == "full"))
    x = L.embed_apply(params["embed"], tokens, cfg)
    x, _ = decode_stack(params, cfg, x, enc_out,
                        remat=(cfg.remat == "full"))
    mask = (labels >= 0).astype(jnp.float32)
    loss = chunked_xent(params, cfg, x, jnp.maximum(labels, 0), mask)
    return loss, {"xent": loss}


def encdec_prefill(params, cfg, frames, tokens, caches):
    enc_out = encode(params, cfg, frames)
    x = L.embed_apply(params["embed"], tokens, cfg)
    x, caches = decode_stack(params, cfg, x, enc_out, caches=caches,
                             cache_len=jnp.zeros((), jnp.int32))
    return L.logits_apply(params["embed"], x[:, -1:], cfg), caches, enc_out


def encdec_decode(params, cfg, tokens, caches, lengths, enc_out):
    x = L.embed_apply(params["embed"], tokens, cfg)
    positions = lengths[:, None] + jnp.arange(tokens.shape[1])[None, :]
    x, caches = decode_stack(params, cfg, x, enc_out, caches=caches,
                             cache_len=lengths, positions=positions)
    return L.logits_apply(params["embed"], x, cfg), caches


def encdec_cache_defs(cfg, batch: int, seq: int, *, tp: int,
                      long_mode: bool = False):
    return {"self": L.stack_defs(
        L.attn_cache_defs(cfg, batch, seq, tp=tp, long_mode=long_mode),
        cfg.num_layers)}
