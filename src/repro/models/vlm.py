"""VLM backbone (internvl2): precomputed patch embeddings (stubbed InternViT
frontend) prepended to the text embedding sequence, then the standard
decoder stack.  Loss is computed on text positions only."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import decoder_forward, chunked_xent


def vlm_train_loss(params, cfg, patches, tokens, labels):
    """patches (B,P,D) float; tokens/labels (B,T_text)."""
    xt = L.embed_apply(params["embed"], tokens, cfg)
    x = jnp.concatenate([patches.astype(xt.dtype), xt], axis=1)
    x, _, aux = decoder_forward(params, cfg, x, remat=(cfg.remat == "full"))
    x_text = x[:, patches.shape[1]:]
    mask = (labels >= 0).astype(jnp.float32)
    loss = chunked_xent(params, cfg, x_text, jnp.maximum(labels, 0), mask)
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


def vlm_prefill(params, cfg, patches, tokens, caches):
    xt = L.embed_apply(params["embed"], tokens, cfg)
    x = jnp.concatenate([patches.astype(xt.dtype), xt], axis=1)
    x, caches, _ = decoder_forward(params, cfg, x, caches=caches,
                                   cache_len=jnp.zeros((), jnp.int32))
    return L.logits_apply(params["embed"], x[:, -1:], cfg), caches
