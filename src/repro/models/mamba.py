"""Mamba (S6) mixer for the Jamba hybrid — selective SSM in pure JAX.

Train/prefill runs the selective scan with ``lax.scan`` over time (constant
HLO size; on a real TPU the chunked SSD formulation would be a Pallas
kernel — noted as a beyond-paper optimization).  Decode is a single-step
state update carrying (conv window, SSM state) — O(1) in sequence length,
which is why Jamba qualifies for ``long_500k``.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .layers import (ParamDef, norm_def, rms_norm, shard, DP, _div,
                     active_tp)


def mamba_defs(cfg, tp: int):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    di_ax = "model" if _div(di, tp) else None
    return {
        "in_proj": ParamDef((d, 2 * di), (None, di_ax)),
        "conv_w": ParamDef((s.d_conv, di), (None, di_ax)),
        "conv_b": ParamDef((di,), (di_ax,), init="zeros"),
        "x_proj": ParamDef((di, dtr + 2 * s.d_state), (di_ax, None)),
        "dt_proj": ParamDef((dtr, di), (None, di_ax)),
        "dt_bias": ParamDef((di,), (di_ax,), init="zeros"),
        "A_log": ParamDef((di, s.d_state), (di_ax, None), init="ones"),
        "D": ParamDef((di,), (di_ax,), init="ones"),
        "out_proj": ParamDef((di, d), (di_ax, None)),
        "ln": norm_def(d),
    }


def _split_xdbc(xdb, dtr, n):
    return xdb[..., :dtr], xdb[..., dtr:dtr + n], xdb[..., dtr + n:]


def _conv_step(window, w, b):
    """window (B, d_conv, di) -> conv output at the last position."""
    return jnp.einsum("bcd,cd->bd", window, w) + b


def mamba_apply(p, x, cfg, *, cache=None, cache_len=None):
    """x (B,T,D) -> (y, new_cache).  cache = {"conv": (B,dc-1,di),
    "ssm": (B,di,N)}; train/prefill pass cache=None."""
    s = cfg.ssm
    b, t, d = x.shape
    di = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    n = s.d_state
    di_ax = "model" if _div(di, active_tp()) else None

    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    xz = jnp.einsum("btd,de->bte", xn, p["in_proj"].astype(xn.dtype))
    xz = shard(xz, DP, None, di_ax)
    xin, z = xz[..., :di], xz[..., di:]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (di, N)

    if t > 1 or cache is None:
        # train / prefill: causal depthwise conv over T + selective scan
        pad = jnp.zeros((b, s.d_conv - 1, di), xin.dtype)
        xp = jnp.concatenate([pad, xin], axis=1)
        xc = sum(xp[:, i: i + t, :] * p["conv_w"][i].astype(xin.dtype)
                 for i in range(s.d_conv)) + p["conv_b"].astype(xin.dtype)
        xc = jax.nn.silu(xc)
        xdb = jnp.einsum("btd,de->bte", xc, p["x_proj"].astype(xc.dtype))
        dt_r, b_ssm, c_ssm = _split_xdbc(xdb, dtr, n)
        dt = jax.nn.softplus(
            jnp.einsum("btr,rd->btd", dt_r, p["dt_proj"].astype(dt_r.dtype))
            + p["dt_bias"].astype(dt_r.dtype)).astype(jnp.float32)

        # selective scan.  §Perf iteration 6 tried Q=8 chunk-unrolling to
        # keep the state out of HBM between steps: REFUTED on this backend
        # (t_mem 16.5->21.3 s on jamba train — XLA does not fuse the
        # unrolled chain; compile 4x slower).  The real lever is a Pallas
        # kernel with VMEM-resident state (DESIGN.md §9); Q=1 is the
        # measured best XLA-level schedule.
        Q = 1

        def step_chunk(h, inp):
            dt_c, b_c, c_c, x_c = inp                       # (Q,B,...)
            ys = []
            for q in range(Q):
                decay = jnp.exp(dt_c[q][..., None] * A)     # (B,di,N)
                h = h * decay + (dt_c[q] * x_c[q])[..., None] \
                    * b_c[q][:, None, :]
                ys.append(jnp.einsum("bdn,bn->bd", h, c_c[q]))
            return h, jnp.stack(ys)

        def to_chunks(a):
            a = jnp.moveaxis(a.astype(jnp.float32), 1, 0)   # (T,B,...)
            return a.reshape((t // Q, Q) + a.shape[1:])

        h0 = jnp.zeros((b, di, n), jnp.float32)
        xs = (to_chunks(dt), to_chunks(b_ssm), to_chunks(c_ssm),
              to_chunks(xc))
        h_last, ys = jax.lax.scan(step_chunk, h0, xs)
        y = jnp.moveaxis(ys.reshape(t, b, di), 0, 1).astype(x.dtype)
        y = y + xc * p["D"].astype(xc.dtype)
        new_cache = None
        if cache is not None:                               # prefill fills cache
            conv_tail = xp[:, -(s.d_conv - 1):, :]
            new_cache = {"conv": conv_tail.astype(cache["conv"].dtype),
                         "ssm": h_last.astype(cache["ssm"].dtype)}
    else:
        assert t == 1
        window = jnp.concatenate([cache["conv"].astype(xin.dtype), xin], axis=1)
        xc = jax.nn.silu(_conv_step(window, p["conv_w"].astype(xin.dtype),
                                    p["conv_b"].astype(xin.dtype)))  # (B,di)
        xdb = jnp.einsum("bd,de->be", xc, p["x_proj"].astype(xc.dtype))
        dt_r, b_ssm, c_ssm = _split_xdbc(xdb, dtr, n)
        dt = jax.nn.softplus(
            jnp.einsum("br,rd->bd", dt_r, p["dt_proj"].astype(dt_r.dtype))
            + p["dt_bias"].astype(dt_r.dtype)).astype(jnp.float32)
        h = cache["ssm"]
        decay = jnp.exp(dt[..., None] * A)
        h = h * decay + (dt * xc.astype(jnp.float32))[..., None] \
            * b_ssm.astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_ssm.astype(jnp.float32))
        y = (y.astype(x.dtype) + xc * p["D"].astype(xc.dtype))[:, None, :]
        new_cache = {"conv": window[:, 1:, :].astype(x.dtype), "ssm": h}

    y = y * jax.nn.silu(z)
    out = jnp.einsum("btd,de->bte", y, p["out_proj"].astype(y.dtype))
    return x + shard(out, DP, None, None), new_cache


def mamba_cache_defs(cfg, batch: int, *, tp: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    di_ax = "model" if _div(di, tp) else None
    return {"conv": ParamDef((batch, s.d_conv - 1, di), (DP, None, di_ax),
                             init="zeros", dtype=cfg.dtype),
            "ssm": ParamDef((batch, di, s.d_state), (DP, di_ax, None),
                            init="zeros", dtype="float32")}
