"""Model substrate: ParamDef-driven parameters, sharding helpers, and the
attention / MLP / MoE building blocks shared by every architecture.

Parameters are declared as ``ParamDef`` trees; from one declaration we derive
(a) initialized arrays, (b) ShapeDtypeStruct stand-ins for the dry-run (no
allocation), and (c) PartitionSpecs for pjit — so the three can never drift.

Tensor-parallel rules (model axis ``tp`` ways):
  * attention heads sharded over "model" iff divisible, else replicated
    (GSPMD needs divisible input shardings; noted per arch in DESIGN.md);
  * KV heads likewise (GQA usually replicates KV under TP);
  * d_ff always sharded (all assigned archs are 16-divisible);
  * vocab sharded over "model" iff divisible, else the embedding is sharded
    on d_model (row-parallel logits with one psum);
  * MoE experts sharded over "model" (16 experts / 16-way TP).
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass, field, replace

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from .. import compat

# ---------------------------------------------------------------- mesh state
# DP is a sentinel resolved to the data-parallel axes of the active mesh;
# DPM additionally folds in the model axis (long-context cache sharding)
DP = "__dp__"
DPM = "__dp_model__"

_ACTIVE = {"mesh": None, "dp_axes": ("data",), "tp": 1}


@contextlib.contextmanager
def use_mesh(mesh, dp_axes=("data",)):
    prev = dict(_ACTIVE)
    _ACTIVE["mesh"] = mesh
    _ACTIVE["dp_axes"] = tuple(dp_axes)
    _ACTIVE["tp"] = int(mesh.shape.get("model", 1)) if mesh is not None else 1
    try:
        yield
    finally:
        _ACTIVE.update(prev)


def active_tp() -> int:
    return _ACTIVE["tp"]


def active_dp() -> int:
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return 1
    out = 1
    for a in _ACTIVE["dp_axes"]:
        out *= int(mesh.shape.get(a, 1))
    return out


def resolve_pspec(spec) -> P:
    out = []
    for s in spec:
        if s == DP:
            out.append(_ACTIVE["dp_axes"])
        elif s == DPM:
            out.append(tuple(_ACTIVE["dp_axes"]) + ("model",))
        else:
            out.append(s)
    return P(*out)


def shard(x, *spec):
    """with_sharding_constraint that no-ops off-mesh (smoke tests)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_pspec(spec)))


# ----------------------------------------------------------------- ParamDef
@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    pspec: tuple = ()
    init: str = "normal"          # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "float32"

    def materialize(self, key):
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        return (jax.random.normal(key, self.shape, jnp.float32)
                * self.scale).astype(self.dtype)


def is_def(x):
    return isinstance(x, ParamDef)


def init_tree(defs, seed: int = 0):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(jax.random.PRNGKey(seed), max(len(leaves), 1))
    return jax.tree.unflatten(
        treedef, [d.materialize(k) for d, k in zip(leaves, keys)])


def abstract_tree(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs, is_leaf=is_def)


def pspec_tree(defs):
    return jax.tree.map(lambda d: resolve_pspec(d.pspec), defs, is_leaf=is_def)


def stack_defs(defs, n: int):
    """Prepend a layer-stack dimension (for lax.scan over periods)."""
    return jax.tree.map(
        lambda d: replace(d, shape=(n,) + tuple(d.shape),
                          pspec=(None,) + tuple(d.pspec)),
        defs, is_leaf=is_def)


def _div(n: int, tp: int) -> bool:
    return tp > 0 and n % tp == 0


# ------------------------------------------------------------------ norms
def rms_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(ms + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def norm_def(d):
    return ParamDef((d,), (None,), init="ones")


# ------------------------------------------------------------------- rope
def rope_tables(positions, dim: int, theta: float):
    """positions (...,) int -> (..., dim/2) cos/sin tables."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., T, H, D); cos/sin = positions-shaped + (D/2,): (T,D/2) or
    (B,T,D/2).  One head axis is inserted; leading dims broadcast."""
    half = x.shape[-1] // 2
    cos = cos[..., None, :]                        # (..., T, 1, D/2)
    sin = sin[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _act(name):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu,
            approximate=True), "relu": jax.nn.relu}[name]


# ================================================================ attention
def padded_heads(h: int, kvh: int, tp: int) -> int:
    """Pad the query-head dim to the TP degree when not divisible (Megatron
    head padding): padded heads are hard-masked to zero after attention, so
    the function is exactly the published model — but attention shards
    tp-ways instead of replicating (16x compute/bytes for 24/40-head archs
    on a 16-way model axis).  GQA group mapping follows the padded layout.
    """
    if tp <= 1 or _div(h, tp):
        return h
    hp = -(-h // tp) * tp
    # keep GQA grouping valid: padded heads must divide into kv groups
    while hp % kvh != 0:
        hp += tp
    return hp


def attn_defs(cfg, tp: int):
    d, h, kvh, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.resolved_head_dim)
    hp = padded_heads(h, kvh, tp)
    h_ax = "model" if _div(hp, tp) else None
    kv_ax = "model" if _div(kvh, tp) else None
    return {
        "wq": ParamDef((d, hp, hd), (None, h_ax, None)),
        "wk": ParamDef((d, kvh, hd), (None, kv_ax, None)),
        "wv": ParamDef((d, kvh, hd), (None, kv_ax, None)),
        "wo": ParamDef((hp, hd, d), (h_ax, None, None)),
        "ln": norm_def(d),
    }


def _head_mask(out, h_real: int, kvh: int = 1):
    """Zero the padded heads of (..., H_pad, hd) attention output.

    Padding is per KV group: real head i occupies slot
    (i // g) * g_pad + (i % g), so slot s is real iff s % g_pad < g.
    (This is also the checkpoint-import remap rule.)"""
    hp = out.shape[-2]
    if hp == h_real:
        return out
    g, gp = h_real // kvh, hp // kvh
    mask = ((jnp.arange(hp) % gp) < g).astype(out.dtype)
    return out * mask[:, None]


def _attn_mask(b, t, s, *, causal, window, q_pos0, kv_len):
    """(B, t, s) boolean visibility mask; q_pos0 scalar or (B,)."""
    if np.ndim(q_pos0) == 0:
        q_pos = jnp.broadcast_to(q_pos0 + jnp.arange(t), (b, t))
    else:
        q_pos = q_pos0[:, None] + jnp.arange(t)[None, :]
    k_pos = jnp.arange(s)
    mask = jnp.ones((b, t, s), dtype=bool)
    if causal:
        mask &= q_pos[..., None] >= k_pos
    if window and window > 0:
        mask &= (q_pos[..., None] - k_pos) < window
    if kv_len is not None:
        mask &= k_pos[None, None, :] < kv_len[:, None, None]
    return mask


_CHUNK_Q_ABOVE = 1024       # stream softmax over q chunks beyond this T
_CHUNK_Q = 512


def _sdpa_core(q, k, v, *, causal, window, q_pos0, kv_len, dtype):
    # bf16-native: QK^T and PV keep bf16 operands with f32 accumulation
    # (preferred_element_type) — no materialized f32 copies of K/V/cache.
    b, t, kvh, g, hd = q.shape
    s = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _attn_mask(b, t, s, causal=causal, window=window,
                      q_pos0=q_pos0, kv_len=kv_len)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(dtype or q.dtype)


def _sdpa(q, k, v, *, causal, window, q_pos0=0, kv_len=None, dtype=None):
    """q (B,T,KVH,G,hd), k/v (B,S,KVH,hd): masked attention, fp32 softmax.

    ``window > 0``: sliding-window (local) causal attention.
    ``kv_len`` (B,) masks cache positions >= length (decode).
    Long sequences stream over q chunks (scan) so the score matrix peak is
    (cq, S) not (T, S) — the flash-attention memory shape in pure jnp (the
    Pallas kernel is the TPU-native version of the same schedule).
    """
    b, t, kvh, g, hd = q.shape
    if t <= _CHUNK_Q_ABOVE or t % _CHUNK_Q != 0 or np.ndim(q_pos0) != 0:
        return _sdpa_core(q, k, v, causal=causal, window=window,
                          q_pos0=q_pos0, kv_len=kv_len, dtype=dtype)
    nq = t // _CHUNK_Q
    qc = jnp.moveaxis(q.reshape(b, nq, _CHUNK_Q, kvh, g, hd), 1, 0)
    starts = q_pos0 + jnp.arange(nq) * _CHUNK_Q

    def step(_, xs):
        qi, st = xs
        o = _sdpa_core(qi, k, v, causal=causal, window=window,
                       q_pos0=st, kv_len=kv_len, dtype=dtype)
        return None, o

    _, outs = jax.lax.scan(step, None, (qc, starts))
    return jnp.moveaxis(outs, 0, 1).reshape(b, t, kvh, g, hd)


def _sdpa_mask(q, k, v, mask, dtype=None):
    """Attention with an explicit (B, t, s) visibility mask (bf16-native)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(dtype or q.dtype)


def attn_apply(p, x, cfg, *, kind="attn", causal=True, positions=None,
               cache=None, cache_len=None, kv_override=None, kv_len=None):
    """GQA attention.  Returns (y, new_cache).

    Modes: plain (cache=None), prefill (cache + t>1, fills from offset 0),
    decode (cache + t==1, per-sequence offsets ``cache_len`` (B,)).
    ``local`` layers keep a **ring cache** of size window (the GraphStore
    L-type insight: bound the hot set, reuse slots in place).
    kv_override: precomputed (k, v) for cross-attention (with ``kv_len``).
    """
    b, t, d = x.shape
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    h = p["wq"].shape[1]                  # padded head count (>= cfg heads)
    g = h // kvh
    h_ax = "model" if _div(h, active_tp()) else None
    window = cfg.window_size if kind == "local" else 0
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("btd,dhk->bthk", xn, p["wq"].astype(xn.dtype))
    q = shard(q, DP, None, h_ax, None)

    if kv_override is not None:                      # ---- cross-attention
        k, v = kv_override
        qg = q.reshape(b, t, kvh, g, hd)
        out = _sdpa(qg, k, v, causal=False, window=0, kv_len=kv_len)
        out = _head_mask(out.reshape(b, t, h, hd), cfg.num_heads, kvh)
        y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(out.dtype))
        return x + shard(y, DP, None, None), cache

    k = jnp.einsum("btd,dhk->bthk", xn, p["wk"].astype(xn.dtype))
    v = jnp.einsum("btd,dhk->bthk", xn, p["wv"].astype(xn.dtype))
    if positions is None:
        positions = jnp.arange(t)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    if kind != "nope":
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    qg = q.reshape(b, t, kvh, g, hd)

    if cache is None:                                # ---- plain (train)
        out = _sdpa(qg, k, v, causal=causal, window=window)
        new_cache = None
    elif t > 1:                                      # ---- prefill
        out = _sdpa(qg, k, v, causal=causal, window=window)
        if kind == "local" and t >= cache["k"].shape[1]:
            w = cache["k"].shape[1]
            p0 = t - w
            ks = jnp.roll(k[:, -w:], shift=p0 % w, axis=1)
            vs = jnp.roll(v[:, -w:], shift=p0 % w, axis=1)
            new_cache = {"k": ks.astype(cache["k"].dtype),
                         "v": vs.astype(cache["v"].dtype)}
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1)}
    else:                                            # ---- decode (t == 1)
        off = attn_decode_pos(cache_len, b)
        if kind == "local":
            w = cache["k"].shape[1]
            slot = off % w
            kc = _batched_update(cache["k"], k, slot)
            vc = _batched_update(cache["v"], v, slot)
            new_cache = {"k": kc, "v": vc}
            n = off + 1                               # tokens now cached
            j = jnp.arange(w)[None, :]                # ring slots
            abs_pos = j + ((n[:, None] - 1 - j) // w) * w
            q_pos = off[:, None]
            visible = (abs_pos >= 0) & (abs_pos < n[:, None]) \
                & (abs_pos <= q_pos) & (q_pos - abs_pos < w)
            out = _sdpa_mask(qg, kc, vc, visible[:, None, :])
        else:
            kc = _batched_update(cache["k"], k, off)
            vc = _batched_update(cache["v"], v, off)
            new_cache = {"k": kc, "v": vc}
            out = _sdpa(qg, kc, vc, causal=True, window=0,
                        q_pos0=off, kv_len=off + 1)
    out = _head_mask(out.reshape(b, t, h, hd), cfg.num_heads, kvh)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(out.dtype))
    return x + shard(y, DP, None, None), new_cache


def _batched_update(cache, new, offsets):
    """Per-sequence write offsets (decode with ragged lengths)."""
    def upd(c, n, o):
        return jax.lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), o, 0)
    return jax.vmap(upd)(cache, new, offsets)


def attn_decode_pos(cache_len, b):
    if np.ndim(cache_len) == 0:
        return jnp.full((b,), cache_len, jnp.int32)
    return cache_len


def attn_cache_defs(cfg, batch: int, seq: int, *, tp: int,
                    long_mode: bool = False):
    """Decode KV-cache defs.  Normal mode: batch over DP, seq over "model"
    when KV heads cannot shard (keeps big caches on-chip).  long_mode
    (batch < DP degree): batch replicated, seq over DP(+model)."""
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv_ax = "model" if _div(kvh, tp) else None
    if long_mode:
        pspec = (None, DP if kv_ax else DPM, kv_ax, None)
    else:
        pspec = (DP, None if kv_ax else "model", kv_ax, None)
    return {"k": ParamDef((batch, seq, kvh, hd), pspec, init="zeros",
                          dtype=cfg.dtype),
            "v": ParamDef((batch, seq, kvh, hd), pspec, init="zeros",
                          dtype=cfg.dtype)}


# ===================================================================== MLA
def mla_defs(cfg, tp: int):
    m = cfg.mla
    d = cfg.d_model
    h = padded_heads(cfg.num_heads, 1, tp)
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    h_ax = "model" if _div(h, tp) else None
    return {
        "wdq": ParamDef((d, m.q_lora_rank), (None, None)),
        "q_ln": norm_def(m.q_lora_rank),
        "wuq": ParamDef((m.q_lora_rank, h, qd), (None, h_ax, None)),
        "wdkv": ParamDef((d, m.kv_lora_rank + m.qk_rope_head_dim),
                         (None, None)),
        "kv_ln": norm_def(m.kv_lora_rank),
        "wukv": ParamDef((m.kv_lora_rank, h,
                          m.qk_nope_head_dim + m.v_head_dim),
                         (None, h_ax, None)),
        "wo": ParamDef((h, m.v_head_dim, d), (h_ax, None, None)),
        "ln": norm_def(d),
    }


def mla_apply(p, x, cfg, *, positions=None, cache=None, cache_len=None):
    """Multi-head latent attention; the cache stores the *compressed* KV
    (c_kv + shared k_rope) — MLA's serving advantage."""
    m = cfg.mla
    b, t, d = x.shape
    h = p["wuq"].shape[1]                 # padded head count
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    cq = rms_norm(jnp.einsum("btd,dr->btr", xn, p["wdq"].astype(xn.dtype)),
                  p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", cq, p["wuq"].astype(cq.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    dkv = jnp.einsum("btd,dr->btr", xn, p["wdkv"].astype(xn.dtype))
    ckv = rms_norm(dkv[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:]                     # (B,T,rope_d) shared
    if positions is None:
        positions = jnp.arange(t)
    cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0]
    if cache is not None:
        off = cache_len if cache_len is not None else 0
        if np.ndim(off) == 0:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), off, axis=1)
            kr_c = jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope.astype(cache["krope"].dtype), off, axis=1)
        else:
            ckv_c = _batched_update(cache["ckv"], ckv, off)
            kr_c = _batched_update(cache["krope"], k_rope, off)
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        ckv, k_rope = ckv_c, kr_c
    else:
        new_cache = None
    kv = jnp.einsum("bsr,rhk->bshk", ckv.astype(x.dtype),
                    p["wukv"].astype(x.dtype))
    k_nope, v = kv[..., :nope], kv[..., nope:]
    s_len = k_nope.shape[1]
    scale = 1.0 / np.sqrt(nope + rope_d)
    scores = (jnp.einsum("bthk,bshk->bhts", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    q_pos0 = 0
    kv_len = None
    if cache is not None:
        q_pos0 = cache_len if cache_len is not None else 0
        kv_len = (cache_len + t)
        if np.ndim(kv_len) == 0:
            kv_len = jnp.full((b,), kv_len, jnp.int32)
    mask = _attn_mask(b, t, s_len, causal=True, window=0,
                      q_pos0=q_pos0, kv_len=kv_len)
    scores = jnp.where(mask[:, None], scores, -1e30)
    pr = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshk->bthk", pr.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = _head_mask(out.astype(x.dtype), cfg.num_heads)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))
    return x + shard(y, DP, None, None), new_cache


def mla_cache_defs(cfg, batch: int, seq: int, *, tp: int,
                   long_mode: bool = False):
    m = cfg.mla
    pspec = (None, DPM, None) if long_mode else (DP, "model", None)
    return {"ckv": ParamDef((batch, seq, m.kv_lora_rank), pspec,
                            init="zeros", dtype=cfg.dtype),
            "krope": ParamDef((batch, seq, m.qk_rope_head_dim), pspec,
                              init="zeros", dtype=cfg.dtype)}


# ===================================================================== MLP
def mlp_defs(cfg, tp: int, d_ff: int = 0):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    f_ax = "model" if _div(f, tp) else None
    return {
        "w_gate": ParamDef((d, f), (None, f_ax)),
        "w_in": ParamDef((d, f), (None, f_ax)),
        "w_out": ParamDef((f, d), (f_ax, None)),
        "ln": norm_def(d),
    }


def mlp_apply(p, x, cfg):
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    a = _act(cfg.act)(jnp.einsum("btd,df->btf", xn, p["w_gate"].astype(xn.dtype)))
    u = jnp.einsum("btd,df->btf", xn, p["w_in"].astype(xn.dtype))
    hfa = "model" if _div(p["w_in"].shape[-1], active_tp()) else None
    h = shard(a * u, DP, None, hfa)
    y = jnp.einsum("btf,fd->btd", h, p["w_out"].astype(h.dtype))
    return x + shard(y, DP, None, None)


# ===================================================================== MoE
def moe_defs(cfg, tp: int):
    mc = cfg.moe
    d = cfg.d_model
    f = mc.d_ff or cfg.d_ff
    e = mc.num_experts
    e_ax = "model" if _div(e, tp) else None
    f_ax = "model" if _div(f, tp) else None
    defs = {
        "router": ParamDef((d, e), (None, None)),
        "w_gate": ParamDef((e, d, f), (e_ax, None, None)),
        "w_in": ParamDef((e, d, f), (e_ax, None, None)),
        "w_out": ParamDef((e, f, d), (e_ax, None, None)),
        "ln": norm_def(d),
    }
    if mc.num_shared:
        defs["shared"] = {
            "w_gate": ParamDef((d, mc.num_shared * f), (None, f_ax)),
            "w_in": ParamDef((d, mc.num_shared * f), (None, f_ax)),
            "w_out": ParamDef((mc.num_shared * f, d), (f_ax, None)),
        }
    return defs


def _moe_local(xl, router, wg, wi, wo, *, cfg, axes=()):
    """Per-data-shard MoE dispatch/compute/combine (runs inside shard_map;
    the model axis stays auto so the expert einsums shard E 16-ways)."""
    mc = cfg.moe
    bl, t, d = xl.shape
    nl = bl * t
    e, k = mc.num_experts, mc.top_k
    cap = max(8, int(mc.capacity_factor * nl * k / e))
    xn = xl.reshape(nl, d)
    logits = jnp.einsum("nd,de->ne", xn.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,)).at[idx.reshape(-1)].add(1.0) / (nl * k)
    aux = e * jnp.sum(me * ce)

    oh = jax.nn.one_hot(idx.reshape(nl * k), e, dtype=jnp.int32)
    ranks = jnp.cumsum(oh, axis=0) - oh
    rank = jnp.take_along_axis(ranks, idx.reshape(nl * k)[:, None],
                               axis=1)[:, 0].reshape(nl, k)
    buf = jnp.zeros((e * cap, d), xn.dtype)
    for j in range(k):
        keep = rank[:, j] < cap
        dest = jnp.where(keep, idx[:, j] * cap + rank[:, j], e * cap)
        buf = buf.at[dest].set(xn * keep[:, None].astype(xn.dtype),
                               mode="drop")
    eb = buf.reshape(e, cap, d)
    hg = _act(cfg.act)(jnp.einsum("ecd,edf->ecf", eb, wg.astype(eb.dtype)))
    hu = jnp.einsum("ecd,edf->ecf", eb, wi.astype(eb.dtype))
    ob = jnp.einsum("ecf,efd->ecd", hg * hu,
                    wo.astype(eb.dtype)).reshape(e * cap, d)
    y = jnp.zeros_like(xn)
    for j in range(k):
        keep = rank[:, j] < cap
        src = jnp.where(keep, idx[:, j] * cap + rank[:, j], 0)
        y = y + ob[src] * (gates[:, j] * keep)[:, None].astype(xn.dtype)
    if axes:
        aux = jax.lax.pmean(aux, axes)
    return y.reshape(bl, t, d), aux


def moe_apply(p, x, cfg):
    """Capacity-based top-k MoE (GShard-style, per-data-shard capacity).

    On a mesh the dispatch/compute/combine runs under shard_map over the
    data axes with "model" left auto: scatter/gather locality is by
    construction, expert weights shard E over "model" (EP), and the only
    cross-shard traffic is the minimal expert-output exchange + weight-grad
    reductions (§Perf iterations 3-4)."""
    mc = cfg.moe
    b, t, d = x.shape
    mesh = _ACTIVE["mesh"]
    dp_axes = _ACTIVE["dp_axes"]
    xn_in = rms_norm(x, p["ln"], cfg.norm_eps)
    if mesh is not None and dp_axes and b % active_dp() == 0:
        # §Perf iteration 4: shard_map over the data axes (model stays
        # auto) — dispatch/combine scatter/gathers are provably local per
        # data shard, experts still shard E over "model".  GSPMD-only
        # formulations emit (tokens, d)-sized masked all-reduces across
        # data (measured 2x34 GB/layer on phi3.5-moe).
        local = functools.partial(_moe_local, cfg=cfg, axes=dp_axes)
        dspec = P(dp_axes, None, None)
        y, aux = compat.shard_map(
            local, mesh=mesh,
            in_specs=(dspec, P(None, None), P(None, None, None),
                      P(None, None, None), P(None, None, None)),
            out_specs=(dspec, P()),
            axis_names=set(dp_axes), check=False)(
            xn_in, p["router"], p["w_gate"], p["w_in"], p["w_out"])
    else:
        y, aux = _moe_local(xn_in, p["router"], p["w_gate"], p["w_in"],
                            p["w_out"], cfg=cfg)
    y = shard(y, DP, None, None)
    if mc.num_shared:
        sp = p["shared"]
        a = _act(cfg.act)(jnp.einsum("btd,df->btf",
                                     rms_norm(x, p["ln"], cfg.norm_eps),
                                     sp["w_gate"].astype(x.dtype)))
        u = jnp.einsum("btd,df->btf", rms_norm(x, p["ln"], cfg.norm_eps),
                       sp["w_in"].astype(x.dtype))
        y = y + jnp.einsum("btf,fd->btd", a * u, sp["w_out"].astype(x.dtype))
    return x + shard(y, DP, None, None), aux


# ================================================================ embedding
def embed_defs(cfg, tp: int):
    v, d = cfg.vocab_size, cfg.d_model
    if _div(v, tp):
        emb_spec = ("model", None)
    else:
        emb_spec = (None, "model")           # row-parallel logits fallback
    defs = {"tokens": ParamDef((v, d), emb_spec, scale=1.0 / np.sqrt(d)),
            "final_ln": norm_def(d)}
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((d, v),
                                (None, "model") if _div(v, tp)
                                else ("model", None))
    return defs


def embed_apply(p, tokens, cfg):
    x = jnp.take(p["tokens"].astype(jnp.dtype(cfg.dtype)), tokens, axis=0)
    if cfg.name.startswith("gemma3"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return shard(x, DP, None, None)


def logits_apply(p, x, cfg):
    xn = rms_norm(x, p["final_ln"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = p["tokens"].astype(xn.dtype)
        return jnp.einsum("btd,vd->btv", xn, w)
    return jnp.einsum("btd,dv->btv", xn, p["head"].astype(xn.dtype))
