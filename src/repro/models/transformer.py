"""Decoder-LM assembly: period-scanned heterogeneous layer stacks.

Every arch's layer sequence is ``period_pattern * n_periods + remainder``.
Weights are stacked per *position-in-period* with a leading ``n_periods``
dim and the whole stack is driven by one ``lax.scan`` — HLO size is O(1) in
depth for every architecture (62-layer gemma3-27b compiles as 1 period body
+ 2 unrolled remainder layers).  Mixed patterns (gemma3 5:1 local:global,
jamba 7:1 mamba:attn + MoE alternation, xlstm 5:1 mLSTM:sLSTM) keep exact
per-layer-type weights because positions are stacked independently.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba as M
from . import xlstm as X


# --------------------------------------------------------------- param defs
def mixer_defs(cfg, kind: str, tp: int):
    if kind in ("attn", "local", "nope"):
        return L.mla_defs(cfg, tp) if cfg.mla is not None \
            else L.attn_defs(cfg, tp)
    if kind == "mamba":
        return M.mamba_defs(cfg, tp)
    if kind == "mlstm":
        return X.mlstm_defs(cfg, tp)
    if kind == "slstm":
        return X.slstm_defs(cfg, tp)
    raise ValueError(kind)


def ffn_defs(cfg, kind: str, tp: int):
    if kind == "none":
        return None
    if kind == "moe":
        return L.moe_defs(cfg, tp)
    return L.mlp_defs(cfg, tp)


def _pos_ffn_kind(cfg, pos: int) -> str:
    kind = cfg.period_pattern[pos]
    if kind in ("mlstm", "slstm") and cfg.d_ff == 0:
        return "none"
    if cfg.moe is not None:
        assert len(cfg.period_pattern) % cfg.moe.every == 0 or \
            cfg.moe.every % len(cfg.period_pattern) == 0, \
            "MoE interval must align with the period"
        return cfg.ffn_kind(pos)
    return "mlp"


def decoder_param_defs(cfg, tp: int):
    period = len(cfg.period_pattern)
    stack = []
    for pos, kind in enumerate(cfg.period_pattern):
        blk = {"mixer": mixer_defs(cfg, kind, tp)}
        fk = _pos_ffn_kind(cfg, pos)
        if fk != "none":
            blk["ffn"] = ffn_defs(cfg, fk, tp)
        stack.append(L.stack_defs(blk, cfg.n_periods))
    rem = []
    for pos, kind in enumerate(cfg.remainder_kinds):
        blk = {"mixer": mixer_defs(cfg, kind, tp)}
        fk = _pos_ffn_kind(cfg, pos)
        if fk != "none":
            blk["ffn"] = ffn_defs(cfg, fk, tp)
        rem.append(blk)
    return {"embed": L.embed_defs(cfg, tp),
            "stack": tuple(stack), "rem": tuple(rem)}


# ------------------------------------------------------------ cache defs
def block_cache_defs(cfg, kind: str, batch: int, seq: int, *, tp: int,
                     long_mode: bool = False):
    if kind in ("attn", "nope"):
        return L.mla_cache_defs(cfg, batch, seq, tp=tp, long_mode=long_mode) \
            if cfg.mla is not None \
            else L.attn_cache_defs(cfg, batch, seq, tp=tp, long_mode=long_mode)
    if kind == "local":
        w = min(cfg.window_size, seq)
        # local layers only ever need the trailing window of cache (ring)
        return L.mla_cache_defs(cfg, batch, w, tp=tp) if cfg.mla is not None \
            else L.attn_cache_defs(cfg, batch, w, tp=tp)
    if kind == "mamba":
        return M.mamba_cache_defs(cfg, batch, tp=tp)
    if kind == "mlstm":
        return X.mlstm_cache_defs(cfg, batch, tp=tp)
    if kind == "slstm":
        return X.slstm_cache_defs(cfg, batch, tp=tp)
    raise ValueError(kind)


def decoder_cache_defs(cfg, batch: int, seq: int, *, tp: int,
                       long_mode: bool = False):
    stack = [L.stack_defs(
        block_cache_defs(cfg, kind, batch, seq, tp=tp, long_mode=long_mode),
        cfg.n_periods) for kind in cfg.period_pattern]
    rem = [block_cache_defs(cfg, kind, batch, seq, tp=tp, long_mode=long_mode)
           for kind in cfg.remainder_kinds]
    return {"stack": tuple(stack), "rem": tuple(rem)}


# ------------------------------------------------------------- block apply
def apply_block(cfg, kind: str, blk_params, x, *, cache=None, cache_len=None,
                positions=None):
    """Returns (x, new_cache, aux)."""
    p_mix = blk_params["mixer"]
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local", "nope"):
        if cfg.mla is not None:
            x, new_c = L.mla_apply(p_mix, x, cfg, positions=positions,
                                   cache=cache, cache_len=cache_len)
        else:
            # local layers: attn_apply implements ring-cache semantics
            x, new_c = L.attn_apply(p_mix, x, cfg, kind=kind,
                                    positions=positions, cache=cache,
                                    cache_len=cache_len)
    elif kind == "mamba":
        x, new_c = M.mamba_apply(p_mix, x, cfg, cache=cache,
                                 cache_len=cache_len)
    elif kind == "mlstm":
        x, new_c = X.mlstm_apply(p_mix, x, cfg, cache=cache,
                                 cache_len=cache_len)
    elif kind == "slstm":
        x, new_c = X.slstm_apply(p_mix, x, cfg, cache=cache,
                                 cache_len=cache_len)
    else:
        raise ValueError(kind)
    if "ffn" in blk_params:
        ffn_p = blk_params["ffn"]
        if "router" in ffn_p:
            x, aux = L.moe_apply(ffn_p, x, cfg)
        else:
            x = L.mlp_apply(ffn_p, x, cfg)
    return x, new_c, aux


# ---------------------------------------------------------------- forward
def decoder_forward(params, cfg, x, *, caches=None, cache_len=None,
                    positions=None, remat: bool = False):
    """x (B,T,D) hidden states -> (hidden, new_caches, aux_sum)."""
    period = len(cfg.period_pattern)
    aux_total = jnp.zeros((), jnp.float32)

    def period_body(x, xs):
        stack_p, stack_c = xs
        aux_sum = jnp.zeros((), jnp.float32)
        new_cs = []
        for pos, kind in enumerate(cfg.period_pattern):
            c = None if stack_c is None else stack_c[pos]
            x, nc, aux = apply_block(cfg, kind, stack_p[pos], x,
                                     cache=c, cache_len=cache_len,
                                     positions=positions)
            new_cs.append(nc)
            aux_sum = aux_sum + aux
        return x, (tuple(new_cs), aux_sum)

    body = period_body
    if remat:
        body = jax.checkpoint(period_body)

    stack_p = tuple(params["stack"])
    stack_c = tuple(caches["stack"]) if caches is not None else None

    def scan_body(carry, xs_sliced):
        x, aux = carry
        sp = xs_sliced[0]
        sc = xs_sliced[1] if stack_c is not None else None
        x, (ncs, a) = body(x, (sp, sc))
        return (x, aux + a), ncs

    xs = (stack_p,) if stack_c is None else (stack_p, stack_c)
    (x, aux_total), new_stack_c = jax.lax.scan(
        scan_body, (x, aux_total), xs)

    new_rem_c = []
    for pos, kind in enumerate(cfg.remainder_kinds):
        c = None if caches is None else caches["rem"][pos]
        x, nc, aux = apply_block(cfg, kind, params["rem"][pos], x,
                                 cache=c, cache_len=cache_len,
                                 positions=positions)
        new_rem_c.append(nc)
        aux_total = aux_total + aux

    new_caches = None
    if caches is not None:
        new_caches = {"stack": new_stack_c, "rem": tuple(new_rem_c)}
    return x, new_caches, aux_total


# -------------------------------------------------------------------- loss
def chunked_xent(params, cfg, hidden, labels, mask):
    """Cross-entropy without materializing (B,T,V): scan over seq chunks;
    logits stay (B,chunk,V) sharded over (dp, -, model)."""
    b, t, d = hidden.shape
    ck = min(cfg.loss_chunk, t)
    while t % ck:
        ck -= 1
    n_chunks = t // ck
    hc = hidden.reshape(b, n_chunks, ck, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, ck).swapaxes(0, 1)
    mc = mask.reshape(b, n_chunks, ck).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        h, lab, m = xs
        logits = L.logits_apply(params["embed"], h, cfg).astype(jnp.float32)
        logits = L.shard(logits, L.DP, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk_loss, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ----------------------------------------------------------- full LM paths
def lm_train_loss(params, cfg, tokens, labels):
    x = L.embed_apply(params["embed"], tokens, cfg)
    x, _, aux = decoder_forward(params, cfg, x, remat=(cfg.remat == "full"))
    mask = (labels >= 0).astype(jnp.float32)
    loss = chunked_xent(params, cfg, x, jnp.maximum(labels, 0), mask)
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


def lm_prefill(params, cfg, tokens, caches):
    """Fill caches for the prompt; returns (last_logits, caches)."""
    x = L.embed_apply(params["embed"], tokens, cfg)
    x, caches, _ = decoder_forward(params, cfg, x, caches=caches,
                                   cache_len=jnp.zeros((), jnp.int32))
    logits = L.logits_apply(params["embed"], x[:, -1:], cfg)
    return logits, caches


def lm_decode(params, cfg, tokens, caches, lengths):
    """One decode step: tokens (B,1), lengths (B,) current cache fill."""
    x = L.embed_apply(params["embed"], tokens, cfg)
    positions = lengths[:, None] + jnp.arange(tokens.shape[1])[None, :]
    x, caches, _ = decoder_forward(params, cfg, x, caches=caches,
                                   cache_len=lengths, positions=positions)
    logits = L.logits_apply(params["embed"], x, cfg)
    return logits, caches
