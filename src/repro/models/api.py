"""Unified model API: one object per architecture exposing param defs,
init/abstract/pspec trees, train/prefill/decode functions, cache defs, and
the dry-run ``input_specs`` (ShapeDtypeStruct stand-ins + PartitionSpecs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from . import transformer as T
from . import encdec as E
from .vlm import vlm_train_loss, vlm_prefill
from ..configs.base import ModelConfig, ShapeConfig


@dataclass
class ModelAPI:
    cfg: ModelConfig
    tp: int

    # ----------------------------------------------------------- parameters
    def param_defs(self):
        if self.cfg.family == "encdec":
            return E.encdec_param_defs(self.cfg, self.tp)
        return T.decoder_param_defs(self.cfg, self.tp)

    def init_params(self, seed: int = 0):
        return L.init_tree(self.param_defs(), seed)

    def abstract_params(self, *, dtype=None):
        """dtype="bfloat16" gives the serving-weight tree (inference cells
        hold bf16 weights; training holds fp32 masters)."""
        tree = L.abstract_tree(self.param_defs())
        if dtype is None:
            return tree
        import jax.numpy as _jnp
        dt = _jnp.dtype(dtype)
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                a.shape, dt if _jnp.issubdtype(a.dtype, _jnp.floating)
                else a.dtype), tree)

    def param_pspecs(self):
        return L.pspec_tree(self.param_defs())

    # ---------------------------------------------------------------- cache
    def cache_defs(self, batch: int, seq: int, *, long_mode: bool = False):
        if self.cfg.family == "encdec":
            return E.encdec_cache_defs(self.cfg, batch, seq, tp=self.tp)
        if self.cfg.family == "vlm":
            seq = seq  # patches are part of the prefill; cache covers them
        return T.decoder_cache_defs(self.cfg, batch, seq, tp=self.tp,
                                    long_mode=long_mode)

    def abstract_cache(self, batch, seq, **kw):
        return L.abstract_tree(self.cache_defs(batch, seq, **kw))

    def cache_pspecs(self, batch, seq, **kw):
        return L.pspec_tree(self.cache_defs(batch, seq, **kw))

    # ------------------------------------------------------------ functions
    def train_loss(self, params, batch):
        cfg = self.cfg
        if cfg.family == "encdec":
            return E.encdec_train_loss(params, cfg, batch["frames"],
                                       batch["tokens"], batch["labels"])
        if cfg.family == "vlm":
            return vlm_train_loss(params, cfg, batch["patches"],
                                  batch["tokens"], batch["labels"])
        return T.lm_train_loss(params, cfg, batch["tokens"], batch["labels"])

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        if cfg.family == "encdec":
            return E.encdec_prefill(params, cfg, batch["frames"],
                                    batch["tokens"], caches)
        if cfg.family == "vlm":
            return vlm_prefill(params, cfg, batch["patches"],
                               batch["tokens"], caches)
        return T.lm_prefill(params, cfg, batch["tokens"], caches)

    def decode(self, params, batch, caches):
        cfg = self.cfg
        if cfg.family == "encdec":
            return E.encdec_decode(params, cfg, batch["tokens"], caches,
                                   batch["lengths"], batch["enc_out"])
        return T.lm_decode(params, cfg, batch["tokens"], caches,
                           batch["lengths"])

    # ----------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            if cfg.family == "encdec":
                return {"frames": jax.ShapeDtypeStruct((b, s // 2, cfg.d_model), dt),
                        "tokens": jax.ShapeDtypeStruct((b, s // 2), i32),
                        "labels": jax.ShapeDtypeStruct((b, s // 2), i32)}
            if cfg.family == "vlm":
                p = cfg.num_patches
                return {"patches": jax.ShapeDtypeStruct((b, p, cfg.d_model), dt),
                        "tokens": jax.ShapeDtypeStruct((b, s - p), i32),
                        "labels": jax.ShapeDtypeStruct((b, s - p), i32)}
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "prefill":
            if cfg.family == "encdec":
                return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                        "tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if cfg.family == "vlm":
                p = cfg.num_patches
                return {"patches": jax.ShapeDtypeStruct((b, p, cfg.d_model), dt),
                        "tokens": jax.ShapeDtypeStruct((b, s - p), i32)}
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        # decode
        out = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
               "lengths": jax.ShapeDtypeStruct((b,), i32)}
        if cfg.family == "encdec":
            out["enc_out"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
        return out

    def input_pspecs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        long_mode = _is_long_mode(shape)
        dp = () if long_mode else L.DP
        two = L.resolve_pspec((dp, None))
        three = L.resolve_pspec((dp, None, None))
        one = L.resolve_pspec((dp,))
        specs = {k: (three if v.ndim == 3 else two if v.ndim == 2 else one)
                 for k, v in self.input_specs(shape).items()}
        return specs


def _is_long_mode(shape: ShapeConfig) -> bool:
    return shape.kind == "decode" and shape.global_batch == 1


def build(cfg: ModelConfig, tp: int = 1) -> ModelAPI:
    return ModelAPI(cfg=cfg, tp=tp)
