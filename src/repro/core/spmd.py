"""SPMD model-parallel execution of DFG programs (engine scale-out axis 2).

The engine's cached-jit path runs the whole post-BatchPre suffix of a DFG as
one XLA program on one device.  This module lowers that same suffix through
``shard_map`` over a (data, model) device mesh instead:

  * **model axis** — embedding/hidden dims are striped: the activations'
    feature axis and every weight's contracted (row) axis are sharded, each
    mesh slice runs the bound C-kernels (Pallas or Shell jnp) at slice
    shapes, and a ``psum`` at the combine boundary rebuilds the full GEMM
    output *before* the nonlinearity — the Megatron/GShard row-parallel
    split (levanter ``sharded_gpt2.py`` / lingvo ``gshard_builder.py``);
  * **data axis** — super-batch destination rows are striped: each slice
    aggregates and transforms its own row block, with a tiled
    ``all_gather`` re-materialising the full activation at each layer
    boundary (the next hop's gather indexes into ALL previous-level rows).

The partition plan is inferred over the DFG node vocabulary (SpMM*/SDDMM/
Prefix/GEMM/BiasAdd/AggCombine/elementwise); ops outside the vocabulary
execute fully replicated, so any DFG still runs on a mesh — it just doesn't
scale.  Hidden dims that don't divide the mesh are zero-padded to
divisibility (zeros stay exact zeros through every aggregation, matmul and
relu in these models, and outputs are sliced back), so odd widths work.

Numerics: ``psum`` re-orders the contraction, so sharded == single-device
at fp32 *allclose* tolerance, not bitwise — asserted for GCN/GIN/NGCF
across mesh shapes in ``tests/test_spmd.py`` and ``benchmarks/fig28_spmd``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

ROWS_FULL, ROWS_DATA = "full", "data"
FEAT_REP, FEAT_MODEL = "rep", "model"

AGG_OPS = frozenset({"SpMM", "SpMM_Mean", "SpMM_Sum"})
_FUSED_OP = "AggCombine"


class SpmdPlanError(ValueError):
    """The DFG uses a sharded value in a way the plan cannot honor."""


@dataclass(frozen=True)
class VState:
    """Partition state of one value inside the mapped body: how its leading
    (row) axis and trailing (feature) axis relate to the mesh."""
    rows: str = ROWS_FULL       # "full" (replicated) | "data" (row-striped)
    feat: str = FEAT_REP        # "rep" | "model" (feature-striped)


_WEIGHT = VState("wrow", "wrow")      # sentinel: model-striped contracted dim


def mesh_axes(mesh) -> tuple[str | None, int, str | None, int]:
    """(data_axis, d, model_axis, m) — absent axes behave as size 1."""
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    da = "data" if "data" in names else None
    ma = "model" if "model" in names else None
    return da, sizes.get("data", 1), ma, sizes.get("model", 1)


def mesh_descriptor(mesh) -> tuple:
    """Hashable mesh identity for the engine's jit cache key."""
    return tuple(zip(mesh.axis_names, mesh.devices.shape))


def _ceil_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _agg_partial_ref(h, nbr, mask, w):
    """jnp fallback for the AggCombinePartial C-kernel (mean aggregation —
    the fusion pass only creates mean chains)."""
    g = jnp.take(h, nbr, axis=0) * mask[..., None]
    s = g.sum(axis=1) / jnp.maximum(mask.sum(axis=1), 1.0)[:, None]
    return jnp.dot(s, w, preferred_element_type=jnp.float32)


# --------------------------------------------------------------- input roles
def _classify_inputs(suffix, arr_set: set[str]) -> dict[str, str]:
    """Role per DFG input ref, from how the suffix consumes it:
    h (activations: feature-striped), idx (nbr/mask: row-striped),
    weight (contracted-dim-striped), bias (replicated, width-padded),
    gemm_x (GEMM lhs fed directly: width-padded only)."""
    roles: dict[str, str] = {}

    def mark(ref: str, role: str) -> None:
        if ref not in arr_set:
            return
        prev = roles.get(ref)
        roles[ref] = role if prev in (None, role) else "rep"   # conflict

    for n in suffix:
        if n.op in AGG_OPS or n.op == "SDDMM":
            mark(n.inputs[0], "h")
            mark(n.inputs[1], "idx")
            mark(n.inputs[2], "idx")
        elif n.op == "Prefix":
            mark(n.inputs[0], "h")
            mark(n.inputs[1], "idx")
        elif n.op == "GEMM":
            mark(n.inputs[1], "weight")
            mark(n.inputs[0], "gemm_x") if n.inputs[0] in arr_set else None
        elif n.op == _FUSED_OP:
            mark(n.inputs[0], "h")
            mark(n.inputs[1], "idx")
            mark(n.inputs[2], "idx")
            mark(n.inputs[3], "weight")
            mark(n.inputs[4], "bias")
        elif n.op == "BiasAdd":
            mark(n.inputs[1], "bias")
    return roles


def _input_padding(roles, env, arr_refs, d: int, m: int) -> dict[str, tuple]:
    """Zero-padding per input ref so every striped axis divides the mesh.

    Feature/contracted/width dims all pad with the same ``ceil(x/m)*m``
    rule, so matched dims (h cols <-> weight rows, weight cols <-> bias
    width <-> next weight's rows) stay matched; padded columns are exact
    zeros through aggregation, matmul, bias and relu, and outputs are
    sliced back to true widths.  Row-striped idx inputs pad to the data
    axis (nbr pads with index 0 — always valid — under an all-zero mask);
    activations pad their row count up to the largest padded idx row count
    so ``Prefix`` row-slices stay in bounds.
    """
    pads: dict[str, tuple] = {}
    max_dp = 0
    for r in arr_refs:
        if roles.get(r) == "idx":
            max_dp = max(max_dp, _ceil_to(env[r].shape[0], d))
    for r in arr_refs:
        v, role = env[r], roles.get(r)
        if role == "h":
            rows = max(v.shape[0], max_dp)
            p = ((0, rows - v.shape[0]),
                 (0, _ceil_to(v.shape[1], m) - v.shape[1]))
        elif role == "idx":
            p = ((0, _ceil_to(v.shape[0], d) - v.shape[0]), (0, 0))
        elif role == "weight":
            p = ((0, _ceil_to(v.shape[0], m) - v.shape[0]),
                 (0, _ceil_to(v.shape[1], m) - v.shape[1]))
        elif role == "bias":
            p = ((0, _ceil_to(v.shape[0], m) - v.shape[0]),)
        elif role == "gemm_x":
            p = ((0, 0), (0, _ceil_to(v.shape[-1], m) - v.shape[-1]))
        else:
            continue
        if any(hi for _, hi in p):
            pads[r] = p
    return pads


def _input_spec(role: str | None, rank: int, da, ma) -> P:
    if role == "h":
        return P(None, ma)
    if role == "idx":
        return P(da, None)
    if role == "weight":
        return P(ma, None)
    return P(*([None] * rank))


def _input_state(role: str | None) -> VState:
    if role == "h":
        return VState(ROWS_FULL, FEAT_MODEL)
    if role == "idx":
        return VState(ROWS_DATA, FEAT_REP)
    if role == "weight":
        return _WEIGHT
    return VState(ROWS_FULL, FEAT_REP)


# ------------------------------------------------------------- program build
def build_sharded_program(suffix, resolved, arr_refs, static_env,
                          suffix_outs, env, mesh, registry) -> Callable:
    """Lower a jit-safe DFG suffix onto ``mesh`` via shard_map.

    Returns a callable over the ``arr_refs``-ordered input arrays (same
    signature as the engine's plain ``_program``) that pads inputs to mesh
    divisibility, runs the partitioned body, and slices outputs back to the
    exact single-device shapes.
    """
    da, d, ma, m = mesh_axes(mesh)
    arr_set = set(arr_refs)
    roles = _classify_inputs(suffix, arr_set)
    pads = _input_padding(roles, env, arr_refs, d, m)

    # global PADDED shape of every value: eval_shape of the plain program
    # on padded inputs (abstract — nothing executes)
    def _plain(*vals):
        e: dict[str, Any] = dict(static_env)
        e.update(zip(arr_refs, vals))
        record = {}
        for node, (_, fn) in zip(suffix, resolved):
            args = [e[i] for i in node.inputs]
            out = fn(*args, **node.attrs) if node.attrs else fn(*args)
            if len(node.outputs) == 1:
                e[node.outputs[0]] = out
            else:
                e.update(zip(node.outputs, out))
        for r in e:
            if hasattr(e[r], "shape"):
                record[r] = e[r]
        return record

    def _struct(r, padded: bool):
        v = env[r]
        shape = list(v.shape)
        if padded:
            for ax, (_, hi) in enumerate(pads.get(r, ())):
                shape[ax] += hi
        return jax.ShapeDtypeStruct(tuple(shape), v.dtype)

    gshape = {r: s.shape for r, s in jax.eval_shape(
        _plain, *(_struct(r, True) for r in arr_refs)).items()}
    true_shapes = jax.eval_shape(
        _plain, *(_struct(r, False) for r in arr_refs))
    true_out = {r: true_shapes[r].shape for r in suffix_outs}

    states: dict[str, VState] = {r: _input_state(roles.get(r))
                                 for r in arr_refs}
    steps: list[Callable] = []

    # ---- runtime helpers (trace-time; no-ops skipped at plan time) -------
    def _gather_rows(x):
        return jax.lax.all_gather(x, da, axis=0, tiled=True)

    def _gather_feat(x):
        return jax.lax.all_gather(x, ma, axis=x.ndim - 1, tiled=True)

    def _slice_feat(x):
        w = x.shape[-1] // m
        i = jax.lax.axis_index(ma)
        return jax.lax.dynamic_slice_in_dim(x, i * w, w, axis=x.ndim - 1)

    def _slice_rows(x, loc):
        i = jax.lax.axis_index(da)
        return jax.lax.dynamic_slice_in_dim(x, i * loc, loc, axis=0)

    # ---- plan-time normalizers ------------------------------------------
    def full_rows(ref):
        """Ensure ref holds full rows inside the body (gather + store)."""
        st = states[ref]
        if st is _WEIGHT:
            raise SpmdPlanError(f"weight input {ref!r} used as activation")
        if st.rows == ROWS_DATA:
            if d > 1:
                steps.append(lambda e, r=ref: e.__setitem__(
                    r, _gather_rows(e[r])))
            states[ref] = VState(ROWS_FULL, st.feat)

    def feat_model_arg(ref):
        """Value -> this shard's feature block; returns an e->array fn."""
        st = states[ref]
        if st.feat == FEAT_MODEL or m == 1:
            return lambda e, r=ref: e[r]
        return lambda e, r=ref: _slice_feat(e[r])

    def rep_everything(ref):
        """Unknown-op fallback: gather to fully replicated."""
        st = states.get(ref)
        if st is None:
            return
        if st is _WEIGHT:
            raise SpmdPlanError(
                f"weight input {ref!r} consumed by an op outside the SPMD "
                "vocabulary — cannot replicate a contracted-dim shard")
        full_rows(ref)
        if states[ref].feat == FEAT_MODEL:
            if m > 1:
                steps.append(lambda e, r=ref: e.__setitem__(
                    r, _gather_feat(e[r])))
            states[ref] = VState(states[ref].rows, FEAT_REP)

    def assign(node, out):
        """Step helper: bind a node's output(s) into the body env."""
        if len(node.outputs) == 1:
            return [(node.outputs[0], out)]
        return list(zip(node.outputs, out))

    # ---- per-node planning ----------------------------------------------
    for node, (dev, fn) in zip(suffix, resolved):
        op, ins = node.op, node.inputs

        if op in AGG_OPS:
            h, nbr, mask = ins
            full_rows(h)
            get_h = feat_model_arg(h)
            steps.append(lambda e, n=node, f=fn, g=get_h, nb=nbr, mk=mask:
                         e.__setitem__(n.outputs[0], f(g(e), e[nb], e[mk])))
            states[node.outputs[0]] = VState(states[nbr].rows, FEAT_MODEL)

        elif op == "SDDMM":
            h, nbr, mask = ins
            full_rows(h)
            get_h = feat_model_arg(h)
            if states[nbr].rows == ROWS_DATA and d > 1:
                # the kernel pairs dst rows with h[:D]; under row striping
                # slice i's dst rows live at offset i*loc — shard-aware jnp
                def _sddmm_step(e, n=node, g=get_h, nb=nbr, mk=mask):
                    hh, nv, mv = g(e), e[nb], e[mk]
                    selfh = _slice_rows(hh, nv.shape[0])
                    out = (jnp.take(hh, nv, axis=0) * selfh[:, None, :]
                           * mv[..., None])
                    e[n.outputs[0]] = out
                steps.append(_sddmm_step)
            else:
                steps.append(lambda e, n=node, f=fn, g=get_h, nb=nbr,
                             mk=mask: e.__setitem__(
                                 n.outputs[0], f(g(e), e[nb], e[mk])))
            states[node.outputs[0]] = VState(states[nbr].rows, FEAT_MODEL)

        elif op == "Prefix":
            h, nbr = ins
            full_rows(h)
            hfeat = states[h].feat
            if states[nbr].rows == ROWS_DATA and d > 1:
                steps.append(lambda e, n=node, hr=h, nb=nbr: e.__setitem__(
                    n.outputs[0], _slice_rows(e[hr], e[nb].shape[0])))
            else:
                steps.append(lambda e, n=node, f=fn, hr=h, nb=nbr:
                             e.__setitem__(n.outputs[0], f(e[hr], e[nb])))
            states[node.outputs[0]] = VState(states[nbr].rows, hfeat)

        elif op == "GEMM" and states.get(ins[1]) is _WEIGHT:
            x, w = ins
            get_x = feat_model_arg(x)

            def _gemm_step(e, n=node, f=fn, g=get_x, wr=w):
                z = f(g(e), e[wr])
                if m > 1:
                    z = jax.lax.psum(z, ma)
                e[n.outputs[0]] = z
            steps.append(_gemm_step)
            states[node.outputs[0]] = VState(states[x].rows, FEAT_REP)

        elif op == _FUSED_OP and states.get(ins[3]) is _WEIGHT:
            h, nbr, mask, w, b = ins
            full_rows(h)
            get_h = feat_model_arg(h)
            try:
                _, pfn = registry.resolve("AggCombinePartial")
            except KeyError:
                pfn = _agg_partial_ref

            def _fused_step(e, n=node, pf=pfn, g=get_h, nb=nbr, mk=mask,
                            wr=w, br=b):
                z = pf(g(e), e[nb], e[mk], e[wr])
                if m > 1:
                    z = jax.lax.psum(z, ma)
                e[n.outputs[0]] = jnp.maximum(z + e[br], 0.0)
            steps.append(_fused_step)
            states[node.outputs[0]] = VState(states[nbr].rows, FEAT_REP)

        elif op == "BiasAdd":
            x, b = ins
            sx = states[x]
            get_b = (feat_model_arg(b) if sx.feat == FEAT_MODEL
                     else (lambda e, r=b: e[r]))
            steps.append(lambda e, n=node, f=fn, xr=x, g=get_b:
                         e.__setitem__(n.outputs[0], f(e[xr], g(e))))
            states[node.outputs[0]] = sx

        elif op in ("ReLU", "LeakyReLU", "Scale"):
            steps.append(lambda e, n=node, f=fn: e.__setitem__(
                n.outputs[0],
                f(*(e[i] for i in n.inputs), **n.attrs) if n.attrs
                else f(*(e[i] for i in n.inputs))))
            states[node.outputs[0]] = states.get(ins[0], VState())

        elif op == "DegNorm":
            steps.append(lambda e, n=node, f=fn: e.__setitem__(
                n.outputs[0], f(e[n.inputs[0]])))
            states[node.outputs[0]] = VState(
                states.get(ins[0], VState()).rows, FEAT_REP)

        elif op == "Reduce":
            x = ins[0]
            ndim = len(gshape[x])
            ax = node.attrs.get("axis", 1) % ndim
            if ax == 0:
                full_rows(x)
            if ax == ndim - 1:
                rep_everything(x)
            steps.append(lambda e, n=node, f=fn: e.__setitem__(
                n.outputs[0], f(e[n.inputs[0]], **n.attrs)))
            states[node.outputs[0]] = states[x]

        elif op in ("Add", "Mul"):
            x, y = ins
            sx = states.get(x, VState())
            sy = states.get(y, VState())
            if _WEIGHT in (sx, sy):
                raise SpmdPlanError(f"weight input consumed by {op}")
            gx, gy = gshape.get(x), gshape.get(y)
            getx = lambda e, r=x: e[r]          # noqa: E731
            gety = lambda e, r=y: e[r]          # noqa: E731
            # unify rows: row-slice the replicated side (leading dims match)
            rows = ROWS_FULL
            if sx.rows == ROWS_DATA or sy.rows == ROWS_DATA:
                rows = ROWS_DATA
                if sx.rows != ROWS_DATA and len(gx) >= 1 and d > 1:
                    getx = lambda e, r=x, lc=gx[0] // d: _slice_rows(e[r], lc)  # noqa: E731,E501
                if sy.rows != ROWS_DATA and len(gy) >= 1 and d > 1:
                    gety = lambda e, r=y, lc=gy[0] // d: _slice_rows(e[r], lc)  # noqa: E731,E501
            # unify feat: feature-slice the replicated side unless it
            # broadcasts (trailing width 1 / lower rank)
            feat = FEAT_REP
            if sx.feat == FEAT_MODEL or sy.feat == FEAT_MODEL:
                feat = FEAT_MODEL
                if sx.feat != FEAT_MODEL and gx and gx[-1] != 1 and m > 1:
                    getx = (lambda e, g0=getx: _slice_feat(g0(e)))
                if sy.feat != FEAT_MODEL and gy and gy[-1] != 1 and m > 1:
                    gety = (lambda e, g0=gety: _slice_feat(g0(e)))
            steps.append(lambda e, n=node, f=fn, g1=getx, g2=gety:
                         e.__setitem__(n.outputs[0], f(g1(e), g2(e))))
            states[node.outputs[0]] = VState(rows, feat)

        else:
            # outside the SPMD vocabulary: run fully replicated
            for i in ins:
                rep_everything(i)
            steps.append(lambda e, n=node, f=fn, a=assign: [
                e.__setitem__(r, v) for r, v in a(
                    n, f(*(e[i] for i in n.inputs), **n.attrs) if n.attrs
                    else f(*(e[i] for i in n.inputs)))])
            for o in node.outputs:
                states[o] = VState()

    # ---- output specs (shard_map reassembles striped outputs) ------------
    out_specs = []
    for r in suffix_outs:
        st, rank = states[r], len(gshape[r])
        if st is _WEIGHT:
            raise SpmdPlanError(f"DFG output {r!r} is a weight input")
        if rank < 2 and (st.rows == ROWS_DATA or st.feat == FEAT_MODEL):
            rep_everything(r)
            st = states[r]
        lead = da if st.rows == ROWS_DATA else None
        trail = ma if st.feat == FEAT_MODEL else None
        out_specs.append(
            P(*([lead] + [None] * (rank - 2) + [trail])) if rank >= 2
            else P(*([None] * rank)))

    in_specs = tuple(_input_spec(roles.get(r), len(env[r].shape), da, ma)
                     for r in arr_refs)

    def body(*vals):
        e: dict[str, Any] = dict(static_env)
        e.update(zip(arr_refs, vals))
        for step in steps:
            step(e)
        return tuple(e[r] for r in suffix_outs)

    mapped = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=tuple(out_specs))

    def program(*vals):
        padded = [jnp.pad(v, pads[r]) if r in pads else v
                  for r, v in zip(arr_refs, vals)]
        outs = mapped(*padded)
        return tuple(
            o[tuple(slice(0, s) for s in true_out[r])]
            if tuple(o.shape) != tuple(true_out[r]) else o
            for o, r in zip(outs, suffix_outs))

    return program
