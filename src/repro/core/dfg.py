"""GraphRunner's dataflow-graph (DFG) program model — paper §4.2, Fig. 10.

Users describe a GNN (or any computation) as a DFG of abstract C-operations
via ``createIn/createOp/createOut``; ``save()`` emits the paper's markup
file: a topologically-sorted node list where each node records its sequence
number, C-operation name, input refs (``"<node>_<slot>"`` or an input name)
and output refs.  The engine deserializes the markup, resolves every
C-operation against the registry (device-priority dynamic binding) and
executes node by node — no cross-compilation, reprogrammable at run time.
"""
from __future__ import annotations

import json
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

from .registry import KernelRegistry


@dataclass
class _Node:
    seq: int
    op: str
    inputs: list[str]
    outputs: list[str]
    attrs: dict = field(default_factory=dict)


class Ref(str):
    """A value reference inside a DFG ("Weight", "2_0", ...)."""


class DFG:
    def __init__(self):
        self._nodes: list[_Node] = []
        self._ins: list[str] = []
        self._outs: dict[str, str] = {}
        self._markup_cache: str | None = None   # memoized save() output

    # ------------------------------------------------- paper creation API
    def create_in(self, name: str) -> Ref:
        self._ins.append(name)
        self._markup_cache = None
        return Ref(name)

    def create_op(self, op: str, inputs: list[Ref], n_out: int = 1,
                  attrs: dict | None = None) -> list[Ref]:
        seq = len(self._nodes)
        outs = [f"{seq}_{i}" for i in range(n_out)]
        self._nodes.append(_Node(seq, op, [str(i) for i in inputs], outs,
                                 attrs or {}))
        self._markup_cache = None
        return [Ref(o) for o in outs]

    def create_out(self, name: str, src: Ref) -> None:
        self._outs[name] = str(src)
        self._markup_cache = None

    # ------------------------------------------------- markup (de)serialize
    def save(self) -> str:
        """Markup file (paper Fig. 10c), JSON-encoded.  Memoized: the jit
        engine keys its trace cache on this string every call, and a
        round-tripped DFG already holds its own markup."""
        if self._markup_cache is None:
            self._markup_cache = json.dumps({
                "inputs": self._ins,
                "nodes": [{"seq": n.seq, "op": n.op, "in": n.inputs,
                           "out": n.outputs, "attrs": n.attrs}
                          for n in self._nodes],
                "outputs": self._outs,
            })
        return self._markup_cache

    @classmethod
    def load(cls, markup: str) -> "DFG":
        obj = json.loads(markup)
        dfg = cls()
        dfg._ins = list(obj["inputs"])
        dfg._nodes = [_Node(n["seq"], n["op"], list(n["in"]), list(n["out"]),
                            dict(n.get("attrs", {}))) for n in obj["nodes"]]
        dfg._outs = dict(obj["outputs"])
        dfg._markup_cache = markup
        return dfg

    # ------------------------------------------------- topological order
    def topo_nodes(self) -> list[_Node]:
        """Nodes sorted so every input is produced before use (paper: the DFG
        is converted to a computational structure by topological sort)."""
        produced = set(self._ins)
        remaining = list(self._nodes)
        order: list[_Node] = []
        while remaining:
            progressed = False
            for n in list(remaining):
                if all(i in produced for i in n.inputs):
                    order.append(n)
                    produced.update(n.outputs)
                    remaining.remove(n)
                    progressed = True
            if not progressed:
                raise ValueError("DFG has a cycle or missing input: "
                                 f"{[n.op for n in remaining]}")
        return order


# GCN layer chain folded into the fused aggregate-combine C-operation:
# SpMM_Mean -> GEMM -> BiasAdd -> ReLU   =>   AggCombine(h, nbr, mask, w, b)
_FUSE_CHAIN = ("SpMM_Mean", "GEMM", "BiasAdd", "ReLU")
_FUSED_OP = "AggCombine"


def fuse_aggregate_combine(nodes: list[_Node],
                           protected: set[str]) -> list[_Node]:
    """Rewrite SpMM_Mean->GEMM->BiasAdd->ReLU chains into AggCombine nodes.

    A chain fuses only when every intermediate value has exactly one
    consumer and is not a DFG output (``protected``).  The fused node is
    placed at the ReLU's position, where all five inputs are available.
    """
    uses: dict[str, int] = {}
    consumer: dict[str, _Node] = {}
    for n in nodes:
        for i in n.inputs:
            uses[i] = uses.get(i, 0) + 1
            consumer[i] = n
    for r in protected:
        uses[r] = uses.get(r, 0) + 2        # never fuse across an output

    drop: set[int] = set()
    replace: dict[int, _Node] = {}          # seq of ReLU node -> fused node
    for n in nodes:
        if n.op != _FUSE_CHAIN[0] or n.seq in drop:
            continue
        chain = [n]
        ok = True
        for want in _FUSE_CHAIN[1:]:
            ref = chain[-1].outputs[0]
            nxt = consumer.get(ref)
            if (len(chain[-1].outputs) != 1 or uses.get(ref) != 1
                    or nxt is None or nxt.op != want or nxt.inputs[0] != ref):
                ok = False
                break
            chain.append(nxt)
        if not ok:
            continue
        spmm_n, gemm_n, bias_n, relu_n = chain
        fused = _Node(relu_n.seq, _FUSED_OP,
                      list(spmm_n.inputs) + [gemm_n.inputs[1],
                                             bias_n.inputs[1]],
                      list(relu_n.outputs), {})
        drop.update(x.seq for x in (spmm_n, gemm_n, bias_n))
        replace[relu_n.seq] = fused

    if not replace:
        return nodes
    return [replace.get(n.seq, n) for n in nodes if n.seq not in drop]


class Engine:
    """GraphRunner execution engine: dynamic binding + per-node execution.

    Two execution paths share the dynamic-binding semantics:

      * **eager** (default): resolve + dispatch node by node, with honest
        per-node timings (``self.timings``);
      * **jit** (``run(..., jit=True)``): the maximal jit-safe suffix of the
        DFG is traced *once* through the currently-bound C-kernels and
        compiled as a single XLA program, cached per (markup, registry
        version, input shapes/dtypes).  Stateful C-operations (registered
        with ``jittable=False``, e.g. the near-storage BatchPre) run eagerly
        in front of the traced suffix.  Re-programming User logic bumps the
        registry version and invalidates stale traces.

    Both paths first apply the aggregate-combine fusion pass whenever a
    fused ``AggCombine`` C-kernel is resolvable (``fuse=None`` -> auto).

    **SPMD** (``mesh=``): with a (data, model) device mesh the jit path
    lowers the traced suffix through ``shard_map`` instead of plain jit —
    hidden/embedding dims striped across the ``model`` axis, super-batch
    rows across ``data``, psum/all_gather at combine boundaries (see
    ``core/spmd.py``).  The eager prefix (BatchPre) is unchanged; the mesh
    descriptor joins the jit cache key so the same engine can serve meshed
    and un-meshed programs side by side.

    The trace cache is a bounded LRU (``jit_cache_size`` entries, default
    32): long-lived serving processes see unbounded distinct shape
    signatures from pad-group drift, and every cached entry pins a compiled
    XLA executable.  Hits/misses/evictions are exposed via
    ``cache_stats()`` and surfaced in service stats / QoS snapshots.
    """

    def __init__(self, registry: KernelRegistry, *, mesh=None,
                 jit_cache_size: int = 32):
        self.registry = registry
        self.mesh = mesh
        self.trace: list[tuple[str, str]] = []     # (op, device) per executed node
        self.timings: list[tuple[str, str, float]] = []
        if jit_cache_size < 1:
            raise ValueError(f"jit_cache_size must be >= 1, got "
                             f"{jit_cache_size}")
        self._jit_cache: OrderedDict = OrderedDict()
        self._jit_cache_size = jit_cache_size
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_evictions = 0

    def cache_stats(self) -> dict:
        """LRU jit-cache counters (entries pin compiled XLA executables)."""
        return {"size": len(self._jit_cache),
                "capacity": self._jit_cache_size,
                "hits": self._cache_hits,
                "misses": self._cache_misses,
                "evictions": self._cache_evictions}

    def run(self, dfg: DFG, feeds: dict[str, Any], *, jit: bool = False,
            fuse: bool | None = None) -> dict[str, Any]:
        env: dict[str, Any] = dict(feeds)
        missing = [i for i in dfg._ins if i not in env]
        if missing:
            raise KeyError(f"missing DFG inputs: {missing}")
        order = dfg.topo_nodes()
        if fuse is None:
            fuse = _FUSED_OP in self.registry.ops
        if fuse:
            order = fuse_aggregate_combine(order, set(dfg._outs.values()))
        self.trace = []
        self.timings = []
        if jit:
            return self._run_jit(dfg, order, env, fuse)
        for node in order:
            self._exec_node(node, env)
        return {name: env[src] for name, src in dfg._outs.items()}

    # ------------------------------------------------------------ eager path
    def _exec_node(self, node: _Node, env: dict[str, Any]) -> None:
        import time as _time
        device, fn = self.registry.resolve(node.op)
        self.trace.append((node.op, device))
        args = [env[i] for i in node.inputs]
        t0 = _time.perf_counter()
        out = fn(*args, **node.attrs) if node.attrs else fn(*args)
        out = _block(out)
        self.timings.append((node.op, device, _time.perf_counter() - t0))
        if len(node.outputs) == 1:
            env[node.outputs[0]] = out
        else:
            for ref, val in zip(node.outputs, out):
                env[ref] = val

    # -------------------------------------------------------------- jit path
    def _run_jit(self, dfg: DFG, order: list[_Node], env: dict[str, Any],
                 fuse: bool) -> dict[str, Any]:
        import time as _time
        # eager prefix: through the last jit-unsafe (stateful) node
        cut = 0
        for idx, node in enumerate(order):
            if node.op in self.registry.unjittable:
                cut = idx + 1
        for node in order[:cut]:
            self._exec_node(node, env)
        suffix = order[cut:]
        if not suffix:
            return {name: env[src] for name, src in dfg._outs.items()}

        produced: set[str] = set()
        for n in suffix:
            produced.update(n.outputs)
        in_refs = sorted({i for n in suffix for i in n.inputs
                          if i not in produced})
        suffix_outs = [src for src in dict.fromkeys(dfg._outs.values())
                       if src in produced]
        arr_refs, sig, static_env = [], [], {}
        for r in in_refs:
            v = env[r]
            if hasattr(v, "shape") and hasattr(v, "dtype"):
                arr_refs.append(r)
                sig.append((r, tuple(v.shape), str(v.dtype)))
            else:                       # non-array feeds are trace constants
                static_env[r] = v
                sig.append((r, "static", repr(v)))
        mesh_key = None
        if self.mesh is not None:
            from .spmd import mesh_descriptor
            mesh_key = mesh_descriptor(self.mesh)
        key = (dfg.save(), self.registry.version, fuse, tuple(sig),
               tuple(suffix_outs), mesh_key)
        hit = self._jit_cache.get(key)
        if hit is not None:
            self._jit_cache.move_to_end(key)
            self._cache_hits += 1
        else:
            self._cache_misses += 1
            resolved = [self.registry.resolve(n.op) for n in suffix]
            trace = [(n.op, d) for n, (d, _) in zip(suffix, resolved)]

            import jax
            if self.mesh is not None:
                from .spmd import build_sharded_program
                _program = build_sharded_program(
                    suffix, resolved, arr_refs, static_env, suffix_outs,
                    env, self.mesh, self.registry)
            else:
                def _program(*vals):
                    e = dict(static_env)
                    e.update(zip(arr_refs, vals))
                    for node, (_, fn) in zip(suffix, resolved):
                        args = [e[i] for i in node.inputs]
                        out = (fn(*args, **node.attrs) if node.attrs
                               else fn(*args))
                        if len(node.outputs) == 1:
                            e[node.outputs[0]] = out
                        else:
                            for ref, val in zip(node.outputs, out):
                                e[ref] = val
                    return tuple(e[r] for r in suffix_outs)

            hit = (jax.jit(_program), trace)
            self._jit_cache[key] = hit
            while len(self._jit_cache) > self._jit_cache_size:
                self._jit_cache.popitem(last=False)
                self._cache_evictions += 1
        fn, trace = hit
        self.trace.extend(trace)
        t0 = _time.perf_counter()
        results = _block(fn(*(env[r] for r in arr_refs)))
        self.timings.append(("__dfg_jit__", "jit", _time.perf_counter() - t0))
        env.update(zip(suffix_outs, results))
        return {name: env[src] for name, src in dfg._outs.items()}


def _block(x):
    """Block on async results so per-node timings are honest."""
    try:
        import jax
        return jax.block_until_ready(x)
    except Exception:  # noqa: BLE001 — non-array outputs
        return x
