"""GraphRunner's dataflow-graph (DFG) program model — paper §4.2, Fig. 10.

Users describe a GNN (or any computation) as a DFG of abstract C-operations
via ``createIn/createOp/createOut``; ``save()`` emits the paper's markup
file: a topologically-sorted node list where each node records its sequence
number, C-operation name, input refs (``"<node>_<slot>"`` or an input name)
and output refs.  The engine deserializes the markup, resolves every
C-operation against the registry (device-priority dynamic binding) and
executes node by node — no cross-compilation, reprogrammable at run time.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any

from .registry import KernelRegistry


@dataclass
class _Node:
    seq: int
    op: str
    inputs: list[str]
    outputs: list[str]
    attrs: dict = field(default_factory=dict)


class Ref(str):
    """A value reference inside a DFG ("Weight", "2_0", ...)."""


class DFG:
    def __init__(self):
        self._nodes: list[_Node] = []
        self._ins: list[str] = []
        self._outs: dict[str, str] = {}

    # ------------------------------------------------- paper creation API
    def create_in(self, name: str) -> Ref:
        self._ins.append(name)
        return Ref(name)

    def create_op(self, op: str, inputs: list[Ref], n_out: int = 1,
                  attrs: dict | None = None) -> list[Ref]:
        seq = len(self._nodes)
        outs = [f"{seq}_{i}" for i in range(n_out)]
        self._nodes.append(_Node(seq, op, [str(i) for i in inputs], outs,
                                 attrs or {}))
        return [Ref(o) for o in outs]

    def create_out(self, name: str, src: Ref) -> None:
        self._outs[name] = str(src)

    # ------------------------------------------------- markup (de)serialize
    def save(self) -> str:
        """Markup file (paper Fig. 10c), JSON-encoded."""
        return json.dumps({
            "inputs": self._ins,
            "nodes": [{"seq": n.seq, "op": n.op, "in": n.inputs,
                       "out": n.outputs, "attrs": n.attrs}
                      for n in self._nodes],
            "outputs": self._outs,
        })

    @classmethod
    def load(cls, markup: str) -> "DFG":
        obj = json.loads(markup)
        dfg = cls()
        dfg._ins = list(obj["inputs"])
        dfg._nodes = [_Node(n["seq"], n["op"], list(n["in"]), list(n["out"]),
                            dict(n.get("attrs", {}))) for n in obj["nodes"]]
        dfg._outs = dict(obj["outputs"])
        return dfg

    # ------------------------------------------------- topological order
    def topo_nodes(self) -> list[_Node]:
        """Nodes sorted so every input is produced before use (paper: the DFG
        is converted to a computational structure by topological sort)."""
        produced = set(self._ins)
        remaining = list(self._nodes)
        order: list[_Node] = []
        while remaining:
            progressed = False
            for n in list(remaining):
                if all(i in produced for i in n.inputs):
                    order.append(n)
                    produced.update(n.outputs)
                    remaining.remove(n)
                    progressed = True
            if not progressed:
                raise ValueError("DFG has a cycle or missing input: "
                                 f"{[n.op for n in remaining]}")
        return order


class Engine:
    """GraphRunner execution engine: dynamic binding + per-node execution."""

    def __init__(self, registry: KernelRegistry):
        self.registry = registry
        self.trace: list[tuple[str, str]] = []     # (op, device) per executed node
        self.timings: list[tuple[str, str, float]] = []

    def run(self, dfg: DFG, feeds: dict[str, Any]) -> dict[str, Any]:
        import time as _time
        env: dict[str, Any] = dict(feeds)
        missing = [i for i in dfg._ins if i not in env]
        if missing:
            raise KeyError(f"missing DFG inputs: {missing}")
        self.trace = []
        self.timings = []
        for node in dfg.topo_nodes():
            device, fn = self.registry.resolve(node.op)
            self.trace.append((node.op, device))
            args = [env[i] for i in node.inputs]
            t0 = _time.perf_counter()
            out = fn(*args, **node.attrs) if node.attrs else fn(*args)
            out = _block(out)
            self.timings.append((node.op, device, _time.perf_counter() - t0))
            if len(node.outputs) == 1:
                env[node.outputs[0]] = out
            else:
                for ref, val in zip(node.outputs, out):
                    env[ref] = val
        return {name: env[src] for name, src in dfg._outs.items()}


def _block(x):
    """Block on async results so per-node timings are honest."""
    try:
        import jax
        return jax.block_until_ready(x)
    except Exception:  # noqa: BLE001 — non-array outputs
        return x
