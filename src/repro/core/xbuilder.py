"""XBuilder — accelerator building system (paper §4.3), TPU-adapted.

The paper splits the FPGA die into **Shell** (fixed logic: storage, runtime,
ICAP engine) and **User** (swappable accelerator, programmed as a partial
bitstream through ``Program()``).  On TPU there are no gates to rewire; the
faithful analog is *runtime re-binding of compiled kernels*:

  * **Shell** = the always-present pure-`jnp` C-kernels (device ``"cpu"``,
    priority 50) — the framework can always run, like the paper's Shell cores.
  * **User bitstreams** = named kernel sets (e.g. Pallas MXU GEMM = the
    systolic array, Pallas VPU SpMM = the vector processor).  ``program()``
    registers a bitstream's device + kernels into the registry;
    ``unprogram()`` removes it (DFX decoupler).  Reconfiguration time =
    registration + (re)compilation, which we measure and report.

Building blocks (paper Table 2): GEMM, ElementWise, Reduce, SpMM, SDDMM.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp

from .registry import KernelRegistry

SHELL_DEVICE = "cpu"
SHELL_PRIORITY = 50


@dataclass
class Bitstream:
    """A 'partial bitfile': a device plus its C-kernel implementations."""
    device: str
    priority: int
    kernels: dict[str, Callable] = field(default_factory=dict)


class XBuilder:
    def __init__(self, registry: KernelRegistry):
        self.registry = registry
        self.loaded: dict[str, Bitstream] = {}
        self.reconfig_log: list[tuple[str, float]] = []
        self._install_shell()

    # ----------------------------------------------------------- Shell logic
    def _install_shell(self) -> None:
        r = self.registry
        r.register_device(SHELL_DEVICE, SHELL_PRIORITY)
        for name, fn in shell_kernels().items():
            r.register_op(name, SHELL_DEVICE, fn)

    # ------------------------------------------------------------ User logic
    def program(self, bitstream: Bitstream) -> float:
        """Paper Program(bitfile): swap in User logic; returns reconfig secs."""
        t0 = time.perf_counter()
        if bitstream.device in self.loaded:
            self.unprogram(bitstream.device)
        self.registry.register_device(bitstream.device, bitstream.priority)
        for op, fn in bitstream.kernels.items():
            self.registry.register_op(op, bitstream.device, fn)
        self.loaded[bitstream.device] = bitstream
        dt = time.perf_counter() - t0
        self.reconfig_log.append((bitstream.device, dt))
        return dt

    def unprogram(self, device: str) -> None:
        if device == SHELL_DEVICE:
            raise ValueError("Shell logic cannot be unprogrammed")
        self.registry.unregister_device(device)
        self.loaded.pop(device, None)


# ----------------------------------------------------------- Shell C-kernels
def shell_kernels() -> dict[str, Callable]:
    """Pure-jnp reference implementations of the Table-2 building blocks plus
    the GNN C-operations used by the paper's DFG example (Fig. 10)."""

    def gemm(a, b):
        return jnp.dot(a, b, preferred_element_type=jnp.float32)

    def spmm(h, nbr, mask, *, mode: str = "mean"):
        # ELL/page-format aggregation: h (N,F); nbr,mask (D,K) -> (D,F)
        g = jnp.take(h, nbr, axis=0) * mask[..., None]
        s = g.sum(axis=1)
        if mode == "sum":
            return s
        deg = jnp.maximum(mask.sum(axis=1), 1.0)
        return s / deg[:, None]

    def sddmm(h, nbr, mask):
        # per-edge elementwise product with the destination row (NGCF term):
        # out[i,k,:] = h[i,:] * h[nbr[i,k],:]        (D,K,F)
        g = jnp.take(h, nbr, axis=0)
        d = h[: nbr.shape[0]]
        return g * d[:, None, :] * mask[..., None]

    def elementwise(x, y=None, *, op: str = "relu"):
        if op == "relu":
            return jnp.maximum(x, 0.0)
        if op == "add":
            return x + y
        if op == "mul":
            return x * y
        raise ValueError(op)

    def reduce_(x, *, axis: int = 1, op: str = "sum"):
        if op == "sum":
            return x.sum(axis=axis)
        if op == "mean":
            return x.mean(axis=axis)
        if op == "max":
            return x.max(axis=axis)
        raise ValueError(op)

    def bias_add(x, b):
        return x + b[None, :]

    return {
        "GEMM": gemm,
        "SpMM": spmm,
        "SpMM_Mean": lambda h, nbr, mask: spmm(h, nbr, mask, mode="mean"),
        "SpMM_Sum": lambda h, nbr, mask: spmm(h, nbr, mask, mode="sum"),
        "SDDMM": sddmm,
        "ElementWise": elementwise,
        "ReLU": lambda x: elementwise(x, op="relu"),
        "Add": lambda x, y: elementwise(x, y, op="add"),
        "Mul": lambda x, y: elementwise(x, y, op="mul"),
        "Reduce": reduce_,
        "BiasAdd": bias_add,
        "Scale": lambda x, s: x * s,
    }
