"""GNN models — GCN, GIN, NGCF (paper §2.1), in two executable forms:

1. **Direct JAX** forward functions (jit-able; the training/validation oracle).
2. **DFG builders** emitting the paper-style computational graph (Fig. 10)
   whose C-operations the GraphRunner engine binds to registered C-kernels
   (Shell jnp or User Pallas) at run time.  Tests assert form 2 == form 1.

All models consume the sampled page-format blocks produced by
``repro.store.sampler``: per GNN layer a ``(num_dst, fanout)`` neighbor-index
matrix + mask over the previous level's node embeddings.

* GCN  — average aggregation (degree-normalized), 1-layer transform + ReLU.
* GIN  — summation aggregation with learnable self-weight eps and a 2-layer
         MLP transform (the paper's "more expressively powerful" combination).
* NGCF — similarity-aware aggregation: element-wise product of neighbor and
         target embeddings feeds a second weight matrix (heaviest aggregation
         of the three, paper Fig. 16c).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .dfg import DFG

# ----------------------------------------------------------------- params

def _glorot(rng, fan_in, fan_out):
    s = np.sqrt(6.0 / (fan_in + fan_out))
    return jnp.asarray(rng.uniform(-s, s, (fan_in, fan_out)), dtype=jnp.float32)


def init_params(model: str, dims: list[int], seed: int = 0) -> list[dict]:
    """dims = [F_in, F_h1, ..., F_out]; one param dict per GNN layer."""
    rng = np.random.default_rng(seed)
    params = []
    for fi, fo in zip(dims[:-1], dims[1:]):
        if model == "gcn":
            params.append({"W": _glorot(rng, fi, fo),
                           "b": jnp.zeros((fo,), jnp.float32)})
        elif model == "gin":
            params.append({
                "eps": jnp.zeros((), jnp.float32),
                "W1": _glorot(rng, fi, fo), "b1": jnp.zeros((fo,), jnp.float32),
                "W2": _glorot(rng, fo, fo), "b2": jnp.zeros((fo,), jnp.float32),
            })
        elif model == "ngcf":
            params.append({"W1": _glorot(rng, fi, fo),
                           "W2": _glorot(rng, fi, fo),
                           "b": jnp.zeros((fo,), jnp.float32)})
        else:
            raise ValueError(model)
    return params


# ------------------------------------------------------------- aggregation
def agg_mean(h, nbr, mask):
    g = jnp.take(h, nbr, axis=0) * mask[..., None]
    deg = jnp.maximum(mask.sum(axis=1), 1.0)
    return g.sum(axis=1) / deg[:, None]


def agg_sum(h, nbr, mask):
    g = jnp.take(h, nbr, axis=0) * mask[..., None]
    return g.sum(axis=1)


# ------------------------------------------------------------ direct models
def gcn_forward(params, emb, blocks):
    """blocks: [(nbr, mask), ...] ordered layer_1..layer_L (outermost first)."""
    h = emb
    for p, (nbr, mask) in zip(params, blocks):
        h = agg_mean(h, nbr, mask)
        h = jnp.dot(h, p["W"], preferred_element_type=jnp.float32) + p["b"]
        h = jax.nn.relu(h)
    return h


def gin_forward(params, emb, blocks):
    h = emb
    for p, (nbr, mask) in zip(params, blocks):
        s = agg_sum(h, nbr, mask)                       # includes self-loop
        self_h = h[: nbr.shape[0]]                      # prefix ordering
        z = s + p["eps"] * self_h                       # (1+eps)·self + Σ nbrs
        z = jnp.dot(z, p["W1"], preferred_element_type=jnp.float32) + p["b1"]
        z = jax.nn.relu(z)
        z = jnp.dot(z, p["W2"], preferred_element_type=jnp.float32) + p["b2"]
        h = jax.nn.relu(z)
    return h


def ngcf_forward(params, emb, blocks, *, alpha: float = 0.2):
    h = emb
    for p, (nbr, mask) in zip(params, blocks):
        self_h = h[: nbr.shape[0]]
        g = jnp.take(h, nbr, axis=0)                       # (D,K,F) neighbors
        prod = g * self_h[:, None, :]                      # similarity term
        deg = jnp.maximum(mask.sum(axis=1), 1.0)[:, None]
        m1 = (g * mask[..., None]).sum(axis=1) / deg
        m2 = (prod * mask[..., None]).sum(axis=1) / deg
        z = (jnp.dot(m1, p["W1"], preferred_element_type=jnp.float32)
             + jnp.dot(m2, p["W2"], preferred_element_type=jnp.float32)
             + jnp.dot(self_h, p["W1"], preferred_element_type=jnp.float32)
             + p["b"])
        h = jnp.where(z > 0, z, alpha * z)                 # LeakyReLU
    return h


FORWARD = {"gcn": gcn_forward, "gin": gin_forward, "ngcf": ngcf_forward}


# ---------------------------------------------------------------- DFG form
def build_gcn_dfg(num_layers: int) -> DFG:
    """Paper Fig. 10b: Batch -> SpMM_Mean -> GEMM(+W) -> ReLU, per layer."""
    g = DFG()
    h = g.create_in("H")
    for l in range(num_layers):
        nbr = g.create_in(f"nbr{l}")
        mask = g.create_in(f"mask{l}")
        w = g.create_in(f"W{l}")
        b = g.create_in(f"b{l}")
        (a,) = g.create_op("SpMM_Mean", [h, nbr, mask])
        (m,) = g.create_op("GEMM", [a, w])
        (m,) = g.create_op("BiasAdd", [m, b])
        (h,) = g.create_op("ReLU", [m])
    g.create_out("Result", h)
    return g


def build_gin_dfg(num_layers: int) -> DFG:
    g = DFG()
    h = g.create_in("H")
    for l in range(num_layers):
        nbr = g.create_in(f"nbr{l}")
        mask = g.create_in(f"mask{l}")
        eps = g.create_in(f"eps{l}")
        w1, b1 = g.create_in(f"W1_{l}"), g.create_in(f"b1_{l}")
        w2, b2 = g.create_in(f"W2_{l}"), g.create_in(f"b2_{l}")
        (s,) = g.create_op("SpMM_Sum", [h, nbr, mask])
        (selfh,) = g.create_op("Prefix", [h, nbr])
        (se,) = g.create_op("Scale", [selfh, eps])
        (z,) = g.create_op("Add", [s, se])
        (z,) = g.create_op("GEMM", [z, w1])
        (z,) = g.create_op("BiasAdd", [z, b1])
        (z,) = g.create_op("ReLU", [z])
        (z,) = g.create_op("GEMM", [z, w2])
        (z,) = g.create_op("BiasAdd", [z, b2])
        (h,) = g.create_op("ReLU", [z])
    g.create_out("Result", h)
    return g


def build_ngcf_dfg(num_layers: int) -> DFG:
    g = DFG()
    h = g.create_in("H")
    for l in range(num_layers):
        nbr = g.create_in(f"nbr{l}")
        mask = g.create_in(f"mask{l}")
        w1, w2, b = (g.create_in(f"W1_{l}"), g.create_in(f"W2_{l}"),
                     g.create_in(f"b{l}"))
        (m1,) = g.create_op("SpMM_Mean", [h, nbr, mask])
        (prod,) = g.create_op("SDDMM", [h, nbr, mask])          # (D,K,F)
        (m2sum,) = g.create_op("Reduce", [prod], attrs={"axis": 1, "op": "sum"})
        (deg,) = g.create_op("DegNorm", [mask])
        (m2,) = g.create_op("Mul", [m2sum, deg])
        (selfh,) = g.create_op("Prefix", [h, nbr])
        (t1,) = g.create_op("GEMM", [m1, w1])
        (t2,) = g.create_op("GEMM", [m2, w2])
        (t3,) = g.create_op("GEMM", [selfh, w1])
        (z,) = g.create_op("Add", [t1, t2])
        (z,) = g.create_op("Add", [z, t3])
        (z,) = g.create_op("BiasAdd", [z, b])
        (h,) = g.create_op("LeakyReLU", [z])
    g.create_out("Result", h)
    return g


BUILD_DFG = {"gcn": build_gcn_dfg, "gin": build_gin_dfg, "ngcf": build_ngcf_dfg}


def extra_shell_kernels() -> dict:
    """GNN-specific helper C-operations used by the DFG forms."""
    return {
        "Prefix": lambda h, nbr: h[: nbr.shape[0]],
        "DegNorm": lambda mask: 1.0 / jnp.maximum(mask.sum(axis=1), 1.0)[:, None],
        "LeakyReLU": lambda z: jnp.where(z > 0, z, 0.2 * z),
    }


def dfg_feeds(model: str, params, emb, blocks) -> dict:
    """Assemble the feed dict matching the build_*_dfg input names."""
    feeds = {"H": emb}
    for l, (nbr, mask) in enumerate(blocks):
        feeds[f"nbr{l}"] = nbr
        feeds[f"mask{l}"] = mask
    for l, p in enumerate(params):
        if model == "gcn":
            feeds[f"W{l}"], feeds[f"b{l}"] = p["W"], p["b"]
        elif model == "gin":
            feeds[f"eps{l}"] = p["eps"]
            feeds[f"W1_{l}"], feeds[f"b1_{l}"] = p["W1"], p["b1"]
            feeds[f"W2_{l}"], feeds[f"b2_{l}"] = p["W2"], p["b2"]
        elif model == "ngcf":
            feeds[f"W1_{l}"], feeds[f"W2_{l}"] = p["W1"], p["W2"]
            feeds[f"b{l}"] = p["b"]
    return feeds
