from .registry import KernelRegistry
from .dfg import DFG, Engine
from .xbuilder import XBuilder, Bitstream, shell_kernels, SHELL_DEVICE
from .service import HolisticGNNService, make_service_dfg
from . import gnn

__all__ = ["KernelRegistry", "DFG", "Engine", "XBuilder", "Bitstream",
           "shell_kernels", "SHELL_DEVICE", "HolisticGNNService",
           "make_service_dfg", "gnn"]
