"""C-operation / C-kernel registry — paper Table 3 semantics.

Two metadata structures drive GraphRunner's dynamic binding:

  * **device table**: device name -> priority (RegisterDevice),
  * **operation table**: C-operation name -> [(device, C-kernel ptr), ...]
    (RegisterOpDefinition; multiple kernels per operation allowed).

At execution time the engine resolves each C-operation to the registered
C-kernel whose device has the *highest priority* — e.g. with
CPU=50 < vector=150 < systolic=300, a GEMM with all three kernels runs on
the systolic implementation.  This is exactly how the paper routes GEMM to
Gemmini and SpMM to Hwacha in the Hetero configuration.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable


@dataclass
class KernelRegistry:
    devices: dict[str, int] = field(default_factory=dict)
    ops: dict[str, list[tuple[str, Callable]]] = field(default_factory=dict)
    unjittable: set[str] = field(default_factory=set)
    # monotonically bumped on every (un)registration — the engine's whole-DFG
    # jit cache keys on it so reprogramming invalidates stale traces.
    version: int = 0

    # -- paper: RegisterDevice(newDevice)
    def register_device(self, name: str, priority: int) -> None:
        self.devices[name] = int(priority)
        self.version += 1

    # -- paper: RegisterOpDefinition(newOp)
    def register_op(self, op_name: str, device: str, fn: Callable, *,
                    jittable: bool = True) -> None:
        if device not in self.devices:
            raise KeyError(f"device {device!r} not registered")
        lst = self.ops.setdefault(op_name, [])
        lst[:] = [(d, f) for (d, f) in lst if d != device]   # re-registration wins
        lst.append((device, fn))
        if not jittable:
            self.unjittable.add(op_name)
        else:
            self.unjittable.discard(op_name)                 # re-registration wins
        self.version += 1

    def unregister_device(self, device: str) -> None:
        """Drop a device and all its kernels (XBuilder partial reconfig)."""
        self.devices.pop(device, None)
        for name in list(self.ops):
            self.ops[name] = [(d, f) for (d, f) in self.ops[name] if d != device]
            if not self.ops[name]:
                del self.ops[name]
                self.unjittable.discard(name)
        self.version += 1

    def resolve(self, op_name: str) -> tuple[str, Callable]:
        cands = self.ops.get(op_name)
        if not cands:
            raise KeyError(f"no C-kernel registered for C-operation {op_name!r}")
        return max(cands, key=lambda df: self.devices.get(df[0], -1))

    def dispatch(self, op_name: str, *args, **kwargs):
        _, fn = self.resolve(op_name)
        return fn(*args, **kwargs)

    def snapshot(self) -> dict:
        return {op: [d for d, _ in lst] for op, lst in self.ops.items()}
