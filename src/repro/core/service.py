"""HolisticGNN service facade — the CSSD-resident endpoint exposing the
paper's Table-1 RPCs (GraphStore bulk/unit ops, GraphRunner Run/Plugin,
XBuilder Program) over one object, suitable for RPCServer dispatch.

``run`` executes the full near-storage inference pipeline: the DFG's
``BatchPre`` C-operation performs node sampling + reindexing + embedding
gather *against the page store* (no host round-trip), then the engine
binds and executes the model's C-operations by device priority.
"""
from __future__ import annotations

import importlib

import numpy as np
import jax.numpy as jnp

from ..store.blockdev import BlockDevice
from ..store.graphstore import GraphStore
from ..store.sampler import sample_batch
from .dfg import DFG, Engine
from .registry import KernelRegistry
from .xbuilder import XBuilder, Bitstream, SHELL_DEVICE
from . import gnn


class HolisticGNNService:
    def __init__(self, *, h_threshold: int = 128, pad_to: int = 64,
                 dev: BlockDevice | None = None):
        self.store = GraphStore(dev or BlockDevice(), h_threshold=h_threshold)
        self.registry = KernelRegistry()
        self.xbuilder = XBuilder(self.registry)
        for name, fn in gnn.extra_shell_kernels().items():
            self.registry.register_op(name, SHELL_DEVICE, fn)
        self._register_batchpre()
        self.engine = Engine(self.registry)
        self.pad_to = pad_to

    # ------------------------------------------------------------- GraphStore
    def update_graph(self, edge_array, embeddings=None):
        tl = self.store.update_graph(np.asarray(edge_array),
                                     None if embeddings is None
                                     else np.asarray(embeddings))
        return {"total_s": tl.total, "user_visible_s": tl.user_visible}

    def add_vertex(self, vid, embed=None):
        self.store.add_vertex(int(vid), embed)

    def delete_vertex(self, vid):
        self.store.delete_vertex(int(vid))

    def add_edge(self, dst, src):
        self.store.add_edge(int(dst), int(src))

    def delete_edge(self, dst, src):
        self.store.delete_edge(int(dst), int(src))

    def update_embed(self, vid, embed):
        self.store.update_embed(int(vid), np.asarray(embed))

    def get_embed(self, vid):
        return self.store.get_embed(int(vid))

    def get_neighbors(self, vid):
        return self.store.get_neighbors(int(vid))

    # ------------------------------------------------------------ GraphRunner
    def _register_batchpre(self):
        def batch_pre(targets, *, fanouts, seed=0):
            batch = sample_batch(self.store, np.asarray(targets), list(fanouts),
                                 rng=np.random.default_rng(seed),
                                 pad_to=self.pad_to)
            outs = [jnp.asarray(batch.embeddings)]
            for blk in batch.layers:
                outs.append(jnp.asarray(blk.nbr))
                outs.append(jnp.asarray(blk.mask))
            return tuple(outs)
        # stateful (touches the page store): must run eagerly ahead of the
        # engine's whole-DFG jit trace.
        self.registry.register_op("BatchPre", SHELL_DEVICE, batch_pre,
                                  jittable=False)

    def run(self, dfg: str, batch, weights: dict | None = None,
            fanouts=None, seed: int = 0, jit: bool = True):
        """Paper Run(DFG, batch).

        * If the DFG starts with a ``BatchPre`` node (service-style DFG),
          only the raw target VIDs are fed; sampling happens near storage.
        * Otherwise (model-only DFG, Fig. 10b) the service samples first and
          feeds H/nbr/mask inputs directly.

        ``jit=True`` (default) runs the model portion through the engine's
        cached whole-DFG trace; the sampler's ``pad_to`` bucketing keeps the
        number of distinct shape signatures (and hence compiles) small.
        """
        dfg_obj = DFG.load(dfg) if isinstance(dfg, str) else dfg
        feeds = dict(weights or {})
        if "Batch" in dfg_obj._ins:
            feeds["Batch"] = np.asarray(batch)
        else:
            assert fanouts is not None, "model-only DFG needs fanouts"
            b = sample_batch(self.store, np.asarray(batch), list(fanouts),
                             rng=np.random.default_rng(seed), pad_to=self.pad_to)
            feeds["H"] = jnp.asarray(b.embeddings)
            for l, blk in enumerate(b.layers):
                feeds[f"nbr{l}"] = jnp.asarray(blk.nbr)
                feeds[f"mask{l}"] = jnp.asarray(blk.mask)
        out = self.engine.run(dfg_obj, feeds, jit=jit)
        return {k: np.asarray(v) for k, v in out.items()}

    def plugin(self, shared_lib: str):
        """Paper Plugin(shared_lib): import a module exposing register(api)."""
        mod = importlib.import_module(shared_lib)
        mod.register(self.registry)
        return sorted(self.registry.devices)

    # --------------------------------------------------------------- XBuilder
    def program(self, device: str, priority: int, kernels: str):
        """Paper Program(bitfile): ``kernels`` names a module whose
        ``bitstream()`` returns {op_name: fn} — the partial bitfile."""
        mod = importlib.import_module(kernels)
        bs = Bitstream(device=device, priority=int(priority),
                       kernels=mod.bitstream())
        return self.xbuilder.program(bs)


def make_service_dfg(model: str, num_layers: int, fanouts) -> DFG:
    """Service-style DFG whose first node is BatchPre (paper Fig. 10a)."""
    g = DFG()
    batch = g.create_in("Batch")
    outs = g.create_op("BatchPre", [batch], n_out=1 + 2 * num_layers,
                       attrs={"fanouts": list(fanouts)})
    h, rest = outs[0], outs[1:]
    model_dfg = gnn.BUILD_DFG[model](num_layers)
    # splice: rewire the model DFG's inputs onto BatchPre outputs
    remap = {"H": str(h)}
    for l in range(num_layers):
        remap[f"nbr{l}"] = str(rest[2 * l])
        remap[f"mask{l}"] = str(rest[2 * l + 1])
    base = len(g._nodes)
    for node in model_dfg._nodes:
        new_in = []
        for i in node.inputs:
            if i in remap:
                new_in.append(remap[i])
            elif "_" in i and i.split("_")[0].isdigit():
                s, slot = i.rsplit("_", 1)
                new_in.append(f"{int(s) + base}_{slot}")
            else:                                     # weight input
                if i not in g._ins:
                    g.create_in(i)
                new_in.append(i)
        outs2 = [f"{node.seq + base}_{o.rsplit('_', 1)[1]}" for o in node.outputs]
        g._nodes.append(type(node)(node.seq + base, node.op, new_in, outs2,
                                   dict(node.attrs)))
    for name, src in model_dfg._outs.items():
        s, slot = src.rsplit("_", 1)
        g.create_out(name, f"{int(s) + base}_{slot}")
    return g
