"""HolisticGNN service facade — the CSSD-resident endpoint exposing the
paper's Table-1 RPCs (GraphStore bulk/unit ops, GraphRunner Run/Plugin,
XBuilder Program) over one object, suitable for RPCServer dispatch.

``run`` executes the full near-storage inference pipeline: the DFG's
``BatchPre`` C-operation performs node sampling + reindexing + embedding
gather *against the page store* (no host round-trip), then the engine
binds and executes the model's C-operations by device priority.
"""
from __future__ import annotations

import importlib

import numpy as np
import jax.numpy as jnp

from ..store.blockdev import BlockDevice
from ..store.graphstore import GraphStore
from ..store.sampler import sample_batch
from .dfg import DFG, Engine
from .registry import KernelRegistry
from .xbuilder import XBuilder, Bitstream, SHELL_DEVICE
from . import gnn


class HolisticGNNService:
    """The device-resident service object: Table-1 RPC surface over one
    store (single device, sharded array, or replicated array) plus the
    DFG engine, kernel registry, and XBuilder.  Construct once per
    array; dispatch via ``RPCServer`` or call directly in-process."""

    def __init__(self, *, h_threshold: int = 128, pad_to: int = 64,
                 dev: BlockDevice | None = None,
                 cache_pages: int | None = None,
                 n_shards: int = 1, devs: list | None = None,
                 endpoints: list | None = None,
                 replication: int = 1,
                 stats_staleness_s: float = 0.0,
                 flow=None,
                 mesh=None, model_parallel: int | None = None,
                 jit_cache_size: int = 32):
        """``n_shards > 1`` (or an explicit ``devs`` device list) backs the
        service with a hash-partitioned CSSD array (``ShardedGraphStore``)
        instead of one device — every RPC below is shard-transparent, and
        sampling stays bit-identical to the single-device store.

        ``endpoints=[...]`` passes the array as pre-built
        ``ShardEndpoint`` objects instead — e.g. ``make_rop_endpoints(N)``
        for a multi-host array whose shards sit behind their own RoP
        links.  The service (and everything above it) is
        endpoint-transparent: the same RPCs, the same bit-identical
        sampling, whichever transport the shards use.

        ``replication=R >= 2`` upgrades the array to a
        ``ReplicatedGraphStore``: R-way replica placement with
        replica-spread reads (fed by a gossiped counter view refreshed at
        most every ``stats_staleness_s`` seconds), write fan-out, and the
        ``fail_shard`` / ``rebuild_shard`` RPCs for serving through
        device failures.

        ``flow`` (a ``store.sharded.FlowControl``) tunes the array's
        end-to-end flow control: per-shard in-flight windows, queue-full
        retry budget/backoff, and the gossip steering penalties.

        ``mesh`` (a jax (data, model) device mesh) switches the engine's
        cached-jit path to SPMD execution: hidden/embedding dims striped
        across the mesh's ``model`` axis, super-batch rows across
        ``data`` (``core/spmd.py``).  ``model_parallel=M`` is the
        convenience knob: it builds a host mesh over all visible devices
        with the model axis pinned to M (``launch.mesh.make_host_mesh``).
        ``jit_cache_size`` bounds the engine's LRU trace cache."""
        if endpoints is not None or devs is not None or n_shards > 1 \
                or replication > 1:
            if dev is not None:
                raise ValueError("dev= is single-device only; pass the "
                                 "array as devs=[...] or endpoints=[...] "
                                 "instead")
            arr_n = None if (devs is not None or endpoints is not None) \
                else n_shards
            if replication > 1:
                from ..store.sharded import ReplicatedGraphStore
                self.store = ReplicatedGraphStore(
                    n_shards=arr_n, devs=devs, endpoints=endpoints,
                    replication=replication, h_threshold=h_threshold,
                    stats_staleness_s=stats_staleness_s, flow=flow)
            else:
                from ..store.sharded import ShardedGraphStore
                self.store = ShardedGraphStore(
                    n_shards=arr_n, devs=devs, endpoints=endpoints,
                    h_threshold=h_threshold, flow=flow)
        else:
            self.store = GraphStore(dev or BlockDevice(),
                                    h_threshold=h_threshold)
        if cache_pages:
            self.store.attach_cache_pages(cache_pages)
        self.registry = KernelRegistry()
        self.xbuilder = XBuilder(self.registry)
        for name, fn in gnn.extra_shell_kernels().items():
            self.registry.register_op(name, SHELL_DEVICE, fn)
        self._register_batchpre()
        if mesh is None and model_parallel is not None:
            from ..launch.mesh import make_host_mesh
            mesh = make_host_mesh(model=int(model_parallel))
        self.engine = Engine(self.registry, mesh=mesh,
                             jit_cache_size=jit_cache_size)
        self.pad_to = pad_to
        self._programs: dict[str, object] = {}   # markup -> ServiceProgram
        self._weight_store: dict[str, dict] = {} # weights_ref -> feed dict
        self.qos_provider = None                 # set by ServingRuntime
        self.firehose = None                     # set by open_firehose

    # ------------------------------------------------------------- GraphStore
    def update_graph(self, edge_array, embeddings=None,
                     already_undirected=False, chunked=False,
                     chunk_edges=None, emb_chunk_rows=None):
        """Bulk UpdateGraph RPC.

        ``already_undirected=True`` skips the [G-2] mirror pass for
        pre-symmetrized datasets.  ``chunked=True`` routes a sharded
        array through the distributed device-side ingest
        (``update_graph_chunked``: raw chunk streaming + shard-local
        bucket/sort/pack, bit-identical result); single-device stores
        fall back to the monolithic path — there is no array to spread
        the preprocessing over."""
        edges = np.asarray(edge_array)
        emb = None if embeddings is None else np.asarray(embeddings)
        und = bool(already_undirected)
        if chunked and hasattr(self.store, "update_graph_chunked"):
            kw = {}
            if chunk_edges is not None:
                kw["chunk_edges"] = int(chunk_edges)
            if emb_chunk_rows is not None:
                kw["emb_chunk_rows"] = int(emb_chunk_rows)
            tl = self.store.update_graph_chunked(
                edges, emb, already_undirected=und, **kw)
        else:
            tl = self.store.update_graph(edges, emb, already_undirected=und)
        return {"total_s": tl.total, "user_visible_s": tl.user_visible}

    # Unit mutations route through the firehose while one is open (writes
    # become windowed device-side batches; a full log sheds typed
    # BackpressureError — the write-side admission control).
    def _mutator(self):
        return self.firehose if self.firehose is not None else self.store

    def add_vertex(self, vid, embed=None):
        """Unit AddVertex RPC: insert ``vid`` (optional embedding row)."""
        self._mutator().add_vertex(int(vid), embed)

    def delete_vertex(self, vid):
        """Unit DeleteVertex RPC: remove ``vid`` and every incident edge."""
        self._mutator().delete_vertex(int(vid))

    def add_edge(self, dst, src):
        """Unit AddEdge RPC: undirected edge insert (both directions)."""
        self._mutator().add_edge(int(dst), int(src))

    def delete_edge(self, dst, src):
        """Unit DeleteEdge RPC: undirected edge delete (both directions)."""
        self._mutator().delete_edge(int(dst), int(src))

    def update_embed(self, vid, embed):
        """Unit UpdateEmbed RPC: overwrite ``vid``'s embedding row."""
        self._mutator().update_embed(int(vid), np.asarray(embed))

    # -------------------------------------------------------------- firehose
    def open_firehose(self, window_s=0.05, max_window_ops=4096,
                      max_log_ops=65536):
        """Open a mutation firehose: from now on the unit-mutation RPCs
        accumulate in a windowed log and each window applies as ONE
        device-side command per shard (store/ingest.py).  Reads keep
        flowing between windows, bit-identical to serial application."""
        from ..store.ingest import MutationFirehose
        if self.firehose is not None:
            raise RuntimeError("firehose already open")
        self.firehose = MutationFirehose(
            self.store, window_s=float(window_s),
            max_window_ops=int(max_window_ops),
            max_log_ops=int(max_log_ops)).start()
        return self.firehose.snapshot()

    def flush_firehose(self):
        """Explicitly apply everything logged (window boundary on demand)."""
        if self.firehose is None:
            raise RuntimeError("no firehose open")
        applied = self.firehose.flush()
        return {"applied_now": applied, **self.firehose.snapshot()}

    def close_firehose(self):
        """Drain the log, stop the window timer, return final counters;
        unit mutations apply immediately again afterwards."""
        if self.firehose is None:
            raise RuntimeError("no firehose open")
        fh, self.firehose = self.firehose, None
        return fh.close()

    def get_embed(self, vid):
        """Point read of one vertex embedding (test/admin RPC — serving
        reads go through the batched sampler plan/fetch path)."""
        return self.store.get_embed(int(vid))

    def get_neighbors(self, vid):
        """Point read of one vertex's sorted neighbor list."""
        return self.store.get_neighbors(int(vid))

    # ---------------------------------------------------------- fault admin
    def _replicated(self):
        if not hasattr(self.store, "fail_shard"):
            raise RuntimeError("shard fault RPCs need a replicated array "
                               "(construct with replication >= 2)")
        return self.store

    def fail_shard(self, shard):
        """Fault-injection / drain RPC: drop one device out of the array.
        Serving continues from the surviving replicas, bit-identically."""
        return self._replicated().fail_shard(int(shard))

    def rebuild_shard(self, shard, pacing_s=None):
        """Re-materialise a failed shard from its surviving replicas,
        restoring R-way redundancy.  ``pacing_s`` sleeps that long between
        peer-link chunk pulls so the rebuild yields device bandwidth to
        concurrent serving reads."""
        return self._replicated().rebuild_shard(
            int(shard), pacing_s=pacing_s)

    def probe_shards(self):
        """Zero-traffic health probe: one ``counters`` round over every
        shard endpoint (including failed ones — errors are reported, not
        raised).  The autonomic supervisor polls this."""
        if not hasattr(self.store, "probe_shards"):
            raise RuntimeError("probe_shards needs a sharded array")
        return self.store.probe_shards()

    # ------------------------------------------------------- elastic reshard
    def reshard(self, add=None, remove=None, rebalance=False,
                refine=4, chunk_pages=None, pace_s=None):
        """Elastic online reshard RPC (see ``ShardedGraphStore.reshard``).

        Exactly one mode per call:

        * ``add=k`` (int) — grow the array by ``k`` shards.  The service
          builds the new endpoints itself, matched to the array's
          transport: in-process arrays get ``LocalShardEndpoint``s,
          RoP-linked arrays get fresh ``ShardHost`` + ``RopShardEndpoint``
          pairs; each new device clones shard 0's performance profile.
          ``add=[...]`` passes pre-built ``ShardEndpoint``s instead.
        * ``remove=[ids]`` — shrink: migrate those shards' classes to the
          survivors, detach and close them.
        * ``rebalance=True`` — keep N, refine the placement map by
          ``refine`` and move the hottest classes off the most-loaded
          shards (heat = the gossiped read counters).

        ``chunk_pages`` bounds each peer-link migration pull;
        ``pace_s`` sleeps that long between pulls so migration yields
        device bandwidth to serving reads (supervisor-style pacing).
        Serving stays up throughout: reads route to the old owner until
        each class atomically flips, writes gate only during their own
        class's copy window.

        Returns the migration report (classes/copies/bytes/epochs —
        see the store docstring).  Raises ``RuntimeError`` on a
        single-device store and whatever the store raises (mode errors,
        reshard/rebuild already in progress, failed shards present).
        """
        store = self.store
        if not hasattr(store, "reshard"):
            raise RuntimeError("reshard needs a sharded array "
                               "(construct with n_shards > 1)")
        kw = {"rebalance": bool(rebalance), "refine": int(refine)}
        if chunk_pages is not None:
            kw["chunk_pages"] = int(chunk_pages)
        if pace_s is not None:
            kw["pace_s"] = float(pace_s)
        if remove is not None:
            kw["remove"] = [int(s) for s in remove]
        if isinstance(add, (int, np.integer)):
            kw["add"] = self._build_endpoints(int(add))
        elif add is not None:
            kw["add"] = list(add)
        return store.reshard(**kw)

    def _build_endpoints(self, k: int) -> list:
        """``k`` fresh shard endpoints matching the array's transport,
        devices cloned from shard 0's performance profile."""
        from ..store.endpoint import (LocalShardEndpoint, RopShardEndpoint,
                                      ShardHost, clone_dev_profile)
        store = self.store
        template = store.endpoints[0]
        ht = store.h_threshold
        d = store.feature_dim
        eps = []
        for _ in range(k):
            dev = None
            old_dev = getattr(getattr(template, "service", None), "store",
                              None)
            old_dev = getattr(old_dev, "dev", None)
            if old_dev is None:
                old_dev = getattr(getattr(getattr(template, "host", None),
                                          "service", None), "store", None)
                old_dev = getattr(old_dev, "dev", None)
            if old_dev is not None:
                dev = clone_dev_profile(old_dev)
            if isinstance(template, RopShardEndpoint):
                host = ShardHost(dev, h_threshold=ht, feature_dim=d)
                eps.append(RopShardEndpoint(host))
            else:
                eps.append(LocalShardEndpoint(dev=dev, h_threshold=ht,
                                              feature_dim=d))
        return eps

    # ------------------------------------------------------------ GraphRunner
    def _register_batchpre(self):
        def batch_pre(targets, seed=0, *, fanouts):
            batch = sample_batch(self.store, np.asarray(targets), list(fanouts),
                                 rng=np.random.default_rng(int(seed)),
                                 pad_to=self.pad_to)
            outs = [jnp.asarray(batch.embeddings)]
            for blk in batch.layers:
                outs.append(jnp.asarray(blk.nbr))
                outs.append(jnp.asarray(blk.mask))
            return tuple(outs)
        # stateful (touches the page store): must run eagerly ahead of the
        # engine's whole-DFG jit trace.
        self.registry.register_op("BatchPre", SHELL_DEVICE, batch_pre,
                                  jittable=False)

    def run(self, dfg: str, batch, weights: dict | None = None,
            fanouts=None, seed: int = 0, jit: bool = True,
            weights_ref: str | None = None):
        """Paper Run(DFG, batch).

        * If the DFG starts with a ``BatchPre`` node (service-style DFG),
          only the raw target VIDs are fed; sampling happens near storage.
        * Otherwise (model-only DFG, Fig. 10b) the service samples first and
          feeds H/nbr/mask inputs directly.

        ``jit=True`` (default) runs the model portion through the engine's
        cached whole-DFG trace; the sampler's ``pad_to`` bucketing keeps the
        number of distinct shape signatures (and hence compiles) small.
        """
        dfg_obj = DFG.load(dfg) if isinstance(dfg, str) else dfg
        feeds = self._resolve_weights(weights, weights_ref)
        if "Batch" in dfg_obj._ins:
            feeds["Batch"] = np.asarray(batch)
            if "Seed" in dfg_obj._ins:     # per-request sampling stream
                feeds["Seed"] = int(seed)
        else:
            assert fanouts is not None, "model-only DFG needs fanouts"
            b = sample_batch(self.store, np.asarray(batch), list(fanouts),
                             rng=np.random.default_rng(seed), pad_to=self.pad_to)
            feeds["H"] = jnp.asarray(b.embeddings)
            for l, blk in enumerate(b.layers):
                feeds[f"nbr{l}"] = jnp.asarray(blk.nbr)
                feeds[f"mask{l}"] = jnp.asarray(blk.mask)
        out = self.engine.run(dfg_obj, feeds, jit=jit)
        return {k: np.asarray(v) for k, v in out.items()}

    def put_weights(self, name: str, weights: dict) -> dict:
        """Register a model's weights device-side under ``name``.

        Serving clients then pass ``weights_ref=name`` per request instead
        of re-shipping the full weight set over RoP each time — the device
        DRAM holds the deployed model next to the engine.
        """
        stored = {k: jnp.asarray(np.asarray(v)) for k, v in weights.items()}
        self._weight_store[name] = stored
        return {"name": name, "tensors": len(stored),
                "bytes": int(sum(v.size * v.dtype.itemsize
                                 for v in stored.values()))}

    def _resolve_weights(self, weights: dict | None,
                         weights_ref: str | None) -> dict:
        if weights_ref is None:
            return dict(weights or {})
        stored = self._weight_store.get(weights_ref)
        if stored is None:
            raise KeyError(f"unknown weights_ref {weights_ref!r} "
                           "(register with put_weights first)")
        out = dict(stored)
        out.update(weights or {})              # per-request overrides win
        return out

    def _service_program(self, markup: str):
        """Cached BatchPre/model split of a service DFG (serving hot path)."""
        if markup not in self._programs:
            from ..serve.batcher import split_service_dfg
            self._programs[markup] = split_service_dfg(DFG.load(markup))
        return self._programs[markup]

    def run_batch(self, dfg, requests, weights: dict | None = None,
                  jit: bool = True, weights_ref: str | None = None):
        """Continuous-batching entry: several Run requests against the same
        service DFG as ONE fused engine execution.

        ``requests`` is a list of ``{"targets": [...], "seed": int}``.  The
        group is sampled near storage in one pass per hop (per-request rng
        segments keep each request's sample bit-identical to a solo run),
        composed into a block-diagonal super-batch, bucket-padded, and run
        through the cached-jit model portion; each request gets back exactly
        its own output rows.  Returns a list of per-request result dicts.
        """
        from ..serve.batcher import sample_group, pad_group
        markup = dfg if isinstance(dfg, str) else dfg.save()
        prog = self._service_program(markup)
        if prog is None:
            raise ValueError("run_batch needs a BatchPre-led service DFG")
        batch, slices = sample_group(
            self.store, [r["targets"] for r in requests],
            [int(r.get("seed", 0)) for r in requests], prog.fanouts)
        batch = pad_group(batch, self.pad_to)
        feeds = self._resolve_weights(weights, weights_ref)
        feeds[prog.feed_refs[0]] = jnp.asarray(batch.embeddings)
        for l, blk in enumerate(batch.layers):
            feeds[prog.feed_refs[1 + 2 * l]] = jnp.asarray(blk.nbr)
            feeds[prog.feed_refs[2 + 2 * l]] = jnp.asarray(blk.mask)
        out = self.engine.run(prog.model, feeds, jit=jit)
        return [{k: np.asarray(v)[off: off + n] for k, v in out.items()}
                for off, n in slices]

    @staticmethod
    def _device_counters(dev_stats) -> dict:
        return {"read_pages": dev_stats.read_pages,
                "written_pages": dev_stats.written_pages,
                "read_bytes": dev_stats.read_bytes,
                "written_bytes": dev_stats.written_bytes}

    def stats(self):
        """QoS / store / cache / device counters (the `stats` RPC).

        The RPC dispatcher injects its own rolling per-method stats under
        ``rpc``; the serving runtime contributes scheduler + transport QoS
        under ``qos`` via ``qos_provider``.  Against a sharded store every
        per-shard figure comes from ONE endpoint ``stats`` snapshot per
        shard — never from poking shard internals — so the report is
        byte-for-byte the same shape whether the shards are in-process
        (``LocalShardEndpoint``) or behind their own RoP links
        (``RopShardEndpoint``); each shard entry also carries the
        endpoint's device-side per-method RPC stats under ``rpc``.  The
        ``device``/``embcache`` sections aggregate the array and
        ``shards`` breaks out per-shard cache hit rates and page
        counters, so operators (and fig23/fig24/fig25) can read shard
        balance without reaching into the array.  Against a replicated
        array the write-side aggregates (``written_pages``,
        ``unit_updates``) count per-replica applications — a logical
        mutation really does cost R device writes — so compare them
        across replication factors accordingly.
        """
        dev_keys = ("read_pages", "written_pages",
                    "read_bytes", "written_bytes")
        if hasattr(self.store, "shard_stats"):
            snaps = self.store.shard_stats()
            out = {
                "store": {
                    "pages_h": sum(s["store"]["pages_h"] for s in snaps),
                    "pages_l": sum(s["store"]["pages_l"] for s in snaps),
                    "unit_updates": sum(s["store"]["unit_updates"]
                                        for s in snaps),
                    "l_evictions": sum(s["store"]["l_evictions"]
                                       for s in snaps),
                    "num_vertices": self.store.num_vertices,
                    "n_shards": len(snaps),
                    "io_wait_us": self.store.io_wait_us},
                "device": {k: sum(s["device"][k] for s in snaps)
                           for k in dev_keys},
                "shards": [
                    {"device": s["device"],
                     "pages_l": s["store"]["pages_l"],
                     "pages_h": s["store"]["pages_h"],
                     "failed": s["failed"],
                     "embcache": s["cache"],
                     "rpc": s.get("rpc")}
                    for s in snaps],
            }
            if any(s["cache"] is not None for s in snaps):
                from ..store.sharded import aggregate_cache_snapshots
                out["embcache"] = aggregate_cache_snapshots(
                    s["cache"] for s in snaps)
        else:
            st = self.store.stats
            out = {
                "store": {"pages_h": st.pages_h,
                          "pages_l": st.pages_l,
                          "unit_updates": st.unit_updates,
                          "l_evictions": st.l_evictions,
                          "num_vertices": self.store.num_vertices,
                          "n_shards": 1,
                          "io_wait_us": 0.0},
                "device": self._device_counters(self.store.dev.stats),
            }
            if self.store.cache is not None:
                out["embcache"] = self.store.cache.stats.snapshot()
        repl = getattr(self.store, "replication", None)
        if repl is not None:
            out["replication"] = {
                "r": repl,
                "failed_shards": [i for i, f in
                                  enumerate(self.store.failed_shards) if f]}
        if hasattr(self.store, "placement_stats"):
            out["placement"] = self.store.placement_stats()
        sup = getattr(self.store, "health", None)
        if sup is not None:
            out["health"] = sup.snapshot()
        if hasattr(self.store, "backpressure_events"):
            out["flow"] = {
                "backpressure_events": self.store.backpressure_events,
                "backpressure_retries": self.store.backpressure_retries,
                "max_inflight_per_shard":
                    self.store.flow.max_inflight_per_shard,
                "submit_retries": self.store.flow.submit_retries}
        if self.firehose is not None:
            out["firehose"] = self.firehose.snapshot()
        out["engine"] = self.engine_stats()
        if self.qos_provider is not None:
            out["qos"] = self.qos_provider()
        return out

    def engine_stats(self) -> dict:
        """Engine execution-plane counters: mesh placement (None when the
        compute plane is unsharded) + the bounded jit trace cache."""
        mesh = self.engine.mesh
        desc = None
        if mesh is not None:
            from .spmd import mesh_descriptor
            desc = dict(mesh_descriptor(mesh))
        return {"mesh": desc, "jit_cache": self.engine.cache_stats()}

    def close(self) -> None:
        """Release array resources (remote shard hosts stop their poll
        threads); a no-op for single-device services."""
        if hasattr(self.store, "close"):
            self.store.close()

    def plugin(self, shared_lib: str):
        """Paper Plugin(shared_lib): import a module exposing register(api)."""
        mod = importlib.import_module(shared_lib)
        mod.register(self.registry)
        return sorted(self.registry.devices)

    # --------------------------------------------------------------- XBuilder
    def program(self, device: str, priority: int, kernels: str):
        """Paper Program(bitfile): ``kernels`` names a module whose
        ``bitstream()`` returns {op_name: fn} — the partial bitfile."""
        mod = importlib.import_module(kernels)
        bs = Bitstream(device=device, priority=int(priority),
                       kernels=mod.bitstream())
        return self.xbuilder.program(bs)


def make_service_dfg(model: str, num_layers: int, fanouts) -> DFG:
    """Service-style DFG whose first node is BatchPre (paper Fig. 10a)."""
    g = DFG()
    batch = g.create_in("Batch")
    seed = g.create_in("Seed")                # per-request sampling stream
    outs = g.create_op("BatchPre", [batch, seed], n_out=1 + 2 * num_layers,
                       attrs={"fanouts": list(fanouts)})
    h, rest = outs[0], outs[1:]
    model_dfg = gnn.BUILD_DFG[model](num_layers)
    # splice: rewire the model DFG's inputs onto BatchPre outputs
    remap = {"H": str(h)}
    for l in range(num_layers):
        remap[f"nbr{l}"] = str(rest[2 * l])
        remap[f"mask{l}"] = str(rest[2 * l + 1])
    base = len(g._nodes)
    for node in model_dfg._nodes:
        new_in = []
        for i in node.inputs:
            if i in remap:
                new_in.append(remap[i])
            elif "_" in i and i.split("_")[0].isdigit():
                s, slot = i.rsplit("_", 1)
                new_in.append(f"{int(s) + base}_{slot}")
            else:                                     # weight input
                if i not in g._ins:
                    g.create_in(i)
                new_in.append(i)
        outs2 = [f"{node.seq + base}_{o.rsplit('_', 1)[1]}" for o in node.outputs]
        g._nodes.append(type(node)(node.seq + base, node.op, new_in, outs2,
                                   dict(node.attrs)))
    for name, src in model_dfg._outs.items():
        s, slot = src.rsplit("_", 1)
        g.create_out(name, f"{int(s) + base}_{slot}")
    return g
