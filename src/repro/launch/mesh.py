"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """Whatever devices exist, as a (data, model) mesh (tests/examples)."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0 and n >= m:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
