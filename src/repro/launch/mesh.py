"""Mesh construction — production pods and host-device test meshes.

FUNCTIONS, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).

``host_mesh_shape`` is the pure shape-selection policy (unit-testable
without devices); ``make_host_mesh`` applies it to whatever devices exist.
The host mesh is what the SPMD engine path (``core/spmd.py``) runs on:
axis ``"model"`` stripes hidden/embedding dims, axis ``"data"`` stripes
super-batch rows.
"""
from __future__ import annotations

import numpy as np
import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def host_mesh_shape(n: int, *, model: int | None = None) -> tuple[int, int]:
    """(data, model) shape for ``n`` devices.

    ``model=`` pins the model-axis width (it must divide ``n``).  Otherwise
    the model axis takes the largest of 4/2/1 that divides ``n`` — wide
    hidden dims benefit from model parallelism first — and the data axis
    absorbs the rest.  Deliberate odd-count handling: n=6 -> (3, 2),
    n=7 -> (7, 1), n=1 -> (1, 1); never a dropped device, never a
    non-rectangular mesh.
    """
    if n < 1:
        raise ValueError(f"need at least one device, got n={n}")
    if model is not None:
        if model < 1 or n % model != 0:
            raise ValueError(f"model={model} must divide device count {n}")
        return (n // model, model)
    for m in (4, 2, 1):
        if m <= n and n % m == 0:
            return (n // m, m)
    raise AssertionError("unreachable: 1 divides every n")


def make_host_mesh(n: int | None = None, *, model: int | None = None,
                   shape: tuple[int, int] | None = None):
    """A (data, model) mesh over the host's devices (tests/examples/SPMD).

    ``n`` uses only the first n devices (a submesh of a forced-host pool);
    ``model`` pins the model-axis width; ``shape`` bypasses the selection
    policy entirely.  Defaults to all devices with the
    ``host_mesh_shape`` policy.
    """
    devs = jax.devices()
    if n is None:
        n = len(devs)
    if n > len(devs):
        raise ValueError(f"asked for {n} devices, only {len(devs)} exist")
    if shape is None:
        shape = host_mesh_shape(n, model=model)
    elif shape[0] * shape[1] != n:
        raise ValueError(f"shape {shape} does not cover n={n} devices")
    grid = np.asarray(devs[:n], dtype=object).reshape(shape)
    return jax.sharding.Mesh(grid, ("data", "model"))
