from .mesh import make_production_mesh, dp_axes_of, make_host_mesh

__all__ = ["make_production_mesh", "dp_axes_of", "make_host_mesh"]
