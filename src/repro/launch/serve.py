"""Paged-KV serving engine: continuous batching over a page-pooled KV cache
(the paper's GraphStore paging as the serving memory manager).

Single-host scale (the per-replica engine of a pod deployment): requests
arrive with prompts, the scheduler prefixes new sequences (prefill) and
steps the running batch (decode), KV pages are chained per sequence by
``PagedKVManager`` and attention reads through the page table — either the
Pallas ``decode_attention`` kernel (``--pallas``, interpret on CPU) or its
jnp oracle.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import SMOKES, ARCHS
from ..models import build, layers as L
from ..store.pagedkv import PagePool, PagedKVManager, Sequence
from ..kernels import ref as kref
from ..kernels import decode_attention as dk


class PagedLM:
    """Decoder LM over paged KV (attention-only archs).  Layer loop is
    unrolled (serving-scale depth); projections reuse the model params."""

    def __init__(self, cfg, params, pool: PagePool, *, use_pallas=False):
        assert all(k in ("attn", "local") for k in cfg.period_pattern), \
            "paged serving demo supports attention archs"
        self.cfg, self.params, self.pool = cfg, params, pool
        self.mgr = PagedKVManager(pool)
        self.use_pallas = use_pallas

    # --------------------------------------------------------- layer params
    def _layer_params(self, idx: int):
        period = len(self.cfg.period_pattern)
        if idx < self.cfg.n_periods * period:
            pos = idx % period
            per = idx // period
            return jax.tree.map(lambda x: x[per],
                                self.params["stack"][pos]), \
                self.cfg.period_pattern[pos]
        pos = idx - self.cfg.n_periods * period
        return self.params["rem"][pos], self.cfg.remainder_kinds[pos]

    # -------------------------------------------------------------- prefill
    def prefill(self, seq: Sequence) -> int:
        cfg = self.cfg
        toks = jnp.asarray([seq.tokens], jnp.int32)
        x = L.embed_apply(self.params["embed"], toks, cfg)
        t = toks.shape[1]
        pos = jnp.arange(t)
        for li in range(cfg.num_layers):
            p, kind = self._layer_params(li)
            pm = p["mixer"]
            xn = L.rms_norm(x, pm["ln"], cfg.norm_eps)
            q = jnp.einsum("btd,dhk->bthk", xn, pm["wq"])
            k = jnp.einsum("btd,dhk->bthk", xn, pm["wk"])
            v = jnp.einsum("btd,dhk->bthk", xn, pm["wv"])
            cos, sin = L.rope_tables(pos, cfg.resolved_head_dim,
                                     cfg.rope_theta)
            q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
            self.mgr.write_kv(seq, li, np.asarray(k[0]), np.asarray(v[0]), 0)
            b, _, h, hd = q.shape
            g = h // cfg.num_kv_heads
            out = L._sdpa(q.reshape(b, t, cfg.num_kv_heads, g, hd), k, v,
                          causal=True,
                          window=cfg.window_size if kind == "local" else 0)
            y = jnp.einsum("bthk,hkd->btd", out.reshape(b, t, h, hd),
                           pm["wo"])
            x = x + y
            if "ffn" in p:
                x = L.mlp_apply(p["ffn"], x, cfg)
        seq.length = t
        logits = L.logits_apply(self.params["embed"], x[:, -1:], cfg)
        return int(jnp.argmax(logits[0, -1]))

    # --------------------------------------------------------------- decode
    def decode_step(self, seqs: list[Sequence]) -> list[int]:
        cfg = self.cfg
        b = len(seqs)
        toks = jnp.asarray([[s.tokens[-1] if not s.generated
                             else s.generated[-1]] for s in seqs], jnp.int32)
        lengths = np.asarray([s.length for s in seqs], np.int32)
        for s in seqs:                        # grow page chains (H-type)
            self.mgr.ensure_capacity(s, s.length + 1)
        max_pages = max(len(s.pages) for s in seqs)
        pt = self.mgr.page_table(seqs, max_pages)

        x = L.embed_apply(self.params["embed"], toks, cfg)
        posn = jnp.asarray(lengths)[:, None]
        for li in range(cfg.num_layers):
            p, kind = self._layer_params(li)
            pm = p["mixer"]
            xn = L.rms_norm(x, pm["ln"], cfg.norm_eps)
            q = jnp.einsum("btd,dhk->bthk", xn, pm["wq"])
            k = jnp.einsum("btd,dhk->bthk", xn, pm["wk"])
            v = jnp.einsum("btd,dhk->bthk", xn, pm["wv"])
            cos, sin = L.rope_tables(posn, cfg.resolved_head_dim,
                                     cfg.rope_theta)
            q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
            for i, s in enumerate(seqs):      # write the new token's KV
                self.mgr.write_kv(s, li, np.asarray(k[i]), np.asarray(v[i]),
                                  s.length)
            q1 = q[:, 0]                      # (B,H,hd)
            kp = jnp.asarray(self.pool.k[li, : self.pool.num_pages])
            vp = jnp.asarray(self.pool.v[li, : self.pool.num_pages])
            fn = dk.decode_attention if self.use_pallas \
                else kref.decode_attention_ref
            out = fn(q1.swapaxes(1, 1), kp, vp, jnp.asarray(pt),
                     jnp.asarray(lengths + 1))
            y = jnp.einsum("bhk,hkd->bd", out, pm["wo"])[:, None]
            x = x + y
            if "ffn" in p:
                x = L.mlp_apply(p["ffn"], x, cfg)
        for s in seqs:
            s.length += 1
        logits = L.logits_apply(self.params["embed"], x, cfg)
        return [int(t) for t in jnp.argmax(logits[:, 0], axis=-1)]


def serve(cfg, *, num_requests=8, prompt_len=12, max_new=16, seed=0,
          use_pallas=False, page_size=16, num_pages=512, log=print):
    api = build(cfg, tp=1)
    params = api.init_params(seed)
    pool = PagePool(num_pages=num_pages, page_size=page_size,
                    num_layers=cfg.num_layers,
                    num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim)
    engine = PagedLM(cfg, params, pool, use_pallas=use_pallas)
    rng = np.random.default_rng(seed)

    pending = [list(rng.integers(0, cfg.vocab_size, prompt_len))
               for _ in range(num_requests)]
    running: list[Sequence] = []
    done: list[Sequence] = []
    t0 = time.perf_counter()
    steps = 0
    max_batch = 4
    while pending or running:
        while pending and len(running) < max_batch:
            sid = len(done) + len(running)
            seq = engine.mgr.add_sequence(sid, pending.pop(0))
            first = engine.prefill(seq)
            seq.generated.append(first)
            running.append(seq)
        toks = engine.decode_step(running)
        steps += 1
        for s, t in zip(list(running), toks):
            s.generated.append(t)
            if len(s.generated) >= max_new:
                s.done = True
                running.remove(s)
                engine.mgr.release(s)
                done.append(s)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(s.generated) for s in done)
    log(f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
        f"({total_tokens/dt:.1f} tok/s), page pool peak alloc "
        f"{pool.alloc_count} pages, util {engine.mgr.utilization():.2%}")
    return done, {"tokens": total_tokens, "seconds": dt,
                  "decode_steps": steps, "pages_alloc": pool.alloc_count}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: smoke-scale)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pallas", action="store_true")
    args = ap.parse_args(argv)
    cfg = (ARCHS if args.full else SMOKES)[args.arch]
    serve(cfg, num_requests=args.requests, max_new=args.max_new,
          use_pallas=args.pallas)


if __name__ == "__main__":
    main()
