import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production mesh; record memory analysis, cost analysis, and the
collective schedule for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--dev]

``--dev`` shrinks meshes (2x4 / 2x2x4) and shapes for fast iteration on this
CPU container; the recorded artifacts for EXPERIMENTS.md always come from
the full 512-device run.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SMOKES, SHAPES, shapes_for
from ..configs.base import ShapeConfig
from ..models import build, layers as L
from ..train import optimizer as O
from ..train.trainer import make_train_step
from .mesh import make_production_mesh, dp_axes_of

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def _is_long_mode(shape: ShapeConfig) -> bool:
    return shape.kind == "decode" and shape.global_batch == 1


def lower_cell(cfg, shape: ShapeConfig, mesh, *, donate=True):
    """Returns (lowered, aux_info). Must be called inside `with mesh`."""
    dp = dp_axes_of(mesh)
    long_mode = _is_long_mode(shape)
    tp = int(mesh.shape["model"])
    with L.use_mesh(mesh, dp_axes=() if long_mode else dp):
        api = build(cfg, tp=tp)
        abs_params = api.abstract_params(
            dtype=None if shape.kind == "train" else "bfloat16")
        p_sh = _ns(mesh, api.param_pspecs())
        in_specs = api.input_specs(shape)
        in_sh = _ns(mesh, api.input_pspecs(shape))
        vocab_ok = cfg.vocab_size % tp == 0
        logits_spec = L.resolve_pspec((() if long_mode else L.DP, None,
                                       "model" if vocab_ok else None))

        if shape.kind == "train":
            opt_cfg = O.AdamWConfig()
            step = make_train_step(api, opt_cfg)
            abs_opt = O.abstract_state(abs_params)
            o_sh = _ns(mesh, O.opt_pspecs(
                api.param_defs(), dp_axes=dp,
                dp_size=int(np.prod([mesh.shape[a] for a in dp]))))
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, in_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(abs_params, abs_opt, in_specs)
        elif shape.kind == "prefill":
            cache_seq = shape.seq_len
            abs_cache = api.abstract_cache(shape.global_batch, cache_seq)
            c_sh = _ns(mesh, api.cache_pspecs(shape.global_batch, cache_seq))

            def prefill_step(params, batch, caches):
                return api.prefill(params, batch, caches)

            jitted = jax.jit(
                prefill_step,
                in_shardings=(p_sh, in_sh, c_sh),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(abs_params, in_specs, abs_cache)
        else:  # decode
            cache_seq = shape.seq_len
            abs_cache = api.abstract_cache(shape.global_batch, cache_seq,
                                           long_mode=long_mode)
            c_pspecs = api.cache_pspecs(shape.global_batch, cache_seq,
                                        long_mode=long_mode)
            c_sh = _ns(mesh, c_pspecs)

            def serve_step(params, batch, caches):
                return api.decode(params, batch, caches)

            jitted = jax.jit(
                serve_step,
                in_shardings=(p_sh, in_sh, c_sh),
                out_shardings=(NamedSharding(mesh, logits_spec), c_sh),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(abs_params, in_specs, abs_cache)
    total, active = cfg.param_count()
    return lowered, {"params_total": total, "params_active": active}


def analyze(lowered, compiled, *, chips: int, shape: ShapeConfig, aux) -> dict:
    from benchmarks.hlo_analysis import expanded_analysis
    out = dict(aux)
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # noqa: BLE001
        out["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        out["cost_raw"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and
                           k in ("flops", "bytes accessed",
                                 "transcendentals", "optimal_seconds")}
    except Exception as e:  # noqa: BLE001
        out["cost_raw"] = {"error": str(e)}
    # loop-expanded per-device analysis (cost_analysis does not expand
    # while-loop trip counts and our stacks are scanned — see
    # benchmarks/hlo_analysis.py)
    txt = compiled.as_text()
    ea = expanded_analysis(txt)
    out["hlo_flops"] = ea["flops"]              # per device, loop-expanded
    out["hlo_bytes"] = ea["bytes"]
    out["unknown_loops"] = ea["unknown_loops"]
    out["collectives"] = ea["collectives"]
    out["hlo_lines"] = txt.count("\n")

    # MODEL_FLOPS: 6*N_active*D train; 2*N_active*D forward-only
    n_act = aux["params_active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        out["model_flops"] = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        out["model_flops"] = 2.0 * n_act * tokens
    else:
        out["model_flops"] = 2.0 * n_act * shape.global_batch
    out["chips"] = chips
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, dev: bool,
             smoke: bool = False, out_dir: str | None = None) -> dict:
    cfg = (SMOKES if smoke else ARCHS)[arch]
    shape = SHAPES[shape_name]
    if dev:
        mesh = jax.make_mesh((2, 2, 4) if multi_pod else (2, 4),
                             ("pod", "data", "model") if multi_pod
                             else ("data", "model"))
        shape = dataclasses.replace(
            shape, global_batch=max(mesh.shape.get("pod", 1)
                                    * mesh.shape["data"],
                                    shape.global_batch // 32),
            seq_len=min(shape.seq_len, 512))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.perf_counter()
    with mesh:
        lowered, aux = lower_cell(cfg, shape, mesh)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        rec = analyze(lowered, compiled, chips=chips, shape=shape, aux=aux)
    rec.update(arch=arch, shape=shape_name, multi_pod=multi_pod,
               mesh=dict(mesh.shape), lower_s=round(t_lower, 2),
               compile_s=round(t_compile, 2), dev=dev,
               seq_len=shape.seq_len, global_batch=shape.global_batch,
               kind=shape.kind)
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "multi_pod", "chips", "hlo_flops",
                       "hlo_bytes", "model_flops", "compile_s")}, indent=None))
    mem = rec.get("memory", {})
    print(f"  memory_analysis: {mem}")
    cb = rec["collectives"]
    print(f"  collectives: total={cb['total_bytes']/1e9:.3f} GB "
          f"{cb['count_by_kind']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        base = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
        with open(os.path.join(out_dir, base + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        import gzip
        with gzip.open(os.path.join(out_dir, base + ".hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dev", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in shapes_for(a):
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, multi_pod=mp, dev=args.dev,
                         smoke=args.smoke, out_dir=args.out)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, mp, str(e)))
    if failures:
        print(f"\nFAILED {len(failures)} cells:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print(f"\nALL {len(cells) * len(meshes)} dry-run cells compiled OK")


if __name__ == "__main__":
    main()
