"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 20 \
      --smoke --ckpt /tmp/ckpt

On the production pod this is invoked once per host (jax.distributed
initialization is gated on env vars); on this container it runs the same
code on the local devices.  Fault tolerance: kill/restart resumes from the
last committed checkpoint and replays the deterministic data stream.
"""
from __future__ import annotations

import argparse
import os

import jax

from ..configs import ARCHS, SMOKES, SHAPES
from ..configs.base import ShapeConfig
from ..models import build
from ..train import AdamWConfig, Trainer
from .mesh import make_host_mesh, dp_axes_of


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--mesh", action="store_true",
                    help="use a (data, model) mesh over local devices")
    args = ap.parse_args(argv)

    if "JAX_COORDINATOR" in os.environ:        # multi-host pod entry
        jax.distributed.initialize()

    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    shape = ShapeConfig("cli", "train", seq_len=args.seq,
                        global_batch=args.batch)
    mesh = make_host_mesh() if args.mesh else None
    api = build(cfg, tp=(mesh.shape["model"] if mesh else 1))
    tr = Trainer(api, shape, mesh=mesh,
                 dp_axes=dp_axes_of(mesh) if mesh else ("data",),
                 opt_cfg=AdamWConfig(lr=args.lr),
                 grad_accum=args.grad_accum, ckpt_dir=args.ckpt,
                 ckpt_every=args.ckpt_every, zero1=args.zero1)
    params, opt_state, step = tr.run(args.steps)
    last = tr.metrics_log[-1] if tr.metrics_log else {}
    print(f"finished at step {step}: loss={last.get('loss'):.4f} "
          f"grad_norm={last.get('grad_norm'):.3f} "
          f"stragglers={len(tr.monitor.flagged)}")
    return tr


if __name__ == "__main__":
    main()
