"""Step-atomic, async, elastic checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json   — tree structure, shapes, dtypes, crc32 per leaf
           <leaf_key>.npy  — one file per pytree leaf
           COMMIT          — written last; restore only sees committed steps

* **async**: ``save`` snapshots arrays to host then writes on a background
  thread — the train loop never blocks on the filesystem (the paper's bulk
  overlap idea applied to checkpoints).
* **atomic**: a step directory without COMMIT is ignored and garbage-
  collected; a crash mid-write can never corrupt restore.
* **elastic**: leaves are stored unsharded; ``restore`` re-device_puts onto
  any mesh/sharding — restart on a different pod count re-shards for free.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import numpy as np
import jax

from .. import compat


def _flatten(tree):
    flat, treedef = compat.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, jax.tree.structure(tree)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self.save_log: list[tuple[int, float]] = []

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        host = jax.tree.map(lambda x: np.asarray(x), tree)   # device->host now
        self.wait()                                          # one in flight

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            items, _ = _flatten(host)
            manifest = {"step": step, "leaves": {}}
            for key, leaf in items:
                fn = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), leaf)
                manifest["leaves"][key] = {
                    "file": fn, "shape": list(np.shape(leaf)),
                    "dtype": str(np.asarray(leaf).dtype),
                    "crc": zlib.crc32(np.ascontiguousarray(leaf).tobytes()),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write("ok")
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write,
                                            name="checkpoint-writer")
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.committed_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
        for d in os.listdir(self.dir):                 # orphaned tmp dirs
            if d.startswith(".tmp_step_"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def committed_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "COMMIT")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None, *,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``tree_like``; optional shardings
        pytree re-shards every leaf onto the current mesh (elastic)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        items, treedef = _flatten(tree_like)
        leaves = []
        shard_items = None
        if shardings is not None:
            shard_items, _ = _flatten(shardings)
            shard_map_ = dict(shard_items)
        for key, proto in items:
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(d, meta["file"]))
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc"]:
                    raise IOError(f"checkpoint leaf {key} corrupt")
            if shardings is not None:
                arr = jax.device_put(arr, shard_map_[key])
            leaves.append(arr)
        return jax.tree.unflatten(treedef, leaves), step
