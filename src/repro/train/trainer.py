"""Fault-tolerant trainer: jit'd sharded train step (grad accumulation,
remat, donation), async checkpointing with deterministic resume, straggler
detection, and failure recovery (replay from last committed step).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import layers as L
from ..models.api import ModelAPI
from . import optimizer as O
from .checkpoint import Checkpointer


def make_train_step(api: ModelAPI, opt_cfg: O.AdamWConfig, *,
                    grad_accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = api.train_loss(params, batch)
        return loss, metrics

    def step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, -1) + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {}
        params, opt_state, om = O.apply_updates(opt_cfg, params, grads,
                                                opt_state)
        m = {"loss": loss, **{k: v for k, v in metrics.items()}, **om}
        return params, opt_state, m

    return step


@dataclass
class StragglerMonitor:
    """Per-step deadline policy: EMA of step time; steps slower than
    ``factor``x EMA are flagged.  At pod scale the supervisor maps flags to
    a host and triggers re-slicing; single-process we record + expose."""
    factor: float = 3.0
    ema: float | None = None
    alpha: float = 0.2
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if slow:
            self.flagged.append((step, dt))
        return slow


class Trainer:
    def __init__(self, api: ModelAPI, shape, *, mesh=None, dp_axes=("data",),
                 opt_cfg: O.AdamWConfig | None = None, grad_accum: int = 1,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 zero1: bool = False, seed: int = 0):
        self.api = api
        self.shape = shape
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.opt_cfg = opt_cfg or O.AdamWConfig()
        self.grad_accum = grad_accum
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.monitor = StragglerMonitor()
        self.seed = seed
        self.metrics_log: list[dict] = []

        step_fn = make_train_step(api, self.opt_cfg, grad_accum=grad_accum)
        if mesh is not None:
            with L.use_mesh(mesh, dp_axes):
                pspecs = api.param_pspecs()
                ospecs = O.opt_pspecs(api.param_defs(), zero1=zero1,
                                      dp_axes=dp_axes,
                                      dp_size=int(np.prod(
                                          [mesh.shape[a] for a in dp_axes])))
                bspecs = api.input_pspecs(shape)
            ns = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, s), tree)
            self._in_sh = (ns(pspecs), ns(ospecs), ns(bspecs))
            self._out_sh = (ns(pspecs), ns(ospecs), None)
            self.step_fn = jax.jit(step_fn, in_shardings=self._in_sh,
                                   out_shardings=self._out_sh,
                                   donate_argnums=(0, 1))
        else:
            self._in_sh = None
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------ lifecycle
    def init_or_restore(self):
        params_proto = None
        if self.ckpt and self.ckpt.latest_step() is not None:
            abs_p = self.api.abstract_params()
            abs_o = O.abstract_state(abs_p)
            shardings = None
            if self._in_sh is not None:
                shardings = {"params": self._in_sh[0], "opt": self._in_sh[1]}
                tree, step = self.ckpt.restore(
                    {"params": abs_p, "opt": abs_o},
                    shardings={"params": self._in_sh[0],
                               "opt": self._in_sh[1]})
            else:
                tree, step = self.ckpt.restore({"params": abs_p, "opt": abs_o})
            return tree["params"], tree["opt"], step
        params = self.api.init_params(self.seed)
        opt_state = O.init_state(params)
        if self._in_sh is not None:
            params = jax.device_put(params, self._in_sh[0])
            opt_state = jax.device_put(opt_state, self._in_sh[1])
        return params, opt_state, 0

    def run(self, num_steps: int, *, pipeline=None, fault_hook=None):
        from ..data.pipeline import Pipeline
        params, opt_state, start = self.init_or_restore()
        pipe = pipeline or Pipeline(self.api.cfg, self.shape, seed=self.seed,
                                    start_step=start, host_count=1)
        ctx = L.use_mesh(self.mesh, self.dp_axes) if self.mesh is not None \
            else _null_ctx()
        with ctx:
            step = start
            while step < start + num_steps:
                t0 = time.perf_counter()
                ds, batch = pipe.next()
                assert ds == step, f"pipeline desync {ds} != {step}"
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                if fault_hook is not None and fault_hook(step):
                    # simulated node failure: deterministic replay from ckpt
                    raise RuntimeError(f"injected fault at step {step}")
                params, opt_state, m = self.step_fn(params, opt_state, batch)
                m = {k: float(v) for k, v in m.items()}
                dt = time.perf_counter() - t0
                slow = self.monitor.observe(step, dt)
                m.update(step=step, dt=dt, straggler=slow)
                self.metrics_log.append(m)
                step += 1
                if self.ckpt and (step % self.ckpt_every == 0):
                    self.ckpt.save(step, {"params": params, "opt": opt_state})
            if self.ckpt:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               blocking=True)
        pipe.close()
        return params, opt_state, step


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
