"""AdamW in pure JAX (no optax dependency) with ZeRO-1 style optimizer-state
sharding and an int8 error-feedback gradient compressor.

The optimizer state mirrors the parameter pytree; ``opt_pspecs`` derives its
PartitionSpecs from the parameter ParamDefs — with ``zero1=True`` the m/v
moments additionally shard their largest replicated, dp-divisible dimension
over the data axes (ZeRO-1: each DP rank owns a slice of optimizer state).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import layers as L


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10000


def schedule(c: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - c.warmup_steps)
                    / jnp.maximum(c.decay_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * (0.1 + 0.9 * cos)


def init_state(params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
    }


def abstract_state(abstract_params):
    zero = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), abstract_params)
    return {"step": jax.ShapeDtypeStruct((), jnp.int32), "m": zero, "v": zero}


def _zero1_spec(pdef_spec: P, shape, dp_axes, dp_size: int) -> P:
    """Add dp sharding on the largest unsharded, divisible dim (ZeRO-1)."""
    entries = list(pdef_spec) + [None] * (len(shape) - len(pdef_spec))
    best, best_dim = -1, -1
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and dp_size > 0 and s % dp_size == 0 and s > best:
            best, best_dim = s, i
    if best_dim >= 0:
        entries[best_dim] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*entries)


def opt_pspecs(param_defs, *, zero1: bool = False, dp_axes=("data",),
               dp_size: int = 1):
    base = L.pspec_tree(param_defs)
    if not zero1:
        mom = base
    else:
        defs_flat, treedef = jax.tree.flatten(param_defs, is_leaf=L.is_def)
        specs_flat = []
        for d in defs_flat:
            spec = L.resolve_pspec(d.pspec)
            specs_flat.append(_zero1_spec(spec, d.shape, tuple(dp_axes),
                                          dp_size))
        mom = jax.tree.unflatten(treedef, specs_flat)
    return {"step": P(), "m": mom, "v": mom}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(c: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(c, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda g, m: c.b1 * m + (1 - c.b1) * g.astype(jnp.float32) * scale,
        grads, state["m"])
    new_v = jax.tree.map(
        lambda g, v: c.b2 * v
        + (1 - c.b2) * jnp.square(g.astype(jnp.float32) * scale),
        grads, state["v"])
    new_params = jax.tree.map(
        lambda p, m, v: (p - lr * (m / b1c / (jnp.sqrt(v / b2c) + c.eps)
                                   + c.weight_decay * p)).astype(p.dtype),
        params, new_m, new_v)
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------- int8 EF compression
def compress_int8(g, err):
    """Error-feedback int8 quantization: returns (q, scale, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale
