from . import optimizer, checkpoint, trainer, collectives
from .optimizer import AdamWConfig
from .trainer import Trainer, make_train_step
from .checkpoint import Checkpointer

__all__ = ["optimizer", "checkpoint", "trainer", "collectives",
           "AdamWConfig", "Trainer", "make_train_step", "Checkpointer"]
