"""Distributed-optimization collectives: int8 error-feedback compressed
gradient all-reduce over the data axis (shard_map ring).

At 1000+ nodes the DP gradient all-reduce is the dominant wire cost for
small-per-chip-batch regimes; 4x compression (fp32 -> int8 + shared fp32
scale) with error feedback preserves convergence (1-bit Adam lineage).
Implemented as a manual shard_map collective so the wire format is exactly
int8 — XLA cannot silently upcast it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from ..compat import shard_map


def compressed_psum_mean(mesh, axis: str = "data"):
    """Returns f(local_grads, err) -> (mean_grads, new_err) with int8 wire."""

    def _one(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale: max |g| across the ring so int8 grids align
        local_max = jnp.max(jnp.abs(gf))
        gmax = jax.lax.pmax(local_max, axis)
        scale = jnp.maximum(gmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        # int8 payload on the wire; accumulate in int32 (no overflow for
        # <= 2^24 ranks)
        acc = jax.lax.psum(q.astype(jnp.int32), axis)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
        mean = acc.astype(jnp.float32) * scale / n.astype(jnp.float32)
        new_err = gf - q.astype(jnp.float32) * scale
        return mean, new_err

    def inner(grads, errs):
        pairs = jax.tree.map(_one, grads, errs)
        mean = jax.tree.map(lambda t: t[0], pairs,
                            is_leaf=lambda t: isinstance(t, tuple))
        err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
        return mean, err

    spec = P(axis)
    return shard_map(inner, mesh=mesh,
                     in_specs=(spec, spec), out_specs=(spec, spec),
                     check=False)
