"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517); 5:1
mLSTM:sLSTM per period of 6, 12 layers = 2 periods.  Blocks carry their own
up/down projections (d_ff=0: no separate FFN)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", num_layers=12, d_model=768,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
    head_dim=192,
    period_pattern=("mlstm",) * 5 + ("slstm",), tie_embeddings=True,
)

SMOKE = CONFIG.replace(num_layers=6, d_model=64, num_heads=4, num_kv_heads=4,
                       d_ff=0, vocab_size=512, head_dim=16)
