"""Assigned input shapes (paired with every architecture)."""
from .base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", "train", seq_len=4096, global_batch=256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", seq_len=32768, global_batch=32)
DECODE_32K = ShapeConfig("decode_32k", "decode", seq_len=32768, global_batch=128)
LONG_500K = ShapeConfig("long_500k", "decode", seq_len=524288, global_batch=1)

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}

# long_500k runs only for sub-quadratic archs (see DESIGN.md shape-skip notes)
SUBQUADRATIC_ARCHS = {"gemma3-12b", "gemma3-27b", "jamba-v0.1-52b", "xlstm-125m"}


def shapes_for(arch_name: str) -> list[ShapeConfig]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch_name in SUBQUADRATIC_ARCHS:
        out.append(LONG_500K)
    return out
