"""gemma3-27b [dense] — 5:1 local:global, 128k; 62 layers (10 periods + 2)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense", num_layers=62, d_model=5376,
    num_heads=32, num_kv_heads=16, d_ff=21504, vocab_size=262144,
    head_dim=128, period_pattern=("local",) * 5 + ("attn",),
    window_size=1024, rope_theta=1_000_000.0, act="gelu", tie_embeddings=True,
)

SMOKE = CONFIG.replace(num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16, window_size=8)
