"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer (arXiv:2403.19887): period-8 blocks, attention at
position 3, 32 layers = 4 periods exactly."""
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=65536,
    head_dim=128,
    period_pattern=("mamba", "mamba", "mamba", "attn",
                    "mamba", "mamba", "mamba", "mamba"),
    moe=MoEConfig(num_experts=16, top_k=2, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = CONFIG.replace(num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16,
                       moe=MoEConfig(num_experts=4, top_k=2, every=2),
                       ssm=SSMConfig(d_state=4, d_conv=4, expand=2))
