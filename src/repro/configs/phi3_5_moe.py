"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, every layer."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=6400, vocab_size=32064,
    head_dim=128, moe=MoEConfig(num_experts=16, top_k=2, every=1),
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16,
                       moe=MoEConfig(num_experts=4, top_k=2, every=1))
