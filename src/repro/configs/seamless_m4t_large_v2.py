"""seamless-m4t-large-v2 [audio] — encoder-decoder transformer backbone
(arXiv:2308.11596).  24 encoder + 24 decoder layers, d=1024, 16H, ff=8192.
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S, d_model) for the encoder."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", num_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16, d_ff=8192,
    vocab_size=256206, head_dim=64, enc_layers=24, frame_input=True,
    act="relu",
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                       d_ff=128, vocab_size=512, head_dim=16, enc_layers=2)
