"""internvl2-76b [vlm] — InternViT + LLM backbone (arXiv:2404.16821).
Backbone only (80L Llama3-70B-class decoder); the ViT frontend is a STUB:
input_specs() provides precomputed patch embeddings prepended to the text."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=28672, vocab_size=128256,
    head_dim=128, rope_theta=500_000.0, num_patches=1024,
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16, num_patches=16)
