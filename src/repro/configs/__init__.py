"""Architecture registry: --arch <id> resolves through ARCHS."""
from . import (minicpm3_4b, gemma3_12b, llama3_2_3b, gemma3_27b,
               jamba_v0_1_52b, phi3_5_moe, llama4_scout, xlstm_125m,
               seamless_m4t_large_v2, internvl2_76b)
from .base import ModelConfig, ShapeConfig, RunConfig, MoEConfig, MLAConfig, SSMConfig
from .shapes import SHAPES, shapes_for, SUBQUADRATIC_ARCHS

_MODULES = [minicpm3_4b, gemma3_12b, llama3_2_3b, gemma3_27b, jamba_v0_1_52b,
            phi3_5_moe, llama4_scout, xlstm_125m, seamless_m4t_large_v2,
            internvl2_76b]

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES = {m.CONFIG.name: m.SMOKE for m in _MODULES}

# paper's own models ship as presets too (GNN side)
GNN_PRESETS = {"gcn": {"dims": [256, 256, 256]},
               "gin": {"dims": [256, 256, 256]},
               "ngcf": {"dims": [256, 256, 256]}}

__all__ = ["ARCHS", "SMOKES", "SHAPES", "shapes_for", "SUBQUADRATIC_ARCHS",
           "ModelConfig", "ShapeConfig", "RunConfig", "MoEConfig",
           "MLAConfig", "SSMConfig", "GNN_PRESETS"]
