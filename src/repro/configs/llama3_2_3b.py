"""llama3.2-3b [dense] — small llama3 (GQA kv=8)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense", num_layers=28, d_model=3072,
    num_heads=24, num_kv_heads=8, d_ff=8192, vocab_size=128256,
    head_dim=128, rope_theta=500_000.0, tie_embeddings=True,
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16)
