"""gemma3-12b [dense] — 5:1 local:global interleave, 128k context."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense", num_layers=48, d_model=3840,
    num_heads=16, num_kv_heads=8, d_ff=15360, vocab_size=262144,
    head_dim=256, period_pattern=("local",) * 5 + ("attn",),
    window_size=1024, rope_theta=1_000_000.0, act="gelu", tie_embeddings=True,
)

SMOKE = CONFIG.replace(num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16, window_size=8)
