"""Config system: architecture + shape + parallelism + run configs.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (full published size) and ``SMOKE`` (same family, tiny).  Shapes
(``train_4k``/``prefill_32k``/``decode_32k``/``long_500k``) are defined in
``shapes.py`` and paired with every arch per the assignment.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    every: int = 1               # MoE FFN on layers where (idx % every == every-1)
    num_shared: int = 0          # always-on shared experts (llama4)
    d_ff: int = 0                # expert hidden dim (0 -> cfg.d_ff)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // num_heads

    # layer pattern: tuple of per-position-in-period mixer kinds.
    # e.g. dense: ("attn",); gemma3: ("local",)*5 + ("attn",);
    # jamba: ("mamba","mamba","mamba","attn","mamba","mamba","mamba","mamba")
    period_pattern: tuple = ("attn",)
    window_size: int = 1024              # sliding window for "local" mixers

    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None

    # enc-dec (seamless): encoder depth; num_layers is the decoder depth
    enc_layers: int = 0
    # vlm: number of prefix patch embeddings provided by the (stubbed) frontend
    num_patches: int = 0
    # audio: encoder consumes precomputed frame embeddings instead of tokens
    frame_input: bool = False

    act: str = "silu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"              # activation/compute dtype
    param_dtype: str = "float32"

    # training
    remat: str = "full"                  # full | none
    loss_chunk: int = 512                # vocab-loss sequence chunking

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def n_periods(self) -> int:
        return self.num_layers // len(self.period_pattern)

    @property
    def remainder_kinds(self) -> tuple:
        r = self.num_layers % len(self.period_pattern)
        return self.period_pattern[:r]

    def ffn_kind(self, layer_idx: int) -> str:
        if self.moe is None:
            return "mlp"
        return "moe" if (layer_idx % self.moe.every) == (self.moe.every - 1) \
            else "mlp"

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter estimate (embeddings included once)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        h, kvh = self.num_heads, self.num_kv_heads
        total = active = v * d + (0 if self.tie_embeddings else v * d)
        per_layer_attn = d * h * hd + 2 * d * kvh * hd + h * hd * d + 2 * d
        if self.mla is not None:
            m = self.mla
            qd = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer_attn = (d * m.q_lora_rank + m.q_lora_rank * h * qd
                              + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                              + m.kv_lora_rank * h * (m.qk_nope_head_dim
                                                      + m.v_head_dim)
                              + h * m.v_head_dim * d + 2 * d)
        mlp_p = 3 * d * f
        for i in range(self.num_layers):
            kind = self.period_pattern[i % len(self.period_pattern)]
            if kind == "mamba":
                s = self.ssm or SSMConfig()
                di = s.expand * d
                dtr = s.dt_rank or -(-d // 16)
                mix = (d * 2 * di + di * s.d_conv + di * (dtr + 2 * s.d_state)
                       + dtr * di + di + di * d)
            elif kind in ("mlstm", "slstm"):
                di = 2 * d
                mix = d * di * 4 + di * d + 4 * d * 4   # q,k,v,z + out + gates
            else:
                mix = per_layer_attn
            if self.ffn_kind(i) == "moe":
                mcfg = self.moe
                ef = mcfg.d_ff or f
                ffn = mcfg.num_experts * 3 * d * ef + d * mcfg.num_experts
                ffn_act = (mcfg.top_k + mcfg.num_shared) * 3 * d * ef \
                    + d * mcfg.num_experts
                if mcfg.num_shared:
                    ffn += mcfg.num_shared * 3 * d * ef
            elif kind in ("mlstm", "slstm") and f == 0:
                ffn = ffn_act = 0
            else:
                ffn = ffn_act = mlp_p
            total += mix + ffn
            active += mix + (ffn_act if self.ffn_kind(i) == "moe" else ffn)
        if self.enc_layers:
            # encoder self-attn + mlp, plus decoder cross-attn already counted?
            enc = self.enc_layers * (per_layer_attn + mlp_p)
            cross = self.num_layers * per_layer_attn
            total += enc + cross
            active += enc + cross
        return total, active


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int


@dataclass(frozen=True)
class RunConfig:
    arch: ModelConfig
    shape: ShapeConfig
    # parallelism
    use_pallas: bool = False           # True on real TPU
    zero1: bool = False                # shard optimizer state over data axis
    seq_shard_long: bool = True        # context-parallel KV for batch < data
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
