"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + 1 shared expert, every
layer; early-fusion vision handled by the stubbed frontend."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=8192, vocab_size=202048,
    head_dim=128, rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, every=1, num_shared=1),
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16,
                       moe=MoEConfig(num_experts=4, top_k=1, every=1,
                                     num_shared=1))
