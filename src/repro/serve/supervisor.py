"""ShardSupervisor — the autonomic runtime of the replicated CSSD array.

PRs 4-5 gave the array a fault PATH (``fail_shard`` drain, streaming
``rebuild_shard``) but left the fault LOOP to an operator: someone had to
notice the ``DeviceFailedError`` burst, drain the shard, and kick the
rebuild.  The paper pitches the array as an always-on inference service
(§8), and ROADMAP open item 2 names the missing piece exactly — this
module closes the loop:

  healthy ──error──▶ suspect ──burst──▶ failed ──auto──▶ rebuilding ──▶ healthy
     ▲                  │ (decay)                                          │
     └──────────────────┴──────────────────────────────────────────────────┘

  * **detection** — two independent signals feed the state machine: the
    store reports every shard-attributed ``DeviceFailedError`` it maps
    on the serving path (``record_error`` — zero extra RPCs), and a
    monitor thread probes every endpoint's ``counters`` each
    ``probe_interval_s`` (device stats stay readable after ``fail()``,
    so a dead shard is caught even with zero serving traffic);
  * **policy, not blips** — one error marks a shard *suspect* (replica
    selection steers reads away via ``FlowControl.suspect_penalty_pages``
    until the suspicion decays after ``suspect_decay_s`` quiet seconds);
    only ``error_threshold`` errors inside ``window_s`` — or the probe
    reading the device's own failed flag — drain it;
  * **drain** — ``store.fail_shard`` (idempotent; raced operator RPCs are
    fine).  If the drain is REFUSED because a vertex class would lose its
    last replica, the shard is marked failed-undrained and no rebuild is
    attempted — that is data loss, an operator problem, not a loop to
    spin on;
  * **rebuild** — a background thread runs ``store.rebuild_shard`` with
    ``rebuild_pacing_s`` chunk pacing (serving reads keep flowing: the
    store streams under the maintenance gate, and pacing keeps recovery
    pulls from monopolising the survivor devices), retrying up to
    ``max_rebuild_attempts`` every ``rebuild_retry_s``;
  * **re-admission** — on success the shard returns to ``healthy`` and
    replica selection resumes steering load onto it.

Locking: the supervisor lock is a strict LEAF.  ``record_error`` is
called from serving threads that may hold the store's mutation lock, so
the supervisor must NEVER call back into the store while holding its own
lock — transition decisions are made under the lock, drains and rebuilds
execute outside it (guarded by per-shard draining flags + the store's
idempotent fault RPCs).

Transition hooks (``on_transition(shard, old, new, info)``) give the
telemetry layer a callback seam — the metrics-hook shape — and a bounded
event log + ``snapshot()`` feed the service ``stats`` RPC, so a client
can distinguish "overloaded" from "degraded array" by asking.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass

from ..concurrency import witness_lock
from ..store.blockdev import DeviceFailedError

# health states
HEALTHY = "healthy"
SUSPECT = "suspect"
FAILED = "failed"           # drained (or refused: ``drained`` False)
REBUILDING = "rebuilding"


@dataclass
class HealthPolicy:
    """Knobs of the autonomic loop (see module docstring)."""

    error_threshold: int = 3          # errors inside window_s => drain
    window_s: float = 1.0
    suspect_decay_s: float = 5.0      # quiet seconds before un-suspecting
    probe_interval_s: float = 0.05    # monitor heartbeat
    auto_rebuild: bool = True
    rebuild_pacing_s: float = 0.0     # sleep between rebuild chunk pulls
    rebuild_retry_s: float = 0.5
    max_rebuild_attempts: int = 5


class ShardSupervisor:
    """Health monitor + auto-drain/auto-rebuild loop over one array store
    (``ReplicatedGraphStore`` or ``ShardedGraphStore``).

    ``start()`` launches the monitor thread and attaches the supervisor
    as ``store.health`` (the store reports shard errors and reads the
    suspect set through that duck-typed seam).  ``stop()`` detaches and
    joins.  All public queries are safe from any thread.
    """

    def __init__(self, store, policy: HealthPolicy | None = None, *,
                 on_transition=None, max_events: int = 256):
        self.store = store
        self.policy = policy or HealthPolicy()
        self.on_transition = on_transition
        self._lock = witness_lock(             # LEAF — see module docstring
            "supervisor._lock", threading.Lock())
        n = store.n_shards
        self._state = [HEALTHY] * n
        self._drained = [False] * n
        self._errors: list[deque] = [deque(maxlen=64) for _ in range(n)]
        self._last_error = [0.0] * n
        self._first_error = [0.0] * n          # of the current incident
        self._draining = [False] * n
        self._rebuild_attempts = [0] * n
        self._next_rebuild_t = [0.0] * n
        self._rebuild_threads: dict[int, threading.Thread] = {}  # guarded-by: _lock
        self.events: deque = deque(maxlen=int(max_events))
        self.incidents: list[dict] = []        # one per completed drain
        self._hookq: deque = deque()           # guarded-by: _lock
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        # shards already failed at attach time (operator predecessors)
        for s, failed in enumerate(getattr(store, "failed_shards",
                                           [False] * n)):
            if failed:
                self._state[s] = FAILED
                self._drained[s] = True

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "ShardSupervisor":
        """Attach as ``store.health`` and launch the monitor thread
        (idempotent); returns ``self`` for chaining."""
        if self._thread is not None:
            return self
        self.store.health = self
        self._stop.clear()
        self._thread = threading.Thread(target=self._monitor,
                                        name="shard-supervisor", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Detach from the store, join the monitor and any in-flight
        rebuild threads."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        with self._lock:
            rebuilds = list(self._rebuild_threads.values())
        for th in rebuilds:
            th.join(timeout=30.0)
        if getattr(self.store, "health", None) is self:
            self.store.health = None

    def resize(self, n: int, keep: list[int] | None = None) -> None:
        """Re-dimension the per-shard state after an elastic reshard.

        Called by the store's ``_topology_changed`` hook once a grow or
        shrink commits.  On grow the new shards start ``healthy``; on
        shrink the survivors were renumbered by the store, and — since
        ``reshard`` refuses to run with any shard failed — every
        survivor was healthy or suspect at commit time, so the state
        resets to ``healthy`` (a still-flaky shard re-marks itself on
        its next error).  ``keep`` optionally lists the old ids of the
        survivors in new-id order to preserve their state instead.

        Args:
            n: the store's new shard count.
            keep: old shard ids of the survivors, in new-id order
                (shrink only); ``None`` resets shrunk state.
        """
        n = int(n)
        with self._lock:
            old_n = len(self._state)
            if n == old_n:
                return

            def remap(lst, default):
                if n > old_n:
                    return list(lst) + [default] * (n - old_n)
                if keep is not None:
                    return [lst[int(o)] for o in keep]
                return [default] * n

            self._state = remap(self._state, HEALTHY)
            self._drained = remap(self._drained, False)
            self._errors = remap(self._errors, None)
            for i, q in enumerate(self._errors):
                if q is None or (n < old_n and keep is None):
                    self._errors[i] = deque(maxlen=64)
            self._last_error = remap(self._last_error, 0.0)
            self._first_error = remap(self._first_error, 0.0)
            self._draining = remap(self._draining, False)
            self._rebuild_attempts = remap(self._rebuild_attempts, 0)
            self._next_rebuild_t = remap(self._next_rebuild_t, 0.0)
            self.events.append({"t": time.monotonic(), "shard": -1,
                                "from": f"n={old_n}", "to": f"n={n}",
                                "cause": "resize"})

    # ------------------------------------------------------------ queries
    def state_of(self, shard: int) -> str:
        """Current health state of one shard (``healthy`` / ``suspect``
        / ``failed`` / ``rebuilding``)."""
        with self._lock:
            return self._state[int(shard)]

    def states(self) -> list[str]:
        """Health state of every shard, indexed by shard id."""
        with self._lock:
            return list(self._state)

    def suspect_shards(self) -> list[int]:
        """Shards replica selection should steer away from (consumed by
        the store's ``_hist_loads`` penalty)."""
        with self._lock:
            return [s for s, st in enumerate(self._state) if st == SUSPECT]

    def snapshot(self) -> dict:
        """Point-in-time health block for the service ``stats`` RPC:
        per-shard states, suspect list, incident count + last incident,
        the 16 most recent transition events, and the active policy
        thresholds."""
        with self._lock:
            return {
                "states": list(self._state),
                "suspects": [s for s, st in enumerate(self._state)
                             if st == SUSPECT],
                "drained": list(self._drained),
                "incidents": len(self.incidents),
                "last_incident": (dict(self.incidents[-1])
                                  if self.incidents else None),
                "events": [dict(e) for e in list(self.events)[-16:]],
                "policy": {"error_threshold": self.policy.error_threshold,
                           "window_s": self.policy.window_s,
                           "auto_rebuild": self.policy.auto_rebuild},
            }

    # -------------------------------------------------- error-path signal
    def record_error(self, shard: int, exc: Exception) -> None:
        """Shard-attributed ``DeviceFailedError`` from the serving path.

        Cheap (deque append + threshold check) — called inline by reader
        threads.  One error inside a healthy window -> suspect; a burst of
        ``error_threshold`` inside ``window_s`` -> drain (outside the
        lock)."""
        s = int(shard)
        now = time.monotonic()
        drain = False
        with self._lock:
            if self._state[s] in (FAILED, REBUILDING):
                return
            q = self._errors[s]
            q.append(now)
            self._last_error[s] = now
            if self._state[s] == HEALTHY:
                self._first_error[s] = now
                self._transition_locked(s, SUSPECT,
                                        {"error": f"{type(exc).__name__}"})
            burst = sum(1 for t in q if now - t <= self.policy.window_s)
            if burst >= self.policy.error_threshold \
                    and not self._draining[s]:
                self._draining[s] = True
                drain = True
        self._fire_hooks()
        if drain:
            self._drain(s, cause="error_burst")

    # ------------------------------------------------------ monitor thread
    def _monitor(self) -> None:
        while not self._stop.is_set():
            try:
                self._tick()
            except Exception:  # noqa: BLE001 — the loop must survive
                pass
            self._stop.wait(self.policy.probe_interval_s)

    def _tick(self) -> None:
        now = time.monotonic()
        probes = self.store.probe_shards()
        store_failed = list(getattr(self.store, "failed_shards",
                                    [False] * self.store.n_shards))
        to_drain: list[int] = []
        to_rebuild: list[int] = []
        with self._lock:
            for p in probes:
                s = int(p["shard"])
                st = self._state[s]
                dev_dead = bool(p.get("failed")) or "error" in p
                if store_failed[s]:
                    # drained behind our back (operator RPC or a finished
                    # drain): adopt, schedule rebuild
                    if st not in (FAILED, REBUILDING):
                        self._drained[s] = True
                        self._transition_locked(s, FAILED,
                                                {"cause": "observed_drained"})
                elif dev_dead and st in (HEALTHY, SUSPECT) \
                        and not self._draining[s]:
                    # the device's own failed flag is definitive — no
                    # blip policy needed, drain now
                    if st == HEALTHY:
                        self._first_error[s] = now
                    self._draining[s] = True
                    to_drain.append(s)
                elif st == SUSPECT and not self._draining[s] \
                        and now - self._last_error[s] \
                        > self.policy.suspect_decay_s:
                    self._transition_locked(s, HEALTHY, {"cause": "decay"})
            if self.policy.auto_rebuild:
                for s in range(self.store.n_shards):
                    if self._state[s] == FAILED and self._drained[s] \
                            and now >= self._next_rebuild_t[s] \
                            and self._rebuild_attempts[s] \
                            < self.policy.max_rebuild_attempts \
                            and s not in self._rebuild_threads:
                        self._transition_locked(
                            s, REBUILDING,
                            {"attempt": self._rebuild_attempts[s] + 1})
                        to_rebuild.append(s)
        self._fire_hooks()
        for s in to_drain:
            self._drain(s, cause="probe")
        for s in to_rebuild:
            th = threading.Thread(target=self._rebuild, args=(s,),
                                  name=f"shard-rebuild-{s}", daemon=True)
            # register BEFORE start: a fast rebuild could finish and pop
            # its entry before an unlocked post-start assignment ran,
            # leaving a dead thread wedged in the map (and _tick would
            # never schedule that shard again)
            with self._lock:
                self._rebuild_threads[s] = th
            th.start()

    # ------------------------------------------------------------- actions
    def _drain(self, s: int, *, cause: str) -> None:
        """Outside the supervisor lock (fail_shard takes store locks)."""
        t_det = time.monotonic()
        try:
            info = self.store.fail_shard(s)
            drained, refused = True, None
        except DeviceFailedError as e:
            # refused: the shard's class(es) would lose the last replica —
            # data loss, not degradation; no rebuild loop to spin on
            info, drained, refused = {}, False, str(e)
        except Exception as e:  # noqa: BLE001
            info, drained, refused = {}, False, f"{type(e).__name__}: {e}"
        with self._lock:
            self._draining[s] = False
            self._drained[s] = drained
            self._rebuild_attempts[s] = 0
            self._next_rebuild_t[s] = 0.0
            detect_s = max(0.0, t_det - self._first_error[s]) \
                if self._first_error[s] else 0.0
            incident = {"shard": s, "cause": cause, "drained": drained,
                        "detect_s": detect_s, "t_drained": t_det,
                        "refused": refused,
                        "degraded_classes": info.get("degraded_classes")}
            self.incidents.append(incident)
            self._transition_locked(s, FAILED, incident)
        self._fire_hooks()

    def _rebuild(self, s: int) -> None:
        pol = self.policy
        t0 = time.monotonic()
        try:
            info = self.store.rebuild_shard(s, pacing_s=pol.rebuild_pacing_s)
            ok = not info.get("rebuild_in_progress")
        except Exception as e:  # noqa: BLE001 — e.g. a survivor died
            info, ok = {"error": f"{type(e).__name__}: {e}"}, False
        deferred = bool(info.get("reshard_in_progress"))
        with self._lock:
            self._rebuild_threads.pop(s, None)
            if deferred:
                # an elastic reshard holds the maintenance plane — not a
                # failure of THIS shard, so reschedule without burning an
                # attempt (the reshard itself refuses to start while any
                # shard is failed, so this can only race its final flip)
                self._next_rebuild_t[s] = time.monotonic() \
                    + pol.rebuild_retry_s
                self._transition_locked(
                    s, FAILED, {"cause": "rebuild_deferred",
                                "reason": "reshard_in_progress"})
            elif ok:
                self._errors[s].clear()
                self._drained[s] = False
                self._rebuild_attempts[s] = 0
                if self.incidents and self.incidents[-1]["shard"] == s:
                    self.incidents[-1]["rebuild_s"] = \
                        time.monotonic() - t0
                    self.incidents[-1]["restore_s"] = \
                        time.monotonic() - self.incidents[-1]["t_drained"]
                self._transition_locked(
                    s, HEALTHY,
                    {"cause": "rebuilt",
                     "chunks": info.get("chunks"),
                     "seconds": info.get("seconds")})
            else:
                self._rebuild_attempts[s] += 1
                self._next_rebuild_t[s] = time.monotonic() \
                    + pol.rebuild_retry_s
                self._transition_locked(
                    s, FAILED,
                    {"cause": "rebuild_failed",
                     "attempt": self._rebuild_attempts[s],
                     "error": info.get("error")})
        self._fire_hooks()

    # ---------------------------------------------------------- transitions
    def _transition_locked(self, s: int, new: str, info: dict) -> None:  # requires-lock: _lock
        old = self._state[s]
        self._state[s] = new
        ev = {"t": time.monotonic(), "shard": s, "from": old, "to": new}
        ev.update({k: v for k, v in info.items()
                   if isinstance(v, (str, int, float, bool, type(None)))})
        self.events.append(ev)
        if self.on_transition is not None:
            # the hook is arbitrary telemetry code — it must never run
            # under the LEAF supervisor lock (it may acquire anything).
            # Queue it; every caller drains via _fire_hooks() after
            # releasing.
            self._hookq.append((s, old, new, dict(info)))

    def _fire_hooks(self) -> None:
        """Run queued transition hooks.  Call WITHOUT the lock held."""
        while True:
            with self._lock:
                if not self._hookq:
                    return
                s, old, new, info = self._hookq.popleft()
            hook = self.on_transition
            if hook is not None:
                try:
                    hook(s, old, new, info)
                except Exception:  # noqa: BLE001 — hooks must not break
                    pass           # the loop
