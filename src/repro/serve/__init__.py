from .batcher import (ServiceProgram, split_service_dfg, sample_group,
                      pad_group, fingerprint_weights)
from .scheduler import BatchScheduler, AdmissionError, QoSTelemetry
from .runtime import ServingRuntime
from .supervisor import ShardSupervisor, HealthPolicy

__all__ = ["ServiceProgram", "split_service_dfg", "sample_group",
           "pad_group", "fingerprint_weights", "BatchScheduler",
           "AdmissionError", "QoSTelemetry", "ServingRuntime",
           "ShardSupervisor", "HealthPolicy"]
