"""ServingRuntime — the concurrent serving loop of the CSSD: multi-queue
RoP transport + RPC dispatch + continuous-batching scheduler over one
``HolisticGNNService``.

Command routing mirrors the device firmware split:

  * ``run`` commands against a batchable (BatchPre-led) service DFG enter
    the scheduler's admission queue and complete asynchronously, coalesced
    into fused super-batches;
  * everything else — mutations, unit queries, ``stats``, non-service DFGs —
    dispatches immediately through the ordinary RPC server path, so a
    mutable-graph update is never stuck behind a model execution.

The runtime is shard- AND endpoint-transparent: against a
``ShardedGraphStore``-backed service, a fused group's per-hop sampling
submits one batched fetch to every shard endpoint and awaits them
together, mutable commands route to the owning shard's endpoint (whose
device ``on_write`` hook invalidates that shard's page cache), and the
``stats`` RPC carries per-shard cache + IO telemetry — plus, for arrays,
a per-endpoint link snapshot (``shard_links``) — next to the scheduler
QoS block.  Whether the shards are in-process (``LocalShardEndpoint``)
or remote behind their own RoP SQ/CQ pairs (``RopShardEndpoint``,
``examples/serve_gnn.py --remote-shards``), the serving results are
bit-identical.

It is failure-transparent too: against a replicated array
(``replication >= 2``), ``fail_shard``/``rebuild_shard`` dispatch as
immediate commands (never queued behind a model execution, like any
mutation), a fused group whose fetch was already planned onto the dying
shard re-plans against the survivors inside the store's failover retry,
and degraded groups keep returning bit-identical results — the
fault-injection CI gate drives exactly this path mid-serve.

Operating modes:

  * **threaded** (``start()``/``stop()``): a dispatcher thread drains the
    submission queues, a scheduler thread runs fused groups — the serving
    benchmark and example use this;
  * **stepped** (``pump()``): single-threaded deterministic draining —
    grouping and completion order become a pure function of submission
    order, which the bit-exactness and mutable-under-load tests rely on.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor

from ..concurrency import witness_lock
from ..rpc import RPCServer, MultiQueueRoP, AsyncRPCClient
from ..rpc.transport import serialize, deserialize
from .scheduler import BatchScheduler, AdmissionError

# commands counted by the write-side admission telemetry
_MUTATION_METHODS = frozenset({
    "add_vertex", "delete_vertex", "add_edge", "delete_edge",
    "update_embed", "update_graph", "flush_firehose"})


class ServingRuntime:
    def __init__(self, service, *, n_queues: int = 4, queue_depth: int = 64,
                 max_group: int = 16, max_pending: int = 256,
                 coalesce: bool = True, batch_window_s: float = 0.02,
                 immediate_workers: int = 4):
        self.service = service
        self.rop = MultiQueueRoP(n_queues=n_queues, depth=queue_depth)
        self.server = RPCServer(service)
        self.scheduler = BatchScheduler(service, max_group=max_group,
                                        max_pending=max_pending,
                                        coalesce=coalesce,
                                        batch_window_s=batch_window_s)
        # the service's `stats` RPC pulls QoS + transport counters from here
        service.qos_provider = self.qos_snapshot
        # rejected admissions carry the array's health next to queue depth
        self.scheduler.health_provider = self._health_summary
        # threaded mode runs non-run commands on this small pool: a
        # mutation blocked on the store's maintenance gate (a streaming
        # shard rebuild) must not wedge the dispatcher thread — stats
        # probes and reads keep flowing while the write waits it out
        self.immediate_workers = int(immediate_workers)
        self._immediate: ThreadPoolExecutor | None = None
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._next_q = itertools.count()
        # write-side admission telemetry: mutation commands dispatched and
        # shed (typed BackpressureError — e.g. a full firehose log or an
        # exhausted submit-retry budget rejects the write at admission)
        self._write_lock = witness_lock(
            "runtime._write_lock", threading.Lock())
        self.write_ops = 0                     # guarded-by: _write_lock
        self.write_shed = 0                    # guarded-by: _write_lock

    # ---------------------------------------------------------------- clients
    def client(self, qid: int | None = None) -> AsyncRPCClient:
        """A host-side async stub; queues are assigned round-robin."""
        if qid is None:
            qid = next(self._next_q) % len(self.rop.pairs)
        return AsyncRPCClient(self.rop, qid)

    # ----------------------------------------------------------- device side
    def _dispatch(self, qid: int, cmd_id: int, packet: bytes, *,
                  inline: bool = True) -> None:
        req = deserialize(packet)
        method, kwargs = req["method"], dict(req.get("kwargs") or {})
        if method == "run" and self.scheduler.accepts(kwargs.get("dfg")):
            priority = int(kwargs.pop("priority", 0))
            deadline_s = kwargs.pop("deadline_s", None)
            weights_key = kwargs.pop("weights_key", None)

            def on_done(resp: dict) -> None:
                self.rop.post_completion(qid, cmd_id, serialize(resp))

            try:
                self.scheduler.submit(
                    dfg=kwargs["dfg"], batch=kwargs["batch"],
                    weights=kwargs.get("weights"),
                    weights_ref=kwargs.get("weights_ref"),
                    seed=kwargs.get("seed", 0),
                    jit=kwargs.get("jit", True),
                    priority=priority, deadline_s=deadline_s,
                    weights_key=weights_key, on_done=on_done)
            except AdmissionError as e:
                on_done({"ok": False, "error": f"AdmissionError: {e}",
                         "reason": dict(e.reason)})
            return
        kwargs.pop("priority", None)          # QoS hints are runtime-level,
        kwargs.pop("deadline_s", None)        # not service kwargs
        kwargs.pop("weights_key", None)

        def immediate() -> None:
            resp = self.server.dispatch(method, kwargs)
            if method in _MUTATION_METHODS:
                with self._write_lock:
                    self.write_ops += 1
                    if not resp["ok"] and \
                            resp["error"].startswith("BackpressureError"):
                        self.write_shed += 1
            self.rop.post_completion(qid, cmd_id, serialize(resp))

        if inline or self._immediate is None:
            immediate()              # stepped mode stays deterministic
        else:
            self._immediate.submit(immediate)

    # ---------------------------------------------------------- stepped mode
    def pump(self) -> int:
        """Drain every queued submission, then schedule to empty.

        Deterministic: requests are admitted in queue round-robin order and
        grouped by the scheduler's pure (priority, seq) policy.  Returns the
        number of scheduler-completed requests.
        """
        while True:
            got = self.rop.pop_submission(timeout=0)
            if got is None:
                break
            self._dispatch(*got)
        return self.scheduler.drain()

    # ---------------------------------------------------------- threaded mode
    def start(self) -> None:
        if self._threads:
            return
        self._stop.clear()
        self._immediate = ThreadPoolExecutor(
            max_workers=self.immediate_workers,
            thread_name_prefix="rt-immediate")

        def dispatcher():
            while not self._stop.is_set():
                got = self.rop.pop_submission(timeout=0.05)
                if got is not None:
                    self._dispatch(*got, inline=False)

        def worker():
            # the worker drains submissions inline at every group boundary:
            # under load the dispatcher thread is starved of scheduling
            # quanta by the model execution, and groups would otherwise
            # form half-empty.  The dispatcher still guarantees liveness
            # for commands arriving DURING a group execution (mutations,
            # stats) — they never wait for the batcher.
            while not self._stop.is_set():
                while True:
                    got = self.rop.pop_submission(timeout=0)
                    if got is None:
                        break
                    self._dispatch(*got, inline=False)
                if self.scheduler.step():
                    continue
                if self.scheduler.wait_for_work(timeout=0.05):
                    time.sleep(0.0005)        # batching window still open

        for fn, name in ((dispatcher, "rop-dispatch"), (worker, "batcher")):
            th = threading.Thread(target=fn, name=name, daemon=True)
            th.start()
            self._threads.append(th)

    def stop(self) -> None:
        self._stop.set()
        for th in self._threads:
            th.join(timeout=5.0)
        self._threads = []
        if self._immediate is not None:
            self._immediate.shutdown(wait=True)
            self._immediate = None

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.stop()

    # -------------------------------------------------------------- telemetry
    def _health_summary(self) -> dict | None:
        """Compact per-shard health for AdmissionError reasons: failed
        shards from the store, states/suspects from the supervisor when
        one is attached.  None for single-device services."""
        store = getattr(self.service, "store", None)
        failed = getattr(store, "failed_shards", None)
        out: dict = {}
        if failed is not None:
            out["failed_shards"] = [i for i, f in enumerate(failed) if f]
        sup = getattr(store, "health", None)
        if sup is not None:
            out["states"] = sup.states()
            out["suspects"] = sup.suspect_shards()
        return out or None

    def qos_snapshot(self) -> dict:
        out = self.scheduler.qos.snapshot(
            queue_depth=self.scheduler.queue_depth)
        out["transport"] = self.rop.stats_snapshot()
        links = self.shard_link_snapshot()
        if links is not None:
            out["shard_links"] = links
        store = getattr(self.service, "store", None)
        if hasattr(store, "backpressure_events"):
            out["backpressure"] = {
                "events": store.backpressure_events,
                "retries": store.backpressure_retries,
                "max_inflight_per_shard":
                    store.flow.max_inflight_per_shard}
        with self._write_lock:
            out["write_admission"] = {"ops": self.write_ops,
                                      "shed": self.write_shed}
        fh = getattr(self.service, "firehose", None)
        if fh is not None:
            out["firehose"] = fh.snapshot()
        sup = getattr(store, "health", None)
        if sup is not None:
            out["health"] = sup.snapshot()
        if hasattr(self.service, "engine_stats"):
            # compute-plane placement + jit trace cache (mesh is None when
            # the engine runs unsharded) — operators watch evictions here
            # for pad-group drift blowing the trace cache
            out["engine"] = self.service.engine_stats()
        return out

    def shard_link_snapshot(self) -> list[dict] | None:
        """Host-side view of the coordinator->shard endpoint links: total
        commands issued and (for RoP endpoints) bytes through the mmap
        channels — the multi-host observability the ``stats`` RPC's QoS
        block carries next to the scheduler counters.  None for
        single-device services (there is no array)."""
        endpoints = getattr(self.service.store, "endpoints", None)
        if endpoints is None:
            return None
        links = []
        for s, ep in enumerate(endpoints):
            entry = {"shard": s, "calls": ep.rpc_calls()}
            if hasattr(ep, "channel_bytes"):
                entry["channel_bytes"] = ep.channel_bytes()
            links.append(entry)
        return links
