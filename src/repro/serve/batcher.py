"""Continuous-batching composition layer — fuse concurrent Run(DFG, batch)
requests against the same service DFG into ONE near-storage sampling pass
and ONE cached-jit engine execution, bit-identically to serial runs.

Pieces:

  * ``split_service_dfg`` — a service DFG (paper Fig. 10a: leading BatchPre)
    is split into its sampling spec (fanouts) and a model-only DFG whose
    inputs are the BatchPre output refs, so the scheduler can feed a fused
    super-batch straight into the model portion;
  * ``sample_group`` — the fused multi-request sampler: per hop, every
    request's frontier joins one concatenated near-storage
    ``sample_neighbors_batch`` call (a single queued scatter-read serves the
    whole group — one PER SHARD, fanned out concurrently, when the store is
    a ``ShardedGraphStore`` array) with *per-request rng segments*, so each
    request's sample is bit-identical to a solo run; reindexing stays
    request-local (no cross-request dedup — that would change sampling
    semantics);
  * prefix-preserving composition — per-request blocks are merged into one
    block-diagonal super-batch whose level lists keep the engine's
    prefix-ordering invariant (level k is a prefix of level k+1), so
    Prefix-consuming models (GIN, NGCF) stay correct;
  * ``pad_group`` — geometric shape bucketing (base * 2^k per tensor) so
    varying group sizes map to a bounded set of jit signatures.

Why fused == serial, bitwise: every model op computes each destination row
independently (SpMM/GEMM/activations are row-local), XLA's per-row results
are invariant to the number of rows in the batch, and masked padding slots
contribute exact zeros.  ``tests/test_serving.py`` asserts bit-equality.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from ..core.dfg import DFG
from ..rpc.queues import BackpressureError
from ..store.sampler import (LayerBlock, SampledBatch, _gather_neighbors,
                             _reindex, _subsample_batch)

# request tag multiplier for the group-wide reindex: vids from different
# requests must never dedup against each other (that would change sampling
# semantics), so each request's vids are lifted into a disjoint range
_REQ_TAG = 1 << 42

BATCHPRE_OP = "BatchPre"


@dataclass
class ServiceProgram:
    """A service DFG split around its leading BatchPre node."""
    model: DFG               # BatchPre stripped; its outputs became inputs
    fanouts: list[int]
    feed_refs: list[str]     # BatchPre output refs: [H, nbr0, mask0, ...]


def split_service_dfg(dfg: DFG) -> ServiceProgram | None:
    """Split a service-style DFG; None when there is no BatchPre prefix."""
    bp = next((n for n in dfg._nodes if n.op == BATCHPRE_OP), None)
    if bp is None or "Batch" not in dfg._ins:
        return None
    model = DFG()
    consumed = set(bp.inputs)                 # Batch (+ Seed on newer DFGs)
    model._ins = [i for i in dfg._ins if i not in consumed] + list(bp.outputs)
    model._nodes = [n for n in dfg._nodes if n.seq != bp.seq]
    model._outs = dict(dfg._outs)
    return ServiceProgram(model=model, fanouts=list(bp.attrs["fanouts"]),
                          feed_refs=list(bp.outputs))


def fingerprint_weights(weights: dict | None) -> str:
    """Content hash of a feed dict — the coalescing compatibility key."""
    h = hashlib.sha1()
    for k in sorted(weights or {}):
        arr = np.asarray(weights[k])
        h.update(k.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _bucket(n: int, base: int) -> int:
    """Round up to a half-octave bucket (base * 2^k or base * 3 * 2^(k-1)):
    bounded signature count across group sizes, <= 33% padding waste."""
    b = max(base, 1)
    while True:
        if n <= b:
            return b
        if n <= b + b // 2:
            return b + b // 2
        b *= 2


def sample_group(store, targets_list, seeds, fanouts,
                 *, fetch_embeddings: bool = True
                 ) -> tuple[SampledBatch, list[tuple[int, int]]]:
    """Fused multi-request sampling + prefix-preserving composition.

    One near-storage ``sample_neighbors_batch`` per hop serves every
    request's frontier (per-request rng segments keep each sample
    bit-identical to a solo run), and ONE group-wide reindex per hop builds
    the composed block directly — no per-request Python.  The global
    reindex is exact because each request's vids are lifted into a disjoint
    tagged range (no cross-request dedup) and the flattened selection is
    request-major, so global first-seen order equals per-request first-seen
    order with per-request rank bases.

    The composed level lists keep the engine's prefix-ordering invariant:
    composed level k+1 = [composed level k, then each request's new nodes],
    tracked by ``comp_of`` — the composed index of each concat-order node.

    Returns ``(batch, slices)``: the composed super-batch and, per request,
    the ``(row_offset, n_targets)`` slice of the output's leading axis that
    carries that request's rows.
    """
    n_req = len(targets_list)
    rngs = [np.random.default_rng(s) for s in seeds]
    fronts = [np.asarray(t, dtype=np.int64).reshape(-1)
              for t in targets_list]
    seg = np.array([len(f) for f in fronts], dtype=np.int64)
    off0 = np.concatenate([[0], np.cumsum(seg)]).astype(np.int64)
    slices = [(int(off0[r]), int(seg[r])) for r in range(n_req)]
    # composed index of concat-order node p (level 0 is request-grouped)
    comp_of = np.arange(int(seg.sum()), dtype=np.int64)
    comp_rev: list[LayerBlock] = []

    for fanout in fanouts:
        concat = (np.concatenate(fronts) if seg.sum()
                  else np.empty(0, np.int64))
        total_k = len(concat)
        if not total_k:                        # every request is empty
            comp_rev.append(LayerBlock(
                nbr=np.zeros((0, fanout), np.int32),
                mask=np.zeros((0, fanout), np.float32), num_dst=0))
            continue
        segs = seg.tolist()
        if hasattr(store, "sample_neighbors_batch"):
            try:
                sel, lens = store.sample_neighbors_batch(
                    concat, fanout, segments=segs, rngs=rngs)
            except BackpressureError as e:
                # a shed fused fetch names the group it refused
                e.reason.setdefault("stage", "sample")
                e.reason.setdefault("group_requests", n_req)
                raise
        else:                              # host-side store: per-request path
            sel_parts, len_parts = [], []
            for r in range(n_req):
                if not segs[r]:
                    continue
                neigh = _gather_neighbors(store, fronts[r])
                s, l = _subsample_batch(rngs[r], fronts[r], neigh, fanout)
                sel_parts.append(s)
                len_parts.append(l)
            sel = np.concatenate(sel_parts)
            lens = np.concatenate(len_parts).astype(np.int64)

        # ---- group-wide reindex over request-tagged vids
        req_of_row = np.repeat(np.arange(n_req), seg)
        row_of_flat = np.repeat(np.arange(total_k), lens)
        tag_front = concat + req_of_row * _REQ_TAG
        tag_sel = sel.astype(np.int64) + req_of_row[row_of_flat] * _REQ_TAG
        local, next_tagged = _reindex(tag_front, tag_sel)
        new_tagged = next_tagged[total_k:]
        new_counts = np.bincount(new_tagged // _REQ_TAG, minlength=n_req)
        new_off = np.concatenate([[0], np.cumsum(new_counts)]).astype(np.int64)

        # composed nbr values: frontier locals map through comp_of, new
        # nodes append after every level-k node in request-rank order
        remap = np.concatenate([comp_of,
                                total_k + np.arange(len(new_tagged))])
        nbr = np.zeros((total_k, fanout), np.int32)
        mask = np.zeros((total_k, fanout), np.float32)
        rows = comp_of[row_of_flat]
        offs = np.concatenate([[0], np.cumsum(lens)[:-1]])
        cols = np.arange(len(sel)) - np.repeat(offs, lens)
        nbr[rows, cols] = remap[local]
        mask[rows, cols] = 1.0
        comp_rev.append(LayerBlock(nbr=nbr, mask=mask, num_dst=total_k))

        # ---- next level: per-request lists grow by their new nodes
        new_vids = new_tagged % _REQ_TAG
        fronts = [np.concatenate([fronts[r],
                                  new_vids[new_off[r]: new_off[r + 1]]])
                  for r in range(n_req)]
        old_off = np.concatenate([[0], np.cumsum(seg)])
        comp_of = np.concatenate(
            [np.concatenate([comp_of[old_off[r]: old_off[r + 1]],
                             total_k + np.arange(new_off[r], new_off[r + 1])])
             for r in range(n_req)])
        seg = seg + new_counts

    total_nodes = int(seg.sum())
    vids = np.empty(total_nodes, np.int64)
    if total_nodes:
        vids[comp_of] = np.concatenate(fronts)
    emb = None
    if fetch_embeddings and getattr(store, "feature_dim", 0):
        try:
            emb = store.get_embeds(vids)       # ONE coalesced (cached) gather
        except BackpressureError as e:
            e.reason.setdefault("stage", "fetch_embeds")
            e.reason.setdefault("group_requests", n_req)
            raise
    batch = SampledBatch(layers=list(reversed(comp_rev)), node_vids=vids,
                         embeddings=emb,
                         num_targets=int(off0[-1]))
    return batch, slices


def pad_group(batch: SampledBatch, base: int) -> SampledBatch:
    """Bucket-pad a composed super-batch: each tensor's leading dim rounds
    up to a half-octave bucket, so the jit signature set stays bounded
    while the padding overhead stays proportional at any group size."""
    n_pad = _bucket(max(batch.num_nodes, 1), base)
    layers = []
    for blk in batch.layers:
        d_pad = _bucket(max(blk.num_dst, 1), base)
        nbr = np.zeros((d_pad, blk.nbr.shape[1]), dtype=np.int32)
        mask = np.zeros((d_pad, blk.nbr.shape[1]), dtype=np.float32)
        nbr[: blk.num_dst] = blk.nbr
        mask[: blk.num_dst] = blk.mask
        layers.append(LayerBlock(nbr=nbr, mask=mask, num_dst=blk.num_dst))
    emb = None
    if batch.embeddings is not None:
        emb = np.zeros((n_pad, batch.embeddings.shape[1]), dtype=np.float32)
        emb[: batch.num_nodes] = batch.embeddings
    vids = np.full(n_pad, -1, dtype=np.int64)
    vids[: batch.num_nodes] = batch.node_vids
    return SampledBatch(layers=layers, node_vids=vids, embeddings=emb,
                        num_targets=batch.num_targets)
