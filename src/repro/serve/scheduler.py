"""Admission-controlled continuous-batching scheduler.

Holds a bounded pending queue of ``run`` requests and, each scheduling
round, forms ONE fused group: the head request (highest priority class,
FIFO within class) plus every queued request compatible with it — same DFG
markup, same weights fingerprint, same jit flag — up to ``max_group``.  The
group executes as a single fused super-batch through
``HolisticGNNService.run_batch`` and each request's completion callback
receives its own rows.

QoS levers:

  * **admission control / backpressure** — ``submit`` raises
    ``AdmissionError`` once ``max_pending`` requests wait; the serving
    runtime turns that into an error completion (and the multi-queue
    transport's bounded rings backpressure one level below);
  * **priority classes** — higher ``priority`` schedules strictly first;
    a group leader only coalesces with compatible requests, so a high-
    priority singleton never waits for a bulk group to assemble;
  * **deadlines** — requests whose deadline passed while queued complete
    with a ``DeadlineExceeded`` error instead of occupying the engine;
  * **telemetry** — rolling p50/p95/p99 latency, throughput, queue depth
    and group-size accounting, surfaced via the ``stats`` RPC.
"""
from __future__ import annotations

import itertools
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..concurrency import witness_condition, witness_lock
from ..rpc.queues import BackpressureError
from .batcher import fingerprint_weights


class AdmissionError(RuntimeError):
    """Pending queue is full — request rejected at admission.

    Carries a ``reason`` dict (source, queue depth, per-shard health when
    a supervisor is attached) so a client can tell "overloaded" from
    "degraded array" instead of seeing a generic full queue."""

    def __init__(self, msg: str, *, reason: dict | None = None):
        super().__init__(msg)
        self.reason = dict(reason or {})


@dataclass
class ServeRequest:
    seq: int
    dfg: str                      # markup string
    targets: object
    weights: dict
    weights_ref: str | None       # device-resident weights (put_weights)
    wkey: str
    seed: int
    jit: bool
    priority: int
    deadline: float | None        # absolute perf_counter deadline
    on_done: Callable[[dict], None]
    t_enqueue: float = field(default_factory=time.perf_counter)


class QoSTelemetry:
    """Bounded rolling latency window + lifetime counters (thread-safe)."""

    def __init__(self, window: int = 512):
        self._lock = witness_lock(
            "scheduler.qos._lock", threading.Lock())
        self._window = deque(maxlen=window)    # guarded-by: _lock
        self.completed = 0                     # guarded-by: _lock
        self.errors = 0                        # guarded-by: _lock
        self.expired = 0                       # guarded-by: _lock
        self.rejected = 0                      # guarded-by: _lock
        self.backpressured = 0                 # guarded-by: _lock
        self.last_reject_reason: dict | None = None  # guarded-by: _lock
        self.groups = 0                        # guarded-by: _lock
        self.grouped_requests = 0              # guarded-by: _lock

    def record(self, latency_s: float) -> None:
        with self._lock:
            self._window.append((time.perf_counter(), latency_s))
            self.completed += 1

    # locked mutators: the scheduler threads bump the lifetime counters
    # through these so every read in ``snapshot`` sees a consistent set
    def note_rejected(self, reason: dict | None = None) -> None:
        with self._lock:
            self.rejected += 1
            if reason is not None:
                self.last_reject_reason = dict(reason)

    def note_expired(self, n: int = 1) -> None:
        with self._lock:
            self.expired += int(n)

    def note_backpressured(self, reason: dict | None = None) -> None:
        with self._lock:
            self.backpressured += 1
            if reason is not None:
                self.last_reject_reason = dict(reason)

    def note_errors(self, n: int = 1) -> None:
        with self._lock:
            self.errors += int(n)

    def note_group(self, size: int) -> None:
        with self._lock:
            self.groups += 1
            self.grouped_requests += int(size)

    def snapshot(self, *, queue_depth: int = 0) -> dict:
        with self._lock:
            lat = np.array([l for _, l in self._window])
            now = time.perf_counter()
            span = now - self._window[0][0] if len(self._window) > 1 else 0.0
            out = {
                "completed": self.completed, "errors": self.errors,
                "expired": self.expired, "rejected": self.rejected,
                "backpressured": self.backpressured,
                "last_reject_reason": (dict(self.last_reject_reason)
                                       if self.last_reject_reason else None),
                "groups": self.groups,
                "avg_group_size": (self.grouped_requests / self.groups
                                   if self.groups else 0.0),
                "queue_depth": queue_depth,
                "window_n": len(lat),
                "throughput_rps": len(lat) / span if span > 0 else 0.0,
            }
            for p in (50, 95, 99):
                out[f"p{p}_latency_s"] = (float(np.percentile(lat, p))
                                          if len(lat) else 0.0)
            return out


class BatchScheduler:
    def __init__(self, service, *, max_group: int = 16,
                 max_pending: int = 256, coalesce: bool = True,
                 batch_window_s: float = 0.02,
                 telemetry_window: int = 512):
        self.service = service
        self.max_group = int(max_group)
        self.max_pending = int(max_pending)
        self.coalesce = coalesce
        # continuous-batching window: with fewer than max_group pending, a
        # scheduling round holds while requests are STILL ARRIVING (quiet
        # period — under closed-loop traffic one group's completions trigger
        # the next cohort's submissions a fraction of a ms apart, so an
        # age-based window would forever schedule half-groups), hard-capped
        # at batch_window_s from the oldest pending request.  Trades a few
        # ms of latency for much fuller fused batches.  Stepped mode
        # (drain/pump) forces immediate scheduling instead.
        self.batch_window_s = float(batch_window_s)
        self._quiet_s = min(0.003, self.batch_window_s / 4
                            if self.batch_window_s else 0.0)
        self.qos = QoSTelemetry(telemetry_window)
        # optional callable returning a per-shard health summary, set by
        # the serving runtime — folded into AdmissionError reasons so a
        # rejected client learns WHY the queue is full (hot array vs
        # degraded array)
        self.health_provider = None
        self._pending: list[ServeRequest] = []
        self._cond = witness_condition(
            "scheduler._cond", threading.Condition())
        self._seq = itertools.count()

    # -------------------------------------------------------------- admission
    def accepts(self, dfg) -> bool:
        """Only BatchPre-led service DFGs are batchable; everything else
        stays on the synchronous dispatch path."""
        if not isinstance(dfg, str):
            return False
        try:
            return self.service._service_program(dfg) is not None
        except Exception:  # noqa: BLE001 — malformed markup: sync path errors
            return False

    def submit(self, *, dfg, batch, weights=None, seed: int = 0,
               jit: bool = True, priority: int = 0,
               deadline_s: float | None = None,
               weights_key: str | None = None,
               weights_ref: str | None = None,
               on_done: Callable[[dict], None]) -> int:
        """Enqueue one run request; returns its sequence number.

        Raises ``AdmissionError`` when the pending queue is full — callers
        translate this into transport-level backpressure.

        ``weights_ref`` names device-resident weights (``put_weights``);
        ``weights_key``: callers that guarantee weights identity across
        requests (a deployed model version) may pass a key to skip the
        per-request content hash; requests only coalesce on equal keys.
        """
        if weights_key is not None:
            wkey = f"key:{weights_key}"
        elif weights_ref is not None and not weights:
            wkey = f"ref:{weights_ref}"
        else:
            wkey = f"{weights_ref}|{fingerprint_weights(weights)}"
        with self._cond:
            if len(self._pending) >= self.max_pending:
                reason = {"source": "admission",
                          "queue_depth": len(self._pending),
                          "max_pending": self.max_pending}
                hp = self.health_provider
                if hp is not None:
                    try:
                        health = hp()
                    except Exception:  # noqa: BLE001 — reason is best-effort
                        health = None
                    if health:
                        reason["shard_health"] = health
                self.qos.note_rejected(reason)
                raise AdmissionError(
                    f"admission queue full ({self.max_pending} pending)",
                    reason=reason)
            req = ServeRequest(
                seq=next(self._seq),
                dfg=dfg if isinstance(dfg, str) else dfg.save(),
                targets=batch, weights=dict(weights or {}),
                weights_ref=weights_ref, wkey=wkey,
                seed=int(seed),
                jit=bool(jit), priority=int(priority),
                deadline=(None if deadline_s is None
                          else time.perf_counter() + float(deadline_s)),
                on_done=on_done)
            self._pending.append(req)
            self._cond.notify_all()
            return req.seq

    @property
    def queue_depth(self) -> int:
        with self._cond:
            return len(self._pending)

    def wait_for_work(self, timeout: float | None = None) -> bool:
        with self._cond:
            if not self._pending:
                self._cond.wait(timeout)
            return bool(self._pending)

    # ------------------------------------------------------------- scheduling
    def _form_group(self, force: bool) -> list[ServeRequest]:
        """Pop one fused group (priority head + compatible followers)."""
        with self._cond:
            now = time.perf_counter()
            alive: list[ServeRequest] = []
            expired: list[ServeRequest] = []
            for r in self._pending:
                (expired if r.deadline is not None and now > r.deadline
                 else alive).append(r)
            self._pending = alive
            for r in expired:
                self.qos.note_expired()
                r.on_done({"ok": False, "error":
                           "DeadlineExceeded: request expired in queue "
                           f"(waited {now - r.t_enqueue:.3f}s)"})
            if not alive:
                return []
            if (not force and self.batch_window_s > 0
                    and len(alive) < self.max_group
                    and now - max(r.t_enqueue for r in alive) < self._quiet_s
                    and now - min(r.t_enqueue for r in alive)
                    < self.batch_window_s):
                return []                     # hold for fuller coalescing
            alive.sort(key=lambda r: (-r.priority, r.seq))
            head = alive[0]
            group = [head]
            if self.coalesce and self.accepts(head.dfg):
                for r in alive[1:]:
                    if len(group) >= self.max_group:
                        break
                    if (r.dfg == head.dfg and r.wkey == head.wkey
                            and r.jit == head.jit):
                        group.append(r)
            taken = {r.seq for r in group}
            self._pending = [r for r in alive if r.seq not in taken]
            return group

    def step(self, *, force: bool = False) -> int:
        """Schedule + execute ONE group.  Returns requests completed
        (0 while empty — or while the batching window holds, unless
        ``force``)."""
        group = self._form_group(force)
        if not group:
            return 0
        self._execute(group)
        return len(group)

    def drain(self) -> int:
        """Run scheduling rounds until the queue is empty (stepped mode;
        ignores the batching window)."""
        total = 0
        while True:
            done = self.step(force=True)
            if not done:
                return total
            total += done

    # -------------------------------------------------------------- execution
    def _execute(self, group: list[ServeRequest]) -> None:
        head = group[0]
        try:
            if self.accepts(head.dfg):
                results = self.service.run_batch(
                    head.dfg,
                    [{"targets": r.targets, "seed": r.seed} for r in group],
                    weights=head.weights, jit=head.jit,
                    weights_ref=head.weights_ref)
            else:                      # non-service DFG: solo fallback
                results = [self.service.run(head.dfg, head.targets,
                                            weights=head.weights,
                                            seed=head.seed, jit=head.jit,
                                            weights_ref=head.weights_ref)]
        except BackpressureError as e:
            # typed shed: the array's flow control (in-flight windows /
            # queue-full retry budget) refused the fused fetch — report
            # the reason, don't crash the group as a generic error
            self.qos.note_backpressured(dict(e.reason))
            resp = {"ok": False, "error": f"BackpressureError: {e}",
                    "backpressure": True, "reason": dict(e.reason)}
            self.qos.note_errors(len(group))
            for r in group:
                r.on_done(dict(resp))
            return
        except Exception as e:  # noqa: BLE001 — fault fans out to the group
            resp = {"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()}
            self.qos.note_errors(len(group))
            for r in group:
                r.on_done(dict(resp))
            return
        now = time.perf_counter()
        self.qos.note_group(len(group))
        for r, out in zip(group, results):
            self.qos.record(now - r.t_enqueue)
            r.on_done({"ok": True, "result": out})
