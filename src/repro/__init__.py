"""repro — near-storage, hardware/software co-programmable JAX framework
reproducing HolisticGNN (FAST'22) and generalizing its storage/paging and
kernel-dispatch mechanisms to large-scale LM training/serving on TPU pods."""

__version__ = "1.0.0"
