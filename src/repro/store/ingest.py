"""Distributed device-side ingest: sharded bulk load + mutation firehose.

The paper's G-1..G-4 UpdateGraph pipeline (batch -> bucket -> radix-sort ->
CSR build) exists precisely because shipping a huge graph through the host
is the bottleneck — yet through PR 6 the array still preprocessed every
edge globally on the coordinator and shipped each shard one monolithic
``write_adjacency``/``write_embedding_table``.  This module moves the
pipeline to where the data is:

**Bulk load** (``distributed_update_graph``): the coordinator streams RAW
edge chunks round-robin to every shard concurrently over the existing
endpoint links (plus each shard's embedding stripe slices); each shard
mirrors + buckets device-side ([G-2]/[G-3] routing), peers exchange
cross-shard buckets over the peer links (the chunked-rebuild pull
discipline), and every shard sorts, builds its partition-local CSR and
bulk-packs its L/H pages + R replica embedding stripes locally, in
parallel ([G-3]/[G-4] + packing).  Coordinator bytes are O(E) raw chunks —
zero preprocessed CSR bytes — and the graph-pre sort scales with N.
Because routing reproduces ``partition_csr``'s class ownership, the
shard-local sort shares the monolithic key arithmetic, owned-class
self-loops are injected at commit, and the same packing code lays the
pages, the chunked load is **bit-identical** to the monolithic
``update_graph`` — same pages, same reads (tests/test_ingest.py).

**Mutation firehose** (``MutationFirehose``): the same machinery
generalised to a continuous high-rate mutation stream (social feeds,
fraud edges).  Ops accumulate in a coordinator-side log; every time
window the log is decomposed into ONE ordered sub-op list per shard
(replica fan-out folded in) and applied as ONE device-side
``apply_mutations`` command per shard — a concurrent ``_submit_round``
under the ordinary ``_write_gate``/flow-control discipline, so batched
reads flow between windows and overload sheds as typed
``BackpressureError``.  Each shard receives exactly the projection of the
global submission order onto its partition and applies it under the
device store lock, so a read at any window boundary is bit-identical to
applying the same mutations one at a time.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from ..concurrency import witness_lock
from ..rpc.queues import BackpressureError
from .blockdev import sleep_us
from .graphstore import BulkTimeline

DEFAULT_CHUNK_EDGES = 1 << 16        # raw edges per streamed chunk
DEFAULT_EMB_CHUNK_ROWS = 1 << 13     # embedding rows per streamed slice


# ============================================================== bulk load
def distributed_update_graph(store, edge_array, embeddings=None, *,
                             already_undirected: bool = False,
                             chunk_edges: int = DEFAULT_CHUNK_EDGES,
                             emb_chunk_rows: int = DEFAULT_EMB_CHUNK_ROWS
                             ) -> BulkTimeline:
    """Chunked distributed bulk load over a sharded array (see module
    docstring).  Drop-in result-compatible with ``update_graph``; call
    through ``ShardedGraphStore.update_graph_chunked`` so the maintenance
    gate is held.

    Phases (BulkTimeline): ``transfer`` is the raw chunk streaming,
    ``graph_pre`` the peer exchange + the slowest shard's device-side
    sort, ``write_feature``/``write_graph`` the slowest shard's page
    bursts during the parallel commit.
    """
    tl = BulkTimeline()
    t0 = time.perf_counter()
    N = store.n_shards
    R = int(getattr(store, "replication", 1))
    ce = max(1, int(chunk_edges))
    er = max(1, int(emb_chunk_rows))

    edges = np.asarray(edge_array, dtype=np.int64).reshape(-1, 2)
    emb = None
    if embeddings is not None:
        emb = np.ascontiguousarray(embeddings, dtype=np.float32)
        store._feature_dim = int(emb.shape[1])
        store._prepare_emb_layout(len(emb))
    d = 0 if emb is None else int(emb.shape[1])

    # placement plumbing: the session always buckets by the store's
    # current placement map, but the map only goes over the wire when it
    # is NOT the legacy modular layout — default arrays keep the exact
    # legacy ingest_begin payload (and bit-identical page layouts).
    pmap = store._routing.pmap
    begin_kw = dict(n_shards=N, replication=R,
                    already_undirected=bool(already_undirected),
                    emb_rows=0 if emb is None else len(emb),
                    feature_dim=d)
    if not pmap.is_modular(N):
        begin_kw["placement"] = pmap.to_payload()
    C = pmap.n_classes

    store._submit_round([
        (s, "ingest_begin", dict(begin_kw, shard=s)) for s in range(N)])
    try:
        # ---- transfer: stream raw chunks + stripe slices, all shards in
        # parallel (each shard's sequence on its own thread; the max-vid
        # scan rides the device-side bucketing, so the coordinator does
        # no per-edge work at all)
        n_chunks = -(-len(edges) // ce)
        max_vid = [-1] * N

        def stream_shard(s):
            ep = store.endpoints[s]
            mv = -1
            for i in range(s, n_chunks, N):
                out = ep.call("ingest_edges",
                              chunk=edges[i * ce: (i + 1) * ce])
                mv = max(mv, int(out["max_vid"]))
            if emb is not None:
                # one stripe per owned (class, role) pair, canonical
                # order — the session's stripe index is the wire "role"
                for j, (c, _r) in enumerate(pmap.pairs_of(s)):
                    stripe = emb[c::C]
                    for r0 in range(0, len(stripe), er):
                        ep.call("ingest_emb_rows", role=j, row0=r0,
                                rows=stripe[r0: r0 + er])
            max_vid[s] = mv

        store._map(stream_shard, range(N))
        tl.transfer = (0.0, time.perf_counter() - t0)

        # ---- exchange: one shard at a time pulls its buckets from its
        # (idle) peers — the single-puller schedule that keeps N
        # single-threaded shard hosts free of circular waits; only this
        # memcpy-like stage is sequential, the sort/pack below is not
        x0 = time.perf_counter() - t0
        for s in range(N):
            store.endpoints[s].call("ingest_exchange")
        x1 = time.perf_counter() - t0

        # ---- commit: every shard sorts + packs in parallel
        n_glob = max(max_vid) + 1
        c0 = time.perf_counter() - t0
        outs = store._map(
            lambda s: store.endpoints[s].call("ingest_commit",
                                              num_vertices=n_glob),
            range(N))
        # shards deferred their simulated flash time (their page bursts
        # run concurrently, one device each); the coordinator pays the
        # slowest shard's — the array's analytic device-time model
        sleep_us(max(o.get("flash_us", 0.0) for o in outs))
    except BaseException:
        for ep in store.endpoints:           # best-effort session cleanup
            try:
                ep.call("ingest_abort")
            except Exception:  # noqa: BLE001
                pass
        raise

    tl.graph_pre = (x0, x1 + max(o["sort_s"] for o in outs))
    tl.write_feature = (c0, c0 + max(o["write_feature_s"] for o in outs))
    tl.write_graph = (c0, time.perf_counter() - t0)
    tl.total = time.perf_counter() - t0
    tl.user_visible = max(tl.transfer[1], tl.write_feature[1])
    store._num_vertices = max(store._num_vertices, n_glob)
    store._bulk = tl
    return tl


# ======================================================= mutation firehose
@dataclass
class FirehoseCounters:
    """Cumulative firehose accounting (surfaced by ``snapshot``)."""

    submitted: int = 0        # logical ops logged
    applied: int = 0          # logical ops applied device-side
    subops: int = 0           # per-replica sub-ops applied
    windows: int = 0          # apply_mutations rounds issued
    barriers: int = 0         # delete_vertex barrier flushes
    shed: int = 0             # submissions rejected (log full)


class _ShardOps:
    """One shard's packed sub-op window (parallel arrays + embed rows)."""

    __slots__ = ("kinds", "arg0", "arg1", "flags", "emb")

    def __init__(self):
        self.kinds: list[int] = []
        self.arg0: list[int] = []
        self.arg1: list[int] = []
        self.flags: list[int] = []
        self.emb: list[np.ndarray] = []

    def add(self, kind, a0, a1=0, flag=0, emb=None):
        self.kinds.append(int(kind))
        self.arg0.append(int(a0))
        self.arg1.append(int(a1))
        self.flags.append(int(flag))
        if emb is not None:
            self.emb.append(np.asarray(emb, dtype=np.float32))

    def kwargs(self) -> dict:
        kw = dict(kinds=np.asarray(self.kinds, dtype=np.int64),
                  arg0=np.asarray(self.arg0, dtype=np.int64),
                  arg1=np.asarray(self.arg1, dtype=np.int64),
                  flags=np.asarray(self.flags, dtype=np.int64))
        if self.emb:
            kw["emb"] = np.stack(self.emb)
        return kw


class MutationFirehose:
    """Windowed mutation batching over the array (see module docstring).

    Submit ops through the unit-op-shaped methods (``add_edge``,
    ``delete_edge``, ``add_vertex``, ``update_embed``,
    ``delete_vertex``); they accumulate in a bounded coordinator-side log
    and are applied by ``flush`` — on the ``window_s`` timer once
    ``start`` is called, or explicitly.  A full log sheds new submissions
    as typed ``BackpressureError`` (``reason.source = "firehose_log"``) —
    the write-side admission control.

    ``delete_vertex`` is a BARRIER: its decomposition reads the CURRENT
    neighbor set, so the pending window is flushed first, the delete
    applied serially through the store, and batching resumes.
    """

    def __init__(self, store, *, window_s: float = 0.05,
                 max_window_ops: int = 4096, max_log_ops: int = 65536):
        self.store = store
        self.window_s = float(window_s)
        self.max_window_ops = max(1, int(max_window_ops))
        self.max_log_ops = max(1, int(max_log_ops))
        self.counters = FirehoseCounters()    # guarded-by: _lock
        self._log: list[tuple] = []           # guarded-by: _lock
        self._lock = witness_lock("ingest._lock", threading.Lock())
        # one flush at a time: the timer thread and an explicit flush must
        # not interleave their windows (order is the whole contract)
        self._flush_lock = witness_lock(
            "ingest._flush_lock", threading.Lock())
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "MutationFirehose":
        """Run the window timer: a daemon thread flushes every
        ``window_s`` seconds.  Timer-flush errors are stashed on
        ``last_error`` (ops stay logged) so the stream survives transient
        backpressure; ``close`` re-raises by flushing in the caller."""
        if self._thread is None:
            self._stop.clear()

            def loop():
                while not self._stop.wait(self.window_s):
                    try:
                        self.flush()
                    except Exception as e:  # noqa: BLE001 — see docstring
                        self.last_error = e

            self._thread = threading.Thread(target=loop,
                                            name="firehose-window",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the window timer WITHOUT draining the log (see
        ``close`` for the draining variant)."""
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=5.0)
            self._thread = None

    def close(self) -> dict:
        """Stop the timer, apply everything still logged (errors now
        propagate), and return the final counter snapshot."""
        self.stop()
        self.flush()
        return self.snapshot()

    def snapshot(self) -> dict:
        """Counter + config snapshot: submitted/applied/subops/windows/
        barriers/shed, current log depth, and the window limits (the
        ``firehose`` block of the service ``stats`` RPC)."""
        c = self.counters
        with self._lock:
            depth = len(self._log)
        return {"submitted": c.submitted, "applied": c.applied,
                "subops": c.subops, "windows": c.windows,
                "barriers": c.barriers, "shed": c.shed,
                "log_depth": depth, "window_s": self.window_s,
                "max_window_ops": self.max_window_ops,
                "max_log_ops": self.max_log_ops}

    # ----------------------------------------------------------- submission
    def _submit(self, op: tuple) -> None:
        with self._lock:
            if len(self._log) >= self.max_log_ops:
                self.counters.shed += 1
                raise BackpressureError(
                    f"firehose log full ({self.max_log_ops} ops pending); "
                    f"back off and retry",
                    reason={"source": "firehose_log",
                            "depth": len(self._log),
                            "limit": self.max_log_ops})
            self._log.append(op)
            self.counters.submitted += 1

    def _check_embed(self, vid: int) -> None:
        """Replicated arrays bounds-check embed rows at the unit RPC; the
        firehose keeps that contract at submission time, so a bad row is
        rejected to the submitter instead of poisoning a later window."""
        check = getattr(self.store, "_check_emb_vid", None)
        if check is not None:
            check(vid)

    def add_vertex(self, vid, embed=None) -> None:
        """Log one AddVertex (+ optional embedding row).  Raises
        ``BackpressureError`` when the log is full, ``IndexError`` for an
        out-of-range embed row."""
        if embed is not None:
            self._check_embed(int(vid))
        self._submit(("add_vertex", int(vid),
                      None if embed is None
                      else np.asarray(embed, dtype=np.float32)))

    def add_edge(self, dst, src) -> None:
        """Log one undirected AddEdge (raises ``BackpressureError``
        when the log is full)."""
        self._submit(("add_edge", int(dst), int(src)))

    def delete_edge(self, dst, src) -> None:
        """Log one undirected DeleteEdge (``BackpressureError`` when
        the log is full)."""
        self._submit(("delete_edge", int(dst), int(src)))

    def update_embed(self, vid, embed) -> None:
        """Log one UpdateEmbed (bounds-checked at submission; raises
        ``BackpressureError`` when the log is full)."""
        self._check_embed(int(vid))
        self._submit(("update_embed", int(vid),
                      np.asarray(embed, dtype=np.float32)))

    def delete_vertex(self, vid) -> None:
        """Log one DeleteVertex — applied as a BARRIER at flush time
        (pending window drains first; see class docstring)."""
        self._submit(("delete_vertex", int(vid)))

    # ---------------------------------------------------------------- apply
    def flush(self) -> int:
        """Apply every logged op in submission order, at most
        ``max_window_ops`` logical ops per device-side window.  Returns
        the number of logical ops applied."""
        applied = 0
        with self._flush_lock:
            while True:
                with self._lock:
                    window = self._log[: self.max_window_ops]
                    del self._log[: len(window)]
                if not window:
                    return applied
                applied += self._apply_window(window)

    def _replicas(self, vid: int) -> list[tuple[int, int]]:
        """(shard, local embedding row) of every live replica of ``vid``
        — primary first, resolved through the store's current routing
        (placement-map and reshard aware); plain sharded arrays have
        exactly the owner."""
        st = self.store
        if hasattr(st, "_emb_locate"):
            return st._emb_locate(vid)
        return [(int(vid) % st.n_shards, int(vid) // st.n_shards)]

    def _apply_window(self, window: list[tuple]) -> int:
        st = self.store
        if not hasattr(st, "endpoints"):
            # single-device store: no per-shard decomposition to batch —
            # the window degenerates to ordered serial replay
            for op in window:
                kind, args = op[0], op[1:]
                if kind == "add_vertex":
                    st.add_vertex(args[0], args[1])
                else:
                    getattr(st, kind)(*args)
            with self._lock:
                self.counters.applied += len(window)
                self.counters.windows += 1
            return len(window)

        per_shard: dict[int, _ShardOps] = {}

        def ops_of(s: int) -> _ShardOps:
            if s not in per_shard:
                per_shard[s] = _ShardOps()
            return per_shard[s]

        def dispatch():
            if not per_shard:
                return
            items = [(s, "apply_mutations", ops.kwargs())
                     for s, ops in sorted(per_shard.items())]
            outs = st._submit_round(items)
            with self._lock:
                self.counters.windows += 1
                self.counters.subops += sum(o["applied"] for o in outs)
            per_shard.clear()

        def vertex(v, embed=None):
            reps = self._replicas(v)
            for s, _off in reps:
                ops_of(s).add(0, v)
            st._num_vertices = max(st._num_vertices, v + 1)
            if embed is not None:
                embed_row(v, embed, reps)

        def embed_row(v, embed, reps=None):
            for s, row in (reps or self._replicas(v)):
                ops_of(s).add(4, row, emb=embed)

        applied = 0
        # the whole window — replica decomposition AND dispatch — runs
        # under one write gate: the gate waits out any in-flight class
        # migration and holds the mutation lock, so a reshard's routing
        # flip can never land between decomposing an op against the old
        # owners and applying it (nested gates, e.g. the delete_vertex
        # barrier, re-enter without waiting)
        with st._write_gate():
            for op in window:
                kind = op[0]
                if kind == "add_vertex":
                    vertex(op[1], op[2])
                elif kind == "add_edge":
                    dst, src = op[1], op[2]
                    vertex(dst)
                    if src != dst:
                        vertex(src)
                    for s, _row in self._replicas(dst):
                        ops_of(s).add(1, dst, src, flag=1)
                    if dst != src:
                        for s, _row in self._replicas(src):
                            ops_of(s).add(1, src, dst)
                elif kind == "delete_edge":
                    dst, src = op[1], op[2]
                    for s, _row in self._replicas(dst):
                        ops_of(s).add(2, dst, src, flag=1)
                    if dst != src:
                        for s, _row in self._replicas(src):
                            ops_of(s).add(2, src, dst)
                elif kind == "update_embed":
                    embed_row(op[1], op[2])
                elif kind == "delete_vertex":
                    # BARRIER: decomposition reads the current neighbor
                    # set, so everything logged before it applies first
                    dispatch()
                    with self._lock:
                        self.counters.barriers += 1
                    st.delete_vertex(op[1])
                else:
                    raise ValueError(f"unknown firehose op {kind!r}")
                applied += 1
            dispatch()
        with self._lock:
            self.counters.applied += applied
        return applied
