"""Pluggable placement maps for the sharded CSSD array.

Placement answers one question: *which shard owns replica ``r`` of vid
``v``?*  The legacy answer — shard ``(v + r) % N`` — is hard-coded
modular arithmetic, which is cheap but blind to skew: a hot community
hashed onto one shard stays there forever, and growing the array means
reloading everything because every vid's owner changes.

``PlacementMap`` keeps the cheap part (the *class* of a vid is still
``v % C`` for a fixed class count ``C``) and makes the expensive part a
lookup table: an ``owner`` array of shape ``(C, R)`` mapping each
(class, role) to a shard.  That factoring has three properties the
resharding engine needs:

* **Legacy-compatible** — ``modular(N, R)`` reproduces ``(c + r) % N``
  exactly, so default arrays keep bit-identical page layouts.
* **Refinable** — ``refine(k)`` multiplies ``C`` by ``k`` without moving
  any data (class ``c`` splits into ``{c + j*C}``, all owned by the same
  shards), so a grow from 4 to 5 shards only needs ``C`` divisible by 5,
  not a full re-hash.
* **Delta-friendly** — two maps over the same ``C`` diff into an explicit
  move list (:func:`plan_moves`), which is exactly the unit of work the
  online migration streams shard-to-shard.

Planners (:func:`grow_plan`, :func:`shrink_plan`, :func:`heat_plan`)
produce target maps from the gossiped read-counter heat snapshot; the
coordinator (``ShardedGraphStore.reshard``) turns the diff into paced
page copies and atomic per-class routing flips.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = [
    "PlacementMap", "modular", "rows_of_class", "common_refine",
    "plan_moves", "grow_plan", "shrink_plan", "heat_plan", "Move",
]


def rows_of_class(n_rows: int, cls: int, n_classes: int) -> int:
    """Number of embedding rows whose vid ≡ ``cls`` (mod ``n_classes``)."""
    if n_rows <= cls:
        return 0
    return (n_rows - cls + n_classes - 1) // n_classes


@dataclass(frozen=True)
class PlacementMap:
    """Class-granular (class, role) → shard ownership table.

    Args:
        n_classes: class count ``C``; the class of vid ``v`` is ``v % C``.
        owner: int64 array of shape ``(C, R)``; ``owner[c, r]`` is the
            shard holding replica ``r`` of every vid in class ``c``.
            Shards within one row must be distinct (replicas of a class
            never share a device).
    """

    n_classes: int
    owner: np.ndarray

    def __post_init__(self):
        o = np.ascontiguousarray(np.asarray(self.owner, dtype=np.int64))
        if o.ndim != 2 or o.shape[0] != self.n_classes:
            raise ValueError(f"owner must be ({self.n_classes}, R), "
                             f"got {o.shape}")
        object.__setattr__(self, "owner", o)

    # ------------------------------------------------------------ properties
    @property
    def replication(self) -> int:
        """Replica count ``R`` (second dimension of ``owner``)."""
        return int(self.owner.shape[1])

    # ------------------------------------------------------------ validation
    def validate(self, n_shards: int) -> None:
        """Raise ``ValueError`` unless the map is total and well-formed
        for an array of ``n_shards`` devices (owners in range, replicas
        of each class on distinct shards)."""
        o = self.owner
        if o.size and (o.min() < 0 or o.max() >= n_shards):
            raise ValueError(
                f"placement owners out of range [0, {n_shards})")
        for c in range(self.n_classes):
            row = o[c]
            if len(set(int(s) for s in row)) != len(row):
                raise ValueError(
                    f"class {c}: replicas share a shard ({row.tolist()})")

    def is_modular(self, n_shards: int) -> bool:
        """True iff this map is exactly the legacy ``(c + r) % N`` layout
        (the case where page layouts stay bit-identical to the seed)."""
        if self.n_classes != n_shards:
            return False
        c = np.arange(self.n_classes, dtype=np.int64)[:, None]
        r = np.arange(self.replication, dtype=np.int64)[None, :]
        return bool(np.array_equal(self.owner, (c + r) % n_shards))

    # ------------------------------------------------------------- lookups
    def classes_of(self, shard: int) -> list[int]:
        """Sorted classes for which ``shard`` holds any replica."""
        return sorted(int(c) for c in
                      np.nonzero((self.owner == shard).any(axis=1))[0])

    def pairs_of(self, shard: int) -> list[tuple[int, int]]:
        """Canonical stripe order of ``shard``: (class, role) pairs,
        role-major then class-ascending.  This is the on-device
        embedding stripe order after a bulk load or full rebuild; at the
        default modular map it equals the legacy role-major striping."""
        out = []
        for r in range(self.replication):
            for c in np.nonzero(self.owner[:, r] == shard)[0]:
                out.append((int(c), r))
        return out

    # ----------------------------------------------------------- refinement
    def refine(self, k: int) -> "PlacementMap":
        """Split every class into ``k`` finer classes without moving data:
        class ``c`` becomes ``{c + j*C : j < k}``, same owner row.  The
        class of any vid under the fine map is consistent with the coarse
        map (``v % kC ≡ v % C (mod C)``), so existing on-device layouts
        and extents remain valid."""
        if k < 1:
            raise ValueError("refine factor must be >= 1")
        if k == 1:
            return self
        return PlacementMap(self.n_classes * k, np.tile(self.owner, (k, 1)))

    # ---------------------------------------------------------------- wire
    def to_payload(self) -> dict:
        """Wire form for RPCs (``ingest_begin(placement=...)``)."""
        return {"n_classes": int(self.n_classes), "owner": self.owner}

    @staticmethod
    def from_payload(payload: dict) -> "PlacementMap":
        """Rebuild a map from its ``to_payload`` wire form."""
        return PlacementMap(int(payload["n_classes"]),
                            np.asarray(payload["owner"], dtype=np.int64))

    def __eq__(self, other) -> bool:
        return (isinstance(other, PlacementMap)
                and self.n_classes == other.n_classes
                and np.array_equal(self.owner, other.owner))

    def __hash__(self):
        return hash((self.n_classes, self.owner.tobytes()))


def modular(n_shards: int, replication: int = 1) -> PlacementMap:
    """The legacy layout: replica ``r`` of class ``c`` on ``(c+r) % N``."""
    c = np.arange(n_shards, dtype=np.int64)[:, None]
    r = np.arange(replication, dtype=np.int64)[None, :]
    return PlacementMap(n_shards, (c + r) % n_shards)


def common_refine(a: PlacementMap, b: PlacementMap
                  ) -> tuple[PlacementMap, PlacementMap]:
    """Refine both maps to their least common class count so their owner
    tables are directly comparable (same replication required)."""
    if a.replication != b.replication:
        raise ValueError("placement maps differ in replication")
    lcm = math.lcm(a.n_classes, b.n_classes)
    return a.refine(lcm // a.n_classes), b.refine(lcm // b.n_classes)


@dataclass(frozen=True)
class Move:
    """One unit of migration work produced by :func:`plan_moves`.

    ``kind`` is ``"copy"`` (pages must ship from ``src`` to ``dst``) or
    ``"relabel"`` (``dst`` already holds the class as role ``src_role``;
    only the coordinator's extent metadata changes, no bytes move).
    """

    cls: int
    role: int
    src: int            # old owner of (cls, role)
    dst: int            # new owner of (cls, role)
    kind: str           # "copy" | "relabel"
    src_role: int = -1  # for relabel: role under which dst already holds cls


def plan_moves(old: PlacementMap, new: PlacementMap
               ) -> tuple[list[Move], dict[int, list[int]]]:
    """Diff two same-``C`` maps into (moves, drops).

    ``moves`` lists every (class, role) whose owner changes, classified
    as a real page copy or a metadata-only relabel (the new owner already
    holds the class under another role).  ``drops`` maps each shard to
    the sorted classes it no longer holds under *any* role — the pages
    it may free once the routing flip commits.
    """
    if old.n_classes != new.n_classes:
        raise ValueError("plan_moves requires equal n_classes "
                         "(use common_refine first)")
    if old.replication != new.replication:
        raise ValueError("plan_moves requires equal replication")
    moves: list[Move] = []
    drops: dict[int, list[int]] = {}
    for c in range(old.n_classes):
        o_row, n_row = old.owner[c], new.owner[c]
        o_set = set(int(s) for s in o_row)
        for r in range(old.replication):
            src, dst = int(o_row[r]), int(n_row[r])
            if src == dst:
                continue
            if dst in o_set:
                src_role = int(np.nonzero(o_row == dst)[0][0])
                moves.append(Move(c, r, src, dst, "relabel", src_role))
            else:
                moves.append(Move(c, r, src, dst, "copy"))
        for s in o_set - set(int(s) for s in n_row):
            drops.setdefault(s, []).append(c)
    for s in drops:
        drops[s].sort()
    return moves, drops


# ------------------------------------------------------------------ planners
def _refined(pmap: PlacementMap, heat: np.ndarray | None, k: int
             ) -> tuple[PlacementMap, np.ndarray]:
    """Refine a map by ``k`` and split its per-class heat to match."""
    fine = pmap.refine(k)
    if heat is None:
        h = np.ones(pmap.n_classes, dtype=np.float64)
    else:
        h = np.asarray(heat, dtype=np.float64).copy()
        if len(h) != pmap.n_classes:
            raise ValueError("heat length != n_classes")
    if h.sum() <= 0:
        h = np.ones_like(h)
    return fine, np.tile(h / k, k)


def _loads(pmap: PlacementMap, heat: np.ndarray, n_shards: int) -> np.ndarray:
    """Per-shard role-0 heat (the primary-read load proxy)."""
    out = np.zeros(n_shards, dtype=np.float64)
    np.add.at(out, pmap.owner[:, 0], heat)
    return out


def grow_plan(pmap: PlacementMap, n_old: int, n_new: int,
              heat: np.ndarray | None = None) -> PlacementMap:
    """Target map for growing the array from ``n_old`` to ``n_new`` shards.

    Refines so the class count divides evenly across ``n_new``, then
    greedily hands each new shard its fair share of role-0 classes,
    always stealing the hottest class from the currently most-loaded
    old shard.  Replica roles > 0 stay put (new shards start as
    primaries only; a later ``heat_plan`` pass can rebalance replicas).

    Returns the new :class:`PlacementMap`; diff it against the refined
    source with :func:`plan_moves`.
    """
    if n_new <= n_old:
        raise ValueError("grow_plan needs n_new > n_old")
    f = n_new // math.gcd(pmap.n_classes, n_new)
    fine, h = _refined(pmap, heat, f)
    owner = fine.owner.copy()
    loads = _loads(fine, h, n_new)
    per_new = fine.n_classes // n_new
    moved: set[int] = set()
    for s_new in range(n_old, n_new):
        for _ in range(per_new):
            # steal the hottest movable class from the most-loaded shard
            order = np.argsort(-loads[:n_old], kind="stable")
            best = None
            for donor in order:
                cand = [c for c in np.nonzero(owner[:, 0] == donor)[0]
                        if c not in moved
                        and s_new not in owner[c]]
                if cand:
                    best = max(cand, key=lambda c: (h[c], -c))
                    break
            if best is None:
                break
            moved.add(int(best))
            loads[owner[best, 0]] -= h[best]
            loads[s_new] += h[best]
            owner[best, 0] = s_new
    return PlacementMap(fine.n_classes, owner)


def shrink_plan(pmap: PlacementMap, remove: list[int], n_shards: int,
                heat: np.ndarray | None = None) -> PlacementMap:
    """Target map for draining shards ``remove`` out of an ``n_shards``
    array: every (class, role) they own is reassigned to the currently
    least-loaded survivor not already holding that class.  Shard ids are
    NOT renumbered here — the reshard engine compacts indices only after
    all copies land and the drained endpoints detach.
    """
    removed = set(int(s) for s in remove)
    survivors = [s for s in range(n_shards) if s not in removed]
    if len(survivors) < pmap.replication:
        raise ValueError("not enough survivors for replication")
    f = len(survivors) // math.gcd(pmap.n_classes, len(survivors))
    fine, h = _refined(pmap, heat, f)
    owner = fine.owner.copy()
    loads = _loads(fine, h, n_shards)
    loads[list(removed)] = np.inf        # never receive
    for c in range(fine.n_classes):
        for r in range(fine.replication):
            if int(owner[c, r]) not in removed:
                continue
            row = set(int(s) for s in owner[c])
            cand = [s for s in survivors if s not in row]
            dst = min(cand, key=lambda s: (loads[s], s))
            owner[c, r] = dst
            if r == 0:
                loads[dst] += h[c]
    return PlacementMap(fine.n_classes, owner)


def heat_plan(pmap: PlacementMap, heat: np.ndarray, live: list[int],
              refine: int = 4) -> PlacementMap:
    """Heat-weighted rebalance over the live shards.

    Refines by ``refine`` (finer classes let hot coarse classes split
    across shards), then LPT-assigns role-0 classes in descending heat
    order to the least-loaded live shard, tie-breaking toward the
    current owner so cold classes don't churn.  Replica roles > 0 keep
    their owner unless it would collide with the new primary.
    """
    if not live:
        raise ValueError("heat_plan needs at least one live shard")
    fine, h = _refined(pmap, heat, max(1, refine))
    owner = fine.owner.copy()
    live_set = set(int(s) for s in live)
    loads = {s: 0.0 for s in live_set}
    for c in np.argsort(-h, kind="stable"):
        cur = int(owner[c, 0])
        others = set(int(s) for s in owner[c, 1:])
        cand = [s for s in live_set if s not in others]
        if not cand:
            continue
        dst = min(cand, key=lambda s: (loads[s], 0 if s == cur else 1, s))
        owner[c, 0] = dst
        loads[dst] += h[c]
        # replica roles: keep unless they now collide with the primary
        for r in range(1, fine.replication):
            if int(owner[c, r]) == dst:
                row = set(int(s) for s in owner[c])
                alt = [s for s in live_set if s not in row] or \
                      [s for s in live_set if s != dst and
                       s != int(owner[c, r])]
                if cur != dst and cur not in row:
                    owner[c, r] = cur
                elif alt:
                    owner[c, r] = min(alt)
    return PlacementMap(fine.n_classes, owner)
