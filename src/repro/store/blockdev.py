"""Page-granular block device — the simulated SSD under GraphStore.

The paper's CSSD exposes a 4 TB NVMe SSD to the FPGA through an internal
PCIe switch; GraphStore addresses it with logical page numbers (LPNs) at
4 KB flash-page granularity.  Here the device is a growable pool of 4 KB
pages backed by numpy.  Two address spaces mirror Figure 7 of the paper:

  * the *neighbor space* grows from LPN 0 upward (adjacency pages),
  * the *embedding space* grows from the top of the device downward
    (sequential embedding table, no page-level mapping needed).

The device records per-operation byte counters and timestamped I/O events
so benchmarks can reconstruct bandwidth timelines (paper Fig. 18c) and
write-amplification stats.  The event log is a bounded ring by default —
sustained serving traffic must not grow device memory (same argument as
the RPC server's rolling per-method stats); benchmarks that reconstruct
full timelines opt into an unbounded trace with ``trace_events=True``.

Two array-scale behaviours live at this layer:

  * **fault flag** — ``fail()`` marks the device dead; every subsequent
    command (read/write/alloc) raises ``DeviceFailedError``.  The
    replicated coordinator's replica selection excludes failed shards and
    its failover retry re-plans any fetch already in flight against one;
  * **busy-until command serialization** — simulated latency is arbitrated
    through a per-device ``busy_until`` deadline, so two commands issued
    concurrently against ONE device queue behind each other (a device has
    one command pipeline), while commands on different devices of an array
    still overlap.  Previously each caller slept independently, silently
    granting a single device unbounded command concurrency.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..concurrency import witness_lock

PAGE_BYTES = 4096
SLOT_DTYPE = np.int32
SLOTS_PER_PAGE = PAGE_BYTES // 4  # 1024 int32 slots

EVENTS_CAP = 4096                 # default I/O event ring size


class DeviceFailedError(RuntimeError):
    """A command was issued against a failed device."""


@dataclass
class IOEvent:
    t: float          # seconds since device creation
    kind: str         # 'read' | 'write'
    lpn: int
    nbytes: int
    tag: str          # e.g. 'graph', 'embed', 'meta'


@dataclass
class IOStats:
    read_pages: int = 0
    written_pages: int = 0
    read_bytes: int = 0
    written_bytes: int = 0
    # bounded ring: an append-only list would grow without limit under the
    # serving runtime; ``BlockDevice(trace_events=True)`` swaps in an
    # unbounded deque for benchmarks that need the full trace
    events: deque = field(default_factory=lambda: deque(maxlen=EVENTS_CAP))

    def record(self, kind: str, lpn: int, nbytes: int, tag: str, t0: float):
        if kind == "read":
            self.read_pages += 1
            self.read_bytes += nbytes
        else:
            self.written_pages += 1
            self.written_bytes += nbytes
        self.events.append(IOEvent(time.perf_counter() - t0, kind, lpn, nbytes, tag))


def sleep_us(us: float) -> None:
    """Wall-clock wait of ``us`` microseconds (simulated device time).

    Millisecond-plus waits use ``time.sleep``; sub-millisecond waits spin
    on the monotonic clock (sleep() has a multi-10µs scheduler floor that
    would swamp the simulated page latency with host noise), yielding the
    GIL at every probe (sleep(0) = sched_yield) so commands in flight on
    OTHER simulated devices — the shards of a CSSD array — burn their
    flash time concurrently instead of serializing behind the interpreter
    lock.
    """
    if us <= 0:
        return
    if us >= 1000.0:
        time.sleep(us * 1e-6)
    else:
        end = time.perf_counter() + us * 1e-6
        while time.perf_counter() < end:
            time.sleep(0)


class _LatencyAccount:
    """Deferred simulated-latency accumulator (see ``defer_latency``)."""
    __slots__ = ("us",)

    def __init__(self):
        self.us = 0.0


class _DeferCtx:
    __slots__ = ("dev", "acct")

    def __init__(self, dev):
        self.dev = dev

    def __enter__(self) -> _LatencyAccount:
        self.acct = _LatencyAccount()
        self.dev._defer.acct = self.acct
        return self.acct

    def __exit__(self, *exc):
        self.dev._defer.acct = None
        return False


class BlockDevice:
    """Growable array of 4 KB pages with front/back allocation.

    ``write_page``/``read_page`` move whole pages (flash access granularity);
    GraphStore's layouts are designed so that mutable graph updates touch a
    single page (the paper's write-amplification argument).
    """

    def __init__(self, num_pages: int = 1 << 14, *, simulate_latency: bool = False,
                 page_read_us: float = 0.0, page_write_us: float = 0.0,
                 command_latency_us: float = 0.0, trace_events: bool = False):
        self._pages = np.zeros((num_pages, SLOTS_PER_PAGE), dtype=SLOT_DTYPE)
        self._front = 0                 # next free LPN in neighbor space
        self._back = num_pages          # one past last used LPN in embedding space
        self._free: list[int] = []      # recycled neighbor-space pages
        self._lock = witness_lock("blockdev._lock", threading.Lock())
        self._t0 = time.perf_counter()
        self.stats = IOStats()
        if trace_events:
            self.stats.events = deque()        # unbounded full trace
        self.simulate_latency = simulate_latency
        self.page_read_us = page_read_us
        self.page_write_us = page_write_us
        # fixed per-command round-trip (NVMe submission/completion + flash
        # access setup): the cost that BATCHED commands amortise — one
        # read_pages(n) pays it once, n read_page calls pay it n times.
        self.command_latency_us = command_latency_us
        # internal flash channels: a single queued multi-page command streams
        # from all channels at once; serial single-page commands cannot.
        self.channels = 8
        # write observer: called as on_write(lpn0, n_pages) for every page
        # write/free (and with the whole device span on _grow relocation) —
        # the device-DRAM page cache hooks its invalidation here.
        self.on_write = None
        # growth observer: called as on_grow(extra_pages) after ``_grow``
        # relocates the embedding space to the new device top — holders of
        # embedding-space base LPNs (GraphStore._emb_base) shift by the
        # same amount or they silently read the zeroed old location.
        self.on_grow = None
        # per-thread deferred-latency slot (see defer_latency)
        self._defer = threading.local()
        # busy-until command arbitration: one command pipeline per device
        self._busy_lock = witness_lock(
            "blockdev._busy_lock", threading.Lock())
        self._busy_until = 0.0
        self.failed = False

    # ------------------------------------------------------------------ fault
    def fail(self) -> None:
        """Fail the device: every later command raises ``DeviceFailedError``.

        The data pages are NOT cleared — a failed device's content is simply
        unreachable, exactly what a replicated array must survive.
        """
        self.failed = True

    def _check_alive(self) -> None:
        if self.failed:
            raise DeviceFailedError("command issued against a failed device")

    def defer_latency(self):
        """Context manager: accumulate this thread's simulated latency on
        this device instead of sleeping, yielding the accumulator.

        The sharded coordinator wraps each shard's fetch in this and then
        pays ONE ``sleep_us(max(per-shard totals))`` — the devices of an
        array run their commands concurrently, exactly as the flash
        channels inside one device do (whose parallelism is modelled the
        same analytic way).  Thread-local, so a mutation landing from
        another thread mid-fetch still pays its own latency inline.
        """
        return _DeferCtx(self)

    # ------------------------------------------------------------------ alloc
    @property
    def num_pages(self) -> int:
        return self._pages.shape[0]

    def _grow(self, min_extra: int) -> list:
        """Grow the page array (caller holds ``_lock``).  Returns the
        observer callbacks to fire AFTER the lock is released — arbitrary
        hook code (the page cache, the store's base-LPN shift) must not
        run under the device allocator lock."""
        old = self._pages
        extra = max(min_extra, old.shape[0])
        grown = np.zeros((old.shape[0] + extra, SLOTS_PER_PAGE), dtype=SLOT_DTYPE)
        grown[: old.shape[0]] = old
        # embedding space lives at the top: relocate it.
        back_len = old.shape[0] - self._back
        if back_len:
            grown[-back_len:] = old[self._back:]
            grown[self._back: old.shape[0]] = 0
        self._back = grown.shape[0] - back_len
        self._pages = grown
        hooks = []
        if self.on_grow is not None:           # embedding LPNs shifted up
            hooks.append((self.on_grow, (extra,)))
        if self.on_write is not None:          # embedding span relocated:
            hooks.append((self.on_write,       # every cached LPN is stale
                          (0, grown.shape[0])))
        return hooks

    @staticmethod
    def _fire(hooks: list) -> None:
        for fn, args in hooks:
            fn(*args)

    def alloc_front(self) -> int:
        """Allocate one page in the neighbor space (graph pages)."""
        self._check_alive()
        hooks: list = []
        with self._lock:
            if self._free:
                return self._free.pop()
            if self._front >= self._back:
                hooks = self._grow(1)
            lpn = self._front
            self._front += 1
        self._fire(hooks)
        return lpn

    def alloc_back(self, n: int) -> int:
        """Allocate ``n`` contiguous pages at the top (embedding space).

        Returns the first LPN of the span (ascending order within the span).
        """
        self._check_alive()
        hooks: list = []
        with self._lock:
            if self._back - n < self._front:
                hooks = self._grow(n)
            self._back -= n
            base = self._back
        self._fire(hooks)
        return base

    def free_page(self, lpn: int) -> None:
        self._check_alive()
        with self._lock:
            self._free.append(lpn)
        if self.on_write is not None:
            self.on_write(lpn, 1)

    # -------------------------------------------------------------------- i/o
    def _maybe_sleep(self, us: float):
        if self.simulate_latency and us > 0:
            acct = getattr(self._defer, "acct", None)
            if acct is not None:
                acct.us += us                 # deferred: coordinator pays
                return
            # busy-until queue model: a device executes ONE command stream.
            # The command starts when the device frees up (queueing delay)
            # and holds it for its service time; concurrent callers on this
            # device serialize, callers on other devices overlap.
            with self._busy_lock:
                now = time.perf_counter()
                start = self._busy_until if self._busy_until > now else now
                self._busy_until = start + us * 1e-6
                end = self._busy_until
            sleep_us((end - now) * 1e6)

    def write_page(self, lpn: int, data: np.ndarray, *, tag: str = "graph") -> None:
        assert data.dtype == SLOT_DTYPE and data.shape == (SLOTS_PER_PAGE,)
        self._check_alive()
        self._maybe_sleep(self.command_latency_us + self.page_write_us)
        self._pages[lpn] = data
        self.stats.record("write", lpn, PAGE_BYTES, tag, self._t0)
        if self.on_write is not None:
            self.on_write(lpn, 1)

    def write_span(self, lpn0: int, flat: np.ndarray, *, tag: str = "embed") -> None:
        """Bulk sequential write of ``flat`` (int32) starting at page lpn0.

        Stats are span-granular (one event) — per-page Python bookkeeping
        would dwarf the simulated DMA itself.
        """
        n_pages = -(-flat.size // SLOTS_PER_PAGE)
        self._check_alive()
        self._maybe_sleep(self.command_latency_us
                          + self.page_write_us * n_pages / self.channels)
        full = flat.size // SLOTS_PER_PAGE
        if full:
            self._pages[lpn0: lpn0 + full] = \
                flat[: full * SLOTS_PER_PAGE].reshape(full, SLOTS_PER_PAGE)
        rem = flat.size - full * SLOTS_PER_PAGE
        if rem:
            self._pages[lpn0 + full, :rem] = flat[full * SLOTS_PER_PAGE:]
            self._pages[lpn0 + full, rem:] = 0
        self.stats.written_pages += n_pages
        self.stats.written_bytes += n_pages * PAGE_BYTES
        self.stats.events.append(IOEvent(
            time.perf_counter() - self._t0, "write", lpn0,
            n_pages * PAGE_BYTES, tag))
        if self.on_write is not None:
            self.on_write(lpn0, n_pages)

    def read_page(self, lpn: int, *, tag: str = "graph") -> np.ndarray:
        self._check_alive()
        self._maybe_sleep(self.command_latency_us + self.page_read_us)
        self.stats.record("read", lpn, PAGE_BYTES, tag, self._t0)
        return self._pages[lpn]

    def read_pages(self, lpns, *, tag: str = "graph") -> np.ndarray:
        """Batched scattered-page read -> (len(lpns), SLOTS_PER_PAGE).

        One queued command for the whole set (NVMe queue-depth behaviour):
        the simulated latency is still per-page (``n * page_read_us``) but
        the submission overhead is paid once — this is what makes the
        near-storage batch engines (GetNeighbors/GetEmbed) fast, versus one
        ``read_page`` round-trip per page.
        """
        lpns = np.asarray(lpns, dtype=np.int64)
        self._check_alive()
        self._maybe_sleep(self.command_latency_us
                          + self.page_read_us * len(lpns) / self.channels)
        self.stats.read_pages += len(lpns)
        self.stats.read_bytes += len(lpns) * PAGE_BYTES
        self.stats.events.append(IOEvent(
            time.perf_counter() - self._t0, "read",
            int(lpns[0]) if len(lpns) else 0, len(lpns) * PAGE_BYTES, tag))
        return self._pages[lpns]

    def read_span(self, lpn0: int, n_pages: int, *, tag: str = "embed") -> np.ndarray:
        self._check_alive()
        self._maybe_sleep(self.command_latency_us
                          + self.page_read_us * n_pages / self.channels)
        self.stats.read_pages += n_pages
        self.stats.read_bytes += n_pages * PAGE_BYTES
        self.stats.events.append(IOEvent(
            time.perf_counter() - self._t0, "read", lpn0,
            n_pages * PAGE_BYTES, tag))
        return self._pages[lpn0: lpn0 + n_pages].reshape(-1)
