"""ShardEndpoint — the shard-access protocol of the CSSD array.

The paper's core interface claim is "RPC over PCIe": hosts program GNNs
against a graph semantic library with *no knowledge of the storage
configuration* (§3.3).  PRs 3-4 broke that abstraction one level down —
the array coordinator called shard ``GraphStore`` objects as in-process
Python attributes, so the array could never span hosts.  This module
makes the partition boundary a real message boundary:

  * ``ShardService`` — the device-side method surface of ONE shard: a
    ``GraphStore`` behind a named-method API (batched ``fetch``, planning
    metadata, unit mutations, bulk writes, stats snapshots, and the
    chunked rebuild export/import used for shard-to-shard recovery).
    Every method takes and returns only RoP-serializable values;
  * ``LocalShardEndpoint`` — the in-process implementation: direct calls
    into a ``ShardService`` (zero-copy, the pre-endpoint behavior), with
    the same per-method call accounting a remote link would report;
  * ``ShardHost`` + ``RopShardEndpoint`` — the multi-host implementation:
    every call is serialized over a per-shard ``MultiQueueRoP`` SQ/CQ
    pair + ``PCIeChannel`` mmap buffers and handled by the shard host's
    firmware poll thread.  Batched reads are *submitted* to all shards
    and *awaited* together, so the array still pays max(shard costs).

The coordinator (``store/sharded.py``) speaks ONLY this protocol — no
``.gmap`` / ``.h_chain`` / ``.dev`` attribute access — which is what lets
``ShardedGraphStore``/``ReplicatedGraphStore`` drive an array whose
shards live behind real links.  Because both endpoint flavours run the
same ``ShardService`` code over the same page layouts, an array of
``RopShardEndpoint`` shards is **bit-identical** to the same array of
``LocalShardEndpoint`` shards under the same seed (healthy, degraded,
and post-rebuild — ``tests/test_endpoint.py``).

Timing model: the ``fetch`` handler defers its device's simulated flash +
command time and ships the total back as ``io_us``; the coordinator
awaits every shard's completion and sleeps once for the slowest shard —
the same analytic concurrency model the flash channels use inside one
device (divide, don't sum).  Non-batched commands pay their simulated
latency where they execute (the shard host's poll thread), so mutations
on different shards still overlap while two commands on one shard queue
behind each other.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from .blockdev import (BlockDevice, DeviceFailedError, SLOTS_PER_PAGE,
                       SLOT_DTYPE)
from .graphstore import GraphStore, bucket_pairs, csr_from_pairs, mirror_edges
from .placement import PlacementMap, rows_of_class

_REBUILD_CHUNK_PAGES = 512        # default pages per rebuild stream chunk
_EXCHANGE_CHUNK_EDGES = 1 << 18   # default pairs per peer-exchange pull


class _IngestSession:
    """Device-side state of ONE distributed bulk load on one shard.

    Holds the shard's own directed-pair bucket (``local``), the pending
    buckets destined for each peer (``outbound``), and the preallocated
    per-role embedding stripes — everything the commit needs to run the
    [G-3]/[G-4] sort + CSR build and the bulk page packing entirely
    device-side.
    """

    def __init__(self, shard: int, n_shards: int, replication: int,
                 already_undirected: bool, emb_rows: int, feature_dim: int,
                 placement: PlacementMap | None = None):
        self.shard = int(shard)
        self.n_shards = int(n_shards)
        self.replication = int(replication)
        self.already_undirected = bool(already_undirected)
        self.placement = placement
        self.edges_in = 0                       # raw edges streamed in
        self.exchanged_in = 0                   # pairs pulled from peers
        self.local: list[np.ndarray] = []       # pair chunks this shard owns
        self.outbound: list[list[np.ndarray]] = \
            [[] for _ in range(self.n_shards)]
        self.out_ready: list[np.ndarray | None] = [None] * self.n_shards
        # per-stripe embedding staging in canonical (class, role) order.
        # Default map: stripe index == role r, class (shard - r) % N,
        # local row = vid // N — the exact layout _emb_shard_rows ships
        # on the monolithic path.  A custom placement replaces the class
        # set and modulus but keeps the same role-major stripe order
        # (PlacementMap.pairs_of).
        self.feature_dim = int(feature_dim)
        self.emb_rows = int(emb_rows)
        if placement is not None:
            self.modulus = placement.n_classes
            self.class_pairs = placement.pairs_of(self.shard)
        else:
            self.modulus = self.n_shards
            self.class_pairs = [((self.shard - r) % self.n_shards, r)
                                for r in range(self.replication)]
        self.stripes: list[np.ndarray] = []
        for c, _r in self.class_pairs:
            rows = rows_of_class(self.emb_rows, c, self.modulus)
            self.stripes.append(
                np.zeros((rows, self.feature_dim), dtype=np.float32))

    def owned_classes(self) -> set[int]:
        return {c for c, _r in self.class_pairs}


# ------------------------------------------------------------ plan packing
def pack_plan(desc: list) -> dict:
    """Array-pack a ``fetch_plan`` descriptor list for the wire.

    A desc entry is ``None`` / ``("L", row, start, end)`` /
    ``("H", rows, counts)``; shipping them as JSON tuples would put
    O(vids) structure in the packet header, so they are flattened into a
    handful of ndarray payload buffers instead (the header stays O(1)).
    """
    l_idx, l_row, l_start, l_end = [], [], [], []
    h_idx, h_len, h_rows, h_counts = [], [], [], []
    for i, d in enumerate(desc):
        if d is None:
            continue
        if d[0] == "L":
            l_idx.append(i)
            l_row.append(int(d[1]))
            l_start.append(int(d[2]))
            l_end.append(int(d[3]))
        else:
            h_idx.append(i)
            h_len.append(len(d[1]))
            h_rows.append(np.asarray(d[1], dtype=np.int64))
            h_counts.append(np.asarray(d[2], dtype=np.int64))
    return {
        "n": len(desc),
        "l_idx": np.asarray(l_idx, dtype=np.int64),
        "l_row": np.asarray(l_row, dtype=np.int64),
        "l_start": np.asarray(l_start, dtype=np.int64),
        "l_end": np.asarray(l_end, dtype=np.int64),
        "h_idx": np.asarray(h_idx, dtype=np.int64),
        "h_len": np.asarray(h_len, dtype=np.int64),
        "h_rows": (np.concatenate(h_rows) if h_rows
                   else np.empty(0, dtype=np.int64)),
        "h_counts": (np.concatenate(h_counts) if h_counts
                     else np.empty(0, dtype=np.int64)),
    }


def unpack_plan(packed: dict) -> list:
    """Inverse of ``pack_plan`` — reconstructs the descriptor list."""
    desc: list = [None] * int(packed["n"])
    for i, row, start, end in zip(np.asarray(packed["l_idx"]).tolist(),
                                  np.asarray(packed["l_row"]).tolist(),
                                  np.asarray(packed["l_start"]).tolist(),
                                  np.asarray(packed["l_end"]).tolist()):
        desc[i] = ("L", row, start, end)
    h_rows = np.asarray(packed["h_rows"], dtype=np.int64)
    h_counts = np.asarray(packed["h_counts"], dtype=np.int64)
    off = 0
    for i, ln in zip(np.asarray(packed["h_idx"]).tolist(),
                     np.asarray(packed["h_len"]).tolist()):
        desc[i] = ("H", h_rows[off: off + ln], h_counts[off: off + ln])
        off += ln
    return desc


def clone_dev_profile(old: BlockDevice) -> BlockDevice:
    """A fresh replacement device with the failed one's perf profile."""
    return BlockDevice(
        old.num_pages, simulate_latency=old.simulate_latency,
        page_read_us=old.page_read_us, page_write_us=old.page_write_us,
        command_latency_us=old.command_latency_us,
        trace_events=old.stats.events.maxlen is None)


# ---------------------------------------------------------- device side
class ShardService:
    """The RPC-exposed surface of one CSSD shard.

    Wraps a partition-local ``GraphStore``; every public method is a
    shard RPC (dispatched by ``RPCServer`` on remote hosts, called
    directly by ``LocalShardEndpoint`` in-process).  Methods only accept
    and return wire types — the coordinator never sees the store object.
    """

    def __init__(self, store: GraphStore):
        self.store = store
        # peer links for shard-to-shard rebuild streaming: list of objects
        # with ``.call(method, **kw)`` (AsyncRPCClient for remote arrays,
        # a direct caller for local ones), index-aligned with the array.
        self.peers: list | None = None
        # the RoP this service is drained from, when it is remote
        # (``ShardHost`` sets it) — lets ``counters`` report live SQ/CQ
        # depth so gossip can steer reads away from hot shards
        self.rop = None
        # active distributed bulk-load session (ingest_begin..ingest_commit)
        self._ingest: _IngestSession | None = None

    # ------------------------------------------------------ batched fetch
    def fetch(self, l_vids=None, h_vids=None, h_pgs=None, emb_rows=None,
              pack: bool = False) -> dict:
        """ONE batched read command covering every page the coordinator
        needs from this shard: an adjacency plan fetch (``l_vids``),
        explicit H-chain page reads (``h_vids``/``h_pgs``, the replicated
        page-granular spread), and/or an embedding row gather
        (``emb_rows``) — each its own queued scatter-read, all under one
        deferred-latency account whose total ships back as ``io_us`` so
        the coordinator can pay max over shards.  This is why per-shard
        RPC count is O(1) per batched read, never O(pages)."""
        out: dict = {"block": None, "desc": None, "hblk": None, "emb": None}
        store = self.store
        with store.dev.defer_latency() as acct:
            if l_vids is not None and len(l_vids):
                block, desc = store.fetch_plan(
                    np.asarray(l_vids, dtype=np.int64))
                out["block"] = block
                out["desc"] = pack_plan(desc) if pack else desc
            if h_vids is not None and len(h_vids):
                out["hblk"] = store.chain_pages(
                    np.asarray(h_vids, dtype=np.int64),
                    np.asarray(h_pgs, dtype=np.int64))
            if emb_rows is not None and len(emb_rows):
                out["emb"] = store.get_embeds(
                    np.asarray(emb_rows, dtype=np.int64))
        out["io_us"] = acct.us
        return out

    def plan_info(self, vids) -> dict:
        """Planning metadata for a batch of vids (no page I/O): per-vid
        H-chain page count (0 when not H-mapped) and L-table range-search
        index (-1 when the shard has no L pages).  The replicated
        coordinator calls this once per vertex class per batched read —
        the in-DRAM mapping tables stay device-side."""
        return self.store.plan_info(np.asarray(vids, dtype=np.int64))

    # ----------------------------------------------------------- unit ops
    def get_neighbors(self, vid):
        """Sorted neighbor list of one locally-owned vid."""
        return self.store.get_neighbors(int(vid))

    def get_embed_row(self, row):
        """One embedding row by SHARD-LOCAL row index (the coordinator
        does the vid -> (shard, row) placement math)."""
        return self.store.get_embed(int(row))

    def add_vertex(self, vid) -> None:
        """Insert one vid into the local partition (idempotent)."""
        self.store.add_vertex(int(vid))

    def insert_neighbor(self, vid, nbr, count: bool = False) -> None:
        """Add ``nbr`` to ``vid``'s local adjacency; ``count=True``
        bills it as the unit update (one logical op counted once across
        the replica fan-out)."""
        st = self.store
        with st._lock:
            if count:
                st.stats.unit_updates += 1
            st._insert_neighbor(int(vid), int(nbr))

    def remove_neighbor(self, vid, nbr, count: bool = False) -> None:
        """Remove ``nbr`` from ``vid``'s local adjacency (see
        ``insert_neighbor`` for ``count``)."""
        st = self.store
        with st._lock:
            if count:
                st.stats.unit_updates += 1
            st._remove_neighbor(int(vid), int(nbr))

    def drop_vertex_pages(self, vid, count: bool = False) -> None:
        """Drop every adjacency page of ``vid`` (vertex delete)."""
        st = self.store
        with st._lock:
            if count:
                st.stats.unit_updates += 1
            st._drop_vertex_pages(int(vid))

    def update_embed_row(self, row, embed) -> None:
        """Overwrite one embedding row by shard-local row index."""
        self.store.update_embed(int(row), np.asarray(embed))

    # --------------------------------------------------------- bulk writes
    def write_adjacency(self, indptr, indices) -> None:
        """Bulk-pack a pre-partitioned CSR into the local page store
        (coordinator-side ingest path)."""
        self.store._write_adjacency(np.asarray(indptr, dtype=np.int64),
                                    np.asarray(indices))

    def write_embedding_table(self, rows) -> None:
        """Bulk-write the shard-local embedding stripe table."""
        self.store._write_embedding_table(
            np.ascontiguousarray(rows, dtype=np.float32))

    # ------------------------------------------------ distributed bulk load
    # The G-1..G-4 pipeline run WHERE THE DATA IS: the coordinator streams
    # bounded RAW edge chunks (ingest_edges) and embedding stripe slices
    # (ingest_emb_rows); each shard mirrors + buckets device-side, peers
    # exchange cross-shard buckets over the peer links (ingest_take /
    # ingest_exchange — the chunked-rebuild pull discipline), and
    # ingest_commit sorts, builds the partition-local CSR and bulk-packs
    # the pages locally.  The coordinator never touches an edge beyond
    # slicing chunks, so its shipped bytes are the raw arrays — no
    # preprocessed CSR ever crosses the coordinator link.

    def _require_ingest(self) -> _IngestSession:
        if self._ingest is None:
            raise RuntimeError("no ingest session open (ingest_begin first)")
        return self._ingest

    def ingest_begin(self, shard, n_shards, replication: int = 1,
                     already_undirected: bool = False, emb_rows: int = 0,
                     feature_dim: int = 0, placement=None) -> dict:
        """Open a bulk-load session on this shard.

        ``placement`` (a ``PlacementMap`` payload dict, or ``None`` for
        the default ``vid % N`` layout) selects the ownership rule the
        session buckets and stripes under; it is omitted from the wire
        at the default map, so legacy callers are unaffected."""
        if self._ingest is not None:
            raise RuntimeError("ingest session already open on this shard")
        if self.store.dev.failed:
            raise DeviceFailedError("shard device failed; cannot ingest")
        pmap = None
        if placement is not None:
            pmap = (placement if isinstance(placement, PlacementMap)
                    else PlacementMap.from_payload(placement))
        self._ingest = _IngestSession(shard, n_shards, replication,
                                      already_undirected, emb_rows,
                                      feature_dim, placement=pmap)
        return {"shard": int(shard)}

    def ingest_edges(self, chunk) -> dict:
        """One bounded raw edge chunk: [G-2] mirrored and [G-3] bucketed
        device-side.  Pairs whose row this shard owns stay local; the rest
        accumulate in per-peer outbound buckets for the exchange."""
        ss = self._require_ingest()
        raw = np.asarray(chunk, dtype=np.int64).reshape(-1, 2)
        ss.edges_in += len(raw)
        pairs = mirror_edges(raw, already_undirected=ss.already_undirected)
        max_vid = int(raw.max()) if raw.size else -1
        for t, b in enumerate(bucket_pairs(pairs, ss.n_shards,
                                           replication=ss.replication,
                                           placement=ss.placement)):
            if not len(b):
                continue
            if t == ss.shard:
                ss.local.append(b)
            else:
                ss.outbound[t].append(b)
        return {"edges": int(len(raw)), "max_vid": max_vid}

    def ingest_emb_rows(self, role, row0, rows) -> dict:
        """Stage a slice of one embedding stripe in local-row order.
        ``role`` is the stripe index in canonical (class, role) order —
        under the default map that is the replica role holding class
        ``(shard - role) % N``."""
        ss = self._require_ingest()
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        r0 = int(row0)
        ss.stripes[int(role)][r0: r0 + len(rows)] = rows
        return {"rows": int(len(rows))}

    def ingest_take(self, for_shard, cursor, max_edges) -> dict:
        """Peer-pull export: one bounded slice of the pairs this shard
        bucketed for ``for_shard`` (the exchange counterpart of
        ``export_adj_chunk``)."""
        ss = self._require_ingest()
        t = int(for_shard)
        if ss.out_ready[t] is None:
            parts = ss.outbound[t]
            ss.out_ready[t] = (np.concatenate(parts) if parts
                               else np.empty((0, 2), dtype=np.int64))
            ss.outbound[t] = []
        buf = ss.out_ready[t]
        c = max(0, int(cursor))
        out = buf[c: c + max(1, int(max_edges))]
        done = c + len(out) >= len(buf)
        if done:                         # free the shipped bucket
            ss.out_ready[t] = np.empty((0, 2), dtype=np.int64)
        return {"pairs": out, "next": c + len(out), "done": bool(done)}

    def ingest_exchange(self, max_edges: int = _EXCHANGE_CHUNK_EDGES) -> dict:
        """Pull every peer's bucket for THIS shard over the peer links,
        in bounded chunks.

        The coordinator calls this one shard at a time: the puller's poll
        thread drives its (otherwise idle) peers' queues — the same
        single-puller discipline as the chunked rebuild, which is what
        keeps N single-threaded shard hosts free of circular waits."""
        ss = self._require_ingest()
        if self.peers is None:
            raise RuntimeError("ingest_exchange needs peer links "
                               "(set_peers)")
        pulled = 0
        for p, peer in enumerate(self.peers):
            if p == ss.shard:
                continue
            cursor, done = 0, False
            while not done:
                chunk = peer.call("ingest_take", for_shard=ss.shard,
                                  cursor=cursor, max_edges=int(max_edges))
                pairs = np.asarray(chunk["pairs"],
                                   dtype=np.int64).reshape(-1, 2)
                if len(pairs):
                    ss.local.append(pairs)
                    pulled += len(pairs)
                cursor = int(chunk["next"])
                done = bool(chunk["done"])
        ss.exchanged_in += pulled
        return {"pulled": int(pulled)}

    def ingest_commit(self, num_vertices) -> dict:
        """[G-3]/[G-4] + bulk pack, all device-local: sort + dedup the
        owned pairs into the partition CSR (global row space, owned-class
        self-loops) and write the pages through the SAME packing code the
        monolithic path uses — overlapping the embedding-table burst with
        the sort exactly as ``GraphStore.update_graph`` does.  Identical
        inputs to identical code: the resulting pages are bit-identical
        to the monolithic ``write_adjacency``/``write_embedding_table``.
        """
        ss = self._require_ingest()
        st = self.store
        n = int(num_vertices)
        t0 = time.perf_counter()
        box: dict = {"wf_s": 0.0, "wf_us": 0.0}

        def write_feature():
            s0 = time.perf_counter()
            # simulated flash time is DEFERRED (thread-local accumulator):
            # the array's devices burn their write bursts concurrently, so
            # the coordinator pays one max(per-shard flash_us) after the
            # commit round — the same analytic model the batched read
            # fan-out uses — instead of N inline sleeps serializing here
            with st.dev.defer_latency() as acct:
                if ss.feature_dim and ss.emb_rows:
                    st._write_embedding_table(
                        np.concatenate(ss.stripes) if len(ss.stripes) > 1
                        else ss.stripes[0])
            box["wf_s"] = time.perf_counter() - s0
            box["wf_us"] = acct.us

        th = threading.Thread(target=write_feature)
        th.start()
        s0 = time.perf_counter()
        pairs = (np.concatenate(ss.local) if ss.local
                 else np.empty((0, 2), dtype=np.int64))
        indptr, indices = csr_from_pairs(
            pairs, n, n_shards=ss.modulus, classes=ss.owned_classes())
        box["sort_s"] = time.perf_counter() - s0
        th.join()
        s0 = time.perf_counter()
        with st.dev.defer_latency() as acct:
            st._write_adjacency(indptr, indices)
        st.num_vertices = max(st.num_vertices, n)
        self._ingest = None
        # one command stream per device: feature + graph bursts serialize
        # on THIS device, so its total flash time is their sum
        flash_us = box["wf_us"] + acct.us
        return {"edges": int(indptr[-1]), "edges_in": ss.edges_in,
                "exchanged_in": ss.exchanged_in,
                "sort_s": box["sort_s"],
                "write_feature_s": box["wf_s"] + box["wf_us"] * 1e-6,
                "write_graph_s": time.perf_counter() - s0 + acct.us * 1e-6,
                "flash_us": flash_us,
                "total_s": time.perf_counter() - t0 + flash_us * 1e-6}

    def ingest_abort(self) -> dict:
        """Drop the session (coordinator cleanup after a failed load)."""
        open_ = self._ingest is not None
        self._ingest = None
        return {"aborted": bool(open_)}

    # --------------------------------------------------- mutation firehose
    def apply_mutations(self, kinds, arg0, arg1, flags, emb=None) -> dict:
        """ONE device-side command applying a firehose WINDOW of unit
        mutations in submission order (store/ingest.py batches them
        per shard per time window).

        Packed parallel arrays; per op ``kinds[i]``:
          0  add_vertex(arg0)           (no-op when the vid exists)
          1  insert_neighbor(arg0, arg1)
          2  remove_neighbor(arg0, arg1)
          3  drop_vertex_pages(arg0)
          4  update_embed_row(arg0, <next row of emb>)
        ``flags`` bit 0 marks the logical-owner application that counts
        toward ``unit_updates`` (same accounting as the unit RPCs).  The
        whole window runs under the store lock, so a concurrent read sees
        window boundaries, never a half-applied op; page writes invalidate
        the shard's cache through the ordinary ``on_write`` hook."""
        st = self.store
        kinds = np.asarray(kinds, dtype=np.int64)
        arg0 = np.asarray(arg0, dtype=np.int64)
        arg1 = np.asarray(arg1, dtype=np.int64)
        flags = np.asarray(flags, dtype=np.int64)
        erows = None if emb is None else np.asarray(emb, dtype=np.float32)
        applied, j = 0, 0
        with st._lock:
            for k, a, b, f in zip(kinds.tolist(), arg0.tolist(),
                                  arg1.tolist(), flags.tolist()):
                if k == 0:
                    st.add_vertex(a)
                elif k == 1:
                    if f & 1:
                        st.stats.unit_updates += 1
                    st._insert_neighbor(a, b)
                elif k == 2:
                    if f & 1:
                        st.stats.unit_updates += 1
                    st._remove_neighbor(a, b)
                elif k == 3:
                    if f & 1:
                        st.stats.unit_updates += 1
                    st._drop_vertex_pages(a)
                elif k == 4:
                    st.update_embed(a, erows[j])
                    j += 1
                else:
                    raise ValueError(f"unknown mutation kind {k}")
                applied += 1
        return {"applied": applied}

    # ----------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """Full shard telemetry snapshot: store page/update counters,
        device IO counters, cache stats (or None), and the failed flag —
        the per-shard block the service ``stats`` RPC aggregates."""
        st = self.store.stats
        dev = self.store.dev.stats
        return {
            "store": {"pages_l": st.pages_l, "pages_h": st.pages_h,
                      "unit_updates": st.unit_updates,
                      "l_evictions": st.l_evictions,
                      "num_vertices": self.store.num_vertices,
                      "feature_dim": self.store.feature_dim,
                      "h_threshold": self.store.h_threshold},
            "device": {"read_pages": dev.read_pages,
                       "written_pages": dev.written_pages,
                       "read_bytes": dev.read_bytes,
                       "written_bytes": dev.written_bytes},
            "cache": (self.store.cache.stats.snapshot()
                      if self.store.cache is not None else None),
            "failed": self.store.dev.failed,
        }

    def counters(self) -> dict:
        """Lightweight load + health probe for the coordinator's gossip
        loop and the supervisor's monitor: cumulative read load, the
        device's failed flag (stats attributes stay readable after
        ``fail()``, so a dead shard is detectable with zero serving
        traffic), and current command-queue pressure when this service
        sits behind a RoP."""
        out = {"read_pages": self.store.dev.stats.read_pages,
               "failed": self.store.dev.failed,
               "inflight": 0, "sq_depth": 0}
        if self.rop is not None:
            snap = self.rop.stats_snapshot()
            out["inflight"] = snap["in_flight"]
            out["sq_depth"] = sum(q["sq_depth"] for q in snap["queues"])
        return out

    # --------------------------------------------------------------- cache
    def attach_cache(self, capacity_pages, cache_graph_pages: bool = True):
        """Attach a device-DRAM page cache of ``capacity_pages``."""
        from .embcache import EmbeddingPageCache
        self.store.attach_cache(EmbeddingPageCache(int(capacity_pages)),
                                cache_graph_pages=cache_graph_pages)

    def cache_stats(self) -> dict | None:
        """Cache counter snapshot, or ``None`` when no cache attached."""
        if self.store.cache is None:
            return None
        return self.store.cache.stats.snapshot()

    def clear_cache(self) -> None:
        """Drop every cached page (counters survive)."""
        if self.store.cache is not None:
            self.store.cache.clear()

    # --------------------------------------------------------------- fault
    def fail(self) -> None:
        """Drop the device (fault injection / drain).  The page cache is
        device DRAM — it died with the device."""
        self.store.dev.fail()
        if self.store.cache is not None:
            self.store.cache.clear()

    # -------------------------------------------------------------- export
    def export_adjacency(self) -> list:
        """Full adjacency export (oracle/validation only)."""
        adj = self.store.to_adjacency()
        return [[int(v), np.asarray(sorted(nb), dtype=SLOT_DTYPE)]
                for v, nb in adj.items()]

    # ------------------------------------------------- rebuild stream: src
    def export_adj_chunk(self, cls, n_shards, start_vid, max_pages) -> dict:
        """One bounded chunk of this shard's class-``cls`` adjacency, in
        ascending-vid order from ``start_vid``: L vids as materialised
        neighbor lists (re-laid by the importer's bulk packing), H chains
        as RAW page-exact data (replicas must keep layout-identical
        chains — the page-granular spread fetch depends on it).  Returns
        ``done`` + the next cursor, so the destination pulls the
        partition one chunk at a time instead of materialising it."""
        st = self.store
        cls, n_shards = int(cls), int(n_shards)
        budget = max(1, int(max_pages))
        l_vids: list[int] = []
        l_nbrs: list[np.ndarray] = []
        h_vids: list[int] = []
        h_lens: list[int] = []
        h_pages: list[np.ndarray] = []
        used = 0
        next_vid = -1
        done = True
        with st._lock:
            # vid list AND kinds in one snapshot: consulting the live map
            # per-vid outside the lock could see a concurrent L->H
            # promotion and ship a half-of-each view of that vertex
            kinds = {v: k for v, k in st.gmap.items()
                     if v % n_shards == cls and v >= int(start_vid)}
        vids_c = sorted(kinds)
        pend_l: list[int] = []
        for v in vids_c:
            if used >= budget:
                next_vid, done = v, False
                break
            kind = kinds[v]
            if kind == "L":
                pend_l.append(v)
                used += 1            # L vids are cheap; count conservatively
            elif kind == "H":
                with st._lock:
                    chain = list(st.h_chain[v])
                    pages = st.dev.read_pages(
                        np.asarray(chain, dtype=np.int64), tag="graph")
                h_vids.append(v)
                h_lens.append(len(chain))
                h_pages.append(np.array(pages))
                used += len(chain)
        if pend_l:
            l_vids = pend_l
            l_nbrs = st.get_neighbors_batch(pend_l)
        return {
            "l_vids": np.asarray(l_vids, dtype=np.int64),
            "l_lens": np.asarray([len(x) for x in l_nbrs], dtype=np.int64),
            "l_nbrs": (np.concatenate(l_nbrs).astype(SLOT_DTYPE) if l_nbrs
                       else np.empty(0, dtype=SLOT_DTYPE)),
            "h_vids": np.asarray(h_vids, dtype=np.int64),
            "h_lens": np.asarray(h_lens, dtype=np.int64),
            "h_pages": (np.concatenate(h_pages) if h_pages
                        else np.empty((0, SLOTS_PER_PAGE), dtype=SLOT_DTYPE)),
            "next_vid": next_vid, "done": done,
        }

    def export_emb_rows(self, rows):
        """Embedding rows by explicit local row index — the migration
        export (moved classes are non-contiguous under coarse extents)."""
        return self.store.get_embeds(np.asarray(rows, dtype=np.int64))

    # ---------------------------------------------- class migration: dst
    def emb_reserve_rows(self, n_rows) -> dict:
        """Grow this shard's embedding table by ``n_rows`` zero rows and
        return the base row index of the new region (the import target
        of one migrating class's stripe)."""
        return {"base": int(self.store.extend_embedding_table(int(n_rows)))}

    def _import_adj_chunk(self, l_vids, l_lens, l_nbrs, h_vids, h_lens,
                          h_pages) -> dict:
        """Import one ``export_adj_chunk`` payload into the LIVE store
        (unlike ``rebuild``, which materialises a fresh one): L vids are
        re-laid through the unit insert path, H chains cloned page-exact.
        Replace-safe, so a chunk redo after a source failover converges."""
        st = self.store
        l_vids = np.asarray(l_vids, dtype=np.int64)
        l_lens = np.asarray(l_lens, dtype=np.int64)
        l_nbrs = np.asarray(l_nbrs, dtype=SLOT_DTYPE)
        h_vids = np.asarray(h_vids, dtype=np.int64)
        h_lens = np.asarray(h_lens, dtype=np.int64)
        h_pages = np.asarray(h_pages, dtype=SLOT_DTYPE)
        off = 0
        for v, ln in zip(l_vids.tolist(), l_lens.tolist()):
            st.import_l_vertex(int(v), l_nbrs[off: off + ln])
            off += ln
        off = 0
        for v, ln in zip(h_vids.tolist(), h_lens.tolist()):
            st.import_h_chain(int(v), h_pages[off: off + ln])
            off += ln
        return {"l": int(len(l_vids)), "h": int(len(h_vids))}

    def migrate_pull(self, cls, modulus, src, start_vid, max_pages) -> dict:
        """Pull ONE bounded adjacency chunk of class ``cls`` from peer
        ``src`` over the peer link and import it into the live store.

        The coordinator drives the cursor loop (so it can pace, probe
        bit-identity at every chunk boundary, and fail the source over),
        but only O(1) metadata crosses the coordinator link — the page
        data moves shard-to-shard.  Returns the next cursor, ``done``,
        and the payload byte count for the migration's accounting."""
        if self.peers is None:
            raise RuntimeError("migrate_pull needs peer links (set_peers)")
        chunk = self.peers[int(src)].call(
            "export_adj_chunk", cls=int(cls), n_shards=int(modulus),
            start_vid=int(start_vid), max_pages=int(max_pages))
        h_pages = np.asarray(chunk["h_pages"], dtype=SLOT_DTYPE)
        l_nbrs = np.asarray(chunk["l_nbrs"], dtype=SLOT_DTYPE)
        self._import_adj_chunk(chunk["l_vids"], chunk["l_lens"], l_nbrs,
                               chunk["h_vids"], chunk["h_lens"], h_pages)
        return {"next_vid": int(chunk["next_vid"]),
                "done": bool(chunk["done"]),
                "l": int(len(chunk["l_vids"])),
                "h": int(len(chunk["h_vids"])),
                "pages": int(len(h_pages)),
                "bytes": int(l_nbrs.nbytes + h_pages.nbytes)}

    def migrate_pull_emb(self, src, cls, modulus, src_base, src_mod,
                         row0, take, dst_row0) -> dict:
        """Pull ``take`` embedding rows of class ``cls`` (class-local
        rows ``[row0, row0+take)``) from peer ``src`` and write them at
        local rows ``[dst_row0, ...)``.  Source rows are computed from
        O(1) extent metadata (``src_base + vid // src_mod``), so the
        coordinator ships no row lists."""
        if self.peers is None:
            raise RuntimeError("migrate_pull_emb needs peer links")
        vids = int(cls) + int(modulus) * (
            int(row0) + np.arange(int(take), dtype=np.int64))
        src_rows = int(src_base) + vids // int(src_mod)
        vals = np.asarray(self.peers[int(src)].call(
            "export_emb_rows", rows=src_rows), dtype=np.float32)
        self.store.write_embed_rows(int(dst_row0), vals)
        return {"rows": int(len(vals)), "bytes": int(vals.nbytes)}

    def drop_class(self, cls, modulus) -> dict:
        """Free every vertex of ``cls`` (mod ``modulus``) — the source
        side's release once the class's routing flip commits."""
        return {"dropped": int(self.store.drop_class(int(cls),
                                                     int(modulus)))}

    # ------------------------------------------------- rebuild stream: dst
    def rebuild(self, plan: dict) -> dict:
        """Re-materialise this shard from survivor peers, streaming.

        ``plan`` (built by the coordinator — pure metadata, no page data):
        ``n_shards``, ``num_vertices``, ``chunk_pages``, ``feature_dim``,
        optional ``pace_s``, and per owned class ``{cls, src, src_row0,
        rows}`` in stripe-role order.  The destination pulls bounded
        chunks from each class's survivor endpoint over the PEER links —
        survivor pages never transit the coordinator — cloning H chains
        page-exactly and re-laying L vids + embedding stripes through the
        bulk packing.  ``pace_s`` sleeps between chunk pulls: the rebuild
        throttle point, so recovery reads trickle onto survivor devices
        instead of monopolising them while serving reads queue behind.
        """
        if self.peers is None:
            raise RuntimeError("rebuild needs peer links (set_peers)")
        old = self.store
        t0 = time.perf_counter()
        n_shards = int(plan["n_shards"])
        chunk_pages = int(plan.get("chunk_pages") or _REBUILD_CHUNK_PAGES)
        pace_s = float(plan.get("pace_s") or 0.0)
        n_chunks = 0
        new = GraphStore(clone_dev_profile(old.dev),
                         h_threshold=old.h_threshold)
        vids_all: list[int] = []
        lens_all: list[int] = []
        nbrs_all: list[np.ndarray] = []
        n_cloned = 0
        stripes: list[np.ndarray] = []
        d = int(plan.get("feature_dim") or 0)
        for entry in plan["classes"]:
            src = self.peers[int(entry["src"])]
            cursor, done = 0, False
            while not done:
                if pace_s and n_chunks:
                    time.sleep(pace_s)
                n_chunks += 1
                chunk = src.call("export_adj_chunk", cls=int(entry["cls"]),
                                 n_shards=n_shards, start_vid=cursor,
                                 max_pages=chunk_pages)
                done = bool(chunk["done"])
                cursor = int(chunk["next_vid"])
                lv = np.asarray(chunk["l_vids"], dtype=np.int64)
                if len(lv):
                    vids_all.extend(lv.tolist())
                    lens_all.extend(
                        np.asarray(chunk["l_lens"]).tolist())
                    nbrs_all.append(np.asarray(chunk["l_nbrs"],
                                               dtype=SLOT_DTYPE))
                hv = np.asarray(chunk["h_vids"], dtype=np.int64)
                if len(hv):
                    pages = np.asarray(chunk["h_pages"], dtype=SLOT_DTYPE)
                    off = 0
                    for v, ln in zip(hv.tolist(),
                                     np.asarray(chunk["h_lens"]).tolist()):
                        new.import_h_chain(int(v), pages[off: off + ln])
                        off += ln
                        n_cloned += 1
            if d and int(entry.get("rows", 0)):
                rows_n = int(entry["rows"])
                # rows-mode entries carry (src_base, src_mod) extent
                # metadata so moved classes with coarse (non-contiguous)
                # stripes stream too; legacy src_row0 entries are the
                # contiguous special case src_mod == n_shards
                if "src_row0" in entry:
                    src_base, src_mod = int(entry["src_row0"]), n_shards
                else:
                    src_base = int(entry["src_base"])
                    src_mod = int(entry["src_mod"])
                vids = int(entry["cls"]) + n_shards * np.arange(
                    rows_n, dtype=np.int64)
                src_rows = src_base + vids // src_mod
                max_rows = max(1, chunk_pages * SLOTS_PER_PAGE // max(d, 1))
                parts = []
                for off in range(0, rows_n, max_rows):
                    if pace_s and n_chunks:
                        time.sleep(pace_s)
                    n_chunks += 1
                    parts.append(np.asarray(
                        src.call("export_emb_rows",
                                 rows=src_rows[off: off + max_rows]),
                        dtype=np.float32))
                stripes.append(np.concatenate(parts) if len(parts) > 1
                               else parts[0])
        if vids_all:
            order = np.argsort(np.asarray(vids_all), kind="stable")
            vids_srt = np.asarray(vids_all, dtype=np.int64)[order]
            lens_arr = np.asarray(lens_all, dtype=np.int64)
            n_glob = max(int(plan["num_vertices"]), int(vids_srt[-1]) + 1)
            deg = np.zeros(n_glob, dtype=np.int64)
            deg[vids_srt] = lens_arr[order]
            indptr = np.concatenate([[0], np.cumsum(deg)])
            nbr_cat = (np.concatenate(nbrs_all) if nbrs_all
                       else np.empty(0, dtype=SLOT_DTYPE))
            bounds = np.concatenate([[0], np.cumsum(lens_arr)])
            indices = np.concatenate(
                [nbr_cat[bounds[i]: bounds[i + 1]] for i in order]) \
                .astype(np.int32) if len(nbr_cat) else nbr_cat
            new._write_adjacency(indptr, indices)
        if stripes:
            new._write_embedding_table(np.concatenate(stripes))
        new.num_vertices = max(new.num_vertices, int(plan["num_vertices"]),
                               old.num_vertices)
        if old.cache is not None:
            new.attach_cache(old.cache.clone_empty())
        self.store = new
        return {"vertices": len(vids_all) + n_cloned,
                "h_chains_cloned": n_cloned,
                "pages_written": new.dev.stats.written_pages,
                "chunks": n_chunks, "pace_s": pace_s,
                "seconds": time.perf_counter() - t0}


class _DirectPeer:
    """In-process peer link: ``.call`` dispatches straight into a
    ``ShardService`` (the local-array analogue of a peer RoP client)."""

    def __init__(self, service: ShardService):
        self._service = service

    def call(self, method: str, *, timeout: float | None = None, **kw):
        return getattr(self._service, method)(**kw)


# ------------------------------------------------------------- host side
class ShardEndpoint:
    """Coordinator-facing protocol of one shard (see module docstring).

    Subclasses implement ``call`` (synchronous command), ``fetch_submit``
    / ``fetch_result`` (asynchronous batched read), ``set_peers``, and
    lifecycle.  Everything else is shared convenience built on ``call``.
    """

    # -- transport (subclass responsibility) -----------------------------
    def call(self, method: str, **kw):
        """Synchronous shard command: dispatch ``method`` on the shard's
        ``ShardService`` and return its result (raises what it raises)."""
        raise NotImplementedError

    def call_submit(self, method: str, **kw):
        """Asynchronous command: write it and return a handle.  Lets the
        coordinator fan a per-shard metadata round (plan_info, gossip
        counters) out to every shard and pay ONE round-trip, not N."""
        raise NotImplementedError

    def call_result(self, handle):
        """Await one ``call_submit`` handle and return its result."""
        raise NotImplementedError

    def fetch_submit(self, **kw):
        """Submit one batched-read (``fetch``) command and return a
        handle; the coordinator awaits all shards together and pays
        max(shard costs), not the sum."""
        raise NotImplementedError

    def fetch_result(self, handle) -> dict:
        """Await one ``fetch_submit`` handle -> the shard's fetch block."""
        raise NotImplementedError

    def set_peers(self, endpoints: list["ShardEndpoint"]) -> None:
        """(Re)wire this shard's peer links for shard-to-shard streaming
        (rebuild, migration, ingest exchange).  Idempotent — called again
        after every elastic grow/shrink."""
        raise NotImplementedError

    def close(self) -> None:
        """Release transport resources (base: no-op)."""
        pass

    # -- shared convenience ----------------------------------------------
    def stats(self) -> dict:
        """The shard's full telemetry snapshot (``ShardService.stats``)."""
        return self.call("stats")

    def rpc_calls(self) -> int:
        """Total host-side commands issued to this shard (fig25)."""
        return sum(s.calls for s in self.method_stats.values())


class LocalShardEndpoint(ShardEndpoint):
    """In-process shard: direct ``ShardService`` dispatch, zero-copy.

    Keeps the same per-method call accounting the RoP link keeps, so a
    local array and a remote array report identically in ``stats``."""

    def __init__(self, store: GraphStore | None = None, *,
                 dev: BlockDevice | None = None, h_threshold: int = 128,
                 feature_dim: int = 0):
        from ..rpc.client import ClientStats      # shared stub accounting
        self.service = ShardService(
            store or GraphStore(dev or BlockDevice(),
                                h_threshold=h_threshold,
                                feature_dim=feature_dim))
        self._stats = ClientStats()

    @property
    def local_store(self) -> GraphStore:
        """The wrapped in-process ``GraphStore`` (tests/admin)."""
        return self.service.store

    @property
    def method_stats(self) -> dict:
        """Per-method call accounting (same shape as the RoP client's)."""
        return self._stats.method_stats

    def call(self, method: str, **kw):
        """Direct ``ShardService`` dispatch with RoP-identical per-method
        accounting; ``stats`` results gain the same ``rpc`` injection the
        remote RPC server performs."""
        t0 = time.perf_counter()
        ok = True
        try:
            out = getattr(self.service, method)(**kw)
        except Exception:
            ok = False
            raise
        finally:
            self._stats.record(method, time.perf_counter() - t0, ok)
        if method == "stats":                 # mirror RPCServer's injection
            out["rpc"] = self._stats.stats_snapshot()
        return out

    def call_submit(self, method: str, **kw):
        """In-process "submission" computes immediately — device latency
        is deferred into ``io_us`` where it matters, so awaiting N local
        shards still costs max(shard costs)."""
        return self.call(method, **kw)

    def call_result(self, handle):
        """Handles ARE results in-process."""
        return handle

    def fetch_submit(self, **kw):
        """Batched read, computed inline (see ``call_submit``)."""
        return self.call("fetch", pack=False, **kw)

    def fetch_result(self, handle) -> dict:
        """Handles ARE results in-process."""
        return handle

    def set_peers(self, endpoints) -> None:
        """Wire direct in-process peer links (RoP peers get a real
        peer-queue client).  Idempotent."""
        self.service.peers = [
            _DirectPeer(ep.service) if isinstance(ep, LocalShardEndpoint)
            else ep.peer_link() for ep in endpoints]


class ShardHost:
    """Device side of one REMOTE CSSD shard: a ``GraphStore`` behind an
    ``RPCServer``, drained from its own ``MultiQueueRoP`` by a firmware
    poll thread — the per-shard half of the paper's RoP link."""

    def __init__(self, dev: BlockDevice | None = None, *,
                 h_threshold: int = 128, feature_dim: int = 0,
                 n_queues: int = 2, queue_depth: int = 64):
        from ..rpc import MultiQueueRoP, RPCServer
        self.service = ShardService(GraphStore(dev or BlockDevice(),
                                               h_threshold=h_threshold,
                                               feature_dim=feature_dim))
        self.server = RPCServer(self.service)
        self.rop = MultiQueueRoP(n_queues=n_queues, depth=queue_depth)
        self.service.rop = self.rop       # queue pressure visible in counters
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        """Launch the firmware poll thread (idempotent)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def poll():
            from ..rpc.transport import serialize
            while not self._stop.is_set():
                got = self.rop.pop_submission(timeout=0.05)
                if got is None:
                    continue
                qid, cmd_id, packet = got
                try:
                    reply = self.server.handle(packet)
                except Exception as e:  # noqa: BLE001 — reply-path fault:
                    # the host must stay up and the waiter must wake, or
                    # one bad reply wedges every later command on this
                    # shard (serialization faults surface to the caller)
                    reply = serialize({"ok": False,
                                       "error": f"{type(e).__name__}: {e}"})
                self.rop.post_completion(qid, cmd_id, reply)

        self._thread = threading.Thread(target=poll, name="shard-host",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Signal and join the poll thread."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


class RopShardEndpoint(ShardEndpoint):
    """One shard behind a real RoP link: every command serialized over a
    dedicated SQ/CQ pair + PCIeChannel mmap buffers to the shard host's
    poll thread.  ``fetch_submit`` writes the command and returns; the
    coordinator awaits all shards' completions together and pays
    max(shard costs) — batched-read concurrency across hosts."""

    def __init__(self, host: ShardHost, *, qid: int = 0, peer_qid: int = 1):
        from ..rpc import AsyncRPCClient
        from ..rpc.transport import PCIeChannel
        self.host = host                  # lifecycle + peer wiring only
        self._peer_qid = peer_qid
        self.client = AsyncRPCClient(host.rop, qid,
                                     tx=PCIeChannel(), rx=PCIeChannel())
        host.start()

    @property
    def method_stats(self) -> dict:
        """Per-method call accounting from the RoP client stub."""
        return self.client.method_stats

    def _map_error(self, e: RuntimeError):
        if str(getattr(e, "remote_error", "")) \
                .startswith("DeviceFailedError"):
            raise DeviceFailedError(str(e)) from e
        raise e

    def call(self, method: str, **kw):
        """One synchronous command over the RoP link (remote
        ``DeviceFailedError`` re-raised as the typed local exception)."""
        try:
            return self.client.call(method, **kw)
        except RuntimeError as e:
            self._map_error(e)

    def call_submit(self, method: str, **kw):
        """Write one command into the SQ and return its handle."""
        return self.client.submit(method, **kw)

    def call_result(self, handle):
        """Await one submitted command's CQ completion."""
        try:
            return self.client.result(handle)
        except RuntimeError as e:
            self._map_error(e)

    def fetch_submit(self, **kw):
        """Submit one packed batched-read command (awaited via
        ``fetch_result``; plans travel packed over the wire)."""
        return self.client.submit("fetch", pack=True, **kw)

    def fetch_result(self, handle) -> dict:
        """Await a fetch completion and unpack its plan descriptor."""
        try:
            out = self.client.result(handle)
        except RuntimeError as e:
            self._map_error(e)
        if out["desc"] is not None:
            out["desc"] = unpack_plan(out["desc"])
        return out

    def peer_link(self):
        """A client another shard host can pull rebuild chunks through —
        its own queue pair on this shard's RoP, so peer traffic never
        contends with the coordinator's command queue."""
        from ..rpc import AsyncRPCClient
        from ..rpc.transport import PCIeChannel
        return AsyncRPCClient(self.host.rop,
                              self._peer_qid % len(self.host.rop.pairs),
                              tx=PCIeChannel(), rx=PCIeChannel())

    def set_peers(self, endpoints) -> None:
        """Wire this shard host's peer clients (one queue-pair client
        per RoP peer, direct dispatch to local peers).  Idempotent."""
        self.host.service.peers = [
            _DirectPeer(ep.service) if isinstance(ep, LocalShardEndpoint)
            else ep.peer_link() for ep in endpoints]

    def channel_bytes(self) -> int:
        """Bytes moved over THIS endpoint's coordinator link (both
        directions) — what the rebuild-streaming test bounds."""
        return (self.client.tx.stats.bytes_moved
                + self.client.rx.stats.bytes_moved)

    def close(self) -> None:
        """Stop the shard host's poll thread."""
        self.host.stop()


# -------------------------------------------------------------- builders
def make_local_endpoints(n_shards: int, devs: list | None = None, *,
                         h_threshold: int = 128,
                         feature_dim: int = 0) -> list[LocalShardEndpoint]:
    """An in-process CSSD array: one ``LocalShardEndpoint`` per shard
    over fresh (or caller-provided) simulated devices."""
    devs = devs or [BlockDevice() for _ in range(n_shards)]
    return [LocalShardEndpoint(dev=d, h_threshold=h_threshold,
                               feature_dim=feature_dim) for d in devs]


def make_rop_endpoints(n_shards: int, devs: list | None = None, *,
                       h_threshold: int = 128, feature_dim: int = 0,
                       n_queues: int = 2,
                       queue_depth: int = 64) -> list[RopShardEndpoint]:
    """A multi-host CSSD array: one ``ShardHost`` (own RoP SQ/CQ pairs +
    poll thread) per shard, fronted by ``RopShardEndpoint`` stubs."""
    devs = devs or [BlockDevice() for _ in range(n_shards)]
    eps = [RopShardEndpoint(ShardHost(d, h_threshold=h_threshold,
                                      feature_dim=feature_dim,
                                      n_queues=n_queues,
                                      queue_depth=queue_depth))
           for d in devs]
    for ep in eps:
        ep.set_peers(eps)
        ep._peers_wired = True
    return eps
