"""Device-DRAM page cache — hot embedding (and adjacency) pages pinned near
the accelerator, fronting GraphStore's batched scatter-reads.

The paper's CSSD keeps its DRAM close to the FPGA user logic; at serving
time the same hot vertices recur across requests (power-law access), so a
bounded LRU over 4 KB pages turns most of a warm request's embedding gather
into DRAM hits instead of flash commands.

The structure mirrors what the FPGA would hold in BRAM/DRAM, and is fully
vectorized — a whole scatter-read resolves with array ops, no per-page
Python:

  * a page **slab** ``(capacity, SLOTS_PER_PAGE)`` holding cached page data;
  * an LPN -> slot **mapping table** (dense ndarray over the device's LPN
    space, grown on demand) giving O(batch) vectorized lookup;
  * per-slot **last-use ticks** (one tick per read call) driving batched
    LRU eviction: when a read needs more slots than are free, the least
    recently used slots are reclaimed in one ``argpartition``.

Mechanics:

  * ``read_pages`` is a drop-in for ``BlockDevice.read_pages``: hits are
    gathered from the slab, the misses of one request are fetched with ONE
    queued dev.read_pages (the PR-1 fast path is preserved) and inserted;
  * invalidation is hooked at the device write layer (``BlockDevice.on_write``
    fires for every ``write_page``/``write_span``/``free_page`` and for the
    page-relocating ``_grow``), so every mutable-graph path — unit updates,
    L-page splits, H promotions, embedding RMWs — drops exactly the pages it
    touched and serving stays correct without per-call-site bookkeeping;
  * hit/miss/byte counters are exposed through ``GraphStoreStats.cache``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from ..concurrency import witness_lock
from .blockdev import PAGE_BYTES, SLOTS_PER_PAGE, SLOT_DTYPE


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    bytes_from_cache: int = 0
    bytes_from_dev: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "bytes_from_cache": self.bytes_from_cache,
                "bytes_from_dev": self.bytes_from_dev,
                "hit_rate": self.hit_rate}


class EmbeddingPageCache:
    """Bounded LRU page cache: slab + dense LPN->slot table (thread-safe)."""

    def __init__(self, capacity_pages: int = 4096):
        if capacity_pages < 1:
            raise ValueError("capacity must be at least one page")
        self.capacity = int(capacity_pages)
        self._slab = np.empty((self.capacity, SLOTS_PER_PAGE), SLOT_DTYPE)
        self._slot_lpn = np.full(self.capacity, -1, np.int64)  # slot -> lpn
        self._last_use = np.zeros(self.capacity, np.int64)     # slot -> tick
        self._lpn_slot = np.full(1024, -1, np.int64)           # lpn -> slot
        self._free: list[int] = list(range(self.capacity))
        self._tick = 0
        self._lock = witness_lock("embcache._lock", threading.RLock())
        self.stats = CacheStats()

    def __len__(self) -> int:
        return self.capacity - len(self._free)

    def _table_for(self, max_lpn: int) -> np.ndarray:
        if max_lpn >= len(self._lpn_slot):
            grown = np.full(max(max_lpn + 1, 2 * len(self._lpn_slot)), -1,
                            np.int64)
            grown[: len(self._lpn_slot)] = self._lpn_slot
            self._lpn_slot = grown
        return self._lpn_slot

    def read_pages(self, dev, lpns, *, tag: str = "embed") -> np.ndarray:
        """Cache-fronted batched scatter-read -> (len(lpns), SLOTS_PER_PAGE)."""
        lpns = np.asarray(lpns, dtype=np.int64).reshape(-1)
        if not len(lpns):
            return np.empty((0, SLOTS_PER_PAGE), SLOT_DTYPE)
        with self._lock:
            self._tick += 1
            table = self._table_for(int(lpns.max()))
            slots = table[lpns]
            hit = slots >= 0
            n_hit = int(hit.sum())
            block = np.empty((len(lpns), SLOTS_PER_PAGE), SLOT_DTYPE)
            if n_hit:
                block[hit] = self._slab[slots[hit]]
                self._last_use[slots[hit]] = self._tick
            self.stats.hits += n_hit
            self.stats.bytes_from_cache += n_hit * PAGE_BYTES
            miss = ~hit
            n_miss = len(lpns) - n_hit
            if n_miss:
                miss_lpns = lpns[miss]
                fetched = dev.read_pages(miss_lpns, tag=tag)
                block[miss] = fetched
                self.stats.misses += n_miss
                self.stats.bytes_from_dev += n_miss * PAGE_BYTES
                self._insert(miss_lpns, fetched)
        return block

    def _insert(self, lpns: np.ndarray, pages: np.ndarray) -> None:
        """Install fetched pages; batched LRU eviction frees slots needed.

        ``lpns`` may exceed capacity (a scan bigger than the cache): only
        the trailing ``capacity`` pages are kept — the rest would be evicted
        within this very call anyway.
        """
        if len(lpns) > self.capacity:
            lpns, pages = lpns[-self.capacity:], pages[-self.capacity:]
        need = len(lpns) - len(self._free)
        if need > 0:                          # reclaim the LRU slots in bulk
            used = np.nonzero(self._slot_lpn >= 0)[0]
            order = np.argpartition(self._last_use[used], need - 1)[:need]
            victims = used[order]
            self._lpn_slot[self._slot_lpn[victims]] = -1
            self._slot_lpn[victims] = -1
            self._free.extend(victims.tolist())
            self.stats.evictions += need
        slots = np.array([self._free.pop() for _ in range(len(lpns))],
                         dtype=np.int64)
        self._slab[slots] = pages
        self._slot_lpn[slots] = lpns
        self._last_use[slots] = self._tick
        self._lpn_slot[lpns] = slots

    def clone_empty(self) -> "EmbeddingPageCache":
        """A fresh, cold cache with this cache's capacity — the rebuild
        path attaches one to a failed shard's replacement device (the old
        device's DRAM, and thus its cache contents, died with it)."""
        return EmbeddingPageCache(self.capacity)

    def invalidate(self, lpn0: int, n_pages: int = 1) -> None:
        """Drop [lpn0, lpn0 + n_pages) — the device-write hook."""
        with self._lock:
            lo = min(lpn0, len(self._lpn_slot))
            hi = min(lpn0 + n_pages, len(self._lpn_slot))
            if lo >= hi:
                return
            slots = self._lpn_slot[lo:hi]
            doomed = slots[slots >= 0]
            if len(doomed):
                self._slot_lpn[doomed] = -1
                self._lpn_slot[lo:hi] = -1
                self._free.extend(doomed.tolist())
                self.stats.invalidations += len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._lpn_slot[:] = -1
            self._slot_lpn[:] = -1
            self._free = list(range(self.capacity))
