from .blockdev import BlockDevice, PAGE_BYTES, SLOTS_PER_PAGE
from .graphstore import GraphStore, preprocess_edges
from .sharded import ShardedGraphStore, partition_csr
from .sampler import (sample_batch, sample_batch_ref, pad_batch,
                      SampledBatch, LayerBlock)

__all__ = ["BlockDevice", "PAGE_BYTES", "SLOTS_PER_PAGE", "GraphStore",
           "ShardedGraphStore", "partition_csr",
           "preprocess_edges", "sample_batch", "sample_batch_ref",
           "pad_batch", "SampledBatch", "LayerBlock"]
