from .blockdev import BlockDevice, PAGE_BYTES, SLOTS_PER_PAGE
from .graphstore import GraphStore, preprocess_edges
from .sampler import (sample_batch, sample_batch_ref, pad_batch,
                      SampledBatch, LayerBlock)

__all__ = ["BlockDevice", "PAGE_BYTES", "SLOTS_PER_PAGE", "GraphStore",
           "preprocess_edges", "sample_batch", "sample_batch_ref",
           "pad_batch", "SampledBatch", "LayerBlock"]
