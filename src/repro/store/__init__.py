from .blockdev import (BlockDevice, DeviceFailedError, PAGE_BYTES,
                       SLOTS_PER_PAGE)
from .graphstore import (GraphStore, bucket_pairs, csr_from_pairs,
                         mirror_edges, preprocess_edges)
from .endpoint import (LocalShardEndpoint, RopShardEndpoint, ShardEndpoint,
                       ShardHost, ShardService, make_local_endpoints,
                       make_rop_endpoints)
from .ingest import MutationFirehose, distributed_update_graph
from .sharded import (FlowControl, ReplicatedGraphStore, ShardedGraphStore,
                      partition_csr)
from .sampler import (sample_batch, sample_batch_ref, pad_batch,
                      SampledBatch, LayerBlock)

__all__ = ["BlockDevice", "DeviceFailedError", "PAGE_BYTES",
           "SLOTS_PER_PAGE", "GraphStore", "ShardedGraphStore",
           "ReplicatedGraphStore", "FlowControl", "partition_csr",
           "ShardEndpoint", "ShardService", "LocalShardEndpoint",
           "RopShardEndpoint", "ShardHost", "make_local_endpoints",
           "make_rop_endpoints",
           "preprocess_edges", "mirror_edges", "bucket_pairs",
           "csr_from_pairs", "MutationFirehose", "distributed_update_graph",
           "sample_batch", "sample_batch_ref",
           "pad_batch", "SampledBatch", "LayerBlock"]
