"""GraphStore — graph-centric archiving on a page block device (paper §4.1).

Implements the paper's dual mapping:

  * **H-type** (high-degree vertices): per-vertex chain of pages, each page
    ``[count, next_lpn, n0, n1, ...]``.  The mapping table entry is
    VID -> head LPN (we additionally keep a tail pointer so appends are O(1),
    reads still walk the chain as in the paper).
  * **L-type** (low-degree vertices): many vertices packed in one page.
    Neighbor chunks grow from slot 0; meta grows from the page end:
    ``slot[-1]=n_nodes, slot[-2]=data_len,`` then per node *i*
    ``slot[-3-2i]=vid, slot[-4-2i]=chunk_offset``.  The L-type table key is
    the *largest* VID stored in the page (range search, paper Fig. 8).
  * **gmap**: VID -> {H, L} selector bitmap.

Embeddings are stored sequentially in the *embedding space* (top of the
device, paper Fig. 7) with no page-level mapping: the location of VID *v*'s
feature row is computed from ``v`` directly.

Bulk ingest (``update_graph``) overlaps graph preprocessing (edge array ->
undirected, self-looped, sorted adjacency) with the heavy embedding-table
write, reproducing the paper's Fig. 18 behaviour: from the user's viewpoint
the bulk latency ~= data transfer + embedding write.
"""
from __future__ import annotations

import bisect
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from ..concurrency import witness_lock
from .blockdev import BlockDevice, SLOTS_PER_PAGE, SLOT_DTYPE
from .sampler import _ramp

# H-type page layout
_H_COUNT, _H_NEXT, _H_DATA = 0, 1, 2
H_CAP = SLOTS_PER_PAGE - _H_DATA          # neighbors per H page

# L-type page layout (meta from the end)
_L_NNODES = SLOTS_PER_PAGE - 1
_L_DATALEN = SLOTS_PER_PAGE - 2


def _l_meta_vid(i: int) -> int:
    return SLOTS_PER_PAGE - 3 - 2 * i


def _l_meta_off(i: int) -> int:
    return SLOTS_PER_PAGE - 4 - 2 * i


def neighbors_from_plan(vids_arr: np.ndarray, block, desc) -> list[np.ndarray]:
    """Materialise per-vid neighbor arrays out of a fetched plan.

    Shared back half of the batched GetNeighbors: the single-device store
    feeds it one ``_fetch_plan`` result; the sharded coordinator feeds it a
    recomposition of N per-shard plans (descriptor rows re-based into the
    concatenated block) — either way the output equals ``get_neighbors``
    per vid.
    """
    out: list = [None] * len(vids_arr)
    for pos, d in enumerate(desc):
        if d is None:
            out[pos] = np.empty(0, dtype=SLOT_DTYPE)
        elif d[0] == "L":
            _, row, start, end = d
            out[pos] = block[row, start:end].copy()
        else:
            _, rows, counts = d
            got = [block[r, _H_DATA: _H_DATA + int(c)]
                   for r, c in zip(rows, counts)]
            out[pos] = (np.concatenate(got) if got
                        else np.empty(0, dtype=SLOT_DTYPE))
    return out


def select_from_plan(vids_arr: np.ndarray, block, desc, fanout: int,
                     rng: np.random.Generator | None = None, *,
                     segments=None, rngs=None):
    """Fanout selection over a fetched plan — the back half of the fused
    near-storage sample (see ``GraphStore.sample_neighbors_batch``).

    A pure function of (plan, rng): hubs are Floyd-sampled BY INDEX against
    their chain page counts, uniform draws are consumed one ``fanout``
    block per over-full vertex in frontier order (or per-request segment
    order when ``segments``/``rngs`` are given).  The sharded coordinator
    recomposes N per-shard plans into one global (block, desc) and runs
    this same code, which is why an N-shard sample is bit-identical to the
    single-device sample under the same seed.
    """
    flatb = block.reshape(-1) if block is not None else None
    npos = len(vids_arr)

    # numeric plan arrays (pure-int loop; all math below is vector)
    lens = np.zeros(npos, dtype=np.int64)
    is_l = np.zeros(npos, dtype=bool)
    base = np.zeros(npos, dtype=np.int64)   # L: flat addr of chunk
    for pos, d in enumerate(desc):
        if d is None:
            continue
        if d[0] == "L":
            is_l[pos] = True
            lens[pos] = d[3] - d[2]
            base[pos] = d[1] * SLOTS_PER_PAGE + d[2]
        else:
            lens[pos] = int(d[2].sum())
    over = lens > fanout
    lens_sel = np.where(lens == 0, 1, np.minimum(lens, fanout))
    out_offs = np.concatenate([[0], np.cumsum(lens_sel)[:-1]])
    sel = np.empty(int(lens_sel.sum()), dtype=SLOT_DTYPE)

    # degenerate rows: self-loop
    empty = lens == 0
    sel[out_offs[empty]] = vids_arr[empty]

    # under-full rows copied through (one flat gather; H multi-chunk
    # under-full rows are rare — degree <= fanout but H-mapped)
    for cls in np.nonzero(~over & ~empty & ~is_l)[0]:
        _, rows, counts = desc[cls]
        o, c0 = int(out_offs[cls]), 0
        for r, c in zip(rows, counts):
            sel[o + c0: o + c0 + int(c)] = \
                block[r, _H_DATA: _H_DATA + int(c)]
            c0 += int(c)
    ul = ~over & ~empty & is_l
    if ul.any():
        lv = lens[ul]
        src = np.repeat(base[ul], lv) + _ramp(lv)
        sel[np.repeat(out_offs[ul], lv) + _ramp(lv)] = flatb[src]

    # over-full rows: Floyd by index, vectorized across the frontier
    # (k steps of whole-row vector math, no per-vertex python)
    n_over = int(over.sum())
    if n_over:
        if rngs is not None:
            bounds = np.concatenate([[0], np.cumsum(segments)])
            parts = [g.random(int(over[bounds[s]: bounds[s + 1]]
                                  .sum()) * fanout)
                     for s, g in enumerate(rngs)]
            u = np.concatenate(parts).reshape(-1, fanout)
        else:
            u = rng.random(n_over * fanout).reshape(-1, fanout)
        m_arr = lens[over]
        idx = np.empty((n_over, fanout), dtype=np.int64)
        for j2 in range(fanout):
            t = (u[:, j2] * (m_arr - fanout + j2 + 1)).astype(np.int64)
            if j2:
                dup = (idx[:, :j2] == t[:, None]).any(axis=1)
                t = np.where(dup, m_arr - fanout + j2, t)
            idx[:, j2] = t
        over_pos = np.nonzero(over)[0]
        ol = over & is_l
        if ol.any():
            ol_in_over = is_l[over_pos]
            src = base[ol][:, None] + idx[ol_in_over]
            dst = out_offs[ol][:, None] + np.arange(fanout)[None, :]
            sel[dst.reshape(-1)] = flatb[src.reshape(-1)]
        for r_i, cls in enumerate(over_pos):
            if is_l[cls]:
                continue
            _, rows, counts = desc[cls]      # hub: index by page
            cum = np.cumsum(counts)
            p = np.searchsorted(cum, idx[r_i], side="right")
            off = idx[r_i] - np.where(p > 0, cum[p - 1], 0)
            o = int(out_offs[cls])
            sel[o: o + fanout] = block[rows[p], _H_DATA + off]
    return sel, lens_sel


@dataclass
class BulkTimeline:
    """Timestamped phase spans of a bulk ingest (for Fig. 18)."""
    transfer: tuple[float, float] = (0.0, 0.0)
    graph_pre: tuple[float, float] = (0.0, 0.0)
    write_feature: tuple[float, float] = (0.0, 0.0)
    write_graph: tuple[float, float] = (0.0, 0.0)
    total: float = 0.0
    user_visible: float = 0.0     # transfer + embedding write (+ graph flush tail)


@dataclass
class GraphStoreStats:
    l_evictions: int = 0
    unit_updates: int = 0
    pages_h: int = 0
    pages_l: int = 0
    bulk: BulkTimeline = field(default_factory=BulkTimeline)
    cache: object | None = None   # CacheStats once a page cache is attached


class GraphStore:
    def __init__(self, dev: BlockDevice | None = None, *, h_threshold: int = 128,
                 feature_dim: int = 0):
        self.dev = dev or BlockDevice()
        self.h_threshold = int(h_threshold)
        self.gmap: dict[int, str] = {}                 # vid -> 'H' | 'L'
        self.h_table: dict[int, tuple[int, int]] = {}  # vid -> (head_lpn, tail_lpn)
        # full chain LPN list per H vid (device-DRAM mapping metadata, like
        # the tail pointer): lets batched GetNeighbors fetch whole chains in
        # one queued read instead of one pointer-chase round per page.
        self.h_chain: dict[int, list[int]] = {}
        self._l_keys: list[int] = []                   # sorted max-vid per L page
        self._l_lpns: list[int] = []                   # parallel LPN list
        self.feature_dim = int(feature_dim)
        self._emb_base: int | None = None              # first LPN of embedding span
        self._emb_rows = 0
        self.num_vertices = 0
        self.stats = GraphStoreStats()
        self._free_vids: list[int] = []                # deleted VIDs, reused (paper)
        self._lock = witness_lock("graphstore._lock", threading.RLock())
        self.cache = None                              # device-DRAM page cache
        self._cache_graph = True
        # device growth relocates the embedding space to the new top; the
        # table base must shift with it (without this, a neighbor-space
        # grow AFTER bulk ingest leaves _emb_base pointing at the zeroed
        # old span and every later embedding read returns garbage)
        self.dev.on_grow = self._on_dev_grow

    def _on_dev_grow(self, extra_pages: int) -> None:
        if self._emb_base is not None:
            self._emb_base += extra_pages

    def attach_cache(self, cache, *, cache_graph_pages: bool = True) -> None:
        """Front batched page reads with a device-DRAM LRU (serving hot set).

        Invalidation rides the device's write hook, so every mutable-graph
        path (unit updates, splits, promotions, embedding RMWs, device
        growth) drops exactly the pages it dirtied.
        """
        self.cache = cache
        self._cache_graph = cache_graph_pages
        self.stats.cache = cache.stats
        self.dev.on_write = cache.invalidate

    def attach_cache_pages(self, capacity_pages: int,
                           **kw) -> None:
        """Attach a fresh device-DRAM page cache of ``capacity_pages``
        (uniform entry point with the sharded store, which splits the
        budget across its shards' devices)."""
        from .embcache import EmbeddingPageCache
        self.attach_cache(EmbeddingPageCache(capacity_pages), **kw)

    def _read_pages_cached(self, lpns, tag: str) -> np.ndarray:
        if self.cache is not None and (tag == "embed" or self._cache_graph):
            return self.cache.read_pages(self.dev, lpns, tag=tag)
        return self.dev.read_pages(lpns, tag=tag)

    # ================================================================= helpers
    def _classify(self, degree: int) -> str:
        return "H" if degree > self.h_threshold else "L"

    def _new_l_page(self) -> tuple[int, np.ndarray]:
        lpn = self.dev.alloc_front()
        page = np.zeros(SLOTS_PER_PAGE, dtype=SLOT_DTYPE)
        self.stats.pages_l += 1
        return lpn, page

    @staticmethod
    def _l_free_slots(page: np.ndarray) -> int:
        n, dlen = int(page[_L_NNODES]), int(page[_L_DATALEN])
        return SLOTS_PER_PAGE - 2 - 2 * n - dlen

    @staticmethod
    def _l_scan(page: np.ndarray, vid: int) -> tuple[int, int, int] | None:
        """Return (meta_index, chunk_start, chunk_len) of vid in an L page
        (vectorized: the FPGA scans page meta in hardware; a Python loop
        here would dominate every near-storage GetNeighbors)."""
        n, dlen = int(page[_L_NNODES]), int(page[_L_DATALEN])
        if n == 0:
            return None
        vid_idx = _L_NNODES - 2 - 2 * np.arange(n)      # slot of meta vid i
        vids = page[vid_idx]
        offs = page[vid_idx - 1]
        hit = np.nonzero(vids == vid)[0]
        if not len(hit):
            return None
        i = int(hit[0])
        start = int(offs[i])
        later = offs[(offs > start) & (offs <= dlen)]
        # chunk end = smallest offset beyond start (tombstones included —
        # their offsets remain valid boundaries) or the data length.
        end = int(later.min()) if len(later) else dlen
        return i, start, end - start

    def _l_lookup_page(self, vid: int) -> tuple[int, np.ndarray] | None:
        """Range search the L table (paper Fig. 8): first key >= vid."""
        k = bisect.bisect_left(self._l_keys, vid)
        if k == len(self._l_keys):
            return None
        lpn = self._l_lpns[k]
        return lpn, self.dev.read_page(lpn).copy()

    # ============================================================ bulk ingest
    def update_graph(self, edge_array: np.ndarray,
                     embeddings: np.ndarray | None = None,
                     *, already_undirected: bool = False) -> BulkTimeline:
        """Paper's UpdateGraph(EdgeArray, Embeddings) bulk RPC.

        Overlaps adjacency-list conversion with the (much larger) embedding
        write by running them on two threads, as GraphStore overlaps the
        conversion compute with the storage write burst.
        """
        tl = BulkTimeline()
        t0 = time.perf_counter()

        # --- "transfer": the edge array + embedding list arriving over RoP.
        # No defensive copy: preprocess_edges never mutates its input, so
        # the only allocation is the dtype conversion asarray may need —
        # peak host memory stays one edge array, not two.
        edge_array = np.asarray(edge_array, dtype=np.int64).reshape(-1, 2)
        if embeddings is not None:
            embeddings = np.ascontiguousarray(embeddings, dtype=np.float32)
        tl.transfer = (0.0, time.perf_counter() - t0)

        csr_box: dict = {}

        def graph_pre():
            s = time.perf_counter() - t0
            csr_box["csr"] = preprocess_edges(
                edge_array, already_undirected=already_undirected)
            csr_box["span"] = (s, time.perf_counter() - t0)

        def write_feature():
            s = time.perf_counter() - t0
            if embeddings is not None:
                self._write_embedding_table(embeddings)
            csr_box["wf"] = (s, time.perf_counter() - t0)

        th_g = threading.Thread(target=graph_pre)
        th_f = threading.Thread(target=write_feature)
        th_g.start(); th_f.start()
        th_f.join()
        user_visible_at = time.perf_counter() - t0     # embedding write done
        th_g.join()

        tl.graph_pre = csr_box["span"]
        tl.write_feature = csr_box.get("wf", (0.0, 0.0))

        # --- flush adjacency pages (small vs embeddings; paper Fig. 18c)
        s = time.perf_counter() - t0
        indptr, indices = csr_box["csr"]
        self._write_adjacency(indptr, indices)
        tl.write_graph = (s, time.perf_counter() - t0)

        tl.total = time.perf_counter() - t0
        tl.user_visible = max(user_visible_at, tl.transfer[1])
        self.stats.bulk = tl
        return tl

    def _write_embedding_table(self, embeddings: np.ndarray) -> None:
        n, d = embeddings.shape
        if self.feature_dim and d != self.feature_dim:
            raise ValueError(f"feature dim {d} != store dim {self.feature_dim}")
        self.feature_dim = d
        flat = embeddings.reshape(-1).view(np.int32)
        n_pages = -(-flat.size // SLOTS_PER_PAGE)
        base = self.dev.alloc_back(n_pages)
        self.dev.write_span(base, flat, tag="embed")
        self._emb_base = base
        self._emb_rows = n

    def _write_adjacency(self, indptr: np.ndarray, indices: np.ndarray) -> None:
        n = len(indptr) - 1
        degrees = np.diff(indptr)
        self.num_vertices = max(self.num_vertices, n)

        h_vids = np.nonzero(degrees > self.h_threshold)[0]
        l_vids = np.nonzero((degrees > 0) & (degrees <= self.h_threshold))[0]

        # ---- H-type: per-vertex page chains
        for vid in h_vids:
            nbrs = indices[indptr[vid]: indptr[vid + 1]]
            self.gmap[int(vid)] = "H"
            self._write_h_chain(int(vid), nbrs)

        # ---- L-type: greedy packing in ascending VID order (cumsum splits)
        if len(l_vids):
            sizes = degrees[l_vids] + 2                      # data + 2 meta slots
            csum = np.concatenate([[0], np.cumsum(sizes)])
            cap = SLOTS_PER_PAGE - 2
            start = 0
            while start < len(l_vids):
                hi = np.searchsorted(csum, csum[start] + cap, side="right") - 1
                hi = max(hi, start + 1)                       # at least one node
                lpn, page = self._new_l_page()
                dlen = 0
                cnt = 0
                for vid in l_vids[start:hi]:
                    nbrs = indices[indptr[vid]: indptr[vid + 1]]
                    page[_l_meta_vid(cnt)] = vid
                    page[_l_meta_off(cnt)] = dlen
                    page[dlen: dlen + len(nbrs)] = nbrs
                    dlen += len(nbrs)
                    cnt += 1
                    self.gmap[int(vid)] = "L"
                page[_L_NNODES] = cnt
                page[_L_DATALEN] = dlen
                self.dev.write_page(lpn, page)
                self._l_keys.append(int(l_vids[hi - 1]))
                self._l_lpns.append(lpn)
                start = hi

    # ================================================================ queries
    def get_neighbors(self, vid: int) -> np.ndarray:
        """Paper GetNeighbors(VID) unit RPC."""
        with self._lock:
            kind = self.gmap.get(int(vid))
            if kind is None:
                return np.empty(0, dtype=SLOT_DTYPE)
            if kind == "H":
                out = []
                lpn, _ = self.h_table[int(vid)]
                while lpn >= 0:
                    page = self.dev.read_page(lpn)
                    cnt = int(page[_H_COUNT])
                    out.append(page[_H_DATA: _H_DATA + cnt].copy())
                    lpn = int(page[_H_NEXT])
                return np.concatenate(out) if out else np.empty(0, dtype=SLOT_DTYPE)
            hit = self._l_lookup_page(vid)
            if hit is None:
                return np.empty(0, dtype=SLOT_DTYPE)
            _, page = hit
            found = self._l_scan(page, int(vid))
            if found is None:
                return np.empty(0, dtype=SLOT_DTYPE)
            _, start, ln = found
            return page[start: start + ln].copy()

    def _fetch_plan(self, vids_arr: np.ndarray):
        """Shared front half of the batched near-storage queries.

        Plans the whole request from the in-DRAM mapping tables (L range
        table + H chain lists), fetches every needed page with a single
        queued scatter-read, and locates each vid's data:

        Returns ``(block, desc)`` with ``desc[i]`` one of
          * ``None``                       — unknown vid,
          * ``("L", row, start, end)``     — chunk slice of ``block[row]``,
          * ``("H", rows, counts)``        — chain page rows + chunk counts.
        """
        h_items: list[tuple[int, int]] = []     # (position, vid)
        l_pos: list[int] = []
        l_vids: list[int] = []
        desc: list = [None] * len(vids_arr)
        for pos, v in enumerate(vids_arr.tolist()):
            kind = self.gmap.get(v)
            if kind == "H":
                h_items.append((pos, v))
            elif kind == "L":
                l_pos.append(pos)
                l_vids.append(v)

        keys = np.asarray(self._l_keys, dtype=np.int64)
        lq = np.asarray(l_vids, dtype=np.int64)
        k = np.searchsorted(keys, lq)           # first key >= vid
        miss = k == len(keys)
        l_lpns = sorted({self._l_lpns[ki] for ki in k[~miss].tolist()})
        h_lpns = sorted({lpn for _, vid in h_items
                         for lpn in self.h_chain[vid]})

        lpns = l_lpns + h_lpns                  # ONE queued scatter-read
        if not lpns:
            return None, desc
        block = self._read_pages_cached(lpns, "graph")
        row_of = {lpn: i for i, lpn in enumerate(lpns)}

        if len(lq):
            self._l_locate_batch(block, row_of, l_pos, lq, k, miss, desc)
        for pos, vid in h_items:
            rows = np.array([row_of[lpn] for lpn in self.h_chain[vid]],
                            dtype=np.int64)
            desc[pos] = ("H", rows, block[rows, _H_COUNT].astype(np.int64))
        return block, desc

    def get_neighbors_batch(self, vids) -> list[np.ndarray]:
        """Batched GetNeighbors — the near-storage fast path.

        One scatter-read serves the whole request (vs one page walk per
        VID): L-type vids share their owning pages' single vectorized meta
        scan, H-type chains are materialised straight from the fetched
        block — the batched-DMA behaviour of the FPGA's hardware
        GetNeighbors engine.

        Returns a list of neighbor arrays aligned with ``vids`` (empty array
        for unknown VIDs), each equal to ``get_neighbors(vid)``.
        """
        vids_arr = np.asarray(vids, dtype=np.int64).reshape(-1)
        block, desc = self.fetch_plan(vids_arr)
        return neighbors_from_plan(vids_arr, block, desc)

    def fetch_plan(self, vids_arr):
        """Locked plan fetch over vids this store holds — the *fetch* phase
        of the batched queries (one queued scatter-read).  The sharded
        coordinator calls this once per shard, concurrently; the returned
        block is a snapshot copy, so selection can run outside the lock.
        """
        with self._lock:
            return self._fetch_plan(
                np.asarray(vids_arr, dtype=np.int64).reshape(-1))

    def chain_pages(self, vids: np.ndarray, pgs: np.ndarray) -> np.ndarray:
        """Explicit H-chain page reads: page ``pgs[i]`` of ``vids[i]``'s
        chain, as ONE queued (cached) scatter-read.  The replicated
        coordinator's page-granular replica spread assigns individual
        chain pages to shards; this is the device-side command that
        serves a shard's share of them."""
        with self._lock:
            lpns = np.fromiter(
                (self.h_chain[int(v)][int(p)]
                 for v, p in zip(vids.tolist(), pgs.tolist())),
                dtype=np.int64, count=len(vids))
            return self._read_pages_cached(lpns, "graph")

    def plan_info(self, vids: np.ndarray) -> dict:
        """Planning metadata for a batch of vids, no page I/O: per-vid
        H-chain page count (0 when not H-mapped here) and the L-table
        range-search index (``searchsorted`` over the page keys; -1 when
        this store has no L pages).  Lets an array coordinator plan a
        replica-spread fetch with ONE call per vertex class instead of
        reaching into ``h_chain``/``_l_keys`` directly."""
        with self._lock:
            chain_len = np.fromiter(
                (len(self.h_chain.get(int(v), ())) for v in vids.tolist()),
                dtype=np.int64, count=len(vids))
            if self._l_keys:
                l_page = np.searchsorted(
                    np.asarray(self._l_keys, dtype=np.int64), vids)
            else:
                l_page = np.full(len(vids), -1, dtype=np.int64)
            return {"chain_len": chain_len,
                    "l_page": l_page.astype(np.int64)}

    def import_h_chain(self, vid: int, pages: np.ndarray) -> None:
        """Write a page-exact H chain from raw exported page data (slot
        layout and per-page counts preserved, next pointers re-addressed)
        — the import half of replica rebuild streaming.  Replicas keep
        IDENTICAL chain page layouts, which is what lets the spread fetch
        serve page i of a chain from any live owner.  Replace-safe: any
        chain the vid already owns is freed first, so a migration redo
        after a mid-copy failure cannot leak or double-map pages."""
        with self._lock:
            if self.gmap.get(vid) == "H":
                old, _ = self.h_table.pop(vid)
                self.h_chain.pop(vid, None)
                while old >= 0:
                    pg = self.dev.read_page(old)
                    nxt = int(pg[_H_NEXT])
                    self.dev.free_page(old)
                    old = nxt
            new_lpns = [self.dev.alloc_front() for _ in range(len(pages))]
            for i, lpn in enumerate(new_lpns):
                page = np.asarray(pages[i], dtype=SLOT_DTYPE).copy()
                page[_H_NEXT] = new_lpns[i + 1] if i + 1 < len(new_lpns) \
                    else -1
                self.dev.write_page(lpn, page)
            self.h_table[vid] = (new_lpns[0], new_lpns[-1])
            self.h_chain[vid] = new_lpns
            self.gmap[vid] = "H"
            self.stats.pages_h += len(new_lpns)

    def import_l_vertex(self, vid: int, nbrs: np.ndarray) -> None:
        """Install a complete L-type neighbor list for ``vid`` (the
        adjacency import half of class migration).  Replace-safe: any
        prior mapping — L node or H chain — is removed first, so a redo
        after a mid-copy failure converges to the same state."""
        with self._lock:
            vid = int(vid)
            kind = self.gmap.get(vid)
            if kind == "H":
                lpn, _ = self.h_table.pop(vid)
                self.h_chain.pop(vid, None)
                while lpn >= 0:
                    pg = self.dev.read_page(lpn)
                    nxt = int(pg[_H_NEXT])
                    self.dev.free_page(lpn)
                    lpn = nxt
                self.gmap.pop(vid, None)
            elif kind == "L":
                hit = self._l_lookup_page(vid)
                if hit is not None:
                    lpn, page = hit
                    self._l_remove_node(page, lpn, vid)
                self.gmap.pop(vid, None)
            chunk = np.asarray(nbrs, dtype=SLOT_DTYPE).reshape(-1)
            if not self._l_keys:
                self._l_insert_new_page([vid], [chunk])
            elif vid > self._l_keys[-1]:
                lpn = self._l_lpns[-1]
                page = self.dev.read_page(lpn).copy()
                if self._l_free_slots(page) >= len(chunk) + 2:
                    self._l_append_node(page, vid, chunk)
                    self.dev.write_page(lpn, page)
                    self._l_keys[-1] = vid
                else:
                    self._l_insert_new_page([vid], [chunk])
            else:
                k = bisect.bisect_left(self._l_keys, vid)
                self._l_split_insert(k, vid, chunk)
            self.gmap[vid] = "L"
            self.num_vertices = max(self.num_vertices, vid + 1)

    def drop_class(self, cls: int, modulus: int) -> int:
        """Free every vertex whose vid ≡ ``cls`` (mod ``modulus``) — the
        source-side release after a migrated class's routing flip commits.
        Embedding stripe pages are left in place (no longer addressed;
        the next rebuild compacts them).  Returns the vertex count
        dropped."""
        with self._lock:
            vids = [v for v in list(self.gmap) if v % modulus == cls]
            for v in vids:
                self._drop_vertex_pages(v)
            return len(vids)

    def extend_embedding_table(self, n_rows: int) -> int:
        """Grow the embedding table by ``n_rows`` zero rows and return the
        row index of the first new row (the migration import base).  The
        table is rewritten to a fresh span; the old span is abandoned
        (the simulated device reclaims it on the next rebuild)."""
        with self._lock:
            d = self.feature_dim
            if d == 0:
                raise ValueError("no feature dim set; load a table first")
            if n_rows <= 0:
                return self._emb_rows
            old_rows = self._emb_rows
            old = np.empty((old_rows, d), dtype=np.float32)
            if old_rows:
                self._get_embeds_locked(
                    np.arange(old_rows, dtype=np.int64), old)
            grown = np.concatenate(
                [old, np.zeros((n_rows, d), dtype=np.float32)], axis=0)
            self._write_embedding_table(grown)
            return old_rows

    def write_embed_rows(self, row0: int, rows: np.ndarray) -> None:
        """Overwrite the contiguous embedding rows ``[row0, row0+len)``
        in place (page-granular RMW) — the bulk import half of embedding
        migration.  Raises ``KeyError`` if no table is loaded and
        ``IndexError`` if the range exceeds the table."""
        if self._emb_base is None:
            raise KeyError("no embedding table loaded")
        with self._lock:
            d = self.feature_dim
            rows = np.ascontiguousarray(rows, dtype=np.float32).reshape(-1, d)
            m = len(rows)
            if m == 0:
                return
            if row0 < 0 or row0 + m > self._emb_rows:
                raise IndexError(
                    f"rows [{row0}, {row0 + m}) outside table "
                    f"of {self._emb_rows}")
            lo = row0 * d
            p0 = lo // SLOTS_PER_PAGE
            within = lo - p0 * SLOTS_PER_PAGE
            n_pages = -(-(within + m * d) // SLOTS_PER_PAGE)
            flat = self.dev.read_span(self._emb_base + p0, n_pages,
                                      tag="embed").copy()
            flat[within: within + m * d] = rows.reshape(-1).view(np.int32)
            for i in range(n_pages):
                self.dev.write_page(
                    self._emb_base + p0 + i,
                    flat[i * SLOTS_PER_PAGE: (i + 1) * SLOTS_PER_PAGE],
                    tag="embed")

    def sample_neighbors_batch(self, vids, fanout: int,
                               rng: np.random.Generator | None = None, *,
                               segments=None, rngs=None):
        """Fused near-storage GetNeighbors + fanout subsampling (B-1 half).

        The decisive hub optimisation: a power-law hub with a 30K-neighbor
        chain is *sampled by index* (Floyd, O(fanout)) against the chain's
        page counts, so only the selected slots are ever touched — the full
        neighbor list is never materialised.  Uniform draws are consumed in
        vid order, one ``fanout`` block per over-full vertex, identical to
        the reference sampler's per-vertex stream.

        Multi-request mode (the serving batcher): ``vids`` may concatenate
        several requests' frontiers — ``segments`` gives the per-request row
        counts and ``rngs`` the per-request generators.  Each segment's
        draws then come from its own stream, exactly as a solo call over
        that segment would consume them, so a coalesced super-request stays
        bit-identical per request while the page fetch remains ONE queued
        scatter-read for everything.

        Returns ``(sel, lens)``: selected neighbors flattened row-major and
        per-vid selection lengths (empty/unknown vids yield a self-loop).
        """
        vids_arr = np.asarray(vids, dtype=np.int64).reshape(-1)
        block, desc = self.fetch_plan(vids_arr)
        return select_from_plan(vids_arr, block, desc, fanout, rng,
                                segments=segments, rngs=rngs)

    def _l_locate_batch(self, block, row_of, l_pos, lq, k, miss, desc) -> None:
        """Vectorized L-page meta scan over every fetched page at once.

        Builds the global (vid -> page row, chunk start, chunk end) tables
        with a handful of array ops — the range partition makes per-page
        ascending vids globally sorted, so one ``searchsorted`` resolves all
        queries — and records ("L", row, start, end) descriptors.
        """
        kis = sorted(set(k[~miss].tolist()))
        rows = np.array([row_of[self._l_lpns[ki]] for ki in kis],
                        dtype=np.int64)
        if not len(rows):
            return
        n_m = block[rows, _L_NNODES].astype(np.int64)
        dlen_m = block[rows, _L_DATALEN].astype(np.int64)
        nmax = int(n_m.max())
        j = np.arange(nmax)
        vid_slot = _L_NNODES - 2 - 2 * j                # meta slot of node j
        vids_m = block[rows[:, None], vid_slot[None, :]].astype(np.int64)
        offs_m = block[rows[:, None], vid_slot[None, :] - 1].astype(np.int64)
        in_meta = j[None, :] < n_m[:, None]
        live = in_meta & (vids_m >= 0)

        # chunk ends.  Fast path: bulk-packed pages keep offsets strictly
        # ascending in meta order (no tombstones, no relocations), so node
        # j's chunk ends where node j+1's begins — no sort needed.  Any
        # mutated page (unit updates relocate chunks and leave tombstones)
        # falls back to the general boundary sort below.
        clean = np.all((~in_meta[:, 1:])
                       | (offs_m[:, 1:] > offs_m[:, :-1]), axis=1) \
            if nmax > 1 else np.ones(len(rows), dtype=bool)
        clean &= np.all(live == in_meta, axis=1)
        if clean.all():
            rown, coln = np.nonzero(live)
            flat_vids = vids_m[rown, coln]
            if not np.any(flat_vids[1:] < flat_vids[:-1]):
                ends_m = np.concatenate(
                    [offs_m[:, 1:], np.zeros((len(rows), 1), np.int64)],
                    axis=1)
                last = np.maximum(n_m - 1, 0)
                ends_m[np.arange(len(rows)), last] = dlen_m
                flat_offs = offs_m[rown, coln]
                flat_ends = ends_m[rown, coln]
                q = np.searchsorted(flat_vids, lq)
                qc = np.clip(q, 0, max(len(flat_vids) - 1, 0))
                found = (~miss) & (len(flat_vids) > 0) \
                    & (flat_vids[qc] == lq)
                prow = rown[qc]
                start = flat_offs[qc]
                end = flat_ends[qc]
                for i, pos in enumerate(l_pos):
                    if found[i]:
                        desc[pos] = ("L", int(rows[prow[i]]), int(start[i]),
                                     int(end[i]))
                return

        # general path: valid boundaries flattened with a per-row key so
        # one global sort + one searchsorted serve every query.
        big = SLOTS_PER_PAGE + 1
        bound_ok = in_meta & (offs_m <= dlen_m[:, None])
        bkey = np.where(bound_ok,
                        np.arange(len(rows))[:, None] * big + offs_m,
                        np.iinfo(np.int64).max)
        bkey = np.sort(bkey.reshape(-1))                # sentinels sort last
        n_bounds = int(bound_ok.sum())
        bkey = bkey[:n_bounds]                          # drop sentinels

        # live nodes flattened; the range partition + per-page ascending
        # packing make vids globally sorted already (checked; argsort only
        # as a fallback for adversarial layouts)
        rown, coln = np.nonzero(live)
        flat_vids = vids_m[rown, coln]
        flat_offs = offs_m[rown, coln]
        if np.any(flat_vids[1:] < flat_vids[:-1]):      # pragma: no cover
            sort2 = np.argsort(flat_vids, kind="stable")
            flat_vids, flat_offs, rown = (flat_vids[sort2], flat_offs[sort2],
                                          rown[sort2])
        svids = flat_vids

        q = np.searchsorted(svids, lq)
        qc = np.clip(q, 0, max(len(svids) - 1, 0))
        found = (~miss) & (len(svids) > 0) & (svids[qc] == lq)
        prow = rown[qc]                                 # row within `sub`
        start = flat_offs[qc]
        e = np.searchsorted(bkey, prow * big + start, side="right")
        ec = np.clip(e, 0, max(n_bounds - 1, 0))
        in_row = (e < n_bounds) & (bkey[ec] // big == prow)
        end = np.where(in_row, bkey[ec] % big, dlen_m[prow])

        for i, pos in enumerate(l_pos):
            if found[i]:
                desc[pos] = ("L", int(rows[prow[i]]), int(start[i]),
                             int(end[i]))

    def get_embed(self, vid: int) -> np.ndarray:
        """Paper GetEmbed(VID): read only the pages covering row ``vid``."""
        if self._emb_base is None:
            raise KeyError("no embedding table loaded")
        with self._lock:
            d = self.feature_dim
            lo, hi = vid * d, (vid + 1) * d
            p0, p1 = lo // SLOTS_PER_PAGE, -(-hi // SLOTS_PER_PAGE)
            flat = self.dev.read_span(self._emb_base + p0, p1 - p0, tag="embed")
            row = flat[lo - p0 * SLOTS_PER_PAGE: hi - p0 * SLOTS_PER_PAGE]
            return row.view(np.float32).copy()

    def get_embeds(self, vids: np.ndarray) -> np.ndarray:
        """Coalesced batched embedding gather.

        All rows' covering pages are merged (duplicates and overlaps
        collapsed) into one queued scatter-read; rows are then sliced out of
        the fetched block with a vectorized gather.  The sequential layout
        of the embedding space (paper Fig. 7) means adjacent VIDs share
        pages, so the merged page set is far smaller than one span per row.
        """
        if self._emb_base is None:
            raise KeyError("no embedding table loaded")
        vids = np.asarray(vids, dtype=np.int64).reshape(-1)
        d = self.feature_dim
        out = np.empty((len(vids), d), dtype=np.float32)
        if not len(vids):
            return out
        with self._lock:
            return self._get_embeds_locked(vids, out)

    def _get_embeds_locked(self, vids: np.ndarray, out: np.ndarray) -> np.ndarray:
        d = self.feature_dim
        lo = vids * d
        p0 = lo // SLOTS_PER_PAGE
        p1 = (lo + d + SLOTS_PER_PAGE - 1) // SLOTS_PER_PAGE
        span = int((p1 - p0).max())                     # pages per row (>=1)
        cand = p0[:, None] + np.arange(span)[None, :]   # (rows, span)
        pages = np.unique(cand[cand < p1[:, None]])     # merged page set
        block = self._read_pages_cached(self._emb_base + pages, "embed")
        # a row's pages are consecutive integers, hence adjacent rows of the
        # fetched block — so each embedding row is CONTIGUOUS in the block's
        # flat view and one broadcast gather slices every row at once
        fstart = np.searchsorted(pages, p0) * SLOTS_PER_PAGE \
            + (lo - p0 * SLOTS_PER_PAGE)
        flatb = block.reshape(-1)
        # gather through a sliding-window VIEW: one fancy index over
        # virtual rows, instead of materialising a (rows, d) int64 index
        # matrix (which costs more to build than the gather itself —
        # ~10x on feature-heavy tables)
        win = np.lib.stride_tricks.sliding_window_view(flatb, d) \
            if len(flatb) >= d else None
        if win is not None:
            out[...] = win[fstart].view(np.float32)
        else:                                           # tiny device edge
            out[...] = flatb[fstart[:, None] + np.arange(d)[None, :]] \
                .view(np.float32)
        return out

    # ============================================================== unit ops
    def _l_collect(self, page: np.ndarray) -> list[tuple[int, np.ndarray]]:
        """All live (vid, neighbor-chunk) pairs of an L page."""
        n = int(page[_L_NNODES])
        out = []
        for i in range(n):
            vid = int(page[_l_meta_vid(i)])
            if vid < 0:
                continue                                   # tombstone
            meta = self._l_scan(page, vid)
            _, start, ln = meta
            out.append((vid, page[start: start + ln].copy()))
        return out

    @staticmethod
    def _l_build_page(nodes: list[tuple[int, np.ndarray]]) -> np.ndarray:
        page = np.zeros(SLOTS_PER_PAGE, dtype=SLOT_DTYPE)
        dlen = 0
        for i, (vid, ch) in enumerate(nodes):
            page[_l_meta_vid(i)] = vid
            page[_l_meta_off(i)] = dlen
            page[dlen: dlen + len(ch)] = ch
            dlen += len(ch)
        page[_L_NNODES] = len(nodes)
        page[_L_DATALEN] = dlen
        return page

    def _l_split_insert(self, k: int, vid: int, chunk: np.ndarray) -> None:
        """Insert (vid, chunk) into L page k; split the page if full.

        Paper adaptation: the paper evicts one neighbor set to a fresh page,
        which breaks the range-search partition under out-of-order VIDs; we
        use a range-preserving page split instead (same cost profile: one
        extra page + one table insert)."""
        lpn = self._l_lpns[k]
        page = self.dev.read_page(lpn).copy()
        nodes = [nc for nc in self._l_collect(page) if nc[0] != vid]
        nodes.append((vid, chunk))
        nodes.sort(key=lambda nc: nc[0])
        need = sum(len(c) + 2 for _, c in nodes) + 2
        if need <= SLOTS_PER_PAGE:
            self.dev.write_page(lpn, self._l_build_page(nodes))
            if vid > self._l_keys[k]:
                self._l_keys[k] = vid
            return
        self.stats.l_evictions += 1
        sizes = np.array([len(c) + 2 for _, c in nodes])
        csum = np.cumsum(sizes)
        half = int(np.searchsorted(csum, csum[-1] / 2)) + 1
        half = min(max(half, 1), len(nodes) - 1)
        low, high = nodes[:half], nodes[half:]
        new_lpn, _ = self._new_l_page()
        self.dev.write_page(new_lpn, self._l_build_page(low))
        self.dev.write_page(lpn, self._l_build_page(high))
        self._l_keys[k] = max(self._l_keys[k], high[-1][0])
        self._l_keys.insert(k, low[-1][0])
        self._l_lpns.insert(k, new_lpn)

    def add_vertex(self, vid: int, embed: np.ndarray | None = None) -> None:
        """AddVertex: self-loop only, starts as L-type (paper).  Ascending
        VIDs append to the last page; out-of-order VIDs split-insert into
        the page covering their range."""
        with self._lock:
            vid = int(vid)
            if vid in self.gmap:
                return
            self.stats.unit_updates += 1
            loop = np.array([vid], dtype=SLOT_DTYPE)
            if not self._l_keys:
                self._l_insert_new_page([vid], [loop])
            elif vid > self._l_keys[-1]:
                lpn = self._l_lpns[-1]
                page = self.dev.read_page(lpn).copy()
                if self._l_free_slots(page) >= 3:
                    self._l_append_node(page, vid, loop)
                    self.dev.write_page(lpn, page)
                    self._l_keys[-1] = vid
                else:
                    self._l_insert_new_page([vid], [loop])
            else:
                k = bisect.bisect_left(self._l_keys, vid)
                self._l_split_insert(k, vid, loop)
            self.gmap[vid] = "L"
            self.num_vertices = max(self.num_vertices, vid + 1)
            if embed is not None:
                self.update_embed(vid, embed)

    def _l_insert_new_page(self, vids, chunks) -> None:
        lpn, page = self._new_l_page()
        dlen = 0
        for i, (v, ch) in enumerate(zip(vids, chunks)):
            page[_l_meta_vid(i)] = v
            page[_l_meta_off(i)] = dlen
            page[dlen: dlen + len(ch)] = ch
            dlen += len(ch)
        page[_L_NNODES] = len(vids)
        page[_L_DATALEN] = dlen
        self.dev.write_page(lpn, page)
        key = int(max(vids))
        k = bisect.bisect_left(self._l_keys, key)
        self._l_keys.insert(k, key)
        self._l_lpns.insert(k, lpn)

    @staticmethod
    def _l_append_node(page: np.ndarray, vid: int, chunk: np.ndarray) -> None:
        n, dlen = int(page[_L_NNODES]), int(page[_L_DATALEN])
        page[_l_meta_vid(n)] = vid
        page[_l_meta_off(n)] = dlen
        page[dlen: dlen + len(chunk)] = chunk
        page[_L_NNODES] = n + 1
        page[_L_DATALEN] = dlen + len(chunk)

    def add_edge(self, dst: int, src: int) -> None:
        """AddEdge: undirected — inserts src into N(dst) and dst into N(src)."""
        with self._lock:
            self.stats.unit_updates += 1
            for v in (dst, src):
                if v not in self.gmap:
                    self.add_vertex(v)
            self._insert_neighbor(int(dst), int(src))
            if dst != src:
                self._insert_neighbor(int(src), int(dst))

    def _insert_neighbor(self, vid: int, nbr: int) -> None:
        if self.gmap[vid] == "H":
            head, tail = self.h_table[vid]
            page = self.dev.read_page(tail).copy()
            cnt = int(page[_H_COUNT])
            if cnt < H_CAP:
                page[_H_DATA + cnt] = nbr
                page[_H_COUNT] = cnt + 1
                self.dev.write_page(tail, page)
            else:
                lpn = self.dev.alloc_front()
                newp = np.zeros(SLOTS_PER_PAGE, dtype=SLOT_DTYPE)
                newp[_H_COUNT] = 1
                newp[_H_NEXT] = -1
                newp[_H_DATA] = nbr
                self.dev.write_page(lpn, newp)
                page[_H_NEXT] = lpn
                self.dev.write_page(tail, page)
                self.h_table[vid] = (head, lpn)
                self.h_chain[vid].append(lpn)
                self.stats.pages_h += 1
            return
        # ---- L-type
        k = bisect.bisect_left(self._l_keys, vid)
        lpn = self._l_lpns[k]
        page = self.dev.read_page(lpn).copy()
        meta = self._l_scan(page, vid)
        assert meta is not None, f"vid {vid} missing from L page"
        mi, start, ln = meta

        if ln + 1 > self.h_threshold:
            # degree crossed the threshold: promote to H-type
            nbrs = np.concatenate([page[start: start + ln],
                                   np.array([nbr], dtype=SLOT_DTYPE)])
            self._l_remove_node(page, lpn, vid)
            self._promote_to_h(vid, nbrs)
            return

        if self._l_free_slots(page) >= 1:
            dlen = int(page[_L_DATALEN])
            if start + ln == dlen:                       # chunk is last: append
                page[dlen] = nbr
                page[_L_DATALEN] = dlen + 1
            else:                                        # relocate chunk to end
                chunk = page[start: start + ln].copy()
                self._l_shift_left(page, start, ln)
                dlen = int(page[_L_DATALEN])
                page[dlen: dlen + ln] = chunk
                page[dlen + ln] = nbr
                page[_l_meta_off(mi)] = dlen
                page[_L_DATALEN] = dlen + ln + 1
            self.dev.write_page(lpn, page)
            return

        # no space: range-preserving split of this page (paper adaptation
        # of the neighbor-set eviction; see _l_split_insert)
        chunk = np.concatenate([page[start: start + ln],
                                np.array([nbr], dtype=SLOT_DTYPE)])
        self._l_split_insert(k, vid, chunk)

    def _promote_to_h(self, vid: int, nbrs: np.ndarray) -> None:
        self._write_h_chain(vid, nbrs)
        self.gmap[vid] = "H"

    def _write_h_chain(self, vid: int, nbrs: np.ndarray) -> None:
        """Write a fresh H chain for ``vid`` and record its mapping."""
        head = tail = -1
        chain: list[int] = []
        for c0 in range(0, len(nbrs), H_CAP):
            chunk = nbrs[c0: c0 + H_CAP]
            lpn = self.dev.alloc_front()
            page = np.zeros(SLOTS_PER_PAGE, dtype=SLOT_DTYPE)
            page[_H_COUNT] = len(chunk)
            page[_H_NEXT] = -1
            page[_H_DATA: _H_DATA + len(chunk)] = chunk
            self.dev.write_page(lpn, page)
            self.stats.pages_h += 1
            chain.append(lpn)
            if head < 0:
                head = lpn
            else:
                prev = self.dev.read_page(tail).copy()
                prev[_H_NEXT] = lpn
                self.dev.write_page(tail, prev)
            tail = lpn
        self.h_table[vid] = (head, tail)
        self.h_chain[vid] = chain

    def _l_shift_left(self, page: np.ndarray, start: int, ln: int) -> None:
        """Remove chunk [start, start+ln) from the data region, fix offsets."""
        dlen = int(page[_L_DATALEN])
        page[start: dlen - ln] = page[start + ln: dlen].copy()
        page[_L_DATALEN] = dlen - ln
        n = int(page[_L_NNODES])
        for j in range(n):
            off = int(page[_l_meta_off(j)])
            if off > start:
                page[_l_meta_off(j)] = off - ln

    def _l_remove_node(self, page: np.ndarray, lpn: int, vid: int) -> None:
        meta = self._l_scan(page, vid)
        if meta is None:
            return
        mi, start, ln = meta
        self._l_shift_left(page, start, ln)
        page[_l_meta_vid(mi)] = -1                       # tombstone (paper: reuse)
        page[_l_meta_off(mi)] = int(page[_L_DATALEN])
        self.dev.write_page(lpn, page)

    def delete_edge(self, dst: int, src: int) -> None:
        with self._lock:
            self.stats.unit_updates += 1
            self._remove_neighbor(int(dst), int(src))
            if dst != src:
                self._remove_neighbor(int(src), int(dst))

    def _remove_neighbor(self, vid: int, nbr: int) -> None:
        kind = self.gmap.get(vid)
        if kind is None:
            return
        if kind == "H":
            lpn, _ = self.h_table[vid]
            while lpn >= 0:
                page = self.dev.read_page(lpn).copy()
                cnt = int(page[_H_COUNT])
                data = page[_H_DATA: _H_DATA + cnt]
                hit = np.nonzero(data == nbr)[0]
                if len(hit):
                    i = int(hit[0])
                    data[i] = data[cnt - 1]
                    page[_H_COUNT] = cnt - 1
                    self.dev.write_page(lpn, page)
                    return
                lpn = int(page[_H_NEXT])
            return
        hit = self._l_lookup_page(vid)
        if hit is None:
            return
        lpn, page = hit
        meta = self._l_scan(page, vid)
        if meta is None:
            return
        mi, start, ln = meta
        data = page[start: start + ln]
        pos = np.nonzero(data == nbr)[0]
        if not len(pos):
            return
        i = int(pos[0])
        page[start + i: start + ln - 1] = page[start + i + 1: start + ln].copy()
        self._l_shift_tail_one(page, start, ln)
        self.dev.write_page(lpn, page)

    def _l_shift_tail_one(self, page: np.ndarray, start: int, ln: int) -> None:
        """Shrink chunk at ``start`` by one slot, compacting the data region."""
        dlen = int(page[_L_DATALEN])
        page[start + ln - 1: dlen - 1] = page[start + ln: dlen].copy()
        page[_L_DATALEN] = dlen - 1
        n = int(page[_L_NNODES])
        for j in range(n):
            off = int(page[_l_meta_off(j)])
            if off >= start + ln:
                page[_l_meta_off(j)] = off - 1

    def delete_vertex(self, vid: int) -> None:
        with self._lock:
            vid = int(vid)
            self.stats.unit_updates += 1
            nbrs = self.get_neighbors(vid)
            for nbr in nbrs:
                if int(nbr) != vid:
                    self._remove_neighbor(int(nbr), vid)
            self._drop_vertex_pages(vid)

    def _drop_vertex_pages(self, vid: int) -> None:
        """Release ``vid``'s own mapping + pages (not its neighbors' backlinks
        — the sharded coordinator removes those on each neighbor's owning
        shard before calling this on the owner)."""
        kind = self.gmap.pop(vid, None)
        if kind == "H":
            lpn, _ = self.h_table.pop(vid)
            self.h_chain.pop(vid, None)
            while lpn >= 0:
                page = self.dev.read_page(lpn)
                nxt = int(page[_H_NEXT])
                self.dev.free_page(lpn)
                lpn = nxt
        elif kind == "L":
            hit = self._l_lookup_page(vid)
            if hit is not None:
                lpn, page = hit
                self._l_remove_node(page, lpn, vid)
        self._free_vids.append(vid)

    def update_embed(self, vid: int, embed: np.ndarray) -> None:
        """UpdateEmbed(VID, Embed): in-place page RMW of one feature row."""
        if self._emb_base is None:
            raise KeyError("no embedding table loaded")
        with self._lock:
            d = self.feature_dim
            row = np.ascontiguousarray(embed, dtype=np.float32).reshape(-1)
            assert row.size == d
            lo = vid * d
            p0 = lo // SLOTS_PER_PAGE
            within = lo - p0 * SLOTS_PER_PAGE
            n_pages = -(-(within + d) // SLOTS_PER_PAGE)
            flat = self.dev.read_span(self._emb_base + p0, n_pages,
                                      tag="embed").copy()
            flat[within: within + d] = row.view(np.int32)
            for i in range(n_pages):
                self.dev.write_page(
                    self._emb_base + p0 + i,
                    flat[i * SLOTS_PER_PAGE: (i + 1) * SLOTS_PER_PAGE],
                    tag="embed")

    # ============================================================== export
    def to_adjacency(self) -> dict[int, set[int]]:
        """Full adjacency export (oracle/validation only — reads every page)."""
        out: dict[int, set[int]] = {}
        for vid in list(self.gmap):
            out[vid] = set(int(x) for x in self.get_neighbors(vid))
        return out


# ---------------------------------------------------------------- preprocessing
# The paper's G-1..G-4 UpdateGraph pipeline, exposed as SHARD-LOCAL pieces
# so the distributed ingest path (store/ingest.py) can run each stage where
# the data is: the coordinator ships raw edge chunks, every shard mirrors
# and buckets its chunks device-side ([G-2]/[G-3] routing), peers exchange
# buckets, and each shard sorts + builds its partition-local CSR
# ([G-3]/[G-4]) with the exact arithmetic the monolithic path uses — which
# is what makes the chunked load bit-identical to ``preprocess_edges`` +
# ``partition_csr``.

def mirror_edges(edge_array: np.ndarray, *,
                 already_undirected: bool = False) -> np.ndarray:
    """[G-2] mirror {dst,src}->{src,dst}: directed pair list of the
    undirected edge set (no-op when the input is already symmetric)."""
    e = np.asarray(edge_array, dtype=np.int64).reshape(-1, 2)
    if already_undirected or e.size == 0:
        return e
    return np.concatenate([e, e[:, ::-1]], axis=0)


def bucket_pairs(pairs: np.ndarray, n_shards: int, *,
                 replication: int = 1, placement=None) -> list[np.ndarray]:
    """[G-3] routing: directed pairs grouped by destination shard.

    Under the default map, replica ``r`` of row ``vid`` lives on shard
    ``(vid + r) % N``, so each pair is routed to the R shards that own
    its row — shard ``s`` receives the residue classes
    ``{(s - r) % N, r < R}``, exactly the classes ``partition_csr`` keeps.
    A ``placement`` (:class:`repro.store.placement.PlacementMap`) replaces
    that rule: pairs route by ``vid % C`` through the map's owner table
    (role order preserved, so stripe layouts follow ``pairs_of``).
    """
    pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    if placement is not None:
        cls = pairs[:, 0] % placement.n_classes
        out = []
        for s in range(n_shards):
            parts = [pairs[cls == c] for c, _r in placement.pairs_of(s)]
            parts = [p for p in parts if len(p)]
            out.append(np.concatenate(parts) if parts
                       else np.empty((0, 2), dtype=np.int64))
        return out
    cls = pairs[:, 0] % n_shards
    out: list[np.ndarray] = []
    for s in range(n_shards):
        parts = [pairs[cls == (s - r) % n_shards]
                 for r in range(int(replication))]
        parts = [p for p in parts if len(p)]
        out.append(np.concatenate(parts) if parts
                   else np.empty((0, 2), dtype=np.int64))
    return out


def csr_from_pairs(pairs: np.ndarray, num_vertices: int, *,
                   n_shards: int = 1, classes=None,
                   add_self_loops: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """[G-3]+[G-4] sort stage: directed pairs -> sorted, deduped CSR in
    the GLOBAL row space (non-owned rows keep zero-degree indptr slots,
    as ``partition_csr`` leaves them).

    ``classes`` restricts the [G-4] self-loop injection to the residue
    classes this shard owns; ``None`` injects loops for every vertex (the
    single-device/global case).  The key arithmetic (``row * n + nbr`` +
    ``np.unique``) is shared with the monolithic path, so a shard sorting
    only its own bucket produces exactly the owned-row restriction of the
    globally sorted CSR.
    """
    n = int(num_vertices)
    pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    if add_self_loops and n:
        if classes is None:
            loops = np.arange(n, dtype=np.int64)
        else:
            own = [np.arange(c, n, n_shards, dtype=np.int64)
                   for c in sorted(int(c) for c in classes)]
            loops = (np.concatenate(own) if own
                     else np.empty(0, dtype=np.int64))
        pairs = np.concatenate(
            [pairs, np.stack([loops, loops], axis=1)], axis=0)
    if pairs.size == 0:
        return np.zeros(n + 1, dtype=np.int64), np.empty(0, dtype=SLOT_DTYPE)
    key = pairs[:, 0] * n + pairs[:, 1]
    key = np.unique(key)                      # sort + dedup (the "radix sort")
    src = key // n
    dst = (key % n).astype(SLOT_DTYPE)
    counts = np.bincount(src, minlength=n)
    indptr = np.concatenate([[0], np.cumsum(counts)])
    return indptr, dst


def preprocess_edges(edge_array: np.ndarray, *, already_undirected: bool = False,
                     add_self_loops: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """Paper Fig. 2 graph preprocessing: edge array -> sorted undirected CSR.

    [G-1] load edge array  [G-2] mirror {dst,src}->{src,dst}
    [G-3] merge + sort -> VID-indexed structure  [G-4] inject self-loops.
    Returns (indptr, indices) CSR over max(VID)+1 vertices.  Never mutates
    its input (every stage concatenates into fresh arrays).
    """
    e = np.asarray(edge_array, dtype=np.int64).reshape(-1, 2)
    if e.size == 0:
        return np.zeros(1, dtype=np.int64), np.empty(0, dtype=SLOT_DTYPE)
    n = int(e.max()) + 1
    return csr_from_pairs(
        mirror_edges(e, already_undirected=already_undirected), n,
        add_self_loops=add_self_loops)
