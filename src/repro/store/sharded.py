"""ShardedGraphStore — hash-partitioned coordinator over a CSSD array.

The paper serves a hundred-billion-edge graph from ONE CSSD and argues
scale-out as an array of such devices (§8; Fig. 18's channel-parallel
bandwidth argument, one level up).  This coordinator makes that concrete:
the graph lives partitioned across N BlockDevices, each behind its own
partition-local ``GraphStore`` (mapping tables + page layout + optional
device-DRAM page cache), and every batched query fans out so each shard
pays its command latency *concurrently* — the same amortisation the flash
channels give inside one device.

Partitioning is by vertex hash (``vid % n_shards``):

  * **adjacency** — vid's neighbor chunks live on shard ``vid % N``, keyed
    by the GLOBAL vid.  Neighbor values are global vids, so no translation
    table exists anywhere; the owned-vid subset ``{s, s+N, ...}`` is still
    ascending, so the shard-local L-page range search is unchanged;
  * **embeddings** — vid's feature row is row ``vid // N`` of its shard's
    sequential embedding space.  Round-robin striping keeps each shard's
    row space dense, so the shard-local address math (row -> page span) is
    exactly the single-device math;
  * **mutable ops** (unit updates, bulk ingest, embed RMWs) route to the
    owning shard; each device's ``on_write`` hook invalidates that shard's
    page cache, precisely as on one device.

Read-side batched queries run in three explicit phases:

  plan   — partition the query positions by owning shard (pure table math,
           no I/O);
  fetch  — ONE locked scatter-read per shard (``GraphStore.fetch_plan`` /
           ``get_embeds``); each shard's simulated flash + command time is
           deferred and the array pays a single wait equal to the slowest
           shard, the same analytic concurrency model as the flash
           channels inside one device (divide, don't sum);
  build  — per-shard plans are recomposed into one global (block, desc) —
           descriptor rows re-based into the concatenated block — and fed
           to the SAME ``select_from_plan``/``neighbors_from_plan`` code
           the single-device store runs.

Because the recomposed plan is position-identical to the single-device
plan (same per-vid neighbor lists, same order) and the selection consumes
its rng stream in global frontier order, an N-shard sample is
**bit-identical** to the 1-device sample under the same seed —
``tests/test_sharded_store.py`` asserts this for N in {1, 2, 4} all the
way through ``run``/``run_batch``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from .blockdev import BlockDevice, sleep_us
from .graphstore import (BulkTimeline, GraphStore, GraphStoreStats,
                         neighbors_from_plan, preprocess_edges,
                         select_from_plan)


def partition_csr(indptr: np.ndarray, indices: np.ndarray,
                  n_shards: int, shard: int):
    """Mask a global CSR down to the rows shard ``shard`` owns.

    Non-owned rows keep indptr slots with zero degree, so the row index
    space stays global and ``GraphStore._write_adjacency`` (which skips
    degree-0 rows) lays out exactly the owned vertices.
    """
    n = len(indptr) - 1
    degrees = np.diff(indptr)
    own = (np.arange(n) % n_shards) == shard
    deg_s = np.where(own, degrees, 0)
    indptr_s = np.concatenate([[0], np.cumsum(deg_s)])
    row_of = np.repeat(np.arange(n), degrees)
    return indptr_s, indices[own[row_of]]


class _AggCacheStats:
    """Aggregated view over the shards' per-device cache counters."""

    def __init__(self, shards):
        self._shards = shards

    def snapshot(self) -> dict:
        tot = dict.fromkeys(("hits", "misses", "evictions", "invalidations",
                             "bytes_from_cache", "bytes_from_dev"), 0)
        for sh in self._shards:
            snap = sh.cache.stats.snapshot()
            for k in tot:
                tot[k] += snap[k]
        n = tot["hits"] + tot["misses"]
        tot["hit_rate"] = tot["hits"] / n if n else 0.0
        return tot

    @property
    def hit_rate(self) -> float:
        return self.snapshot()["hit_rate"]

    @property
    def hits(self) -> int:
        return self.snapshot()["hits"]

    @property
    def misses(self) -> int:
        return self.snapshot()["misses"]

    @property
    def invalidations(self) -> int:
        return self.snapshot()["invalidations"]


class _ShardedCacheView:
    """Duck-type of ``EmbeddingPageCache`` for telemetry/maintenance call
    sites (``.stats`` snapshots, ``.clear()``) spanning every shard."""

    def __init__(self, shards):
        self._shards = shards
        self.stats = _AggCacheStats(shards)

    def clear(self) -> None:
        for sh in self._shards:
            sh.cache.clear()


class ShardedGraphStore:
    """Drop-in for ``GraphStore`` across the query/mutation surface the
    service layer uses, backed by ``n_shards`` partition-local stores."""

    def __init__(self, n_shards: int | None = None,
                 devs: list | None = None, *,
                 h_threshold: int = 128, feature_dim: int = 0):
        if devs is not None:
            if n_shards is not None and n_shards != len(devs):
                raise ValueError(f"n_shards={n_shards} conflicts with "
                                 f"{len(devs)} explicit devices")
            n_shards = len(devs)
        elif n_shards is None:
            n_shards = 2
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.n_shards = int(n_shards)
        devs = devs or [BlockDevice() for _ in range(self.n_shards)]
        self.shards = [GraphStore(d, h_threshold=h_threshold,
                                  feature_dim=feature_dim) for d in devs]
        self.h_threshold = int(h_threshold)
        self._bulk = BulkTimeline()
        # composite mutations span shards; one coordinator lock restores
        # the single-store mutation atomicity (membership check + inserts
        # as one critical section).  Readers do NOT take it — a hop fetch
        # racing an add_edge may observe the half-inserted undirected edge,
        # the inherent visibility model of an array of devices.
        self._mutate = threading.RLock()

    # ------------------------------------------------------------- topology
    @property
    def devs(self) -> list:
        return [sh.dev for sh in self.shards]

    def owner_of(self, vid: int) -> int:
        return int(vid) % self.n_shards

    def _owner(self, vid: int) -> GraphStore:
        return self.shards[int(vid) % self.n_shards]

    def _map(self, fn, items):
        """Bulk-ingest fan-out: per-shard write bursts (ms-scale simulated
        sleeps, GIL released) overlap on real threads.  The pool is
        transient — created per phase, joined before returning — so idle
        stores hold no threads.  The read fan-out does NOT use threads:
        its per-shard planning is interpreter-bound, so shard concurrency
        there is modelled analytically instead (see ``_fetch_shards``)."""
        items = list(items)
        if len(items) <= 1:
            return [fn(x) for x in items]
        with ThreadPoolExecutor(max_workers=len(items),
                                thread_name_prefix="shard") as pool:
            return list(pool.map(fn, items))

    @property
    def feature_dim(self) -> int:
        return self.shards[0].feature_dim

    @property
    def num_vertices(self) -> int:
        return max(sh.num_vertices for sh in self.shards)

    @property
    def stats(self) -> GraphStoreStats:
        out = GraphStoreStats(
            l_evictions=sum(sh.stats.l_evictions for sh in self.shards),
            unit_updates=sum(sh.stats.unit_updates for sh in self.shards),
            pages_h=sum(sh.stats.pages_h for sh in self.shards),
            pages_l=sum(sh.stats.pages_l for sh in self.shards),
            bulk=self._bulk)
        if self.cache is not None:
            out.cache = self.cache.stats
        return out

    # ---------------------------------------------------------------- cache
    @property
    def cache(self):
        if self.shards[0].cache is None:
            return None
        return _ShardedCacheView(self.shards)

    def attach_cache_pages(self, capacity_pages: int, **kw) -> None:
        """Split one device-DRAM budget evenly across the shards — each
        device fronts its own reads and invalidates through its own
        ``on_write`` hook, so coherence needs no cross-shard traffic."""
        from .embcache import EmbeddingPageCache
        per_shard = max(1, int(capacity_pages) // self.n_shards)
        for sh in self.shards:
            sh.attach_cache(EmbeddingPageCache(per_shard), **kw)

    # ----------------------------------------------------------- bulk ingest
    def update_graph(self, edge_array: np.ndarray,
                     embeddings: np.ndarray | None = None,
                     *, already_undirected: bool = False) -> BulkTimeline:
        """Bulk UpdateGraph across the array.

        The global edge preprocessing runs once, overlapped with the
        (much larger) embedding write exactly as on one device — except the
        embedding table is striped ``embeddings[s::N]`` and every shard's
        sequential write burst proceeds in parallel on its own device.
        """
        tl = BulkTimeline()
        t0 = time.perf_counter()

        edge_array = np.asarray(edge_array, dtype=np.int64).reshape(-1, 2).copy()
        if embeddings is not None:
            embeddings = np.ascontiguousarray(embeddings, dtype=np.float32)
        tl.transfer = (0.0, time.perf_counter() - t0)

        box: dict = {}

        def graph_pre():
            s = time.perf_counter() - t0
            box["csr"] = preprocess_edges(
                edge_array, already_undirected=already_undirected)
            box["span"] = (s, time.perf_counter() - t0)

        def write_feature():
            s = time.perf_counter() - t0
            if embeddings is not None:
                self._map(lambda sh: self.shards[sh]._write_embedding_table(
                    embeddings[sh:: self.n_shards]), range(self.n_shards))
            box["wf"] = (s, time.perf_counter() - t0)

        th_g = threading.Thread(target=graph_pre)
        th_f = threading.Thread(target=write_feature)
        th_g.start(); th_f.start()
        th_f.join()
        user_visible_at = time.perf_counter() - t0
        th_g.join()
        tl.graph_pre = box["span"]
        tl.write_feature = box.get("wf", (0.0, 0.0))

        s0 = time.perf_counter() - t0
        indptr, indices = box["csr"]

        def write_adj(s):
            ip, ix = partition_csr(indptr, indices, self.n_shards, s)
            self.shards[s]._write_adjacency(ip, ix)

        self._map(write_adj, range(self.n_shards))
        tl.write_graph = (s0, time.perf_counter() - t0)
        tl.total = time.perf_counter() - t0
        tl.user_visible = max(user_visible_at, tl.transfer[1])
        self._bulk = tl
        return tl

    # ------------------------------------------------------ batched queries
    def _partition(self, vids: np.ndarray) -> list[tuple[int, np.ndarray]]:
        """plan phase: query positions grouped by owning shard (no I/O)."""
        owner = vids % self.n_shards
        parts = [(s, np.nonzero(owner == s)[0])
                 for s in range(self.n_shards)]
        return [(s, pos) for s, pos in parts if len(pos)]

    def _fetch_shards(self, parts, fn) -> list:
        """fetch phase: one call per shard, device concurrency modelled
        analytically.

        Each shard's simulated flash + command time is DEFERRED while its
        scatter-read runs, then the array pays one wait equal to the
        slowest shard — the devices execute their queued commands
        concurrently, mirroring how the flash channels inside one device
        are modelled (divide, don't sum).  Real threads would only
        serialize the interpreter-bound planning behind the GIL and charge
        a handoff tax per shard.
        """
        outs, worst = [], 0.0
        for item in parts:
            with self.shards[item[0]].dev.defer_latency() as acct:
                outs.append(fn(item))
            worst = max(worst, acct.us)
        sleep_us(worst)
        return outs

    def _fan_fetch(self, vids_arr: np.ndarray):
        """plan -> per-shard fetch -> build: the shared front half of the
        batched queries (see module docstring).  Returns a global
        (block, desc) position-identical to a single device's
        ``_fetch_plan`` over the same vids.
        """
        parts = self._partition(vids_arr)

        # fetch: ONE locked scatter-read per shard, devices concurrent
        plans = self._fetch_shards(
            parts, lambda it: self.shards[it[0]].fetch_plan(vids_arr[it[1]]))

        # build: re-base each shard's descriptor rows into the concatenated
        # block and scatter them back to their global positions
        desc: list = [None] * len(vids_arr)
        blocks = []
        row_off = 0
        for (s, pos), (blk, dsc) in zip(parts, plans):
            for p, d in zip(pos.tolist(), dsc):
                if d is None:
                    continue
                if d[0] == "L":
                    desc[p] = ("L", d[1] + row_off, d[2], d[3])
                else:
                    desc[p] = ("H", d[1] + row_off, d[2])
            if blk is not None:
                blocks.append(blk)
                row_off += blk.shape[0]
        if not blocks:
            return None, desc
        # single contributing shard: its block is already global
        block = blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
        return block, desc

    def get_neighbors(self, vid: int) -> np.ndarray:
        return self._owner(vid).get_neighbors(int(vid))

    def get_neighbors_batch(self, vids) -> list[np.ndarray]:
        vids_arr = np.asarray(vids, dtype=np.int64).reshape(-1)
        block, desc = self._fan_fetch(vids_arr)
        return neighbors_from_plan(vids_arr, block, desc)

    def sample_neighbors_batch(self, vids, fanout: int,
                               rng: np.random.Generator | None = None, *,
                               segments=None, rngs=None):
        """Fused fetch+subsample across the array — one scatter-read per
        shard per hop, then the single-device selection over the recomposed
        plan (rng consumed in global frontier order: bit-identical)."""
        vids_arr = np.asarray(vids, dtype=np.int64).reshape(-1)
        block, desc = self._fan_fetch(vids_arr)
        return select_from_plan(vids_arr, block, desc, fanout, rng,
                                segments=segments, rngs=rngs)

    # ----------------------------------------------------------- embeddings
    def get_embed(self, vid: int) -> np.ndarray:
        return self._owner(vid).get_embed(int(vid) // self.n_shards)

    def get_embeds(self, vids: np.ndarray) -> np.ndarray:
        """Coalesced gather across the array: each shard serves its owned
        rows (local row = vid // N) with ONE scatter-read, concurrently;
        rows scatter back to their query positions."""
        d = self.feature_dim
        if not d:
            raise KeyError("no embedding table loaded")
        vids = np.asarray(vids, dtype=np.int64).reshape(-1)
        out = np.empty((len(vids), d), dtype=np.float32)
        if not len(vids):
            return out

        def fetch(item):
            s, pos = item
            return pos, self.shards[s].get_embeds(vids[pos] // self.n_shards)

        for pos, rows in self._fetch_shards(self._partition(vids), fetch):
            out[pos] = rows
        return out

    def update_embed(self, vid: int, embed: np.ndarray) -> None:
        self._owner(vid).update_embed(int(vid) // self.n_shards, embed)

    # ------------------------------------------------------------- unit ops
    def add_vertex(self, vid: int, embed: np.ndarray | None = None) -> None:
        with self._mutate:
            vid = int(vid)
            sh = self._owner(vid)
            sh.add_vertex(vid)                   # adjacency under global vid
            if embed is not None:
                sh.update_embed(vid // self.n_shards, embed)

    def add_edge(self, dst: int, src: int) -> None:
        """Undirected insert: each endpoint's chunk updates on ITS owning
        shard (two independent single-page RMWs, possibly on different
        devices)."""
        with self._mutate:
            dst, src = int(dst), int(src)
            for v in (dst, src):
                sh = self._owner(v)
                if v not in sh.gmap:
                    sh.add_vertex(v)
            sh_d = self._owner(dst)
            with sh_d._lock:
                sh_d.stats.unit_updates += 1
                sh_d._insert_neighbor(dst, src)
            if dst != src:
                sh_s = self._owner(src)
                with sh_s._lock:
                    sh_s._insert_neighbor(src, dst)

    def delete_edge(self, dst: int, src: int) -> None:
        with self._mutate:
            dst, src = int(dst), int(src)
            sh_d = self._owner(dst)
            with sh_d._lock:
                sh_d.stats.unit_updates += 1
                sh_d._remove_neighbor(dst, src)
            if dst != src:
                sh_s = self._owner(src)
                with sh_s._lock:
                    sh_s._remove_neighbor(src, dst)

    def delete_vertex(self, vid: int) -> None:
        """Remove ``vid`` everywhere: backlinks on each neighbor's owning
        shard first, then the owner drops the vertex's own pages."""
        with self._mutate:
            vid = int(vid)
            own = self._owner(vid)
            nbrs = own.get_neighbors(vid)
            for nbr in nbrs:
                nbr = int(nbr)
                if nbr == vid:
                    continue
                sh = self._owner(nbr)
                with sh._lock:
                    sh._remove_neighbor(nbr, vid)
            with own._lock:
                own.stats.unit_updates += 1
                own._drop_vertex_pages(vid)

    # --------------------------------------------------------------- export
    def to_adjacency(self) -> dict[int, set[int]]:
        out: dict[int, set[int]] = {}
        for sh in self.shards:
            out.update(sh.to_adjacency())
        return out
