"""ShardedGraphStore — hash-partitioned coordinator over a CSSD array.

The paper serves a hundred-billion-edge graph from ONE CSSD and argues
scale-out as an array of such devices (§8; Fig. 18's channel-parallel
bandwidth argument, one level up).  This coordinator makes that concrete:
the graph lives partitioned across N shards, each behind its own
``ShardEndpoint`` (``store/endpoint.py``) — a partition-local
``GraphStore`` reached either in-process (``LocalShardEndpoint``,
zero-copy) or over a per-shard RoP link (``RopShardEndpoint``:
MultiQueueRoP SQ/CQ pair + PCIeChannel, its own host poll thread).  The
coordinator speaks ONLY the endpoint protocol — no shard attribute
access — so the array can span hosts, and every batched query fans out
so each shard pays its command latency *concurrently* — the same
amortisation the flash channels give inside one device.

Partitioning is by vertex hash (``vid % n_shards``):

  * **adjacency** — vid's neighbor chunks live on shard ``vid % N``, keyed
    by the GLOBAL vid.  Neighbor values are global vids, so no translation
    table exists anywhere; the owned-vid subset ``{s, s+N, ...}`` is still
    ascending, so the shard-local L-page range search is unchanged;
  * **embeddings** — vid's feature row is row ``vid // N`` of its shard's
    sequential embedding space.  Round-robin striping keeps each shard's
    row space dense, so the shard-local address math (row -> page span) is
    exactly the single-device math;
  * **mutable ops** (unit updates, bulk ingest, embed RMWs) route to the
    owning shard's endpoint; each device's ``on_write`` hook invalidates
    that shard's page cache, precisely as on one device.

Read-side batched queries run in three explicit phases:

  plan   — partition the query positions by owning shard (pure table math,
           no I/O);
  fetch  — ONE batched ``fetch`` command per shard, SUBMITTED to every
           shard and AWAITED together; each shard's simulated flash +
           command time is deferred device-side and shipped back as
           ``io_us``, and the array pays a single wait equal to the
           slowest shard — the same analytic concurrency model as the
           flash channels inside one device (divide, don't sum);
  build  — per-shard plans are recomposed into one global (block, desc) —
           descriptor rows re-based into the concatenated block — and fed
           to the SAME ``select_from_plan``/``neighbors_from_plan`` code
           the single-device store runs.

Because the recomposed plan is position-identical to the single-device
plan (same per-vid neighbor lists, same order) and the selection consumes
its rng stream in global frontier order, an N-shard sample is
**bit-identical** to the 1-device sample under the same seed — and, since
both endpoint flavours run the same device-side code, a remote
(``RopShardEndpoint``) array is bit-identical to a local one
(``tests/test_sharded_store.py``, ``tests/test_endpoint.py``).

``ReplicatedGraphStore`` (below) extends the array with R-way replica
placement: page-granular replica-spread reads against hub skew (fed by a
gossiped, staleness-bounded view of the shards' read counters), write
fan-out, and a ``fail_shard``/``rebuild_shard`` fault path whose rebuild
streams survivor pages shard-to-shard over the endpoints' peer links —
same plan->fetch->build contract, same bit-identity (see its docstring).
"""
from __future__ import annotations

import random
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from contextlib import contextmanager
from dataclasses import dataclass

import numpy as np

from ..concurrency import witness_condition, witness_lock
from ..rpc.queues import BackpressureError, QueueFullError
from .blockdev import (BlockDevice, DeviceFailedError, SLOTS_PER_PAGE,
                       sleep_us)
from .endpoint import LocalShardEndpoint, make_local_endpoints
from .graphstore import (BulkTimeline, GraphStoreStats, _H_COUNT,
                         neighbors_from_plan, preprocess_edges,
                         select_from_plan)
from .placement import (PlacementMap, common_refine, grow_plan, heat_plan,
                        modular, plan_moves, rows_of_class, shrink_plan)
from .sampler import _ramp


@dataclass
class FlowControl:
    """End-to-end flow-control policy of the array coordinator.

    ``max_inflight_per_shard`` bounds how many batched-read rounds may
    have a command outstanding against one shard host at once (the
    in-flight window; 0 disables); a round that cannot take a window
    slot within ``window_timeout_s`` sheds as ``BackpressureError``
    instead of piling onto the shard's SQ rings.  A ``QueueFullError``
    from a ring is retried with exponential backoff
    (``backoff_base_s * 2^attempt``, capped at ``backoff_max_s``, plus
    up to ``jitter`` fraction of random extra so colliding submitters
    decorrelate) at most ``submit_retries`` times, then surfaces as
    typed ``BackpressureError`` too.  The penalty knobs feed replica
    selection: each gossiped queued command counts as
    ``queue_depth_penalty_pages`` of pre-existing load, and a
    supervisor-suspect shard starts ``suspect_penalty_pages`` deep — so
    reads steer away from hot or suspect shards *before* rings fill,
    unless a vertex class has no other live candidate."""

    max_inflight_per_shard: int = 8
    window_timeout_s: float = 5.0
    submit_retries: int = 4
    backoff_base_s: float = 0.002
    backoff_max_s: float = 0.1
    jitter: float = 0.5
    queue_depth_penalty_pages: float = 8.0
    suspect_penalty_pages: float = 1e5


def partition_csr(indptr: np.ndarray, indices: np.ndarray,
                  n_shards: int, shard: int, *, replication: int = 1,
                  placement: PlacementMap | None = None):
    """Mask a global CSR down to the rows shard ``shard`` owns.

    Non-owned rows keep indptr slots with zero degree, so the row index
    space stays global and ``GraphStore._write_adjacency`` (which skips
    degree-0 rows) lays out exactly the owned vertices.

    With ``replication=R`` the shard owns R residue classes — replica ``r``
    of vertex ``vid`` lives on shard ``(vid + r) % N``, so shard ``s``
    holds the classes ``{(s - r) % N, r < R}``.  The owned vid subset is
    still ascending, so the shard-local L-page range search is unchanged.

    A ``placement`` map replaces that modular rule: the shard owns the
    classes ``placement.classes_of(shard)`` under modulus
    ``placement.n_classes`` (``replication`` is then ignored — the map
    already encodes every replica role).
    """
    n = len(indptr) - 1
    degrees = np.diff(indptr)
    if placement is not None:
        modulus = placement.n_classes
        classes = placement.classes_of(shard)
    else:
        modulus = n_shards
        classes = [(shard - r) % n_shards for r in range(replication)]
    own = np.isin(np.arange(n) % modulus, classes)
    deg_s = np.where(own, degrees, 0)
    indptr_s = np.concatenate([[0], np.cumsum(deg_s)])
    row_of = np.repeat(np.arange(n), degrees)
    return indptr_s, indices[own[row_of]]


class _Routing:
    """One immutable routing generation of the array.

    Readers snapshot the coordinator's ``_routing`` reference once per
    operation and use only the snapshot, so an in-flight batched read
    keeps addressing the OLD owner of a migrating class while the
    resharder copies it; the atomic reference swap (under ``_mutate``,
    bumping ``epoch``) is the per-class flip.  Fields:

    * ``pmap`` — the :class:`PlacementMap` (class/role → shard),
    * ``ew_mod`` / ``ew_base`` — per (class, role) embedding extents:
      the local row of vid on its role-``r`` shard is
      ``ew_base[c, r] + vid // ew_mod[c, r]`` (coarse pre-refinement
      stripes keep their old modulus; migrated-in classes get dense
      ``mod = n_classes`` regions),
    * ``epoch`` — monotonically increasing flip counter,
    * ``heat`` — per-class accumulated read weight (the gossip-derived
      signal ``heat_plan`` partitions on); rides the routing object so
      its length always matches ``pmap.n_classes``.
    """

    __slots__ = ("pmap", "ew_mod", "ew_base", "epoch", "heat")

    def __init__(self, pmap: PlacementMap, ew_mod: np.ndarray,
                 ew_base: np.ndarray, epoch: int, heat: np.ndarray):
        self.pmap = pmap
        self.ew_mod = ew_mod
        self.ew_base = ew_base
        self.epoch = int(epoch)
        self.heat = heat


def _class_flow(supplies: dict, cand_of: dict, caps: np.ndarray):
    """Max-flow of class supplies into shard capacities (Edmonds-Karp on
    the tiny classes->candidates->shards graph).  Returns (total_flow,
    {(class, shard): amount})."""
    classes = list(supplies)
    n_cls, n_sh = len(classes), len(caps)
    v = n_cls + n_sh + 2
    src, snk = 0, v - 1
    cap = np.zeros((v, v))
    for i, c in enumerate(classes):
        cap[src, 1 + i] = supplies[c]
        for s in cand_of[c]:
            cap[1 + i, 1 + n_cls + s] = supplies[c]
    for s in range(n_sh):
        cap[1 + n_cls + s, snk] = caps[s]
    total = 0.0
    while True:
        parent = np.full(v, -1)
        parent[src] = src
        queue = [src]
        while queue and parent[snk] < 0:
            u = queue.pop(0)
            for w_ in np.nonzero(cap[u] > 1e-9)[0]:
                if parent[w_] < 0:
                    parent[w_] = u
                    queue.append(int(w_))
        if parent[snk] < 0:
            break
        aug, x = np.inf, snk
        while x != src:
            aug = min(aug, cap[parent[x], x])
            x = parent[x]
        x = snk
        while x != src:
            cap[parent[x], x] -= aug
            cap[x, parent[x]] += aug
            x = parent[x]
        total += aug
    flows = {(c, int(s)): float(cap[1 + n_cls + s, 1 + i])
             for i, c in enumerate(classes) for s in cand_of[c]
             if cap[1 + n_cls + s, 1 + i] > 1e-9}
    return total, flows


def _minmax_quotas(supplies: dict, cand_of: dict,
                   start: np.ndarray) -> dict:
    """Exact min-max assignment of per-class weights onto their candidate
    shards above existing ``start`` loads: binary search on the common
    load level, each probe a max-flow feasibility check.  Returns
    ``{class: additions aligned with cand_of[class]}``.  Greedy per-class
    waterfills are myopic on the replica ring (adjacent classes share
    candidates) and can overshoot an early shard a later class needs; the
    flow formulation is optimal for any replication factor."""
    total = float(sum(supplies.values()))
    if not supplies or total <= 0:
        return {c: np.zeros(len(cand_of[c])) for c in supplies}
    lo = float(np.min(start))
    hi = float(np.max(start)) + total
    eps = 1e-6 * max(1.0, total)
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        got, _fl = _class_flow(supplies, cand_of,
                               np.maximum(0.0, mid - start))
        if got >= total - eps:
            hi = mid
        else:
            lo = mid
    _, flows = _class_flow(supplies, cand_of,
                           np.maximum(0.0, hi + eps - start))
    return {c: np.asarray([flows.get((c, int(s)), 0.0)
                           for s in cand_of[c]])
            for c in supplies}


_CACHE_KEYS = ("hits", "misses", "evictions", "invalidations",
               "bytes_from_cache", "bytes_from_dev")


def aggregate_cache_snapshots(snaps) -> dict:
    """Sum per-shard cache snapshots into one array-level view (None
    entries — shards without a cache — are skipped).  Single source of
    truth for the counter key set, shared with the service ``stats``."""
    tot = dict.fromkeys(_CACHE_KEYS, 0)
    for snap in snaps:
        if snap is None:
            continue
        for k in tot:
            tot[k] += snap[k]
    n = tot["hits"] + tot["misses"]
    tot["hit_rate"] = tot["hits"] / n if n else 0.0
    return tot


class _AggCacheStats:
    """Aggregated view over the shards' per-device cache counters,
    pulled through the endpoint ``cache_stats`` snapshots."""

    def __init__(self, endpoints):
        self._endpoints = endpoints

    def snapshot(self) -> dict:
        return aggregate_cache_snapshots(
            ep.call("cache_stats") for ep in self._endpoints)

    @property
    def hit_rate(self) -> float:
        return self.snapshot()["hit_rate"]

    @property
    def hits(self) -> int:
        return self.snapshot()["hits"]

    @property
    def misses(self) -> int:
        return self.snapshot()["misses"]

    @property
    def invalidations(self) -> int:
        return self.snapshot()["invalidations"]


class _ShardedCacheView:
    """Duck-type of ``EmbeddingPageCache`` for telemetry/maintenance call
    sites (``.stats`` snapshots, ``.clear()``) spanning every shard."""

    def __init__(self, endpoints):
        self._endpoints = endpoints
        self.stats = _AggCacheStats(endpoints)

    def clear(self) -> None:
        for ep in self._endpoints:
            ep.call("clear_cache")


class ShardedGraphStore:
    """Drop-in for ``GraphStore`` across the query/mutation surface the
    service layer uses, backed by ``n_shards`` shard endpoints.

    Construction (exactly one backing form):

    Args:
        n_shards: shard count when the store builds its own local
            endpoints (defaults to 2; inferred from ``devs`` or
            ``endpoints`` when those are given).
        devs: explicit ``BlockDevice`` list, one per shard (local
            endpoints are built around them).
        endpoints: pre-built ``ShardEndpoint`` list (local, remote, or
            mixed); adopted as-is, including their ``h_threshold``.
        h_threshold: L/H degree threshold pushed to owned endpoints.
        feature_dim: embedding width for owned endpoints (0 until a
            table is loaded).
        placement: optional :class:`repro.store.placement.PlacementMap`
            replacing the default ``vid % N`` ownership (must have one
            role column for the unreplicated store).
        flow: :class:`FlowControl` policy (defaults applied when None).

    Raises:
        ValueError: conflicting backing arguments, zero shards, or a
            placement map that is not total over the array.
    """

    def __init__(self, n_shards: int | None = None,
                 devs: list | None = None, *, endpoints: list | None = None,
                 h_threshold: int = 128, feature_dim: int = 0,
                 placement: PlacementMap | None = None,
                 flow: FlowControl | None = None):
        if endpoints is not None:
            if devs is not None:
                raise ValueError("pass either endpoints=[...] or "
                                 "devs=[...], not both")
            if n_shards is not None and n_shards != len(endpoints):
                raise ValueError(f"n_shards={n_shards} conflicts with "
                                 f"{len(endpoints)} endpoints")
            if not endpoints:
                raise ValueError("need at least one shard")
            self.endpoints = list(endpoints)
            self.n_shards = len(self.endpoints)
        else:
            if devs is not None:
                if n_shards is not None and n_shards != len(devs):
                    raise ValueError(f"n_shards={n_shards} conflicts with "
                                     f"{len(devs)} explicit devices")
                n_shards = len(devs)
            elif n_shards is None:
                n_shards = 2
            if n_shards < 1:
                raise ValueError("need at least one shard")
            self.n_shards = int(n_shards)
            devs = devs or [BlockDevice() for _ in range(self.n_shards)]
            self.endpoints = make_local_endpoints(
                self.n_shards, devs, h_threshold=h_threshold,
                feature_dim=feature_dim)
        self.h_threshold = int(h_threshold)
        self._bulk = BulkTimeline()
        # composite mutations span shards; one coordinator lock restores
        # the single-store mutation atomicity (membership check + inserts
        # as one critical section).  Readers do NOT take it — a hop fetch
        # racing an add_edge may observe the half-inserted undirected edge,
        # the inherent visibility model of an array of devices.
        self._mutate = witness_lock("sharded._mutate", threading.RLock())
        # maintenance gate: a streaming shard rebuild holds it for the
        # whole stream, mutations take it FIRST (always _maintenance ->
        # _mutate, never the reverse) and therefore block until the
        # replacement is re-admitted — the survivors stay the exact
        # current state, no replay log — while reads, which take only
        # _mutate, keep flowing throughout the rebuild.
        self._maintenance = witness_lock(
            "sharded._maintenance", threading.RLock())
        # end-to-end flow control: per-shard in-flight windows + typed
        # backpressure (see FlowControl).  ``health`` is the optional
        # supervisor (serve/supervisor.py attaches itself here); the
        # store reports shard errors to it and reads its suspect set —
        # duck-typed, so the store layer never imports the serve layer.
        self.flow = flow or FlowControl()
        self.health = None
        self.backpressure_events = 0         # guarded-by: _bp_lock
        self.backpressure_retries = 0        # guarded-by: _bp_lock
        self._bp_lock = witness_lock(        # misc small-state guard
            "sharded._bp_lock", threading.Lock())
        self._rebuilding: set[int] = set()
        self._windows = [
            threading.BoundedSemaphore(self.flow.max_inflight_per_shard)
            if self.flow.max_inflight_per_shard > 0 else None
            for _ in range(self.n_shards)]
        # cumulative simulated array wait (each fetch pays max over shards):
        # the device-model latency, free of host scheduler noise — what the
        # scale-out benchmarks compare across array configurations.
        self.io_wait_us = 0.0                # guarded-by: _bp_lock
        # coordinator-side bookkeeping (no synchronous shard peeks): the
        # coordinator is the only writer, so it tracks the global vertex
        # count and feature dim itself and boots them from one stats
        # snapshot per endpoint.  Caller-supplied endpoints are adopted
        # as built — the coordinator takes THEIR h_threshold rather than
        # pushing its own default over a layout the shards may already
        # have ingested with.
        own_endpoints = endpoints is None
        self._feature_dim = int(feature_dim)
        self._num_vertices = 0
        self._failed = [False] * self.n_shards
        for s, ep in enumerate(self.endpoints):
            if not getattr(ep, "_peers_wired", False):
                ep.set_peers(self.endpoints)
                ep._peers_wired = True
            snap = ep.stats()
            self._num_vertices = max(self._num_vertices,
                                     int(snap["store"]["num_vertices"]))
            self._feature_dim = max(self._feature_dim,
                                    int(snap["store"]["feature_dim"]))
            self._failed[s] = bool(snap["failed"])
            if not own_endpoints:
                self.h_threshold = int(snap["store"]["h_threshold"])
        # routing generation + reshard machinery.  ``replication`` and
        # ``_emb_rows`` live on the base class so the routing/locate math
        # is shared; the replicated subclass overwrites them before its
        # own ``_init_routing`` call.
        self.replication = 1
        self._emb_rows = 0
        # reader barrier: batched reads register with ``_read_routing``
        # so a class flip can quiesce every in-flight read that may hold
        # a pre-flip routing snapshot before the old owner's pages are
        # dropped.  Independent lock — NEVER held together with _mutate.
        self._rd_cv = witness_condition(
            "sharded._rd_cv", threading.Condition(threading.Lock()))
        self._rd_active = 0
        self._rd_barrier = False
        # per-class write gates during a copy window + reshard state.
        self._mig_classes: set[int] = set()
        self._mig_cv = threading.Condition(self._mutate)
        self._resharding = False
        self._reshard_stats: dict = {}
        if not hasattr(self, "_init_routing_deferred"):
            self._init_routing(1, placement)

    # ------------------------------------------------------------- routing
    def _init_routing(self, replication: int, placement) -> None:
        """Install the initial routing generation: the given placement
        map (validated against the array) or the legacy modular map
        ``owner[c, r] = (c + r) % N``, with canonical embedding extents.

        Raises:
            ValueError: placement map that is not total, out of range,
                or has the wrong number of role columns.
        """
        pmap = placement if placement is not None else modular(
            self.n_shards, replication)
        if pmap.owner.shape[1] != replication:
            raise ValueError(
                f"placement map has {pmap.owner.shape[1]} role columns, "
                f"store replication is {replication}")
        pmap.validate(self.n_shards)
        self._routing = self._canonical_routing(pmap, self._emb_rows, 0)

    def _canonical_routing(self, pmap: PlacementMap, n_rows: int,
                           epoch: int, heat: np.ndarray | None = None
                           ) -> _Routing:
        """Build a ``_Routing`` whose embedding extents are the canonical
        dense layout: every class striped at modulus ``n_classes``, each
        shard's stripes concatenated in ``pairs_of`` order (role-major,
        class-ascending — exactly the legacy ``_stripe_off`` cumsum at
        the default modular map)."""
        C, R = pmap.n_classes, pmap.owner.shape[1]
        ew_mod = np.full((C, R), C, dtype=np.int64)
        ew_base = np.zeros((C, R), dtype=np.int64)
        for s in range(self.n_shards):
            acc = 0
            for c, r in pmap.pairs_of(s):
                ew_base[c, r] = acc
                acc += rows_of_class(n_rows, c, C)
        if heat is None:
            heat = np.zeros(C, dtype=np.float64)
        return _Routing(pmap, ew_mod, ew_base, epoch, heat)

    def _swap_routing(self, rt: _Routing) -> None:
        """Atomically publish a new routing generation (callers hold
        ``_mutate``; readers pick it up on their next snapshot)."""
        self._routing = rt

    @contextmanager
    def _read_routing(self):
        """Register a routing-snapshot read: yields the current routing
        and holds the read barrier open until the reader finishes, so a
        class flip can wait out every read planned against the pre-flip
        owners before dropping their pages."""
        cv = self._rd_cv
        with cv:
            while self._rd_barrier:
                cv.wait()
            self._rd_active += 1
            rt = self._routing
        try:
            yield rt
        finally:
            with cv:
                self._rd_active -= 1
                cv.notify_all()

    @contextmanager
    def _quiesce_reads(self):
        """Block new snapshot reads and wait for in-flight ones to drain
        (used between a routing flip and dropping the vacated pages).
        Never entered while holding ``_mutate`` — a draining reader may
        need it."""
        cv = self._rd_cv
        with cv:
            while self._rd_barrier:
                cv.wait()
            self._rd_barrier = True
            while self._rd_active > 0:
                cv.wait()
        try:
            yield
        finally:
            with cv:
                self._rd_barrier = False
                cv.notify_all()

    def _check_not_resharding(self, what: str) -> None:
        if self._resharding:
            raise RuntimeError(
                f"{what} rejected: online reshard in progress")

    def _emb_locate(self, vid: int, rt: _Routing | None = None):
        """Live (shard, local embedding row) candidates for ``vid``,
        primary role first.

        Raises:
            DeviceFailedError: every replica of the vid's class is on a
                failed shard.
        """
        rt = rt or self._routing
        c = int(vid) % rt.pmap.n_classes
        out = []
        for r in range(rt.pmap.owner.shape[1]):
            s = int(rt.pmap.owner[c, r])
            if not self._failed[s]:
                out.append((s, int(rt.ew_base[c, r])
                            + int(vid) // int(rt.ew_mod[c, r])))
        if not out:
            raise DeviceFailedError(
                f"all replicas of vid {vid} (class {c}) are failed")
        return out

    # ------------------------------------------------------------- topology
    @property
    def failed_shards(self) -> list[bool]:
        """Per-shard failed flags (True = dropped by ``fail_shard``)."""
        return list(self._failed)

    @property
    def shards(self) -> list:
        """The in-process ``GraphStore`` objects (tests/benchmarks only —
        coordinator code never touches them).  Raises for remote arrays,
        whose stores live behind the RoP link."""
        try:
            return [ep.local_store for ep in self.endpoints]
        except AttributeError:
            raise RuntimeError("shards are remote (RopShardEndpoint); "
                               "use the endpoint stats API") from None

    @property
    def devs(self) -> list:
        """The shards' ``BlockDevice``s (in-process arrays only)."""
        return [sh.dev for sh in self.shards]

    def owner_of(self, vid: int) -> int:
        """Primary owner shard of ``vid`` under the current routing
        (equals ``vid % n_shards`` at the default modular placement)."""
        rt = self._routing
        return int(rt.pmap.owner[int(vid) % rt.pmap.n_classes, 0])

    def _owner_ep(self, vid: int):
        return self.endpoints[self.owner_of(vid)]

    def _map(self, fn, items):
        """Bulk-ingest fan-out: per-shard write bursts (ms-scale simulated
        sleeps, GIL released) overlap on real threads.  The pool is
        transient — created per phase, joined before returning — so idle
        stores hold no threads.  The read fan-out does NOT use threads:
        batched reads are submitted to every endpoint and awaited
        together instead (see ``_endpoint_fetch``)."""
        items = list(items)
        if len(items) <= 1:
            return [fn(x) for x in items]
        with ThreadPoolExecutor(max_workers=len(items),
                                thread_name_prefix="shard") as pool:
            return list(pool.map(fn, items))

    @property
    def feature_dim(self) -> int:
        """Embedding feature dimension (0 until a table is loaded)."""
        return self._feature_dim

    @property
    def num_vertices(self) -> int:
        """Vertex-id space size as of the last bulk load + unit adds."""
        return self._num_vertices

    def shard_stats(self) -> list[dict]:
        """One ``stats`` snapshot per shard endpoint — the telemetry the
        service layer aggregates (identical shape local or remote)."""
        return [ep.stats() for ep in self.endpoints]

    @property
    def stats(self) -> GraphStoreStats:
        """Array-aggregated ``GraphStoreStats`` (summed over one
        endpoint ``stats`` snapshot per shard)."""
        snaps = self.shard_stats()
        out = GraphStoreStats(
            l_evictions=sum(s["store"]["l_evictions"] for s in snaps),
            unit_updates=sum(s["store"]["unit_updates"] for s in snaps),
            pages_h=sum(s["store"]["pages_h"] for s in snaps),
            pages_l=sum(s["store"]["pages_l"] for s in snaps),
            bulk=self._bulk)
        if any(s["cache"] is not None for s in snaps):
            out.cache = _AggCacheStats(self.endpoints)
        return out

    def close(self) -> None:
        """Release endpoint resources (remote hosts stop their poll
        threads; local endpoints are no-ops)."""
        for ep in self.endpoints:
            ep.close()

    # ---------------------------------------------------------------- cache
    @property
    def cache(self):
        """Aggregated device-DRAM cache view, or ``None`` when no
        cache is attached (see ``attach_cache_pages``)."""
        if self.endpoints[0].call("cache_stats") is None:
            return None
        return _ShardedCacheView(self.endpoints)

    def attach_cache_pages(self, capacity_pages: int, **kw) -> None:
        """Split one device-DRAM budget evenly across the shards — each
        device fronts its own reads and invalidates through its own
        ``on_write`` hook, so coherence needs no cross-shard traffic."""
        per_shard = max(1, int(capacity_pages) // self.n_shards)
        for ep in self.endpoints:
            ep.call("attach_cache", capacity_pages=per_shard, **kw)

    # ----------------------------------------------------------- bulk ingest
    def _prepare_emb_layout(self, n_rows: int) -> None:
        """Called once per bulk ingest with the embedding row count,
        before any shard's table write: records the row count and
        installs fresh canonical embedding extents for the current
        placement map (``vid // N`` per stripe at the default map)."""
        with self._mutate:
            self._emb_rows = int(n_rows)
            rt = self._routing
            self._swap_routing(self._canonical_routing(
                rt.pmap, self._emb_rows, rt.epoch + 1, rt.heat))

    def _emb_shard_rows(self, embeddings: np.ndarray, s: int) -> np.ndarray:
        """The embedding rows shard ``s`` stores, in local-row order:
        one round-robin stripe per owned (class, role) pair, in
        canonical ``pairs_of`` order (``embeddings[s::N]`` at the
        default unreplicated map)."""
        pmap = self._routing.pmap
        C = pmap.n_classes
        return np.concatenate(
            [embeddings[c::C] for c, _r in pmap.pairs_of(s)]) \
            if pmap.pairs_of(s) else embeddings[:0]

    def _adj_shard_csr(self, indptr: np.ndarray, indices: np.ndarray,
                       s: int):
        """The global-CSR mask shard ``s`` writes as adjacency (all owned
        classes under the current placement map)."""
        return partition_csr(indptr, indices, self.n_shards, s,
                             placement=self._routing.pmap)

    def update_graph(self, edge_array: np.ndarray,
                     embeddings: np.ndarray | None = None,
                     *, already_undirected: bool = False) -> BulkTimeline:
        """Bulk UpdateGraph across the array.

        The global edge preprocessing runs once, overlapped with the
        (much larger) embedding write exactly as on one device — except the
        embedding table is striped ``embeddings[s::N]`` and every shard's
        sequential write burst proceeds in parallel on its own device.

        Raises:
            RuntimeError: an online reshard is migrating classes.
        """
        self._check_not_resharding("bulk ingest")
        tl = BulkTimeline()
        t0 = time.perf_counter()

        # No defensive copy: preprocess_edges never mutates its input, so
        # the coordinator holds ONE edge array during bulk load (the copy
        # here used to double peak host memory for nothing).
        edge_array = np.asarray(edge_array, dtype=np.int64).reshape(-1, 2)
        if embeddings is not None:
            embeddings = np.ascontiguousarray(embeddings, dtype=np.float32)
            self._feature_dim = int(embeddings.shape[1])
            self._prepare_emb_layout(len(embeddings))
        tl.transfer = (0.0, time.perf_counter() - t0)

        box: dict = {}

        def graph_pre():
            s = time.perf_counter() - t0
            box["csr"] = preprocess_edges(
                edge_array, already_undirected=already_undirected)
            box["span"] = (s, time.perf_counter() - t0)

        def write_feature():
            s = time.perf_counter() - t0
            if embeddings is not None:
                self._map(lambda sh: self.endpoints[sh].call(
                    "write_embedding_table",
                    rows=self._emb_shard_rows(embeddings, sh)),
                    range(self.n_shards))
            box["wf"] = (s, time.perf_counter() - t0)

        th_g = threading.Thread(target=graph_pre)
        th_f = threading.Thread(target=write_feature)
        th_g.start(); th_f.start()
        th_f.join()
        user_visible_at = time.perf_counter() - t0
        th_g.join()
        tl.graph_pre = box["span"]
        tl.write_feature = box.get("wf", (0.0, 0.0))

        s0 = time.perf_counter() - t0
        indptr, indices = box["csr"]
        self._num_vertices = max(self._num_vertices, len(indptr) - 1)

        def write_adj(s):
            ip, ix = self._adj_shard_csr(indptr, indices, s)
            self.endpoints[s].call("write_adjacency", indptr=ip, indices=ix)

        self._map(write_adj, range(self.n_shards))
        tl.write_graph = (s0, time.perf_counter() - t0)
        tl.total = time.perf_counter() - t0
        tl.user_visible = max(user_visible_at, tl.transfer[1])
        self._bulk = tl
        return tl

    def update_graph_chunked(self, edge_array: np.ndarray,
                             embeddings: np.ndarray | None = None,
                             *, already_undirected: bool = False,
                             chunk_edges: int | None = None,
                             emb_chunk_rows: int | None = None
                             ) -> BulkTimeline:
        """Distributed device-side bulk load: the coordinator streams RAW
        edge chunks and embedding stripe slices; every shard buckets,
        sorts and packs its partition locally, exchanging cross-shard
        pairs with its peers (store/ingest.py).  Bit-identical pages and
        reads vs ``update_graph``, with coordinator bytes O(E) raw chunks
        (zero preprocessed CSR) and the graph-pre sort scaling with N.

        Held behind the maintenance gate like any bulk ingest; reads
        (which take only the mutation lock) keep flowing throughout."""
        from .ingest import distributed_update_graph
        kw: dict = {}
        if chunk_edges is not None:
            kw["chunk_edges"] = int(chunk_edges)
        if emb_chunk_rows is not None:
            kw["emb_chunk_rows"] = int(emb_chunk_rows)
        with self._maintenance:
            self._check_not_resharding("bulk ingest")
            if any(self._failed):
                raise DeviceFailedError(
                    "bulk ingest needs every shard live; rebuild_shard "
                    "first")
            return distributed_update_graph(
                self, edge_array, embeddings,
                already_undirected=already_undirected, **kw)

    def firehose(self, **kw) -> "object":
        """A ``MutationFirehose`` over this array: windowed write batching
        with per-shard device-side application (store/ingest.py)."""
        from .ingest import MutationFirehose
        return MutationFirehose(self, **kw)

    # ------------------------------------------------------ batched queries
    def _partition(self, vids: np.ndarray,
                   rt: _Routing | None = None
                   ) -> list[tuple[int, np.ndarray]]:
        """plan phase: query positions grouped by primary-owner shard
        under routing snapshot ``rt`` (no I/O).  Also accumulates the
        per-class read heat the heat-aware resharder partitions on."""
        rt = rt or self._routing
        cls = vids % rt.pmap.n_classes
        np.add.at(rt.heat, cls, 1.0)
        owner = rt.pmap.owner[cls, 0]
        parts = [(s, np.nonzero(owner == s)[0])
                 for s in range(self.n_shards)]
        return [(s, pos) for s, pos in parts if len(pos)]

    # ------------------------------------------------------- flow control
    @contextmanager
    def _write_gate(self, vids=None):
        """Mutation critical section: maintenance gate first, then the
        mutation lock (the one legal order — see ``_maintenance``).

        While a reshard copies a class, writes touching that class wait
        on ``_mig_cv`` until its flip (``vids=None`` — e.g. a
        delete_vertex whose neighbor set is unknown up front — waits out
        ANY migrating class).  Nested gates never wait: a migration
        window cannot begin while ``_mutate`` is held, so reentrant
        callers already inside the gate see ``_mig_classes`` unchanged.
        """
        with self._maintenance:
            with self._mutate:
                if vids is None:
                    while self._mig_classes:
                        self._mig_cv.wait()
                else:
                    while any(int(v) % self._routing.pmap.n_classes
                              in self._mig_classes for v in vids):
                        self._mig_cv.wait()
                yield

    def _notify_shard_error(self, shard: int, exc: Exception) -> None:
        """Report a shard-attributed ``DeviceFailedError`` to the attached
        supervisor (if any) — the error-mapping half of failure detection.
        Never raises: health reporting must not break the serving path."""
        sup = self.health
        if sup is not None:
            try:
                sup.record_error(int(shard), exc)
            except Exception:  # noqa: BLE001 — telemetry is best-effort
                pass

    def _shed(self, msg: str, reason: dict) -> BackpressureError:
        with self._bp_lock:
            self.backpressure_events += 1
        return BackpressureError(msg, reason=reason)

    def _bp_backoff(self, attempt: int) -> None:
        """Exponential backoff + jitter between submit retries."""
        fl = self.flow
        with self._bp_lock:
            self.backpressure_retries += 1
        delay = min(fl.backoff_max_s, fl.backoff_base_s * (2 ** attempt))
        time.sleep(delay * (1.0 + fl.jitter * random.random()))

    def _acquire_windows(self, shards) -> list:
        """Take one in-flight window slot per distinct target shard; on
        timeout release what was taken and shed typed backpressure.
        Returns the semaphore OBJECTS, not shard indices — a reshard may
        remap ``_windows`` while this round is in flight, and the release
        must hit the semaphores actually acquired."""
        taken: list = []
        for s in shards:
            win = self._windows[s]
            if win is None:
                continue
            if not win.acquire(timeout=self.flow.window_timeout_s):
                for t in taken:
                    t.release()
                raise self._shed(
                    f"shard {s} in-flight window full "
                    f"(limit {self.flow.max_inflight_per_shard}, waited "
                    f"{self.flow.window_timeout_s}s)",
                    {"source": "inflight_window", "shard": int(s),
                     "limit": self.flow.max_inflight_per_shard})
            taken.append(win)
        return taken

    def _release_windows(self, taken) -> None:
        for win in taken:
            win.release()

    def _submit_round(self, items: list) -> list:
        """One concurrent metadata round: submit ``(shard, method,
        kwargs)`` to every listed endpoint, then await all completions.

        A ``QueueFullError`` part-way through the submits must not abort
        the round half-issued: the handles already written are reaped
        (their completions consumed), then the FULL shard set is retried
        after exponential backoff — bounded by ``flow.submit_retries``,
        after which it sheds as typed ``BackpressureError``.  A shard
        that fails mid-round is reported to the supervisor and the
        remaining completions are reaped before the error propagates."""
        for attempt in range(self.flow.submit_retries + 1):
            handles: list = []
            try:
                for s, method, kw in items:
                    handles.append(
                        (s, self.endpoints[s].call_submit(method, **kw)))
            except QueueFullError as e:
                self._reap_call_handles(handles)
                if attempt >= self.flow.submit_retries:
                    raise self._shed(
                        f"submit round over {len(items)} shards still "
                        f"queue-full after {attempt + 1} attempts: {e}",
                        {"source": "queue_full", "shard": int(items[len(handles)][0]),
                         "attempts": attempt + 1, "qid": e.qid}) from e
                self._bp_backoff(attempt)
                continue
            except Exception as e:
                self._reap_call_handles(handles)
                if isinstance(e, DeviceFailedError):
                    self._notify_shard_error(items[len(handles)][0], e)
                raise
            outs: list = []
            try:
                for s, h in handles:
                    outs.append(self.endpoints[s].call_result(h))
            except Exception as e:
                self._reap_call_handles(handles[len(outs) + 1:])
                if isinstance(e, DeviceFailedError):
                    self._notify_shard_error(handles[len(outs)][0], e)
                raise
            return outs
        raise AssertionError("unreachable")

    def _reap_call_handles(self, handles) -> None:
        """Consume outstanding ``call_submit`` completions (best-effort)
        so abandoned replies never sit in the CQs forever."""
        for s, h in handles:
            try:
                self.endpoints[s].call_result(h)
            except Exception:  # noqa: BLE001 — best-effort reap
                pass

    def probe_shards(self) -> list[dict]:
        """Supervisor heartbeat: one concurrent ``counters`` round over
        EVERY endpoint (failed devices answer too — stats attributes stay
        readable after ``fail()``), independent of the gossip cache.
        Per-shard dicts carry ``failed`` + queue pressure; an endpoint
        whose probe itself errors reports ``{"error": ...}`` instead of
        taking the array down."""
        handles: list = []
        for s, ep in enumerate(self.endpoints):
            try:
                handles.append((s, ep.call_submit("counters"), None))
            except Exception as e:  # noqa: BLE001 — probe must not throw
                handles.append((s, None, e))
        out: list[dict] = []
        for s, h, err in handles:
            if err is None:
                try:
                    c = dict(self.endpoints[s].call_result(h))
                    c["shard"] = s
                    out.append(c)
                    continue
                except Exception as e:  # noqa: BLE001
                    err = e
            out.append({"shard": s, "error": f"{type(err).__name__}: {err}"})
        return out

    def _endpoint_fetch(self, reqs, *, pay: bool = True):
        """fetch phase: ONE batched ``fetch`` command per shard, submitted
        to every endpoint, then awaited together.

        Each shard's simulated flash + command time is deferred
        device-side and ships back as ``io_us``; the array pays one wait
        equal to the slowest shard — the devices execute their queued
        commands concurrently, mirroring how the flash channels inside
        one device are modelled (divide, don't sum).  ``reqs`` is a list
        of ``(shard, fetch-kwargs)``; returns (payloads, worst_io_us).

        Flow control wraps the round end to end: one in-flight window
        slot per target shard bounds how many rounds can stack onto one
        shard host, and a ``QueueFullError`` part-way through the
        submits reaps what was issued and retries the round with
        backoff before shedding as typed ``BackpressureError``.
        """
        slots = self._acquire_windows([s for s, _ in reqs])
        try:
            handles = self._submit_fetches(reqs)
            outs, worst = self._await_fetches(handles)
        finally:
            self._release_windows(slots)
        if pay:
            with self._bp_lock:
                self.io_wait_us += worst
            sleep_us(worst)
        return outs, worst

    def _submit_fetches(self, reqs) -> list:
        for attempt in range(self.flow.submit_retries + 1):
            handles: list = []
            try:
                for s, kw in reqs:
                    handles.append((s, self.endpoints[s].fetch_submit(**kw)))
                return handles
            except QueueFullError as e:
                self._reap_fetch_handles(handles)
                if attempt >= self.flow.submit_retries:
                    raise self._shed(
                        f"batched fetch still queue-full after "
                        f"{attempt + 1} attempts: {e}",
                        {"source": "queue_full",
                         "shard": int(reqs[len(handles)][0]),
                         "attempts": attempt + 1, "qid": e.qid}) from e
                self._bp_backoff(attempt)
            except Exception as e:
                # a local endpoint computes at submit time, so a drained
                # device surfaces HERE rather than at await
                self._reap_fetch_handles(handles)
                if isinstance(e, DeviceFailedError):
                    self._notify_shard_error(reqs[len(handles)][0], e)
                raise
        raise AssertionError("unreachable")

    def _await_fetches(self, handles):
        outs, worst = [], 0.0
        try:
            for s, h in handles:
                payload = self.endpoints[s].fetch_result(h)
                worst = max(worst, float(payload["io_us"]))
                outs.append(payload)
        except BaseException as e:
            # a shard failed mid-await (drain path): reap every
            # outstanding completion before re-raising, or their reply
            # payloads sit in the CQs forever — each failover retry would
            # leak the healthy shards' full page blocks.  The handle
            # whose await raised is already consumed.
            self._reap_fetch_handles(handles[len(outs) + 1:])
            if isinstance(e, DeviceFailedError):
                self._notify_shard_error(handles[len(outs)][0], e)
            raise
        return outs, worst

    def _reap_fetch_handles(self, handles) -> None:
        for s, h in handles:
            try:
                self.endpoints[s].fetch_result(h)
            except Exception:  # noqa: BLE001 — best-effort reap
                pass

    def _fan_fetch(self, vids_arr: np.ndarray):
        """plan -> per-shard fetch -> build: the shared front half of the
        batched queries (see module docstring).  Returns a global
        (block, desc) position-identical to a single device's
        ``_fetch_plan`` over the same vids.
        """
        with self._read_routing() as rt:
            parts = self._partition(vids_arr, rt)

            # fetch: ONE batched command per shard, all shards concurrent
            payloads, _ = self._endpoint_fetch(
                [(s, {"l_vids": vids_arr[pos]}) for s, pos in parts])

        # build: re-base each shard's descriptor rows into the concatenated
        # block and scatter them back to their global positions
        desc: list = [None] * len(vids_arr)
        blocks = []
        row_off = 0
        for (s, pos), pl in zip(parts, payloads):
            blk, dsc = pl["block"], pl["desc"]
            for p, d in zip(pos.tolist(), dsc):
                if d is None:
                    continue
                if d[0] == "L":
                    desc[p] = ("L", d[1] + row_off, d[2], d[3])
                else:
                    desc[p] = ("H", d[1] + row_off, d[2])
            if blk is not None:
                blocks.append(blk)
                row_off += blk.shape[0]
        if not blocks:
            return None, desc
        # single contributing shard: its block is already global
        block = blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
        return block, desc

    def get_neighbors(self, vid: int) -> np.ndarray:
        """Neighbor list of one vid from its owning shard."""
        with self._read_routing() as rt:
            c = int(vid) % rt.pmap.n_classes
            ep = self.endpoints[int(rt.pmap.owner[c, 0])]
            return ep.call("get_neighbors", vid=int(vid))

    def get_neighbors_batch(self, vids) -> list[np.ndarray]:
        """Batched neighbor read: one fetch command per shard, results
        recomposed in input order (bit-identical to the single-device
        store)."""
        vids_arr = np.asarray(vids, dtype=np.int64).reshape(-1)
        block, desc = self._fan_fetch(vids_arr)
        return neighbors_from_plan(vids_arr, block, desc)

    def sample_neighbors_batch(self, vids, fanout: int,
                               rng: np.random.Generator | None = None, *,
                               segments=None, rngs=None):
        """Fused fetch+subsample across the array — one batched command per
        shard per hop, then the single-device selection over the recomposed
        plan (rng consumed in global frontier order: bit-identical)."""
        vids_arr = np.asarray(vids, dtype=np.int64).reshape(-1)
        block, desc = self._fan_fetch(vids_arr)
        return select_from_plan(vids_arr, block, desc, fanout, rng,
                                segments=segments, rngs=rngs)

    # ----------------------------------------------------------- embeddings
    def get_embed(self, vid: int) -> np.ndarray:
        """One embedding row from the vid's owning shard."""
        with self._read_routing() as rt:
            s, row = self._emb_locate(vid, rt)[0]
            return self.endpoints[s].call("get_embed_row", row=row)

    def get_embeds(self, vids: np.ndarray) -> np.ndarray:
        """Coalesced gather across the array: each shard serves its owned
        rows (local row from the routing extents; ``vid // N`` at the
        default map) with ONE batched command, concurrently; rows
        scatter back to their query positions."""
        d = self.feature_dim
        if not d:
            raise KeyError("no embedding table loaded")
        vids = np.asarray(vids, dtype=np.int64).reshape(-1)
        out = np.empty((len(vids), d), dtype=np.float32)
        if not len(vids):
            return out
        with self._read_routing() as rt:
            cls = vids % rt.pmap.n_classes
            parts = self._partition(vids, rt)
            reqs = []
            for s, pos in parts:
                c = cls[pos]
                reqs.append((s, {"emb_rows": rt.ew_base[c, 0]
                                 + vids[pos] // rt.ew_mod[c, 0]}))
            payloads, _ = self._endpoint_fetch(reqs)
        for (s, pos), pl in zip(parts, payloads):
            out[pos] = pl["emb"]
        return out

    def update_embed(self, vid: int, embed: np.ndarray) -> None:
        """Overwrite one embedding row on the vid's owner (all live
        replicas when replicated)."""
        with self._write_gate((vid,)):
            for s, row in self._emb_locate(vid):
                self.endpoints[s].call("update_embed_row", row=row,
                                       embed=embed)

    # ------------------------------------------------------------- unit ops
    def add_vertex(self, vid: int, embed: np.ndarray | None = None) -> None:
        """Insert an isolated vertex (idempotent), optionally with its
        embedding row."""
        with self._write_gate((vid,)):
            vid = int(vid)
            ep = self._owner_ep(vid)
            ep.call("add_vertex", vid=vid)       # adjacency under global vid
            self._num_vertices = max(self._num_vertices, vid + 1)
            if embed is not None:
                for s, row in self._emb_locate(vid):
                    self.endpoints[s].call("update_embed_row", row=row,
                                           embed=embed)

    def add_edge(self, dst: int, src: int) -> None:
        """Undirected insert: each endpoint's chunk updates on ITS owning
        shard (two independent single-page RMWs, possibly on different
        devices)."""
        with self._write_gate((dst, src)):
            dst, src = int(dst), int(src)
            for v in (dst, src):
                # device-side add_vertex no-ops when the vid exists
                self._owner_ep(v).call("add_vertex", vid=v)
                self._num_vertices = max(self._num_vertices, v + 1)
            self._owner_ep(dst).call("insert_neighbor", vid=dst, nbr=src,
                                     count=True)
            if dst != src:
                self._owner_ep(src).call("insert_neighbor", vid=src,
                                         nbr=dst, count=False)

    def delete_edge(self, dst: int, src: int) -> None:
        """Undirected removal of edge (dst, src) from both owners."""
        with self._write_gate((dst, src)):
            dst, src = int(dst), int(src)
            self._owner_ep(dst).call("remove_neighbor", vid=dst, nbr=src,
                                     count=True)
            if dst != src:
                self._owner_ep(src).call("remove_neighbor", vid=src,
                                         nbr=dst, count=False)

    def delete_vertex(self, vid: int) -> None:
        """Remove ``vid`` everywhere: backlinks on each neighbor's owning
        shard first, then the owner drops the vertex's own pages.  The
        neighbor set (and so the touched class set) is unknown up front,
        so the gate waits out ANY in-flight class migration."""
        with self._write_gate():
            vid = int(vid)
            nbrs = self._owner_ep(vid).call("get_neighbors", vid=vid)
            for nbr in np.asarray(nbrs).tolist():
                nbr = int(nbr)
                if nbr == vid:
                    continue
                self._owner_ep(nbr).call("remove_neighbor", vid=nbr,
                                         nbr=vid, count=False)
            self._owner_ep(vid).call("drop_vertex_pages", vid=vid,
                                     count=True)

    # --------------------------------------------------------------- export
    def to_adjacency(self) -> dict[int, set[int]]:
        """Full adjacency as ``{vid: neighbor set}`` (test/verification
        helper — walks every shard)."""
        out: dict[int, set[int]] = {}
        for ep in self.endpoints:
            for v, nb in ep.call("export_adjacency"):
                out[int(v)] = set(np.asarray(nb).tolist())
        return out

    # ------------------------------------------------------ online reshard
    def placement_stats(self) -> dict:
        """Routing/placement telemetry: class count, routing epoch,
        whether the map is still the legacy modular layout, per-shard
        owned-class counts, live-migration state, accumulated read heat
        and the last reshard's report."""
        rt = self._routing
        with self._mutate:
            migrating = sorted(self._mig_classes)
        with self._bp_lock:
            resharding = self._resharding
            last = dict(self._reshard_stats)
        return {
            "n_classes": int(rt.pmap.n_classes),
            "replication": int(self.replication),
            "epoch": int(rt.epoch),
            "modular": bool(rt.pmap.is_modular(self.n_shards)),
            "classes_per_shard": [len(rt.pmap.classes_of(s))
                                  for s in range(self.n_shards)],
            "resharding": resharding,
            "migrating_classes": migrating,
            "heat_total": float(rt.heat.sum()),
            "last_reshard": last,
        }

    def _live_sources(self, c: int, dst: int) -> list[int]:
        """Live shards holding class ``c`` under the CURRENT routing,
        excluding ``dst`` — the candidate copy sources, primary first."""
        row = self._routing.pmap.owner[c]
        out = []
        for s in (int(x) for x in row):
            if s != dst and not self._failed[s] and s not in out:
                out.append(s)
        if not out:
            raise DeviceFailedError(
                f"no live source holds vertex class {c}")
        return out

    def _migrate_copy(self, m, C: int, chunk_pages: int, pace_s: float,
                      on_progress, acc: dict) -> tuple[int, int]:
        """Stream one copy move: the destination pulls class ``m.cls``'s
        adjacency chunks and embedding rows from a live source over the
        peer links (page data never transits the coordinator).  Returns
        the (ew_base, ew_mod) extent the class gets on ``m.dst`` at flip
        time.  Fails over to another live replica of the class if the
        source dies mid-stream (chunk pulls are replace-safe, so a
        partially-pulled range is simply re-pulled)."""
        c, dst = int(m.cls), int(m.dst)
        dep = self.endpoints[dst]
        srcs = self._live_sources(c, dst)
        if int(m.src) in srcs:       # plan's source first
            srcs.remove(int(m.src))
            srcs.insert(0, int(m.src))

        # ---- adjacency: cursor loop over bounded page chunks
        cursor, done, last_err = 0, False, None
        for src in srcs:
            try:
                while not done:
                    out = dep.call("migrate_pull", cls=c, modulus=C,
                                   src=src, start_vid=cursor,
                                   max_pages=chunk_pages)
                    cursor, done = int(out["next_vid"]), bool(out["done"])
                    acc["chunks"] += 1
                    acc["pages_shipped"] += int(out["pages"])
                    acc["adj_bytes"] += int(out["bytes"])
                    acc["bytes_shipped"] += int(out["bytes"])
                    if on_progress is not None:
                        on_progress({"event": "chunk", "cls": c,
                                     "src": src, "dst": dst,
                                     "next_vid": cursor, "done": done,
                                     "bytes": int(out["bytes"])})
                    if pace_s:
                        time.sleep(pace_s)
                break
            except DeviceFailedError as e:
                self._notify_shard_error(src, e)
                last_err = e
        else:
            raise last_err

        # ---- embeddings: reserve a dense region on dst, pull row chunks
        base = 0
        rows = rows_of_class(self._emb_rows, c, C)
        if rows and self._feature_dim:
            base = int(dep.call("emb_reserve_rows", n_rows=rows)["base"])
            d = max(1, self._feature_dim)
            take = max(1, (chunk_pages * SLOTS_PER_PAGE) // d)
            rt = self._routing
            row0, last_err = 0, None
            while row0 < rows:
                n = min(take, rows - row0)
                for src in self._live_sources(c, dst):
                    r2 = [r for r in range(rt.pmap.owner.shape[1])
                          if int(rt.pmap.owner[c, r]) == src][0]
                    try:
                        out = dep.call(
                            "migrate_pull_emb", src=src, cls=c, modulus=C,
                            src_base=int(rt.ew_base[c, r2]),
                            src_mod=int(rt.ew_mod[c, r2]),
                            row0=row0, take=n, dst_row0=base + row0)
                        acc["emb_bytes"] += int(out["bytes"])
                        acc["bytes_shipped"] += int(out["bytes"])
                        acc["chunks"] += 1
                        break
                    except DeviceFailedError as e:
                        self._notify_shard_error(src, e)
                        last_err = e
                else:
                    raise last_err
                row0 += n
                if on_progress is not None:
                    on_progress({"event": "emb_chunk", "cls": c,
                                 "dst": dst, "rows_done": row0,
                                 "rows": rows})
                if pace_s:
                    time.sleep(pace_s)
        return base, C

    def reshard(self, *, add: list | None = None,
                remove: list | None = None,
                placement: PlacementMap | None = None,
                rebalance: bool = False, refine: int = 4,
                chunk_pages: int | None = None, pace_s: float = 0.0,
                on_progress=None) -> dict:
        """Elastic online reshard: change the array's shard set or its
        placement map under live traffic, with zero downtime.

        Exactly one mode:

        Args:
            add: new ``ShardEndpoint`` list to grow onto (attached
                immediately; the planner steals the hottest classes from
                the most-loaded existing shards).
            remove: shard indices to drain and detach (their classes
                move to the least-loaded survivors; indices compact when
                the last class flips).
            placement: explicit target :class:`PlacementMap` (same
                replication; refined to a common class count with the
                current map).
            rebalance: True = heat-weighted rebalance over the current
                shards using the accumulated read-heat histogram.
            refine: class-split factor for ``rebalance`` (finer classes
                let one hot class spread over several shards).
            chunk_pages: page budget per shard-to-shard chunk pull
                (defaults to ``rebuild_chunk_pages``, or 512).
            pace_s: sleep between chunk pulls so migration traffic
                trickles under serving reads (supervisor-style pacing).
            on_progress: optional callback receiving ``{"event":
                "chunk" | "emb_chunk" | "flip", ...}`` dicts — called
                OUTSIDE all coordinator locks, so probes may issue reads
                (the bit-identity-at-every-chunk-boundary hook).

        The protocol per migrating class: mark the class write-gated →
        destination pulls its pages/rows from a live owner over the peer
        links → the routing epoch flips the class to its new owners
        atomically under the mutation lock → in-flight reads planned
        against the old routing drain behind the read barrier → vacated
        shards free the class's pages.  Batched reads route to the OLD
        owner until the flip, so every read before, during, and after a
        chunk boundary stays bit-identical.

        Returns a report dict: ``classes_moved``, ``copies``,
        ``relabels``, ``pages_shipped``, ``bytes_shipped`` (split into
        ``adj_bytes``/``emb_bytes``), ``chunks``, ``epochs``,
        ``n_shards``, ``seconds``; or ``{"reshard_in_progress": True}``
        / ``{"reshard_rejected": ...}`` when it cannot start.

        Raises:
            ValueError: not exactly one mode, or an invalid target map.
            DeviceFailedError: a shard is failed at start, or a class
                loses its last live source mid-copy.
        """
        modes = sum([add is not None, remove is not None,
                     placement is not None, bool(rebalance)])
        if modes != 1:
            raise ValueError("reshard takes exactly one of add=, "
                             "remove=, placement=, rebalance=True")
        # ---- claim: brief maintenance hold serialises against bulk
        # ingest and any in-flight rebuild stream; from here on both
        # reject with reshard_in_progress until we clear the flag
        with self._maintenance:
            with self._bp_lock:
                if self._resharding:
                    return {"reshard_in_progress": True}
                if self._rebuilding:
                    return {"reshard_rejected": "rebuild_in_progress"}
                self._resharding = True
        t0 = time.perf_counter()
        try:
            return self._run_reshard(add, remove, placement, rebalance,
                                     refine, chunk_pages, pace_s,
                                     on_progress, t0)
        finally:
            with self._mutate:
                self._mig_classes.clear()
                self._mig_cv.notify_all()
            with self._bp_lock:
                self._resharding = False

    def _run_reshard(self, add, remove, placement, rebalance, refine,
                     chunk_pages, pace_s, on_progress, t0) -> dict:
        if any(self._failed):
            raise DeviceFailedError(
                "reshard needs every shard live at start; rebuild first")
        chunk_pages = int(chunk_pages
                          or getattr(self, "rebuild_chunk_pages", 512))
        n_old = self.n_shards
        epoch0 = self._routing.epoch

        # ---- grow: attach the new endpoints before planning, so copy
        # targets are addressable.  endpoints grows BEFORE n_shards so a
        # concurrent probe/gossip thread never indexes past the list.
        if add is not None:
            new_eps = list(add)
            if not new_eps:
                raise ValueError("add= needs at least one endpoint")
            with self._mutate:
                self.endpoints = self.endpoints + new_eps
                self.n_shards = len(self.endpoints)
                self._failed = self._failed + [False] * len(new_eps)
                self._windows = self._windows + [
                    threading.BoundedSemaphore(
                        self.flow.max_inflight_per_shard)
                    if self.flow.max_inflight_per_shard > 0 else None
                    for _ in new_eps]
            for ep in self.endpoints:
                ep.set_peers(self.endpoints)
                ep._peers_wired = True
            self._topology_changed()

        # ---- target map (planners refine internally as needed), then
        # refine the live routing to the common class count — a
        # metadata-only change: tiled extents keep every vid's row
        rt0 = self._routing
        cur = rt0.pmap
        heat = rt0.heat.copy()
        removed: list[int] = []
        if add is not None:
            target = grow_plan(cur, n_old, self.n_shards, heat)
        elif remove is not None:
            removed = sorted(set(int(s) for s in remove))
            if not removed:
                raise ValueError("remove= needs at least one shard")
            if any(not 0 <= s < self.n_shards for s in removed):
                raise ValueError(f"remove={removed} out of range")
            if len(removed) >= self.n_shards:
                raise ValueError("cannot remove every shard")
            target = shrink_plan(cur, removed, self.n_shards, heat)
        elif rebalance:
            live = [s for s in range(self.n_shards)
                    if not self._failed[s]]
            target = heat_plan(cur, heat, live, refine=max(1, int(refine)))
        else:
            if placement.replication != self.replication:
                raise ValueError(
                    f"target map has {placement.replication} roles, "
                    f"store replication is {self.replication}")
            placement.validate(self.n_shards)
            target = placement
        cur_f, target = common_refine(cur, target)
        C = cur_f.n_classes
        k = C // cur.n_classes
        if k > 1:
            with self._mutate:
                rt = self._routing
                self._swap_routing(_Routing(
                    cur_f, np.tile(rt.ew_mod, (k, 1)),
                    np.tile(rt.ew_base, (k, 1)), rt.epoch + 1,
                    np.tile(rt.heat / k, k)))

        moves, drops = plan_moves(cur_f, target)
        by_class: dict[int, list] = {}
        for m in moves:
            by_class.setdefault(int(m.cls), []).append(m)
        drops_of_class: dict[int, list[int]] = {}
        for s, cls_list in drops.items():
            for c in cls_list:
                drops_of_class.setdefault(int(c), []).append(int(s))

        acc = {"classes_moved": len(by_class), "copies": 0, "relabels": 0,
               "pages_shipped": 0, "bytes_shipped": 0, "adj_bytes": 0,
               "emb_bytes": 0, "chunks": 0}

        # ---- per class: gate writes -> copy -> flip -> drain -> drop
        for c in sorted(by_class):
            cls_moves = by_class[c]
            # taking _mutate here also waits out any write already past
            # its gate — writes hold _mutate for their whole fan-out
            with self._mutate:
                self._mig_classes.add(c)
            try:
                flip_ext: dict[int, tuple[int, int]] = {}
                for m in cls_moves:
                    if m.kind == "copy":
                        acc["copies"] += 1
                        flip_ext[m.role] = self._migrate_copy(
                            m, C, chunk_pages, pace_s, on_progress, acc)
                    else:
                        acc["relabels"] += 1
                # ---- the flip: one atomic routing swap moves every
                # changed role of this class to its new owner
                with self._mutate:
                    rt = self._routing
                    owner = rt.pmap.owner.copy()
                    nb, nm = rt.ew_base.copy(), rt.ew_mod.copy()
                    for m in cls_moves:
                        owner[c, m.role] = m.dst
                        if m.kind == "copy":
                            nb[c, m.role], nm[c, m.role] = flip_ext[m.role]
                        else:
                            nb[c, m.role] = rt.ew_base[c, m.src_role]
                            nm[c, m.role] = rt.ew_mod[c, m.src_role]
                    self._swap_routing(_Routing(
                        PlacementMap(C, owner), nm, nb,
                        rt.epoch + 1, rt.heat))
                    self._mig_classes.discard(c)
                    self._mig_cv.notify_all()
            except BaseException:
                with self._mutate:
                    self._mig_classes.discard(c)
                    self._mig_cv.notify_all()
                raise
            # drain reads planned against the pre-flip routing before
            # the vacated owners free the class's pages
            with self._quiesce_reads():
                pass
            for s in drops_of_class.get(c, ()):
                if not self._failed[s]:
                    try:
                        self.endpoints[s].call("drop_class", cls=c,
                                               modulus=C)
                    except Exception:  # noqa: BLE001 — frees are advisory
                        pass
            if on_progress is not None:
                on_progress({"event": "flip", "cls": c,
                             "epoch": self._routing.epoch})

        # ---- shrink finalise: drained shards detach, indices compact
        if removed:
            keep = [s for s in range(self.n_shards) if s not in removed]
            lut = np.full(self.n_shards, -1, dtype=np.int64)
            lut[keep] = np.arange(len(keep))
            with self._quiesce_reads():
                with self._mutate:
                    rt = self._routing
                    pm = PlacementMap(C, lut[rt.pmap.owner])
                    old_eps = self.endpoints
                    # n_shards shrinks BEFORE endpoints so concurrent
                    # iterators never index past the shorter list
                    self.n_shards = len(keep)
                    self.endpoints = [old_eps[s] for s in keep]
                    self._failed = [self._failed[s] for s in keep]
                    self._windows = [self._windows[s] for s in keep]
                    self._swap_routing(_Routing(
                        pm, rt.ew_mod, rt.ew_base, rt.epoch + 1, rt.heat))
            for ep in self.endpoints:
                ep.set_peers(self.endpoints)
                ep._peers_wired = True
            for s in removed:
                try:
                    old_eps[s].close()
                except Exception:  # noqa: BLE001 — detach is best-effort
                    pass
            self._topology_changed()

        acc["epochs"] = self._routing.epoch - epoch0
        acc["n_shards"] = self.n_shards
        acc["seconds"] = time.perf_counter() - t0
        with self._bp_lock:
            self._reshard_stats = dict(acc)
        return acc

    def _topology_changed(self) -> None:
        """Post-attach/detach hook: resize the supervisor's per-shard
        state and reset the replicated gossip feedback (both no-ops on
        the base store without them)."""
        sup = self.health
        if sup is not None and hasattr(sup, "resize"):
            try:
                sup.resize(self.n_shards)
            except Exception:  # noqa: BLE001 — telemetry best-effort
                pass
        if hasattr(self, "_reset_feedback"):
            self._reset_feedback()


class ReplicatedGraphStore(ShardedGraphStore):
    """R-way replicated CSSD array: the sharded store with redundancy,
    skewed-read load-spreading, and a failed-shard drain/rebuild path.

    Placement: replica ``r`` of vertex ``vid`` lives on shard
    ``(vid + r) % N`` — for both its adjacency pages (keyed by global vid,
    as in the base store) and its embedding row.  Shard ``s`` therefore
    holds the R residue classes ``{(s - r) % N}``; its embedding table is
    the concatenation of R round-robin stripes (role ``r`` stripe = class
    ``(s - r) % N``, local row ``stripe_off[s, r] + vid // N``), so the
    shard-local page math stays the single-device math per stripe.

    Reads: the plan stage runs a vectorized *replica-selection* pass over
    every page fetch of the request — H chains at PAGE granularity
    (replicas keep layout-identical chains, so page i can come from any
    live owner), L vids weighted by their shared page cost, embedding
    rows grouped by stripe page — assigned by an exact min-max solver
    (level binary-search + max-flow over the classes->candidates graph,
    ``_minmax_quotas``) on top of a GOSSIPED view of the shards' read
    counters: the coordinator pulls each endpoint's page-read counter at
    most every ``stats_staleness_s`` seconds (0 = every selection) and
    plans against that snapshot, so the feedback loop never reads shard
    state synchronously — the multi-host requirement.  The loop stays
    closed (estimation bias cannot accumulate, just bounded-staleness
    delayed), and since every replica holds identical data and the
    recomposed plan is position-identical to the single-device plan, the
    spread changes WHICH device pays each page, never the result: an
    R-replicated sample stays **bit-identical** to the 1-device store
    under the same seed.  The deferred-latency array cost is ``max`` over
    shards, so flattening the per-shard page distribution is a direct
    latency win on skewed mixes (fig24: balance 0.36 -> 1.00,
    batched-read IO ~1.4x at R=2).

    Writes fan out to every live replica under the coordinator mutation
    lock (each device's ``on_write`` hook invalidates its own page cache);
    a replica that fails mid-fan-out is skipped — its state died with the
    device and ``rebuild_shard`` re-materialises it from a survivor.

    Fault path: ``fail_shard(s)`` drops the device (every later command
    raises ``DeviceFailedError``) after checking each of its classes keeps
    a live replica; in-flight fetches that already planned onto the dying
    shard re-plan against survivors (``_with_failover``).  Degraded reads
    are served — bit-identically — by the surviving replicas.
    ``rebuild_shard(s)`` re-materialises the lost partition onto a fresh
    device by **shard-to-shard chunked streaming**: the coordinator sends
    the destination endpoint a pure-metadata plan, and the destination
    pulls bounded page chunks from each class's surviving endpoint over
    the peer links (batched L export re-laid through the bulk packing, H
    chains cloned page-exactly — preserving the cross-replica chain
    layout the page spread relies on — embedding stripes gathered from
    each class's survivor).  Survivor pages never transit the
    coordinator; restoring R-way redundancy costs the coordinator one
    RPC.
    """

    # base __init__ must not install a 1-role routing the replicated
    # store immediately replaces — it defers to our own _init_routing
    _init_routing_deferred = True

    def __init__(self, n_shards: int | None = None, devs: list | None = None,
                 *, endpoints: list | None = None, replication: int = 2,
                 h_threshold: int = 128, feature_dim: int = 0,
                 placement: PlacementMap | None = None,
                 stats_staleness_s: float = 0.0,
                 rebuild_chunk_pages: int = 512,
                 flow: FlowControl | None = None):
        """Same backing forms as :class:`ShardedGraphStore`, plus:

        Args:
            replication: replica count R (1 <= R <= N); every vertex
                class keeps R copies, one per role column.
            placement: optional R-role :class:`PlacementMap` replacing
                the default ``(c + r) % N`` replica ring.
            stats_staleness_s: max age of the gossiped read-counter
                snapshot replica selection plans against (0 = refresh
                every selection).
            rebuild_chunk_pages: page budget per shard-to-shard chunk
                pull during rebuild and reshard streams.

        Raises:
            ValueError: replication out of range, or a placement map
                whose role count differs from ``replication``.
        """
        super().__init__(n_shards, devs, endpoints=endpoints,
                         h_threshold=h_threshold, feature_dim=feature_dim,
                         flow=flow)
        r = int(replication)
        if not 1 <= r <= self.n_shards:
            raise ValueError(f"replication={r} needs 1 <= R <= "
                             f"n_shards={self.n_shards}")
        self.replication = r
        self._emb_rows = 0
        self._init_routing(r, placement)
        # gossiped selection feedback: every selection starts from a
        # staleness-bounded snapshot of the shards' ACTUAL page-read
        # counters since the last topology change (periodic ``counters``
        # pulls — never a synchronous shard peek).  Cache hits never
        # reach the device counter, so cached reads correctly stop
        # counting as device load.
        self.stats_staleness_s = float(stats_staleness_s)
        self.rebuild_chunk_pages = int(rebuild_chunk_pages)
        self.gossip_pulls = 0                      # guarded-by: _gossip_lock
        self._gossip_lock = witness_lock(
            "sharded._gossip_lock", threading.Lock())
        self._gossip_reads = np.zeros(self.n_shards)   # guarded-by: _gossip_lock
        self._gossip_depth = np.zeros(self.n_shards)   # guarded-by: _gossip_lock
        self._gossip_t = -np.inf                   # guarded-by: _gossip_lock
        self._gossip_inflight = False              # guarded-by: _gossip_lock
        self._read_base = np.zeros(0)              # guarded-by: _gossip_lock
        self._read_base = self._refresh_gossip(force=True).copy()

    # ------------------------------------------------------------- topology
    def replica_shards(self, vid: int) -> list[int]:
        """The shards holding ``vid``'s replicas, role order (primary
        first) — ``[(vid + r) % N]`` at the default modular map."""
        rt = self._routing
        c = int(vid) % rt.pmap.n_classes
        return [int(rt.pmap.owner[c, r]) for r in range(self.replication)]

    def _live_eps(self, vid: int, rt: _Routing | None = None):
        """(shard, role, endpoint) of ``vid``'s live replicas, primary
        first."""
        rt = rt or self._routing
        out = []
        c = int(vid) % rt.pmap.n_classes
        for r in range(self.replication):
            s = int(rt.pmap.owner[c, r])
            if not self._failed[s]:
                out.append((s, r, self.endpoints[s]))
        if not out:
            raise DeviceFailedError(f"no live replica for vertex {vid}")
        return out

    def _survivor_of_class(self, c: int, exclude: int) -> int:
        rt = self._routing
        for r in range(self.replication):
            s = int(rt.pmap.owner[c, r])
            if s != exclude and not self._failed[s]:
                return s
        raise DeviceFailedError(f"no live replica holds vertex class {c}")

    def _meta_shard(self, c: int, rt: _Routing | None = None) -> int:
        """A live replica holding class ``c``'s mapping tables — the
        planning metadata every replica agrees on (same op history)."""
        rt = rt or self._routing
        for r in range(self.replication):
            s = int(rt.pmap.owner[c, r])
            if not self._failed[s]:
                return s
        raise DeviceFailedError(f"no live replica for vertex class {c}")

    # ----------------------------------------------------- embedding layout
    def _rows_of_class(self, c: int) -> int:
        return rows_of_class(self._emb_rows, int(c),
                             self._routing.pmap.n_classes)

    def _check_emb_vid(self, vid: int) -> None:
        """Reject rows beyond the ingested table: in the striped replica
        layout the next local row belongs to ANOTHER role's stripe, so an
        unchecked write would silently corrupt a different vertex's
        replica (the single-device store merely writes past its table)."""
        if not 0 <= int(vid) < self._emb_rows:
            raise KeyError(f"vid {vid} outside the embedding table "
                           f"({self._emb_rows} rows)")

    def update_graph(self, edge_array, embeddings=None, *,
                     already_undirected: bool = False):
        """Bulk UpdateGraph across the replicated array (see the base
        class); every shard writes all R of its owned stripes.

        Raises:
            DeviceFailedError: a shard is failed (rebuild first).
            RuntimeError: an online reshard is migrating classes.
        """
        # behind the maintenance gate: a bulk ingest must not interleave
        # with a streaming rebuild (and a rebuild in progress means a
        # failed flag is still set, which the check below rejects)
        with self._maintenance:
            self._check_not_resharding("bulk ingest")
            if any(self._failed):
                raise DeviceFailedError(
                    "bulk ingest needs every shard live; rebuild_shard first")
            return super().update_graph(edge_array, embeddings,
                                        already_undirected=already_undirected)

    # ----------------------------------------------------- replica selection
    def _refresh_gossip(self, force: bool = False) -> np.ndarray:
        """Pull every endpoint's gossip counters when the cached snapshot
        is older than ``stats_staleness_s`` (or forced).  The only
        coupling between replica selection and shard state is this
        bounded-staleness gossip — fit for shards on other hosts.  One
        concurrent ``counters`` round (``_submit_round``: queue-full
        safe) refreshes both the page-read loads and the per-shard
        command-queue depth the selection penalises."""
        now = time.perf_counter()
        with self._gossip_lock:
            stale = force or (now - self._gossip_t) > self.stats_staleness_s
            if not stale or (self._gossip_inflight and not force):
                # fresh enough, or another thread is already mid-pull:
                # bounded-staleness gossip tolerates the current snapshot
                return self._gossip_reads
            self._gossip_inflight = True
        # the counters round fans out through the shard queues — an RPC
        # must never run under the leaf _gossip_lock, or every reader
        # selecting replicas serializes behind the network
        try:
            outs = self._submit_round(
                [(s, "counters", {}) for s in range(self.n_shards)])
        except BaseException:
            with self._gossip_lock:
                self._gossip_inflight = False
            raise
        with self._gossip_lock:
            self._gossip_inflight = False
            self._gossip_reads = np.array(
                [float(c["read_pages"]) for c in outs])
            self._gossip_depth = np.array(
                [float(c.get("sq_depth", 0)) + float(c.get("inflight", 0))
                 for c in outs])
            self._gossip_t = now
            self.gossip_pulls += 1
            return self._gossip_reads

    def _hist_loads(self) -> np.ndarray:
        """Per-shard starting loads of every selection, in pages: the
        gossiped page-read imbalance since the last topology change, plus
        the flow-control steering penalties — gossiped queue depth (hot
        shard hosts look pre-loaded) and supervisor-suspect status (a
        suspect shard is avoided unless its class has no other live
        candidate; the min-max solver does exactly that)."""
        def _pad(a: np.ndarray, n: int) -> np.ndarray:
            # a reshard may grow n_shards between gossip pulls; a fresh
            # shard starts with zero history until the feedback reset
            return (a if len(a) >= n
                    else np.concatenate([a, np.zeros(n - len(a))]))

        n = self.n_shards
        reads = _pad(self._refresh_gossip(), n)[:n]
        with self._gossip_lock:
            h = reads - _pad(self._read_base, n)[:n]
            depth = _pad(self._gossip_depth, n)[:n].copy()
        h = h - h.min()
        fl = self.flow
        if fl.queue_depth_penalty_pages:
            h = h + depth * fl.queue_depth_penalty_pages
        sup = self.health
        if sup is not None and fl.suspect_penalty_pages:
            for s in sup.suspect_shards():
                if 0 <= s < self.n_shards:
                    h[s] += fl.suspect_penalty_pages
        return h

    def _reset_feedback(self) -> None:
        reads = self._refresh_gossip(force=True)
        with self._gossip_lock:
            self._read_base = reads.copy()

    def _select_replicas(self, vids: np.ndarray, weights=None,
                         key=None) -> np.ndarray:
        """Vectorized plan-stage replica selection.

        Positions group by residue class (every member of a class shares
        the same R candidate shards); the per-class weights are assigned
        to live candidate shards by an exact min-max solver
        (``_minmax_quotas``) on top of the gossiped read-counter
        imbalance.  Within a class, positions stay contiguous in ``key``
        order (ascending vid for adjacency, stripe page for embeddings)
        so page-sharing neighbours land on the same shard, and the split
        points fall at the quota boundaries.

        Pure planning — the returned owner per position only decides which
        device pays the page fetch; replicas hold identical data.
        """
        rt = self._routing
        C, rep = rt.pmap.n_classes, self.replication
        vids = np.asarray(vids, dtype=np.int64).reshape(-1)
        cls = vids % C
        w = (np.ones(len(vids)) if weights is None
             else np.asarray(weights, dtype=np.float64))
        np.add.at(rt.heat, cls, w)
        live = [not f for f in self._failed]
        class_w = np.bincount(cls, weights=w, minlength=C)

        order = (np.argsort(cls, kind="stable") if key is None
                 else np.lexsort((np.asarray(key), cls)))
        sorted_cls = cls[order]
        lo = np.searchsorted(sorted_cls, np.arange(C), side="left")
        hi = np.searchsorted(sorted_cls, np.arange(C), side="right")

        # ---- per-class quotas: exact min-max via level search + max-flow
        occupied = [int(c) for c in range(C) if hi[c] > lo[c]]
        cand_of: dict[int, np.ndarray] = {}
        for c in occupied:
            row = rt.pmap.owner[c]
            cands = np.asarray([int(row[r]) for r in range(rep)
                                if live[int(row[r])]])
            if not len(cands):
                raise DeviceFailedError(
                    f"no live replica for vertex class {c}")
            cand_of[c] = cands
        quota = _minmax_quotas({c: float(class_w[c]) for c in occupied},
                               cand_of, self._hist_loads())

        # ---- split each class's positions at its quota boundaries
        owner = np.empty(len(vids), dtype=np.int64)
        for c in occupied:
            run = order[lo[c]: hi[c]]
            cands = cand_of[c]
            if len(cands) == 1:
                owner[run] = cands[0]
                continue
            cum_w = np.cumsum(w[run])
            cuts = np.searchsorted(cum_w, np.cumsum(quota[c])[:-1] + 1e-9)
            for sdx, seg in zip(cands.tolist(), np.split(run, cuts)):
                if len(seg):
                    owner[seg] = sdx
        return owner

    def _l_share_weights(self, vids: np.ndarray,
                         l_page: np.ndarray) -> np.ndarray:
        """Page cost of each L-vid's fetch, in PAGES: vids resolved to the
        same L page (``plan_info``'s range-table index — packings differ
        across replicas only in companion classes) split that page's
        single fetch between them, so L quotas stay commensurate with
        per-page H quotas."""
        w = np.ones(len(vids))
        cls = vids % self._routing.pmap.n_classes
        for c in np.unique(cls):
            idx = np.nonzero(cls == c)[0]
            pg = l_page[idx]
            if not len(pg) or (pg < 0).all():   # shard holds no L pages
                continue
            _, inv, cnt = np.unique(pg, return_inverse=True,
                                    return_counts=True)
            w[idx] = 1.0 / cnt[inv]
        return w

    def _with_failover(self, fn):
        """Run a read plan, re-planning if a shard fails under it.

        A fetch that already planned onto a shard when ``fail_shard`` hit
        raises ``DeviceFailedError`` from that device (surfaced through
        the endpoint, whatever the transport); the retry re-runs the
        selection, which now excludes it — the drain path of a degraded
        array.  Reads are idempotent, so the retry is safe.
        """
        last = None
        for _ in range(self.n_shards + 1):
            try:
                return fn()
            except DeviceFailedError as e:
                last = e
        raise last

    def _fan_fetch(self, vids_arr: np.ndarray):
        if self.replication == 1:
            return self._with_failover(
                lambda: ShardedGraphStore._fan_fetch(self, vids_arr))
        return self._with_failover(
            lambda: self._fan_fetch_spread(vids_arr))

    def _fan_fetch_spread(self, vids_arr: np.ndarray):
        """plan -> page-granular replica-spread fetch -> build.

        H chains are spread at PAGE granularity: every replica holds a
        layout-identical copy of the chain (same op history; rebuilds
        clone pages exactly), so page i can be served by any live owner —
        an independently assignable unit for the waterfill.  With
        whole-chain atoms a hub's pages would pin to one shard and the
        per-shard max (the array's deferred latency) could never drop
        below the chain length; per-page spread flattens hub-skewed
        fetches to ~total/N.  L vids stay vid-granular, weighted by their
        shared page cost.  The recomposed (block, desc) is
        position-identical to the single-device plan, so selection stays
        bit-identical.
        """
        # plan + fetch under the coordinator mutation lock: one vid's chain
        # pages are read under SEVERAL shards' locks, so a delete landing
        # between them could drop h_chain entries mid-plan (the base store
        # reads each vid inside ONE shard critical section and never had
        # this gap).  The simulated array wait is paid after release, so
        # mutations only ever wait out the (fast) planning math.
        with self._mutate:
            block, desc, worst = self._plan_and_fetch_spread(vids_arr)
        with self._bp_lock:
            self.io_wait_us += worst
        sleep_us(worst)
        return block, desc

    def _plan_and_fetch_spread(self, vids_arr: np.ndarray):
        rt = self._routing       # stable: flips also hold _mutate
        desc: list = [None] * len(vids_arr)
        # ---- planning metadata: ONE plan_info call per occupied vertex
        # class against a live replica (replica-invariant tables) — the
        # coordinator never reads shard mapping state directly
        cls_arr = vids_arr % rt.pmap.n_classes
        chain_len = np.zeros(len(vids_arr), dtype=np.int64)
        l_page = np.full(len(vids_arr), -1, dtype=np.int64)
        idxs, items = [], []
        for c in np.unique(cls_arr).tolist():
            idx = np.nonzero(cls_arr == c)[0]
            idxs.append(idx)
            items.append((self._meta_shard(int(c)), "plan_info",
                          {"vids": vids_arr[idx]}))
        # one concurrent round-trip (queue-full safe: a QueueFullError
        # part-way reaps the submitted handles and retries the full set)
        for idx, info in zip(idxs, self._submit_round(items)):
            chain_len[idx] = np.asarray(info["chain_len"], dtype=np.int64)
            l_page[idx] = np.asarray(info["l_page"], dtype=np.int64)

        uidx: dict[int, int] = {}
        u_vids: list[int] = []
        u_lens: list[int] = []
        pos_of_u: list[list[int]] = []
        l_pos: list[int] = []
        for pos, v in enumerate(vids_arr.tolist()):
            if chain_len[pos] == 0:
                l_pos.append(pos)
            else:
                u = uidx.get(v)
                if u is None:
                    u = uidx[v] = len(u_vids)
                    u_vids.append(v)
                    u_lens.append(int(chain_len[pos]))
                    pos_of_u.append([])
                pos_of_u[u].append(pos)

        # ---- ONE joint selection for the whole fetch: L vids (page-share
        # weighted) and H chain pages (unit weight) compete for the same
        # per-shard budget — planned separately, the hub pages would land
        # on top of an already-balanced L assignment and re-skew the fetch
        l_pos_arr = np.asarray(l_pos, dtype=np.int64)
        l_vids = vids_arr[l_pos_arr]
        item_vid = item_pg = item_row = u_lens_a = None
        sel_vids = [l_vids]
        sel_w = [self._l_share_weights(l_vids, l_page[l_pos_arr])
                 if len(l_vids) else np.empty(0)]
        sel_key = [2 * l_vids]                # even keys: L, by vid
        if u_vids:
            u_vids_a = np.asarray(u_vids, dtype=np.int64)
            u_lens_a = np.asarray(u_lens, dtype=np.int64)
            item_vid = u_vids_a[np.repeat(np.arange(len(u_vids)), u_lens_a)]
            item_pg = _ramp(u_lens_a)
            item_row = np.empty(len(item_vid), dtype=np.int64)
            sel_vids.append(item_vid)
            sel_w.append(np.ones(len(item_vid)))
            # odd keys, chain-contiguous: a vid's pages stay together and
            # split wherever the quotas land — page-granular spread
            sel_key.append(
                2 * (np.max(vids_arr) + 1
                     + np.repeat(np.arange(len(u_vids)), u_lens_a)
                     * (int(u_lens_a.max()) + 1) + item_pg) + 1)
        all_vids = np.concatenate(sel_vids)
        if not len(all_vids):
            return None, desc, 0.0
        owner = self._select_replicas(all_vids,
                                      weights=np.concatenate(sel_w),
                                      key=np.concatenate(sel_key))
        owner_l, owner_h = owner[: len(l_vids)], owner[len(l_vids):]
        parts: dict[int, dict] = {}
        for s in np.unique(owner_l).tolist():
            parts.setdefault(int(s), {})["l"] = np.nonzero(owner_l == s)[0]
        for s in np.unique(owner_h).tolist():
            parts.setdefault(int(s), {})["h"] = np.nonzero(owner_h == s)[0]

        # ---- fetch: ONE batched command per shard (l plan + its share of
        # chain pages together), submitted to all shards, awaited together
        shard_order = sorted(parts)
        reqs = []
        for s in shard_order:
            work = parts[s]
            kw: dict = {}
            if "l" in work:
                kw["l_vids"] = l_vids[work["l"]]
            if "h" in work:
                items = work["h"]
                kw["h_vids"] = item_vid[items]
                kw["h_pgs"] = item_pg[items]
            reqs.append((s, kw))
        payloads, worst = self._endpoint_fetch(reqs, pay=False)

        blocks: list[np.ndarray] = []
        row_off = 0
        for s, pl in zip(shard_order, payloads):
            work = parts[s]
            dsc, blk, hblk = pl["desc"], pl["block"], pl["hblk"]
            if dsc is not None:
                for plx, d in zip(work["l"].tolist(), dsc):
                    if d is None:
                        continue
                    pos = int(l_pos_arr[plx])
                    if d[0] == "L":
                        desc[pos] = ("L", d[1] + row_off, d[2], d[3])
                    else:                     # defensive: kind skew
                        desc[pos] = ("H", d[1] + row_off, d[2])
                if blk is not None:
                    blocks.append(blk)
                    row_off += blk.shape[0]
            if hblk is not None:
                item_row[work["h"]] = row_off + np.arange(len(hblk))
                blocks.append(hblk)
                row_off += hblk.shape[0]
        if not blocks:
            return None, desc, worst
        block = blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
        if u_vids:
            starts = np.concatenate([[0], np.cumsum(u_lens_a)[:-1]])
            for u in range(len(u_vids)):
                rows = item_row[starts[u]: starts[u] + int(u_lens_a[u])]
                d = ("H", rows, block[rows, _H_COUNT].astype(np.int64))
                for pos in pos_of_u[u]:
                    desc[pos] = d
        return block, desc, worst

    # ------------------------------------------------------------ unit reads
    def _unit_call(self, s: int, ep, method: str, **kw):
        """Unit read against one replica, with the shard-attributed error
        reported to the supervisor before failover re-plans it."""
        try:
            return ep.call(method, **kw)
        except DeviceFailedError as e:
            self._notify_shard_error(s, e)
            raise

    def get_neighbors(self, vid: int) -> np.ndarray:
        """Neighbor list of one vid from a live replica, with failover."""
        def read():
            with self._read_routing() as rt:
                s, _r, ep = self._live_eps(vid, rt)[0]
                return self._unit_call(s, ep, "get_neighbors", vid=int(vid))
        return self._with_failover(read)

    def get_embed(self, vid: int) -> np.ndarray:
        """One embedding row from a live replica, with failover.

        Raises:
            KeyError: vid outside the ingested table.
        """
        self._check_emb_vid(vid)

        def read():
            with self._read_routing() as rt:
                s, row = self._emb_locate(vid, rt)[0]
                return self._unit_call(s, self.endpoints[s],
                                       "get_embed_row", row=row)
        return self._with_failover(read)

    def get_embeds(self, vids: np.ndarray) -> np.ndarray:
        """Replica-spread coalesced embedding gather (see class
        docstring); bit-identical rows, load-balanced page fetches.

        Raises:
            KeyError: no table loaded, or a vid outside it.
        """
        d = self.feature_dim
        if not d:
            raise KeyError("no embedding table loaded")
        vids = np.asarray(vids, dtype=np.int64).reshape(-1)
        out = np.empty((len(vids), d), dtype=np.float32)
        if not len(vids):
            return out
        if int(vids.min()) < 0 or int(vids.max()) >= self._emb_rows:
            self._check_emb_vid(int(vids.max()
                                    if vids.max() >= self._emb_rows
                                    else vids.min()))

        def gather():
            with self._read_routing() as rt:
                C = rt.pmap.n_classes
                cls = vids % C
                local = vids // C
                # group by stripe page so rows sharing a 4 KB page are
                # fetched together from ONE replica (no duplicate page
                # fetches); weigh rows in PAGES — page-mates split their
                # page's single fetch — so embedding quotas stay
                # commensurate with adjacency quotas
                page_key = (local * d) // SLOTS_PER_PAGE
                if d >= SLOTS_PER_PAGE:
                    w = np.full(len(vids), d / SLOTS_PER_PAGE)
                else:
                    # page-mates are same-CLASS rows on one stripe page;
                    # rows of different classes sharing a raw page index
                    # live on different shards' stripes and must not pool
                    # their weight
                    ck = cls * (int(page_key.max()) + 1) + page_key
                    _, inv, cnt = np.unique(ck, return_inverse=True,
                                            return_counts=True)
                    w = 1.0 / cnt[inv]
                owner = self._select_replicas(vids, weights=w, key=page_key)
                parts = [(s, np.nonzero(owner == s)[0])
                         for s in range(self.n_shards)]
                parts = [(s, pos) for s, pos in parts if len(pos)]
                reqs = []
                for s, pos in parts:
                    # which role serves each position on shard s
                    role = np.zeros(len(pos), dtype=np.int64)
                    for r in range(self.replication):
                        role[rt.pmap.owner[cls[pos], r] == s] = r
                    rows = rt.ew_base[cls[pos], role] \
                        + vids[pos] // rt.ew_mod[cls[pos], role]
                    reqs.append((s, {"emb_rows": rows}))
                payloads, _ = self._endpoint_fetch(reqs)
            for (s, pos), pl in zip(parts, payloads):
                out[pos] = pl["emb"]
            return out

        return self._with_failover(gather)

    # ----------------------------------------------------- mutation fan-out
    def _fanout(self, eps, fn) -> int:
        """Apply a mutation to every live replica; a replica that fails
        mid-fan-out is skipped (its state died with the device — rebuild
        recovers it from a survivor), so the live replicas never diverge."""
        ok = 0
        for s, r, ep in eps:
            try:
                fn(s, r, ep)
                ok += 1
            except DeviceFailedError as e:
                self._notify_shard_error(s, e)
                continue
        if not ok:
            raise DeviceFailedError("every replica failed mid-write")
        return ok

    def add_vertex(self, vid: int, embed=None) -> None:
        """Insert an isolated vertex on every live replica (idempotent),
        optionally with its embedding row."""
        with self._write_gate((vid,)):
            vid = int(vid)
            self._fanout(self._live_eps(vid),
                         lambda s, r, ep: ep.call("add_vertex", vid=vid))
            self._num_vertices = max(self._num_vertices, vid + 1)
            if embed is not None:
                self.update_embed(vid, embed)

    def update_embed(self, vid: int, embed: np.ndarray) -> None:
        """Overwrite one embedding row on every live replica.

        Raises:
            KeyError: vid outside the ingested table.
        """
        with self._write_gate((vid,)):
            vid = int(vid)
            self._check_emb_vid(vid)
            rt = self._routing
            c = vid % rt.pmap.n_classes

            def write(s, r, ep):
                ep.call("update_embed_row",
                        row=int(rt.ew_base[c, r])
                        + vid // int(rt.ew_mod[c, r]), embed=embed)
            self._fanout(self._live_eps(vid, rt), write)

    def add_edge(self, dst: int, src: int) -> None:
        """Undirected insert, fanned out to every live replica of both
        endpoints' classes."""
        with self._write_gate((dst, src)):
            dst, src = int(dst), int(src)
            for v in (dst, src):
                # device-side add_vertex no-ops when the vid exists
                self._fanout(self._live_eps(v),
                             lambda s, r, ep, v=v: ep.call("add_vertex",
                                                           vid=v))
                self._num_vertices = max(self._num_vertices, v + 1)

            def ins(vid, nbr, count):
                self._fanout(self._live_eps(vid),
                             lambda s, r, ep: ep.call(
                                 "insert_neighbor", vid=vid, nbr=nbr,
                                 count=count))
            ins(dst, src, True)
            if dst != src:
                ins(src, dst, False)

    def delete_edge(self, dst: int, src: int) -> None:
        """Undirected removal, fanned out to every live replica."""
        with self._write_gate((dst, src)):
            dst, src = int(dst), int(src)

            def rm(vid, nbr, count):
                self._fanout(self._live_eps(vid),
                             lambda s, r, ep: ep.call(
                                 "remove_neighbor", vid=vid, nbr=nbr,
                                 count=count))
            rm(dst, src, True)
            if dst != src:
                rm(src, dst, False)

    def delete_vertex(self, vid: int) -> None:
        """Remove ``vid`` and its backlinks on every live replica; the
        touched class set is unknown up front, so the gate waits out any
        in-flight class migration."""
        with self._write_gate():
            vid = int(vid)
            nbrs = self.get_neighbors(vid)
            for nbr in np.asarray(nbrs).tolist():
                nbr = int(nbr)
                if nbr == vid:
                    continue
                self._fanout(self._live_eps(nbr),
                             lambda s, r, ep, nbr=nbr: ep.call(
                                 "remove_neighbor", vid=nbr, nbr=vid,
                                 count=False))
            self._fanout(self._live_eps(vid),
                         lambda s, r, ep: ep.call("drop_vertex_pages",
                                                  vid=vid, count=True))

    # --------------------------------------------------------------- export
    def to_adjacency(self) -> dict[int, set[int]]:
        """Full adjacency export from the LIVE shards (replicas
        deduplicate via the set union) — test/verification helper."""
        out: dict[int, set[int]] = {}
        for s, ep in enumerate(self.endpoints):
            if self._failed[s]:
                continue
            for v, nb in ep.call("export_adjacency"):
                out[int(v)] = set(np.asarray(nb).tolist())
        return out

    # ---------------------------------------------------------- fault path
    def fail_shard(self, shard: int) -> dict:
        """Drop one device out of the array (fault injection / drain).

        Refuses when any vertex class owned by the shard would lose its
        last live replica — that is data loss, not degradation."""
        with self._mutate:
            s = int(shard)
            if not 0 <= s < self.n_shards:
                raise ValueError(f"shard {s} out of range")
            if self._failed[s]:
                return {"shard": s, "already_failed": True}
            rt = self._routing
            rep = self.replication
            owned = rt.pmap.classes_of(s)
            lost = []
            for c in owned:
                if not any(int(rt.pmap.owner[c, r2]) != s
                           and not self._failed[int(rt.pmap.owner[c, r2])]
                           for r2 in range(rep)):
                    lost.append(c)
            if lost:
                raise DeviceFailedError(
                    f"failing shard {s} would lose vertex class(es) "
                    f"{sorted(lost)} (replication={rep})")
            # device dies; its DRAM page cache died with it (endpoint-side)
            self.endpoints[s].call("fail")
            self._failed[s] = True
            self._reset_feedback()        # load history predates the fault
            return {"shard": s, "degraded_classes": sorted(owned)}

    def rebuild_shard(self, shard: int, *,
                      pacing_s: float | None = None) -> dict:
        """Re-materialise a failed shard from survivors — endpoint to
        endpoint.

        The coordinator only ships a pure-metadata plan (which survivor
        holds each owned class, stripe row spans, chunk budget, pacing);
        the destination endpoint pulls bounded page chunks from each
        survivor over the peer links and re-lays them (batched L export
        through the bulk packing — neighbor order is replica-invariant,
        every replica applied the same mutation sequence, and L degrees
        never exceed ``h_threshold`` so no vid is reclassified; H chains
        cloned page-exactly, preserving the cross-replica chain layout
        the page-granular spread fetch relies on; embedding stripes
        gathered from each class's survivor).  The replacement starts
        with a cold (fresh) page cache.

        Serving reads flow THROUGHOUT the stream: the rebuild holds the
        maintenance gate, not the mutation lock, so only mutations (and
        other maintenance) block until re-admission — which is also why
        no replay log is needed: the survivors stay the exact current
        state for the whole stream.  ``pacing_s`` sleeps between chunk
        pulls device-side so recovery traffic trickles onto the
        survivor devices instead of starving serving reads queued
        behind it.

        Idempotent under supervision races: a live shard returns
        ``{"already_live": True}`` and a shard already mid-stream
        returns ``{"rebuild_in_progress": True}`` — the auto-rebuild
        loop and an operator RPC may both fire, and neither must throw.
        """
        s = int(shard)
        if not 0 <= s < self.n_shards:
            raise ValueError(f"shard {s} out of range")
        with self._bp_lock:
            if s in self._rebuilding:
                return {"shard": s, "rebuild_in_progress": True}
            if self._resharding:
                # a reshard owns the peer links and the routing epoch;
                # the supervisor retries after it completes
                return {"shard": s, "rebuild_in_progress": True,
                        "reshard_in_progress": True}
        t0 = time.perf_counter()
        with self._maintenance:
            with self._mutate:
                if not self._failed[s]:
                    return {"shard": s, "already_live": True}
                rt = self._routing
                C = rt.pmap.n_classes
                pairs = rt.pmap.pairs_of(s)
                classes = []
                for c, _r in pairs:
                    src = self._survivor_of_class(c, exclude=s)
                    entry = {"cls": int(c), "src": int(src)}
                    if self._emb_rows and self._feature_dim:
                        r2 = [int(rr) for rr in range(self.replication)
                              if int(rt.pmap.owner[c, rr]) == src][0]
                        entry["src_base"] = int(rt.ew_base[c, r2])
                        entry["src_mod"] = int(rt.ew_mod[c, r2])
                        entry["rows"] = int(rows_of_class(
                            self._emb_rows, c, C))
                    classes.append(entry)
                plan = {"n_shards": C,
                        "num_vertices": int(self._num_vertices),
                        "chunk_pages": self.rebuild_chunk_pages,
                        "pace_s": float(pacing_s or 0.0),
                        "feature_dim": (self._feature_dim
                                        if self._emb_rows else 0),
                        "classes": classes}
            with self._bp_lock:
                self._rebuilding.add(s)
            try:
                # the stream: reads keep serving off the survivors while
                # the destination pulls chunks over the peer links
                info = dict(self.endpoints[s].call("rebuild", plan=plan))
            finally:
                with self._bp_lock:
                    self._rebuilding.discard(s)
            # the replacement laid its stripes canonically dense (one
            # class after another in pairs_of order): update its extents
            # if the pre-fault ones were coarse, BEFORE re-admission, so
            # no reader ever addresses the fresh device with stale math
            with self._mutate:
                rt = self._routing
                nb, nm = rt.ew_base.copy(), rt.ew_mod.copy()
                acc = 0
                for c, r in pairs:
                    nb[c, r] = acc
                    nm[c, r] = C
                    acc += rows_of_class(self._emb_rows, c, C)
                changed = not (np.array_equal(nb, rt.ew_base)
                               and np.array_equal(nm, rt.ew_mod))
                if changed:
                    self._swap_routing(_Routing(rt.pmap, nm, nb,
                                                rt.epoch + 1, rt.heat))
            if changed:
                with self._quiesce_reads():
                    pass
            with self._mutate:
                self._failed[s] = False
                self._reset_feedback()    # fresh topology, fresh history
            info["shard"] = s
            info["seconds"] = time.perf_counter() - t0
            return info
