"""Paged KV-cache manager — GraphStore's VID->LPN mapping generalized to
LM serving (the paper's storage technique as a first-class serving feature).

Exactly the H-type design: each *sequence* (≡ high-degree vertex) owns a
chain of fixed-size pages recorded in a page table (≡ VID->LPN linked
list); pages are allocated from a free list on demand as the sequence
grows and recycled on sequence completion (the paper's deleted-VID reuse).
The physical pool layout (P, page_size, KVH, head_dim) is what the Pallas
``decode_attention`` kernel consumes via scalar-prefetched page tables.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class PagePool:
    num_pages: int
    page_size: int
    num_layers: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "float32"

    def __post_init__(self):
        shp = (self.num_layers, self.num_pages, self.page_size,
               self.num_kv_heads, self.head_dim)
        self.k = np.zeros(shp, self.dtype)
        self.v = np.zeros(shp, self.dtype)
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        self.alloc_count = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise MemoryError("KV page pool exhausted")
        self.alloc_count += 1
        return self._free.pop()

    def free(self, pages) -> None:
        self._free.extend(int(p) for p in pages)


@dataclass
class Sequence:
    sid: int
    tokens: list
    pages: list = field(default_factory=list)   # page-table chain (H-type)
    length: int = 0                             # KV slots filled
    done: bool = False
    generated: list = field(default_factory=list)


class PagedKVManager:
    def __init__(self, pool: PagePool):
        self.pool = pool
        self.seqs: dict[int, Sequence] = {}

    def add_sequence(self, sid: int, tokens) -> Sequence:
        seq = Sequence(sid=sid, tokens=list(tokens))
        self.seqs[sid] = seq
        return seq

    def ensure_capacity(self, seq: Sequence, new_len: int) -> None:
        ps = self.pool.page_size
        while len(seq.pages) * ps < new_len:
            seq.pages.append(self.pool.alloc())

    def write_kv(self, seq: Sequence, layer: int, k: np.ndarray,
                 v: np.ndarray, start: int) -> None:
        """Write (T, KVH, hd) at logical positions [start, start+T)."""
        ps = self.pool.page_size
        t = k.shape[0]
        self.ensure_capacity(seq, start + t)
        for i in range(t):
            pos = start + i
            page = seq.pages[pos // ps]
            off = pos % ps
            self.pool.k[layer, page, off] = k[i]
            self.pool.v[layer, page, off] = v[i]

    def page_table(self, seqs, max_pages: int) -> np.ndarray:
        """(B, max_pages) int32 table for the kernel (pad with page 0)."""
        pt = np.zeros((len(seqs), max_pages), np.int32)
        for i, s in enumerate(seqs):
            pt[i, : len(s.pages)] = s.pages
        return pt

    def release(self, seq: Sequence) -> None:
        self.pool.free(seq.pages)
        seq.pages = []
        self.seqs.pop(seq.sid, None)

    def utilization(self) -> float:
        used = self.pool.num_pages - self.pool.free_pages
        return used / self.pool.num_pages
