"""Batch preprocessing — node sampling, reindexing, embedding lookup (paper §2.2).

Reproduces the paper's B-1..B-5 pipeline directly against GraphStore (no host
storage stack):

  [B-1] read neighbors of the batch targets and sample ``fanout`` of them,
        per hop, producing per-layer subgraphs;
  [B-2] allocate new (local) VIDs in sampled order and reindex the subgraphs;
  [B-3/4] gather the embeddings of all sampled nodes from the store;
  [B-5] emit device-ready padded arrays.

The subgraph layout is the *page-shaped* padded-neighbor block: a fixed-width
``(num_dst, fanout)`` neighbor-index matrix plus mask.  This mirrors
GraphStore's fixed-capacity page chunks and is exactly the ELL layout our
Pallas SpMM kernel consumes — the near-storage format IS the accelerator
format, which is the paper's end-to-end point.

Two implementations share the exact sampling semantics:

  * ``sample_batch``      — the vectorized fast path: one batched neighbor
    fetch per frontier (``get_neighbors_batch`` when the store provides it),
    NumPy scatter into the padded block, and a ``np.unique``/``searchsorted``
    first-seen reindex instead of the per-neighbor dict walk;
  * ``sample_batch_ref``  — the original per-vertex loop, kept as the oracle.

Each hop is an explicit plan -> fetch -> build pipeline: the store's fused
``sample_neighbors_batch`` plans the frontier from its in-DRAM mapping
tables, fetches every needed page (ONE queued scatter-read on a single
device; one PER SHARD, issued concurrently, on a ``ShardedGraphStore``
array) and Floyd-selects by index; ``_build_level`` then recomposes the
global frontier for the next hop.  The store keeps the fanout draws in
per-vertex frontier order, so single-device, sharded, and reference
samplers are bit-identical under the same seed.

With the same rng both produce bit-identical blocks/vids/embeddings (the
fast path draws the per-vertex fanout subsamples in the same order), which
the fast-path tests assert.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class LayerBlock:
    """One GNN layer's sampled bipartite block.

    ``nbr[i, k]`` indexes into the *next* level's node list (local ids);
    ``mask[i, k]`` is 1.0 for valid slots.  Row ``i`` aggregates into local
    node ``i`` of this level (levels are prefix-ordered, see sample_batch).
    """
    nbr: np.ndarray        # (num_dst, fanout) int32
    mask: np.ndarray       # (num_dst, fanout) float32
    num_dst: int


@dataclass
class SampledBatch:
    layers: list[LayerBlock]        # [layer_1 .. layer_L]: layer_L nearest targets
    node_vids: np.ndarray           # (num_nodes,) global VIDs, sampled order
    embeddings: np.ndarray | None   # (num_nodes, D) float32
    num_targets: int

    @property
    def num_nodes(self) -> int:
        return len(self.node_vids)


def _gather_neighbors(store, frontier: np.ndarray) -> list[np.ndarray]:
    """[B-1] one batched near-storage read per frontier when available."""
    if hasattr(store, "get_neighbors_batch"):
        return store.get_neighbors_batch(frontier)
    return [np.asarray(store.get_neighbors(int(v))) for v in frontier]


def _floyd_select(u: np.ndarray, m: int, k: int) -> np.ndarray:
    """Floyd's uniform sampling without replacement: k indices out of m
    using exactly k uniforms — O(k) regardless of the neighbor count, which
    matters for power-law hubs with tens of thousands of neighbors."""
    seen: set[int] = set()
    out = np.empty(k, dtype=np.int64)
    for j in range(k):
        t = int(u[j] * (m - k + j + 1))
        if t in seen:
            t = m - k + j
        seen.add(t)
        out[j] = t
    return out


def _subsample(rng: np.random.Generator, vid: int, neigh: np.ndarray,
               fanout: int) -> np.ndarray:
    """Fanout subsampling for one vertex (Floyd, uniform w/o replacement).

    Shared scheme with the vectorized fast path: each over-full row consumes
    exactly ``fanout`` uniforms, and ``rng.random`` fills from the bit
    stream sequentially, so per-row draws here match one batched draw there
    — both implementations produce the same sample from the same seed."""
    if len(neigh) == 0:
        return np.array([int(vid)], dtype=np.int32)     # degenerate self-loop
    if len(neigh) > fanout:
        u = rng.random(fanout)
        return neigh[_floyd_select(u, len(neigh), fanout)]
    return neigh


def _subsample_batch(rng: np.random.Generator, frontier: np.ndarray,
                     neigh: list[np.ndarray], fanout: int):
    """Vectorized fanout subsampling for a whole frontier.

    One ``rng.random`` call covers every over-full row (``fanout`` uniforms
    each — same stream as the reference's per-row draws), Floyd-selected
    per row in O(fanout).  Returns the selected neighbors flattened
    row-major plus per-row lengths.
    """
    counts = np.fromiter((len(nb) for nb in neigh), dtype=np.int64,
                         count=len(neigh))
    flat_all = np.concatenate(
        [nb if len(nb) else np.array([int(v)], dtype=np.int32)
         for v, nb in zip(frontier, neigh)])
    counts = np.maximum(counts, 1)                   # empty -> [self-loop]
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])

    over = counts > fanout
    lens = np.where(over, fanout, counts)
    out_offs = np.concatenate([[0], np.cumsum(lens)[:-1]])
    sel = np.empty(int(lens.sum()), dtype=flat_all.dtype)

    # under-full rows: copy through (their flat positions, row-major)
    row_of = np.repeat(np.arange(len(counts)), counts)
    keep = ~over[row_of]
    sel[np.repeat(out_offs[~over], lens[~over])
        + _ramp(lens[~over])] = flat_all[keep]

    if over.any():
        over_idx = np.nonzero(over)[0]
        over_lens = counts[over_idx]
        u = rng.random(len(over_idx) * fanout).reshape(-1, fanout)
        idx = np.concatenate(
            [_floyd_select(u[r], int(m), fanout)
             for r, m in enumerate(over_lens)])      # (n_over * fanout,)
        r_of = np.repeat(np.arange(len(over_idx)), fanout)
        src = starts[over_idx[r_of]] + idx
        sel[np.repeat(out_offs[over], fanout) + _ramp(
            np.full(len(over_idx), fanout, np.int64))] = flat_all[src]
    return sel, lens


def _ramp(lens: np.ndarray) -> np.ndarray:
    """[0..l0), [0..l1), ... concatenated (per-segment aranges)."""
    total = int(lens.sum())
    if not total:
        return np.empty(0, dtype=np.int64)
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    return np.arange(total) - np.repeat(starts, lens)


def _build_level(frontier: np.ndarray, flat: np.ndarray, lens: np.ndarray,
                 fanout: int) -> tuple[LayerBlock, np.ndarray]:
    """[B-2/B-5] reindex one frontier's selected neighbors and scatter them
    into the page-shaped padded block.  (The serving batcher's fused
    multi-request sampler performs the same construction group-wide with a
    request-tagged reindex — ``repro.serve.batcher.sample_group``.)
    """
    flat = flat.astype(np.int64, copy=False)
    local, next_nodes = _reindex(frontier, flat)
    rows = np.repeat(np.arange(len(frontier)), lens)
    offs = np.concatenate([[0], np.cumsum(lens)[:-1]])
    cols = np.arange(len(flat)) - np.repeat(offs, lens)
    nbr = np.zeros((len(frontier), fanout), dtype=np.int32)
    mask = np.zeros((len(frontier), fanout), dtype=np.float32)
    nbr[rows, cols] = local
    mask[rows, cols] = 1.0
    return (LayerBlock(nbr=nbr, mask=mask, num_dst=len(frontier)),
            next_nodes)


def _reindex(frontier: np.ndarray, flat: np.ndarray):
    """[B-2] vectorized first-seen reindex.

    ``frontier`` holds local ids 0..F-1; every other VID in ``flat`` gets a
    fresh id F, F+1, ... in order of first appearance — the paper's
    "allocate new VIDs in the order of sampled nodes" rule, computed with
    sorted-search instead of a per-neighbor dict probe.
    """
    fsize = len(frontier)
    order = np.argsort(frontier, kind="stable")
    sorted_front = frontier[order]
    # rightmost match: a duplicated frontier vid maps to its LAST index,
    # matching the reference's dict-overwrite semantics
    pos = np.clip(np.searchsorted(sorted_front, flat, side="right") - 1,
                  0, fsize - 1)
    in_front = sorted_front[pos] == flat
    local = np.empty(len(flat), dtype=np.int64)
    local[in_front] = order[pos[in_front]]
    new_flat = flat[~in_front]
    uniq, first = np.unique(new_flat, return_index=True)
    rank = np.empty(len(uniq), dtype=np.int64)
    rank[np.argsort(first, kind="stable")] = np.arange(len(uniq))
    local[~in_front] = fsize + rank[np.searchsorted(uniq, new_flat)]
    new_vids = np.empty(len(uniq), dtype=np.int64)
    new_vids[rank] = uniq
    return local, np.concatenate([frontier, new_vids])


def sample_batch(store, targets, fanouts, *, rng: np.random.Generator | None = None,
                 fetch_embeddings: bool = True, pad_to: int | None = None) -> SampledBatch:
    """Unique-neighbor sampling (GraphSAGE-style) with ``len(fanouts)`` hops.

    ``fanouts[0]`` is the fanout of the hop nearest the targets (GNN layer L).
    Level lists are prefix-ordered: level k+1's node list begins with level
    k's nodes, so destination *i* of a block is node *i* of the deeper list.

    Vectorized fast path: batched neighbor fetch + NumPy reindex/scatter;
    equivalent to ``sample_batch_ref`` under the same rng.
    """
    rng = rng or np.random.default_rng(0)
    targets = np.asarray(targets, dtype=np.int64)
    levels: list[np.ndarray] = [targets]
    blocks_rev: list[LayerBlock] = []

    for fanout in fanouts:
        frontier = levels[-1]
        if not len(frontier):
            blocks_rev.append(LayerBlock(
                nbr=np.zeros((0, fanout), dtype=np.int32),
                mask=np.zeros((0, fanout), dtype=np.float32), num_dst=0))
            levels.append(frontier)
            continue
        if hasattr(store, "sample_neighbors_batch"):
            # fused near-storage fetch+subsample (hubs sampled by index,
            # never materialised)
            flat, lens = store.sample_neighbors_batch(frontier, fanout, rng)
        else:
            neigh = _gather_neighbors(store, frontier)
            flat, lens = _subsample_batch(rng, frontier, neigh, fanout)
        block, next_nodes = _build_level(frontier, flat, lens, fanout)
        blocks_rev.append(block)
        levels.append(next_nodes)

    node_vids = levels[-1]
    emb = None
    if fetch_embeddings and store.feature_dim:
        emb = store.get_embeds(node_vids)                   # [B-3/4] gather

    batch = SampledBatch(layers=list(reversed(blocks_rev)), node_vids=node_vids,
                         embeddings=emb, num_targets=len(targets))
    if pad_to is not None:
        batch = pad_batch(batch, pad_to)
    return batch


def sample_batch_ref(store, targets, fanouts, *,
                     rng: np.random.Generator | None = None,
                     fetch_embeddings: bool = True,
                     pad_to: int | None = None) -> SampledBatch:
    """Reference sampler: the per-vertex/per-neighbor loop implementation.

    Kept as the equivalence oracle for ``sample_batch`` (same rng -> same
    batch) and as the "before" side of the fast-path benchmarks.
    """
    rng = rng or np.random.default_rng(0)
    targets = np.asarray(targets, dtype=np.int64)
    levels: list[np.ndarray] = [targets]
    blocks_rev: list[LayerBlock] = []

    for fanout in fanouts:
        frontier = levels[-1]
        vid_to_local: dict[int, int] = {int(v): i for i, v in enumerate(frontier)}
        next_nodes = list(frontier)
        nbr = np.zeros((len(frontier), fanout), dtype=np.int32)
        mask = np.zeros((len(frontier), fanout), dtype=np.float32)
        for i, v in enumerate(frontier):
            neigh = store.get_neighbors(int(v))            # [B-1] per-vid read
            neigh = _subsample(rng, int(v), np.asarray(neigh), fanout)
            for k, u in enumerate(neigh):
                u = int(u)
                loc = vid_to_local.get(u)
                if loc is None:                             # [B-2] reindex
                    loc = len(next_nodes)
                    vid_to_local[u] = loc
                    next_nodes.append(u)
                nbr[i, k] = loc
                mask[i, k] = 1.0
        blocks_rev.append(LayerBlock(nbr=nbr, mask=mask, num_dst=len(frontier)))
        levels.append(np.asarray(next_nodes, dtype=np.int64))

    node_vids = levels[-1]
    emb = None
    if fetch_embeddings and store.feature_dim:
        emb = np.stack([store.get_embed(int(v)) for v in node_vids]) \
            if hasattr(store, "get_embed") else store.get_embeds(node_vids)

    batch = SampledBatch(layers=list(reversed(blocks_rev)), node_vids=node_vids,
                         embeddings=emb, num_targets=len(targets))
    if pad_to is not None:
        batch = pad_batch(batch, pad_to)
    return batch


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_batch(batch: SampledBatch, multiple: int) -> SampledBatch:
    """Pad node count and per-block dst counts to a multiple (shape stability
    for jit: a handful of bucketed shapes instead of one per batch). [B-5]"""
    n_pad = _round_up(batch.num_nodes, multiple)
    layers = []
    for blk in batch.layers:
        d_pad = _round_up(blk.num_dst, multiple)
        nbr = np.zeros((d_pad, blk.nbr.shape[1]), dtype=np.int32)
        mask = np.zeros((d_pad, blk.nbr.shape[1]), dtype=np.float32)
        nbr[: blk.num_dst] = blk.nbr
        mask[: blk.num_dst] = blk.mask
        layers.append(LayerBlock(nbr=nbr, mask=mask, num_dst=blk.num_dst))
    emb = None
    if batch.embeddings is not None:
        emb = np.zeros((n_pad, batch.embeddings.shape[1]), dtype=np.float32)
        emb[: batch.num_nodes] = batch.embeddings
    vids = np.full(n_pad, -1, dtype=np.int64)
    vids[: batch.num_nodes] = batch.node_vids
    return SampledBatch(layers=layers, node_vids=vids, embeddings=emb,
                        num_targets=batch.num_targets)
