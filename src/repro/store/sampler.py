"""Batch preprocessing — node sampling, reindexing, embedding lookup (paper §2.2).

Reproduces the paper's B-1..B-5 pipeline directly against GraphStore (no host
storage stack):

  [B-1] read neighbors of the batch targets and sample ``fanout`` of them,
        per hop, producing per-layer subgraphs;
  [B-2] allocate new (local) VIDs in sampled order and reindex the subgraphs;
  [B-3/4] gather the embeddings of all sampled nodes from the store;
  [B-5] emit device-ready padded arrays.

The subgraph layout is the *page-shaped* padded-neighbor block: a fixed-width
``(num_dst, fanout)`` neighbor-index matrix plus mask.  This mirrors
GraphStore's fixed-capacity page chunks and is exactly the ELL layout our
Pallas SpMM kernel consumes — the near-storage format IS the accelerator
format, which is the paper's end-to-end point.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class LayerBlock:
    """One GNN layer's sampled bipartite block.

    ``nbr[i, k]`` indexes into the *next* level's node list (local ids);
    ``mask[i, k]`` is 1.0 for valid slots.  Row ``i`` aggregates into local
    node ``i`` of this level (levels are prefix-ordered, see sample_batch).
    """
    nbr: np.ndarray        # (num_dst, fanout) int32
    mask: np.ndarray       # (num_dst, fanout) float32
    num_dst: int


@dataclass
class SampledBatch:
    layers: list[LayerBlock]        # [layer_1 .. layer_L]: layer_L nearest targets
    node_vids: np.ndarray           # (num_nodes,) global VIDs, sampled order
    embeddings: np.ndarray | None   # (num_nodes, D) float32
    num_targets: int

    @property
    def num_nodes(self) -> int:
        return len(self.node_vids)


def sample_batch(store, targets, fanouts, *, rng: np.random.Generator | None = None,
                 fetch_embeddings: bool = True, pad_to: int | None = None) -> SampledBatch:
    """Unique-neighbor sampling (GraphSAGE-style) with ``len(fanouts)`` hops.

    ``fanouts[0]`` is the fanout of the hop nearest the targets (GNN layer L).
    Level lists are prefix-ordered: level k+1's node list begins with level
    k's nodes, so destination *i* of a block is node *i* of the deeper list —
    the paper's "allocate new VIDs in the order of sampled nodes" rule.
    """
    rng = rng or np.random.default_rng(0)
    targets = np.asarray(targets, dtype=np.int64)
    levels: list[np.ndarray] = [targets]
    blocks_rev: list[LayerBlock] = []

    for fanout in fanouts:
        frontier = levels[-1]
        vid_to_local: dict[int, int] = {int(v): i for i, v in enumerate(frontier)}
        next_nodes = list(frontier)
        nbr = np.zeros((len(frontier), fanout), dtype=np.int32)
        mask = np.zeros((len(frontier), fanout), dtype=np.float32)
        for i, v in enumerate(frontier):
            neigh = store.get_neighbors(int(v))            # [B-1] near-storage read
            if len(neigh) == 0:
                neigh = np.array([int(v)], dtype=np.int32)  # degenerate self-loop
            if len(neigh) > fanout:
                neigh = rng.choice(neigh, size=fanout, replace=False)
            for k, u in enumerate(neigh):
                u = int(u)
                loc = vid_to_local.get(u)
                if loc is None:                             # [B-2] reindex
                    loc = len(next_nodes)
                    vid_to_local[u] = loc
                    next_nodes.append(u)
                nbr[i, k] = loc
                mask[i, k] = 1.0
        blocks_rev.append(LayerBlock(nbr=nbr, mask=mask, num_dst=len(frontier)))
        levels.append(np.asarray(next_nodes, dtype=np.int64))

    node_vids = levels[-1]
    emb = None
    if fetch_embeddings and store.feature_dim:
        emb = store.get_embeds(node_vids)                   # [B-3/4] gather

    batch = SampledBatch(layers=list(reversed(blocks_rev)), node_vids=node_vids,
                         embeddings=emb, num_targets=len(targets))
    if pad_to is not None:
        batch = pad_batch(batch, pad_to)
    return batch


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_batch(batch: SampledBatch, multiple: int) -> SampledBatch:
    """Pad node count and per-block dst counts to a multiple (shape stability
    for jit: a handful of bucketed shapes instead of one per batch). [B-5]"""
    n_pad = _round_up(batch.num_nodes, multiple)
    layers = []
    for blk in batch.layers:
        d_pad = _round_up(blk.num_dst, multiple)
        nbr = np.zeros((d_pad, blk.nbr.shape[1]), dtype=np.int32)
        mask = np.zeros((d_pad, blk.nbr.shape[1]), dtype=np.float32)
        nbr[: blk.num_dst] = blk.nbr
        mask[: blk.num_dst] = blk.mask
        layers.append(LayerBlock(nbr=nbr, mask=mask, num_dst=blk.num_dst))
    emb = None
    if batch.embeddings is not None:
        emb = np.zeros((n_pad, batch.embeddings.shape[1]), dtype=np.float32)
        emb[: batch.num_nodes] = batch.embeddings
    vids = np.full(n_pad, -1, dtype=np.int64)
    vids[: batch.num_nodes] = batch.node_vids
    return SampledBatch(layers=layers, node_vids=vids, embeddings=emb,
                        num_targets=batch.num_targets)
