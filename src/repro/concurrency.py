"""Machine-readable concurrency contract: the lock hierarchy + a
runtime lock-order witness.

This module is the single source of truth for the repo's lock
ordering.  Three consumers read it:

  * ``tools/analysis`` — the static lock-order / guarded-by passes
    check every acquisition in ``src/repro`` against ``LOCK_ORDER``;
  * ``docs/concurrency.md`` — the hierarchy table is generated from
    the registry (``tools/analyze.py --write-docs``; drift fails CI);
  * the **runtime witness** (below) — with ``REPRO_LOCK_WITNESS=1``
    every registered lock is wrapped at its creation site and each
    acquisition is checked against the registry rank order on the
    acquiring thread's live held-stack.

The rules the registry encodes:

  * **Ranks are ascending acquisition order**: a thread holding a lock
    of rank ``r`` may only acquire locks of rank ``> r``.  Re-entrant
    acquisition of the *same instance* is always allowed (RLocks).
  * **Leaf locks** guard tiny state; while one is held the thread may
    not acquire ANY other lock nor make a blocking call (RPC,
    ``sleep``, ``join``, ``Event.wait``).
  * **Exclusion pairs** (``NEVER_TOGETHER``) must never be held
    together in either order (the read-barrier cv vs the mutation
    lock: a draining reader may need ``_mutate``).
  * **Same-name, different-instance** nesting (two shards' stores) is
    only legal for names in ``SAME_NAME_OK`` — justified inline.

Adding a lock to the codebase without registering it here fails the
static analyzer (rule LO005), so the table cannot silently rot.
"""
from __future__ import annotations

import os
import threading
import traceback
from dataclasses import dataclass, field


# --------------------------------------------------------------- registry
@dataclass(frozen=True)
class LockSpec:
    """One registered lock/condition/semaphore attribute.

    ``sites`` binds source attributes to this spec as
    ``(module_basename, attr_name)`` pairs — the static analyzer
    resolves ``with self._mutate:`` in ``sharded.py`` through
    ``("sharded", "_mutate")``.  One attribute may alias another
    spec's lock (``_mig_cv`` shares ``_mutate``'s RLock).
    """

    name: str                 # canonical label, e.g. "sharded._mutate"
    rank: int                 # ascending = outer -> inner
    kind: str                 # "lock" | "rlock" | "condition" | "semaphore"
    sites: tuple              # ((module_basename, attr_name), ...)
    leaf: bool = False        # nothing acquired / no blocking while held
    doc: str = ""

    @property
    def reentrant(self) -> bool:
        return self.kind == "rlock"


LOCK_ORDER: tuple[LockSpec, ...] = (
    LockSpec("ingest._flush_lock", 10, "lock",
             (("ingest", "_flush_lock"),),
             doc="One firehose flush at a time (window order is the "
                 "contract); taken before the write gate."),
    LockSpec("sharded._maintenance", 20, "rlock",
             (("sharded", "_maintenance"),),
             doc="Maintenance plane: bulk ingest, rebuild stream, "
                 "reshard.  Always taken before _mutate, never after."),
    LockSpec("sharded._rd_cv", 25, "condition",
             (("sharded", "_rd_cv"),),
             doc="Reader-barrier condition guarding _rd_active/"
                 "_rd_barrier.  NEVER held together with _mutate in "
                 "either order (exclusion pair)."),
    LockSpec("sharded._mutate", 30, "rlock",
             (("sharded", "_mutate"), ("sharded", "_mig_cv")),
             doc="Coordinator mutation lock (composite cross-shard "
                 "atomicity).  _mig_cv is a Condition over this same "
                 "RLock.  Held across endpoint RPC by design on the "
                 "fan-fetch and firehose-window paths."),
    LockSpec("sharded._bp_lock", 40, "lock",
             (("sharded", "_bp_lock"),), leaf=True,
             doc="Backpressure/IO-wait counters.  LEAF: bump, release."),
    LockSpec("sharded._gossip_lock", 45, "lock",
             (("sharded", "_gossip_lock"),), leaf=True,
             doc="Gossip snapshot arrays.  LEAF: the counters RPC round "
                 "runs OUTSIDE it (snapshot in, publish out)."),
    LockSpec("ingest._lock", 50, "lock",
             (("ingest", "_lock"),), leaf=True,
             doc="Firehose submission log.  LEAF: flush pops the window "
                 "under it, applies after release."),
    LockSpec("scheduler._cond", 55, "condition",
             (("scheduler", "_cond"),),
             doc="Batch scheduler pending-queue condition.  Group "
                 "EXECUTION runs outside it; completion callbacks under "
                 "it may post to queue-pair CVs (rank 80)."),
    LockSpec("sharded._windows", 58, "semaphore",
             (("sharded", "_windows"),),
             doc="Per-shard in-flight window slots (BoundedSemaphore). "
                 "Counted, not order-checked; registered for the doc "
                 "table and so LO005 knows it is accounted for."),
    LockSpec("graphstore._lock", 60, "rlock",
             (("graphstore", "_lock"), ("endpoint", "_lock")),
             doc="Per-shard store critical section (gmap/h_chain/pages). "
                 "Re-entrant; cross-instance nesting is sanctioned for "
                 "the single-puller migration/rebuild stream."),
    LockSpec("blockdev._lock", 70, "lock",
             (("blockdev", "_lock"),),
             doc="Device allocator state (_front/_back/_free).  Grow "
                 "hooks fire AFTER release (caller holds the store "
                 "lock, which keeps relocation private)."),
    LockSpec("embcache._lock", 74, "rlock",
             (("embcache", "_lock"),),
             doc="Device-DRAM page-cache map.  Held across the backing "
                 "device read by design (the miss fill IS the critical "
                 "section)."),
    LockSpec("blockdev._busy_lock", 78, "lock",
             (("blockdev", "_busy_lock"),), leaf=True,
             doc="Busy-until command arbitration.  LEAF: compute the "
                 "deadline, release, sleep outside."),
    LockSpec("queues.cv", 80, "condition",
             (("queues", "cv"),),
             doc="One SQ/CQ pair's condition.  submit() nests the "
                 "work-signal condition inside it (80 -> 85)."),
    LockSpec("queues._work", 85, "condition",
             (("queues", "_work"),), leaf=True,
             doc="Device-side work signal across all pairs.  LEAF."),
    LockSpec("rpcclient._lock", 88, "lock",
             (("queues", "_lock"),), leaf=True,
             doc="AsyncRPCClient pending-reply map + channel guard. "
                 "LEAF: never held across a queue or channel wait."),
    LockSpec("runtime._write_lock", 90, "lock",
             (("runtime", "_write_lock"),), leaf=True,
             doc="Serving-runtime write-admission counters.  LEAF."),
    LockSpec("scheduler.qos._lock", 92, "lock",
             (("scheduler", "_lock"),), leaf=True,
             doc="QoS telemetry counters + latency window.  LEAF: all "
                 "mutation goes through QoSTelemetry's own methods."),
    LockSpec("supervisor._lock", 95, "lock",
             (("supervisor", "_lock"),), leaf=True,
             doc="Supervisor state arrays.  Strict LEAF: drains, "
                 "rebuilds and transition hooks all run outside it."),
)

RANK = {s.name: s.rank for s in LOCK_ORDER}
SPEC = {s.name: s for s in LOCK_ORDER}

# (outer, inner) pairs that violate rank order but are deliberate,
# with the justification the reviewer signed off on.  Kept EMPTY on
# purpose: the hierarchy currently has no exceptions — prefer fixing
# ranks over adding entries here.
SANCTIONED_EDGES: dict[tuple[str, str], str] = {}

# Lock names whose DIFFERENT INSTANCES may nest (same rank).  Only the
# per-shard store lock: the migration/rebuild stream has exactly one
# puller, which holds its own store's lock while reading the source
# shard's under the maintenance gate — no reverse edge can form.
SAME_NAME_OK: dict[str, str] = {
    "graphstore._lock": "single-puller migration/rebuild discipline "
                        "(dest holds its lock while pulling from src; "
                        "the maintenance gate serializes pullers)",
}

# Pairs that must never be held together in either order.
NEVER_TOGETHER: dict[frozenset, str] = {
    frozenset({"sharded._rd_cv", "sharded._mutate"}):
        "a draining reader may need _mutate; holding both inverts the "
        "quiesce protocol and deadlocks the routing flip",
}


def render_lock_table() -> str:
    """The markdown hierarchy table embedded in docs/concurrency.md
    (regenerate with ``tools/analyze.py --write-docs``; drift is a
    DOC001 finding)."""
    rows = ["| rank | lock | kind | leaf | role |",
            "|---:|---|---|:---:|---|"]
    for s in LOCK_ORDER:
        rows.append(f"| {s.rank} | `{s.name}` | {s.kind} | "
                    f"{'yes' if s.leaf else ''} | {s.doc} |")
    return "\n".join(rows) + "\n"


# ---------------------------------------------------------------- witness
WITNESS_ENV = "REPRO_LOCK_WITNESS"
_witness_on = os.environ.get(WITNESS_ENV, "") not in ("", "0")
_tls = threading.local()
_global = threading.Lock()          # guards the two lists below
violations: list[dict] = []
edges_seen: set[tuple[str, str]] = set()


def witness_enabled() -> bool:
    return _witness_on


def set_witness(on: bool) -> None:
    """Programmatic override of ``REPRO_LOCK_WITNESS`` (tests).  Only
    locks created AFTER the flip are wrapped."""
    global _witness_on
    _witness_on = bool(on)


def reset_witness() -> None:
    """Drop recorded violations/edges (test isolation)."""
    with _global:
        violations.clear()
        edges_seen.clear()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _record(kind: str, detail: str) -> None:
    frames = traceback.format_stack(limit=8)[:-2]
    with _global:
        violations.append({"kind": kind, "detail": detail,
                           "thread": threading.current_thread().name,
                           "stack": "".join(frames)})


def _check_acquire(spec: LockSpec, inst: int) -> None:
    st = _stack()
    if any(hid == inst for _, hid in st):
        return                              # re-entry on the same instance
    for held, hid in st:
        pair = (held.name, spec.name)
        if frozenset({held.name, spec.name}) in NEVER_TOGETHER:
            _record("exclusion", f"{held.name} held with {spec.name}: "
                    f"{NEVER_TOGETHER[frozenset(pair)]}")
            continue
        if held.name == spec.name:
            if spec.name not in SAME_NAME_OK:
                _record("same-name", f"two instances of {spec.name} "
                        "nested (not in SAME_NAME_OK)")
            continue
        if pair in SANCTIONED_EDGES:
            with _global:
                edges_seen.add(pair)
            continue
        if held.leaf:
            _record("leaf", f"acquired {spec.name} while holding LEAF "
                    f"{held.name}")
        elif held.rank > spec.rank:
            _record("inversion", f"acquired {spec.name} (rank "
                    f"{spec.rank}) while holding {held.name} (rank "
                    f"{held.rank})")
        with _global:
            edges_seen.add(pair)


class _WitnessBase:
    """Shared acquire/release bookkeeping for lock + condition proxies."""

    def __init__(self, spec: LockSpec, real):
        self._spec = spec
        self._real = real

    def acquire(self, *a, **kw):
        got = self._real.acquire(*a, **kw)
        if got:
            _check_acquire(self._spec, id(self._real))
            _stack().append((self._spec, id(self._real)))
        return got

    def release(self, *a, **kw):
        st = _stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i][1] == id(self._real):
                del st[i]
                break
        return self._real.release(*a, **kw)

    __enter__ = acquire

    def __exit__(self, *exc):
        self.release()
        return False

    # threading.Condition(wrapped_rlock) support: wait() bypasses the
    # proxy on purpose — a blocked waiter holds nothing it can deadlock
    # on, and it re-enters through _acquire_restore with its stack entry
    # still in place (same instance => re-entry is never edge-checked).
    def _release_save(self):
        return self._real._release_save()

    def _acquire_restore(self, state):
        return self._real._acquire_restore(state)

    def _is_owned(self):
        return self._real._is_owned()

    def __getattr__(self, name):
        return getattr(self._real, name)


class _WitnessCondition(_WitnessBase):
    """Condition proxy: acquisition via ``with``/acquire is witnessed;
    wait/notify delegate to the real condition (a waiting thread is
    blocked, so its stale stack entry cannot order-check anything)."""

    def wait(self, timeout=None):
        return self._real.wait(timeout)

    def wait_for(self, predicate, timeout=None):
        return self._real.wait_for(predicate, timeout)

    def notify(self, n=1):
        return self._real.notify(n)

    def notify_all(self):
        return self._real.notify_all()


def witness_lock(name: str, lock):
    """Wrap ``lock`` as registry entry ``name`` when the witness is on;
    return it untouched (zero overhead, identical type) otherwise."""
    if not _witness_on:
        return lock
    return _WitnessBase(SPEC[name], lock)


def witness_condition(name: str, cond):
    """Condition counterpart of ``witness_lock``."""
    if not _witness_on:
        return cond
    return _WitnessCondition(SPEC[name], cond)


def witness_report() -> dict:
    """Violations + distinct observed edges since the last reset."""
    with _global:
        return {"enabled": _witness_on,
                "violations": [dict(v) for v in violations],
                "edges": sorted(edges_seen)}


def assert_clean() -> dict:
    """Raise if the witness recorded any ordering violation; returns
    the report otherwise (drills call this at exit)."""
    rep = witness_report()
    if rep["violations"]:
        lines = [f"[{v['kind']}] {v['detail']} (thread {v['thread']})"
                 for v in rep["violations"]]
        raise AssertionError(
            "lock-order witness recorded %d violation(s):\n%s"
            % (len(lines), "\n".join(lines)))
    return rep
