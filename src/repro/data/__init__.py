from .pipeline import Pipeline, synth_batch

__all__ = ["Pipeline", "synth_batch"]
