"""Deterministic synthetic data pipeline with host sharding + prefetch.

Produces the token/label (and frame/patch) batches each architecture's
``input_specs`` declares.  Deterministic per (seed, step) so a restarted
trainer replays the exact stream from its checkpoint step — a prerequisite
for fault-tolerant resume.  Per-host sharding follows
``jax.process_index()`` so every host materializes only its slice at scale;
a background thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, step: int, *,
                seed: int = 0, host_index: int = 0, host_count: int = 1):
    """The batch for ``step`` (this host's slice)."""
    b = shape.global_batch // host_count
    s = shape.seq_len
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host_index]))
    # zipf-ish token stream: realistic embedding-gather skew (paper §2.3)
    def toks(n, t):
        z = rng.zipf(1.3, size=(n, t))
        return ((z - 1) % cfg.vocab_size).astype(np.int32)
    if cfg.family == "encdec":
        return {"frames": rng.standard_normal(
                    (b, s // 2, cfg.d_model)).astype(np.float32),
                "tokens": toks(b, s // 2),
                "labels": toks(b, s // 2)}
    if cfg.family == "vlm":
        p = cfg.num_patches
        return {"patches": rng.standard_normal(
                    (b, p, cfg.d_model)).astype(np.float32),
                "tokens": toks(b, s - p),
                "labels": toks(b, s - p)}
    t = toks(b, s + 1)
    return {"tokens": t[:, :-1], "labels": t[:, 1:].copy()}


class Pipeline:
    def __init__(self, cfg, shape, *, seed=0, start_step=0, prefetch=2,
                 host_index=None, host_count=None):
        import jax
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.host_index = (jax.process_index() if host_index is None
                           else host_index)
        self.host_count = (jax.process_count() if host_count is None
                           else host_count)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="pipeline-prefetch")
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, self.shape, step, seed=self.seed,
                                host_index=self.host_index,
                                host_count=self.host_count)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        # drain so a worker blocked on a full queue sees the stop flag on
        # its next put timeout, then reap it — close() must not leave the
        # prefetch thread running against a torn-down pipeline
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
