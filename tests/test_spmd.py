"""SPMD engine execution: sharded == single-device numerics (fp32 allclose)
for GCN/GIN/NGCF across mesh shapes, padding of odd hidden/row dims, the
Pallas fused path (AggCombinePartial + psum), the serving batcher on a
meshed service, and the bounded LRU jit cache.

Runs on 8 forced host CPU devices (tests/conftest.py sets XLA_FLAGS before
any jax import); ``spmd_devices`` skips mesh tests if the force didn't
stick.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.dfg import Engine
from repro.core.registry import KernelRegistry
from repro.core.xbuilder import XBuilder, SHELL_DEVICE
from repro.core import gnn
from repro.core.service import HolisticGNNService, make_service_dfg
from repro.kernels.ops import program_config
from repro.launch.mesh import make_host_mesh

MESH_SHAPES = [(1, 1), (1, 2), (2, 2), (1, 4)]
N, K = 60, 5
ROWS = [24, 12]                      # decreasing hop row counts


def _blocks(rng, rows=ROWS, n=N):
    out, prev = [], n
    for d in rows:
        nbr = jnp.asarray(rng.integers(0, prev, (d, K)), jnp.int32)
        mask = jnp.asarray((rng.random((d, K)) < 0.8).astype(np.float32))
        out.append((nbr, mask))
        prev = d
    return out


def _engine(mesh=None, config=None, **kw):
    reg = KernelRegistry()
    xb = XBuilder(reg)
    for name, fn in gnn.extra_shell_kernels().items():
        reg.register_op(name, SHELL_DEVICE, fn)
    if config:
        program_config(xb, config)
    return Engine(reg, mesh=mesh, **kw)


def _model_case(model, dims, seed=1):
    rng = np.random.default_rng(0)
    params = gnn.init_params(model, dims, seed=seed)
    emb = jnp.asarray(rng.standard_normal((N, dims[0])).astype(np.float32))
    dfg = gnn.BUILD_DFG[model](len(dims) - 1)
    feeds = gnn.dfg_feeds(model, params, emb, _blocks(rng))
    return dfg, feeds


# -------------------------------------------------- sharded == single-device
@pytest.mark.parametrize("shape", MESH_SHAPES)
@pytest.mark.parametrize("model,dims", [
    ("gcn", [13, 17, 7]), ("gin", [13, 17, 7]), ("ngcf", [13, 13, 13])])
def test_sharded_matches_single_device(model, dims, shape, spmd_devices):
    dfg, feeds = _model_case(model, dims)
    ref = _engine().run(dfg, dict(feeds), jit=True)
    mesh = make_host_mesh(shape[0] * shape[1], shape=shape)
    out = _engine(mesh).run(dfg, dict(feeds), jit=True)
    for k in ref:
        np.testing.assert_allclose(ref[k], out[k], rtol=2e-5, atol=2e-5)


def test_data_by_model_mesh(spmd_devices):
    """Both axes striped at once (2 data x 4 model = all 8 devices)."""
    for model, dims in [("gcn", [13, 17, 7]), ("ngcf", [13, 13, 13])]:
        dfg, feeds = _model_case(model, dims)
        ref = _engine().run(dfg, dict(feeds), jit=True)
        out = _engine(make_host_mesh(8, shape=(2, 4))).run(
            dfg, dict(feeds), jit=True)
        np.testing.assert_allclose(ref["Result"], out["Result"],
                                   rtol=2e-5, atol=2e-5)


def test_hetero_fused_pallas_path(spmd_devices):
    """The hetero config fuses GCN layers into AggCombine; the sharded
    engine must route through AggCombinePartial + psum and still match."""
    dfg, feeds = _model_case("gcn", [13, 17, 7])
    ref = _engine(config="hetero").run(dfg, dict(feeds), jit=True)
    eng = _engine(make_host_mesh(8, shape=(2, 4)), config="hetero")
    out = eng.run(dfg, dict(feeds), jit=True)
    assert any(op == "AggCombine" for op, _ in eng.trace)  # fusion fired
    np.testing.assert_allclose(ref["Result"], out["Result"],
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dims", [[5, 9, 3], [7, 11, 11]])
def test_odd_hidden_dims_are_padded(dims, spmd_devices):
    """No dim divides the 4-way model axis: zero-padding to divisibility
    must be numerically invisible and outputs sliced back to true shape."""
    dfg, feeds = _model_case("gcn", dims)
    ref = _engine().run(dfg, dict(feeds), jit=True)
    out = _engine(make_host_mesh(8, shape=(2, 4))).run(
        dfg, dict(feeds), jit=True)
    assert np.asarray(out["Result"]).shape == np.asarray(ref["Result"]).shape
    np.testing.assert_allclose(ref["Result"], out["Result"],
                               rtol=2e-5, atol=2e-5)


def test_odd_row_counts_are_padded(spmd_devices):
    """Hop row counts that don't divide the data axis (11, 7 on d=2)."""
    rng = np.random.default_rng(4)
    params = gnn.init_params("gcn", [13, 17, 7], seed=1)
    emb = jnp.asarray(rng.standard_normal((N, 13)).astype(np.float32))
    dfg = gnn.BUILD_DFG["gcn"](2)
    feeds = gnn.dfg_feeds("gcn", params, emb, _blocks(rng, rows=[11, 7]))
    ref = _engine().run(dfg, dict(feeds), jit=True)
    out = _engine(make_host_mesh(8, shape=(2, 4))).run(
        dfg, dict(feeds), jit=True)
    np.testing.assert_allclose(ref["Result"], out["Result"],
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------- service / serving
def _graph_service(**kw):
    rng = np.random.default_rng(7)
    n, e, feat = 400, 3000, 32
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    svc = HolisticGNNService(h_threshold=16, pad_to=32, **kw)
    svc.store.update_graph(edges, emb)
    return svc, n


def test_run_batch_on_meshed_service(spmd_devices):
    """The serving batcher's fused super-batch through the SPMD engine:
    same near-storage sampling, allclose results, mesh in stats."""
    plain, n = _graph_service()
    meshed, _ = _graph_service(model_parallel=4)
    params = gnn.init_params("gcn", [32, 16, 8], seed=1)
    dfg = make_service_dfg("gcn", 2, [5, 5]).save()
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    rng = np.random.default_rng(5)
    reqs = [{"targets": rng.integers(0, n, sz).tolist(), "seed": 50 + i}
            for i, sz in enumerate([8, 3, 16])]
    ref = plain.run_batch(dfg, reqs, weights=weights, jit=True)
    out = meshed.run_batch(dfg, reqs, weights=weights, jit=True)
    for a, b in zip(ref, out):
        for k in a:
            assert a[k].shape == b[k].shape
            np.testing.assert_allclose(a[k], b[k], rtol=2e-5, atol=2e-5)
    st = meshed.stats()["engine"]
    assert st["mesh"] == {"data": 2, "model": 4}
    assert st["jit_cache"]["misses"] >= 1
    plain.close()
    meshed.close()


def test_service_run_on_mesh(spmd_devices):
    """Single-request Run RPC path (BatchPre eager prefix + sharded
    suffix) against an explicit mesh= handle."""
    plain, n = _graph_service()
    meshed, _ = _graph_service(mesh=make_host_mesh(4, shape=(1, 4)))
    params = gnn.init_params("gin", [32, 16, 8], seed=2)
    dfg = make_service_dfg("gin", 2, [5, 5]).save()
    weights = {k: v for k, v in
               gnn.dfg_feeds("gin", params, None, []).items() if k != "H"}
    targets = list(range(12))
    ref = plain.run(dfg, targets, weights=weights, seed=3, jit=True)
    out = meshed.run(dfg, targets, weights=weights, seed=3, jit=True)
    for k in ref:
        np.testing.assert_allclose(ref[k], out[k], rtol=2e-5, atol=2e-5)
    plain.close()
    meshed.close()


# ------------------------------------------------------------- LRU jit cache
def test_jit_cache_lru_eviction_and_stats():
    eng = _engine(jit_cache_size=2)
    dfg = gnn.BUILD_DFG["gcn"](1)
    rng = np.random.default_rng(0)
    params = gnn.init_params("gcn", [8, 4], seed=0)

    def feeds(d):
        emb = jnp.asarray(rng.standard_normal((N, 8)).astype(np.float32))
        return gnn.dfg_feeds("gcn", params, emb, _blocks(rng, rows=[d]))

    f1, f2, f3 = feeds(8), feeds(12), feeds(16)   # 3 distinct signatures
    eng.run(dfg, f1, jit=True)
    eng.run(dfg, f1, jit=True)                    # hit
    st = eng.cache_stats()
    assert (st["hits"], st["misses"], st["evictions"]) == (1, 1, 0)
    eng.run(dfg, f2, jit=True)                    # fills capacity
    eng.run(dfg, f3, jit=True)                    # evicts f1 (LRU)
    st = eng.cache_stats()
    assert st["evictions"] == 1 and st["size"] == st["capacity"] == 2
    eng.run(dfg, f2, jit=True)                    # still cached
    assert eng.cache_stats()["hits"] == 2
    eng.run(dfg, f1, jit=True)                    # was evicted -> miss
    assert eng.cache_stats()["misses"] == 4

    with pytest.raises(ValueError):
        _engine(jit_cache_size=0)


def test_mesh_in_cache_key(spmd_devices):
    """Same DFG + signature on different meshes must not share traces."""
    dfg, feeds = _model_case("gcn", [13, 17, 7])
    eng = _engine(make_host_mesh(2, shape=(1, 2)))
    eng.run(dfg, dict(feeds), jit=True)
    eng.mesh = make_host_mesh(4, shape=(1, 4))    # re-point the engine
    out = eng.run(dfg, dict(feeds), jit=True)
    st = eng.cache_stats()
    assert st["misses"] == 2 and st["hits"] == 0  # distinct cache entries
    ref = _engine().run(dfg, dict(feeds), jit=True)
    np.testing.assert_allclose(ref["Result"], out["Result"],
                               rtol=2e-5, atol=2e-5)
