"""PlacementMap algebra + the placement-totality property: ANY total
placement map (random owner tables included) must round-trip the
plan → fetch → build read path bit-identically to the single device,
and ingesting under a custom map must agree with resharding a default
array INTO that same map."""
import numpy as np
import pytest

from repro.store import (BlockDevice, GraphStore, ReplicatedGraphStore,
                         ShardedGraphStore, sample_batch)
from repro.store.placement import (PlacementMap, common_refine, grow_plan,
                                   heat_plan, modular, plan_moves,
                                   rows_of_class, shrink_plan)


def _graph(n=360, e=2600, feat=16, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _random_map(n_shards, n_classes, replication, seed):
    """A random but TOTAL placement map: every (class, role) owned, the
    replicas of each class on distinct shards."""
    rng = np.random.default_rng(seed)
    owner = np.stack([rng.choice(n_shards, size=replication, replace=False)
                      for _ in range(n_classes)]).astype(np.int64)
    return PlacementMap(n_classes, owner)


def _assert_reads_equal(ref, store, n, seed=7):
    rng = np.random.default_rng(seed)
    vids = rng.integers(0, n, 120)
    np.testing.assert_array_equal(ref.get_embeds(vids),
                                  store.get_embeds(vids))
    for a, b in zip(ref.get_neighbors_batch(vids[:40]),
                    store.get_neighbors_batch(vids[:40])):
        np.testing.assert_array_equal(a, b)
    ba = sample_batch(ref, vids[:32], [6, 6],
                      rng=np.random.default_rng(11), pad_to=32)
    bb = sample_batch(store, vids[:32], [6, 6],
                      rng=np.random.default_rng(11), pad_to=32)
    np.testing.assert_array_equal(ba.node_vids, bb.node_vids)
    np.testing.assert_array_equal(ba.embeddings, bb.embeddings)
    for la, lb in zip(ba.layers, bb.layers):
        np.testing.assert_array_equal(la.nbr, lb.nbr)
        np.testing.assert_array_equal(la.mask, lb.mask)


# --------------------------------------------------------------- map algebra
def test_modular_map_is_legacy_layout():
    m = modular(4, 2)
    assert m.is_modular(4)
    assert m.replication == 2
    for c in range(4):
        for r in range(2):
            assert int(m.owner[c, r]) == (c + r) % 4


def test_refine_preserves_ownership():
    m = _random_map(4, 4, 2, seed=1)
    f = m.refine(3)
    assert f.n_classes == 12
    for v in range(60):
        np.testing.assert_array_equal(m.owner[v % 4], f.owner[v % 12])
    # refining never plans any move
    a, b = common_refine(m, f)
    moves, drops = plan_moves(a, b)
    assert moves == [] and drops == {}


def test_rows_of_class_partitions_rows():
    for n_rows in (0, 1, 7, 64, 101):
        for C in (1, 3, 5, 8):
            assert sum(rows_of_class(n_rows, c, C)
                       for c in range(C)) == n_rows


def test_validate_rejects_bad_maps():
    with pytest.raises(ValueError):
        PlacementMap(2, np.array([[0], [5]])).validate(4)
    with pytest.raises(ValueError):
        PlacementMap(1, np.array([[1, 1]])).validate(4)
    _random_map(4, 8, 2, seed=2).validate(4)


def test_plan_moves_classifies_copy_vs_relabel():
    old = PlacementMap(2, np.array([[0, 1], [1, 2]]))
    # class 0: role 0 moves 0->2 (2 not an owner: copy); class 1:
    # roles swap 1<->2 (both already owners: relabels, no bytes)
    new = PlacementMap(2, np.array([[2, 1], [2, 1]]))
    moves, drops = plan_moves(old, new)
    kinds = {(m.cls, m.role): m.kind for m in moves}
    assert kinds[(0, 0)] == "copy"
    assert kinds[(1, 0)] == "relabel" and kinds[(1, 1)] == "relabel"
    assert drops == {0: [0]}        # shard 0 no longer holds class 0


def test_grow_plan_gives_new_shards_fair_share():
    pm = modular(4, 1)
    heat = np.array([8.0, 4.0, 2.0, 1.0])
    new = grow_plan(pm, 4, 5, heat=heat)
    assert new.n_classes % 5 == 0
    new.validate(5)
    got = len(new.classes_of(4))
    assert got == new.n_classes // 5
    # every move targets the new shard only
    a, b = common_refine(pm, new)
    moves, _ = plan_moves(a, b)
    assert moves and all(m.dst == 4 for m in moves)


def test_shrink_plan_drains_only_removed():
    pm = modular(4, 2)
    new = shrink_plan(pm, [3], 4)
    assert not (new.owner == 3).any()
    new.validate(4)
    a, b = common_refine(pm, new)
    moves, _ = plan_moves(a, b)
    assert moves and all(m.src == 3 for m in moves)


def test_heat_plan_flattens_skewed_heat():
    pm = modular(4, 1)
    heat = np.array([100.0, 1.0, 1.0, 1.0])     # one scorching class
    new = heat_plan(pm, heat, live=[0, 1, 2, 3], refine=4)
    new.validate(4)
    fine_heat = np.tile(heat / 4, 4)
    loads = np.zeros(4)
    np.add.at(loads, new.owner[:, 0], fine_heat)
    assert loads.min() / loads.max() > 0.6      # vs 0.03 before


def test_payload_roundtrip():
    m = _random_map(5, 10, 2, seed=3)
    assert PlacementMap.from_payload(m.to_payload()) == m


# ------------------------------------------------- placement totality property
@pytest.mark.parametrize("n_shards,replication,n_classes,seed", [
    (2, 1, 2, 10), (2, 1, 6, 11), (4, 1, 4, 12), (4, 1, 12, 13),
    (3, 2, 3, 14), (4, 2, 8, 15), (4, 3, 12, 16),
])
def test_any_total_map_reads_bit_identical(n_shards, replication,
                                           n_classes, seed):
    """The read path never assumes modular placement: a store ingested
    under a RANDOM total map answers plan → fetch → build reads
    bit-identically to the single device."""
    edges, emb = _graph()
    n = emb.shape[0]
    ref = GraphStore(BlockDevice(), h_threshold=16)
    ref.update_graph(edges, emb)
    pmap = _random_map(n_shards, n_classes, replication, seed)
    if replication == 1:
        store = ShardedGraphStore(n_shards=n_shards, h_threshold=16,
                                  placement=pmap)
    else:
        store = ReplicatedGraphStore(n_shards=n_shards, h_threshold=16,
                                     replication=replication,
                                     placement=pmap)
    store.update_graph(edges, emb)
    _assert_reads_equal(ref, store, n)
    ps = store.placement_stats()
    assert ps["n_classes"] == n_classes
    assert sum(ps["classes_per_shard"]) >= n_classes


@pytest.mark.parametrize("n_shards,replication", [(3, 1), (4, 2)])
def test_ingest_under_map_agrees_with_reshard_into_map(n_shards,
                                                       replication):
    """Loading a graph directly under a custom map produces the same
    answers as loading under the default map and resharding INTO that
    map online — the two paths to a placement must agree."""
    edges, emb = _graph()
    n = emb.shape[0]
    pmap = _random_map(n_shards, 2 * n_shards, replication, seed=21)

    if replication == 1:
        direct = ShardedGraphStore(n_shards=n_shards, h_threshold=16,
                                   placement=pmap)
        moved = ShardedGraphStore(n_shards=n_shards, h_threshold=16)
    else:
        direct = ReplicatedGraphStore(n_shards=n_shards, h_threshold=16,
                                      replication=replication,
                                      placement=pmap)
        moved = ReplicatedGraphStore(n_shards=n_shards, h_threshold=16,
                                     replication=replication)
    direct.update_graph(edges, emb)
    moved.update_graph(edges, emb)
    report = moved.reshard(placement=pmap, chunk_pages=16)
    assert report["classes_moved"] > 0
    assert report["epochs"] >= 1
    # both now answer identically (and identically to one device)
    ref = GraphStore(BlockDevice(), h_threshold=16)
    ref.update_graph(edges, emb)
    _assert_reads_equal(ref, direct, n)
    _assert_reads_equal(ref, moved, n)
    a, b = common_refine(direct._routing.pmap, moved._routing.pmap)
    moves, _ = plan_moves(a, b)
    assert moves == []              # literally the same placement


def test_mutations_under_custom_map_route_correctly():
    """Unit mutations against a random map land on the mapped owners and
    stay bit-identical to the single device."""
    edges, emb = _graph(n=200, e=1200)
    n = emb.shape[0]
    ref = GraphStore(BlockDevice(), h_threshold=16)
    ref.update_graph(edges, emb)
    store = ShardedGraphStore(n_shards=3, h_threshold=16,
                              placement=_random_map(3, 6, 1, seed=5))
    store.update_graph(edges, emb)
    rng = np.random.default_rng(9)
    for _ in range(40):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        ref.add_edge(u, v)
        store.add_edge(u, v)
    row = rng.standard_normal(emb.shape[1]).astype(np.float32)
    ref.update_embed(7, row)
    store.update_embed(7, row)
    _assert_reads_equal(ref, store, n)
