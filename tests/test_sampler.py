"""Batch preprocessing: k-hop structure, reindexing invariants, padding."""
import numpy as np

from repro.store.blockdev import BlockDevice
from repro.store.graphstore import GraphStore
from repro.store.sampler import sample_batch, pad_batch


def _store(seed=0, n=120, e=700):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, 16)).astype(np.float32)
    gs = GraphStore(BlockDevice(), h_threshold=8)
    gs.update_graph(edges, emb)
    return gs


def test_block_structure_and_prefix_ordering():
    gs = _store()
    targets = [3, 7, 11]
    b = sample_batch(gs, targets, [4, 3], rng=np.random.default_rng(0))
    assert len(b.layers) == 2
    # prefix invariant: first num_targets nodes ARE the targets
    assert list(b.node_vids[:3]) == targets
    # layer_L (last) has num_dst == num_targets
    assert b.layers[-1].num_dst == 3
    # indices within bounds of the deeper level
    deeper = b.num_nodes
    for blk in b.layers:
        assert blk.nbr.max() < deeper
        deeper = blk.num_dst  # next block indexes into this level

    # all sampled neighbors really are neighbors in the store
    lvl_nodes = b.node_vids
    blk = b.layers[0]
    for i in range(blk.num_dst):
        v = int(lvl_nodes[i])
        nbrs = set(int(x) for x in gs.get_neighbors(v))
        for k in range(blk.nbr.shape[1]):
            if blk.mask[i, k]:
                assert int(lvl_nodes[blk.nbr[i, k]]) in nbrs


def test_embedding_gather_matches_store():
    gs = _store(1)
    b = sample_batch(gs, [1, 2], [3, 3], rng=np.random.default_rng(1))
    for i, v in enumerate(b.node_vids):
        np.testing.assert_array_equal(b.embeddings[i], gs.get_embed(int(v)))


def test_sampling_deterministic_and_padding():
    gs = _store(2)
    b1 = sample_batch(gs, [5, 6], [4, 4], rng=np.random.default_rng(7))
    b2 = sample_batch(gs, [5, 6], [4, 4], rng=np.random.default_rng(7))
    np.testing.assert_array_equal(b1.node_vids, b2.node_vids)
    p = pad_batch(b1, 32)
    assert p.num_nodes % 32 == 0
    for blk in p.layers:
        assert blk.nbr.shape[0] % 32 == 0
    np.testing.assert_array_equal(p.node_vids[: b1.num_nodes], b1.node_vids)
