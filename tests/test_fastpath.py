"""Vectorized fast-path equivalence: batched GraphStore queries, the
NumPy sampler vs the per-vertex reference, the fused aggregate-combine
kernel, and the engine's whole-DFG jit path."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.store.blockdev import BlockDevice
from repro.store.graphstore import GraphStore
from repro.store.sampler import sample_batch, sample_batch_ref


def _store(seed=0, n=400, e=3000, h_threshold=8, feat=24):
    """Power-law graph with H/L mix; some vertices stay edge-less (isolated
    vertices have embeddings but no adjacency -> empty-neighbor path)."""
    rng = np.random.default_rng(seed)
    src = rng.zipf(1.4, e) % (n - 10)          # last 10 vids never get edges
    dst = rng.integers(0, n - 10, e)
    edges = np.stack([dst, src], axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    gs = GraphStore(BlockDevice(), h_threshold=h_threshold)
    gs.update_graph(edges, emb)
    return gs, n


@pytest.mark.parametrize("seed,h_threshold", [(0, 8), (1, 4), (2, 64)])
def test_get_neighbors_batch_matches_pointwise(seed, h_threshold):
    gs, n = _store(seed, h_threshold=h_threshold)
    vids = list(range(n)) + [n + 3, n + 17]    # incl. isolated + unknown vids
    batch = gs.get_neighbors_batch(vids)
    assert len(batch) == len(vids)
    kinds = set(gs.gmap.values())
    assert kinds == {"H", "L"}                 # both mapping types exercised
    for v, got in zip(vids, batch):
        np.testing.assert_array_equal(got, gs.get_neighbors(v), err_msg=str(v))


def test_get_neighbors_batch_after_mutations():
    """H/L boundary: batch reads stay correct across promotion and deletes."""
    gs = GraphStore(BlockDevice(), h_threshold=4)
    gs.update_graph(np.array([[0, 1], [1, 2], [2, 3]], np.int64))
    for u in range(4, 10):
        gs.add_edge(0, u)                      # promotes 0 to H-type
    gs.delete_edge(1, 2)
    assert gs.gmap[0] == "H"
    vids = list(range(12))
    for v, got in zip(vids, gs.get_neighbors_batch(vids)):
        np.testing.assert_array_equal(got, gs.get_neighbors(v), err_msg=str(v))


def test_get_neighbors_batch_multipage_h_chain():
    """Degree > H_CAP: chains spanning multiple pages, batch == pointwise,
    including after chain growth through unit-op appends."""
    n_nbrs = 2600                                  # > 2 * H_CAP (1022)
    edges = np.stack([np.zeros(n_nbrs, np.int64),
                      np.arange(1, n_nbrs + 1)], axis=1)
    gs = GraphStore(BlockDevice(), h_threshold=16)
    gs.update_graph(edges)
    assert gs.gmap[0] == "H" and len(gs.h_chain[0]) >= 3
    for u in range(n_nbrs + 1, n_nbrs + 40):       # grow the tail page
        gs.add_edge(0, u)
    got = gs.get_neighbors_batch([0, 1, 2])
    for v, g in zip([0, 1, 2], got):
        np.testing.assert_array_equal(g, gs.get_neighbors(v))


def test_get_embeds_coalesced_matches_rowwise():
    gs, n = _store(3)
    rng = np.random.default_rng(9)
    for ids in (np.arange(n), rng.permutation(n)[:137],
                np.array([0, n - 1, 1, n // 2]), np.array([5])):
        got = gs.get_embeds(ids)
        want = np.stack([gs.get_embed(int(v)) for v in ids])
        np.testing.assert_array_equal(got, want)
    assert gs.get_embeds(np.empty(0, np.int64)).shape == (0, gs.feature_dim)


def _assert_batches_equal(b1, b2):
    np.testing.assert_array_equal(b1.node_vids, b2.node_vids)
    assert b1.num_targets == b2.num_targets
    assert len(b1.layers) == len(b2.layers)
    for l1, l2 in zip(b1.layers, b2.layers):
        assert l1.num_dst == l2.num_dst
        np.testing.assert_array_equal(l1.nbr, l2.nbr)
        np.testing.assert_array_equal(l1.mask, l2.mask)
    if b1.embeddings is None:
        assert b2.embeddings is None
    else:
        np.testing.assert_array_equal(b1.embeddings, b2.embeddings)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("fanouts", [[4, 3], [10, 10], [2]])
def test_sample_batch_matches_reference(seed, fanouts):
    gs, n = _store(seed)
    targets = [3, 7, 11, n - 2]                # n-2 is isolated: self-loop path
    b_vec = sample_batch(gs, targets, fanouts,
                         rng=np.random.default_rng(seed))
    b_ref = sample_batch_ref(gs, targets, fanouts,
                             rng=np.random.default_rng(seed))
    _assert_batches_equal(b_vec, b_ref)


def test_sample_batch_matches_reference_duplicate_targets():
    """Duplicate targets: the reference maps a duplicated vid to its LAST
    frontier index (dict overwrite); the fast path must match."""
    gs, n = _store(0)
    for targets in ([5, 5, 7], [3, 3, 3]):
        b_vec = sample_batch(gs, targets, [4, 3],
                             rng=np.random.default_rng(1))
        b_ref = sample_batch_ref(gs, targets, [4, 3],
                                 rng=np.random.default_rng(1))
        _assert_batches_equal(b_vec, b_ref)


def test_sample_batch_matches_reference_padded():
    gs, n = _store(1, h_threshold=4)
    b_vec = sample_batch(gs, [1, 2, 5], [6, 6],
                         rng=np.random.default_rng(0), pad_to=32)
    b_ref = sample_batch_ref(gs, [1, 2, 5], [6, 6],
                             rng=np.random.default_rng(0), pad_to=32)
    _assert_batches_equal(b_vec, b_ref)
    assert b_vec.num_nodes % 32 == 0


def test_agg_combine_fused_kernel_matches_chain():
    from repro.kernels import agg_combine
    rng = np.random.default_rng(0)
    for (n, f, d, k, o) in [(50, 32, 10, 4, 16), (128, 220, 88, 10, 64)]:
        h = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
        nbr = jnp.asarray(rng.integers(0, n, (d, k)), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, (d, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((f, o)) * 0.1, jnp.float32)
        b = jnp.asarray(rng.standard_normal(o) * 0.1, jnp.float32)
        got = agg_combine(h, nbr, mask, w, b, mode="mean")
        g = jnp.take(h, nbr, axis=0) * mask[..., None]
        agg = g.sum(1) / jnp.maximum(mask.sum(1), 1.0)[:, None]
        want = jnp.maximum(agg @ w + b[None, :], 0.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_engine_jit_path_matches_eager_and_caches():
    from repro.core.service import HolisticGNNService, make_service_dfg
    from repro.core import gnn
    rng = np.random.default_rng(3)
    edges = np.stack([rng.integers(0, 80, 400), rng.integers(0, 80, 400)],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((80, 24)).astype(np.float32)
    svc = HolisticGNNService(h_threshold=8, pad_to=16)
    svc.update_graph(edges, emb)
    for model in ("gcn", "gin", "ngcf"):
        params = gnn.init_params(model, [24, 12, 8], seed=2)
        dfg = make_service_dfg(model, 2, [4, 4])
        weights = gnn.dfg_feeds(model, params, None, [])
        weights.pop("H")
        o_eager = svc.run(dfg.save(), [1, 2], weights=weights,
                          jit=False)["Result"]
        o_jit = svc.run(dfg.save(), [1, 2], weights=weights,
                        jit=True)["Result"]
        np.testing.assert_allclose(o_eager, o_jit, rtol=1e-5, atol=1e-5)
    # one cached trace per model DFG; repeat runs hit the cache
    assert len(svc.engine._jit_cache) == 3
    svc.run(dfg.save(), [1, 2], weights=weights, jit=True)
    assert len(svc.engine._jit_cache) == 3


def test_gcn_fusion_on_hetero_bitstream():
    from repro.core.service import HolisticGNNService, make_service_dfg
    from repro.core import gnn
    from repro.kernels.ops import program_config
    rng = np.random.default_rng(4)
    edges = np.stack([rng.integers(0, 60, 300), rng.integers(0, 60, 300)],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((60, 24)).astype(np.float32)
    svc = HolisticGNNService(h_threshold=8, pad_to=16)
    svc.update_graph(edges, emb)
    params = gnn.init_params("gcn", [24, 12, 8], seed=2)
    dfg = make_service_dfg("gcn", 2, [4, 4])
    weights = gnn.dfg_feeds("gcn", params, None, [])
    weights.pop("H")
    before = svc.run(dfg.save(), [1, 2], weights=weights)["Result"]

    program_config(svc.xbuilder, "hetero")
    after = svc.run(dfg.save(), [1, 2], weights=weights)["Result"]
    # both GCN layers collapsed into the fused kernel on the vector device
    assert svc.engine.trace.count(("AggCombine", "vector")) == 2
    assert not any(op in ("SpMM_Mean", "GEMM", "BiasAdd", "ReLU")
                   for op, _ in svc.engine.trace)
    np.testing.assert_allclose(before, after, rtol=1e-4, atol=1e-4)

    # registry version bump invalidates the fused trace: unprogramming
    # falls back to the unfused shell chain with identical numerics
    svc.xbuilder.unprogram("vector")
    svc.xbuilder.unprogram("systolic")
    fallback = svc.run(dfg.save(), [1, 2], weights=weights)["Result"]
    assert all(d == "cpu" for _, d in svc.engine.trace)
    np.testing.assert_allclose(before, fallback, rtol=1e-5, atol=1e-5)
