"""Elastic online resharding: grow/shrink/rebalance under live reads
with bit-identity probed at every chunk boundary, write gating during
copy windows, mid-migration source failure at R=2, and the
changed-owner-pages-only byte accounting."""
import threading

import numpy as np
import pytest

from repro.store import (BlockDevice, DeviceFailedError, GraphStore,
                         LocalShardEndpoint, ReplicatedGraphStore,
                         ShardedGraphStore, sample_batch)
from repro.store.placement import modular


def _graph(n=360, e=2600, feat=16, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _pair(n_shards, *, replication=None, n=360, e=2600, feat=16):
    edges, emb = _graph(n, e, feat)
    ref = GraphStore(BlockDevice(), h_threshold=16)
    ref.update_graph(edges, emb)
    if replication is None:
        store = ShardedGraphStore(n_shards=n_shards, h_threshold=16)
    else:
        store = ReplicatedGraphStore(n_shards=n_shards, h_threshold=16,
                                     replication=replication)
    store.update_graph(edges, emb)
    return ref, store, n


def _assert_reads_equal(ref, store, n, seed=7):
    rng = np.random.default_rng(seed)
    vids = rng.integers(0, n, 120)
    np.testing.assert_array_equal(ref.get_embeds(vids),
                                  store.get_embeds(vids))
    for a, b in zip(ref.get_neighbors_batch(vids[:40]),
                    store.get_neighbors_batch(vids[:40])):
        np.testing.assert_array_equal(a, b)
    ba = sample_batch(ref, vids[:32], [6, 6],
                      rng=np.random.default_rng(11), pad_to=32)
    bb = sample_batch(store, vids[:32], [6, 6],
                      rng=np.random.default_rng(11), pad_to=32)
    np.testing.assert_array_equal(ba.node_vids, bb.node_vids)
    np.testing.assert_array_equal(ba.embeddings, bb.embeddings)


def _chunk_prober(ref, n):
    """on_progress callback asserting bit-identity at EVERY chunk
    boundary: a batched embedding read + adjacency spot checks against
    the single-device reference, issued from inside the migration."""
    probe_vids = np.arange(0, n, 7)
    ref_emb = ref.get_embeds(probe_vids)
    state = {"probes": 0, "flips": 0, "store": None}

    def cb(ev):
        st = state["store"]
        if ev.get("event") in ("chunk", "emb_chunk"):
            np.testing.assert_array_equal(st.get_embeds(probe_vids),
                                          ref_emb)
            for v in (int(probe_vids[1]), int(probe_vids[-1])):
                np.testing.assert_array_equal(st.get_neighbors(v),
                                              ref.get_neighbors(v))
            state["probes"] += 1
        elif ev.get("event") == "flip":
            state["flips"] += 1
    return cb, state


# --------------------------------------------------------------- grow/shrink
def test_grow_bit_identical_at_every_chunk_boundary():
    ref, store, n = _pair(4)
    cb, state = _chunk_prober(ref, n)
    state["store"] = store
    new_ep = LocalShardEndpoint(dev=BlockDevice(), h_threshold=16,
                                feature_dim=16)
    report = store.reshard(add=[new_ep], chunk_pages=8, on_progress=cb)
    assert state["probes"] > 0 and state["flips"] > 0
    assert store.n_shards == 5
    assert report["classes_moved"] > 0
    assert store.placement_stats()["epoch"] >= report["epochs"] > 0
    _assert_reads_equal(ref, store, n)


def test_shrink_bit_identical_at_every_chunk_boundary():
    ref, store, n = _pair(4)
    cb, state = _chunk_prober(ref, n)
    state["store"] = store
    report = store.reshard(remove=[3], chunk_pages=8, on_progress=cb)
    assert state["probes"] > 0
    assert store.n_shards == 3
    assert report["classes_moved"] > 0
    _assert_reads_equal(ref, store, n)
    # the drained endpoint is detached; survivors answer everything
    ps = store.placement_stats()
    assert not ps["resharding"] and ps["migrating_classes"] == []


def test_grow_ships_only_changed_owner_pages():
    """Byte accounting: a 4 -> 5 grow moves ~1/5 of the data, so the
    shipped bytes must be a small fraction of the resident bytes —
    never a full reload."""
    _, store, _ = _pair(4, n=500, e=4000, feat=32)
    resident = sum(int(ep.local_store.dev.stats.written_bytes)
                   for ep in store.endpoints)
    new_ep = LocalShardEndpoint(dev=BlockDevice(), h_threshold=16,
                                feature_dim=32)
    report = store.reshard(add=[new_ep], chunk_pages=16)
    assert 0 < report["bytes_shipped"] < 0.5 * resident
    assert report["bytes_shipped"] == (report["adj_bytes"]
                                       + report["emb_bytes"])


# ------------------------------------------------------------- write gating
def test_writes_during_migration_apply_exactly_once():
    """Mutations issued concurrently with the copy windows are gated per
    class and land exactly once — the final array equals serial replay
    of the same op log on one device."""
    edges, emb = _graph()
    n = emb.shape[0]
    store = ShardedGraphStore(n_shards=4, h_threshold=16)
    store.update_graph(edges, emb)
    new_ep = LocalShardEndpoint(dev=BlockDevice(), h_threshold=16,
                                feature_dim=16)

    report = {}

    def run():
        report.update(store.reshard(add=[new_ep], chunk_pages=4,
                                    pace_s=0.002))
    t = threading.Thread(target=run)
    t.start()
    rng = np.random.default_rng(3)
    log = []
    while t.is_alive():
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        store.add_edge(u, v)
        log.append(("edge", u, v))
        w = int(rng.integers(0, n))
        row = rng.standard_normal(emb.shape[1]).astype(np.float32)
        store.update_embed(w, row)
        log.append(("emb", w, row))
    t.join()
    assert report["classes_moved"] > 0 and log

    ref = GraphStore(BlockDevice(), h_threshold=16)
    ref.update_graph(edges, emb)
    for op in log:
        if op[0] == "edge":
            ref.add_edge(op[1], op[2])
        else:
            ref.update_embed(op[1], op[2])
    _assert_reads_equal(ref, store, n)


# ------------------------------------------------- mid-migration source kill
def test_source_failure_mid_migration_fails_over():
    """R=2: killing a copy source mid-stream must not abort the reshard —
    the destination re-pulls from the surviving replica and the array
    ends bit-identical (degraded), then heals by rebuild."""
    ref, store, n = _pair(3, replication=2)
    killed = {}

    def cb(ev):
        if ev.get("event") == "chunk" and not killed:
            row = store._routing.pmap.owner[int(ev["cls"])]
            srcs = [int(s) for s in row
                    if int(s) != int(ev["dst"]) and not store._failed[s]]
            if srcs:
                killed["shard"] = srcs[0]
                store.fail_shard(srcs[0])

    new_ep = LocalShardEndpoint(dev=BlockDevice(), h_threshold=16,
                                feature_dim=16)
    report = store.reshard(add=[new_ep], chunk_pages=4, on_progress=cb)
    assert "shard" in killed, "no chunk event fired before completion"
    assert report["classes_moved"] > 0
    assert store.n_shards == 4
    _assert_reads_equal(ref, store, n)           # degraded reads
    out = store.rebuild_shard(killed["shard"])
    assert out.get("rebuilt") or not store._failed[killed["shard"]]
    _assert_reads_equal(ref, store, n)           # healed reads


# ------------------------------------------------------------ heat rebalance
def test_heat_rebalance_moves_hot_classes_and_preserves_reads():
    ref, store, n = _pair(4, replication=1)
    hot = np.array([v for v in range(n) if v % 4 in (1, 2)])
    rng = np.random.default_rng(5)
    for _ in range(12):                          # accumulate skewed heat
        store.get_embeds(rng.choice(hot, 64))
    assert store.placement_stats()["heat_total"] > 0
    report = store.reshard(rebalance=True, refine=4, chunk_pages=16)
    assert report["classes_moved"] > 0
    ps = store.placement_stats()
    assert ps["n_classes"] == 16 and not ps["modular"]
    _assert_reads_equal(ref, store, n)


# ------------------------------------------------------------------ API edges
def test_reshard_mode_validation():
    _, store, _ = _pair(2, n=80, e=300)
    with pytest.raises(ValueError):
        store.reshard()
    with pytest.raises(ValueError):
        store.reshard(remove=[1], rebalance=True)
    with pytest.raises(ValueError):
        store.reshard(placement=modular(3))      # wrong shard count


def test_reshard_rejected_while_shard_failed():
    _, store, _ = _pair(3, replication=2, n=80, e=300)
    store.fail_shard(1)
    with pytest.raises(DeviceFailedError):
        store.reshard(rebalance=True)
    store.rebuild_shard(1)
    report = store.reshard(rebalance=True, refine=2)
    assert "reshard_rejected" not in report


def test_shrink_below_replication_rejected():
    _, store, _ = _pair(3, replication=2, n=80, e=300)
    with pytest.raises(ValueError):
        store.reshard(remove=[1, 2])             # 1 survivor < R=2
