"""RoP transport: serialization round-trips (hypothesis), channel mechanics."""
import numpy as np

from _hyp import given, settings, st

from repro.rpc import serialize, deserialize, PCIeChannel, RPCServer, RPCClient


prims = st.one_of(st.none(), st.booleans(), st.integers(-2**31, 2**31 - 1),
                  st.floats(allow_nan=False, allow_infinity=False),
                  st.text(max_size=20))
nested = st.recursive(
    prims, lambda c: st.one_of(
        st.lists(c, max_size=4),
        st.dictionaries(st.text(min_size=1, max_size=8), c, max_size=4)),
    max_leaves=12)


@settings(max_examples=50, deadline=None)
@given(nested)
def test_roundtrip_json_like(obj):
    got = deserialize(serialize(obj))
    assert got == obj or (obj != obj)


def test_roundtrip_ndarrays():
    rng = np.random.default_rng(0)
    obj = {"a": rng.standard_normal((3, 4)).astype(np.float32),
           "b": [rng.integers(0, 10, 5), "x", 3],
           "c": {"d": rng.standard_normal(7)}}
    got = deserialize(serialize(obj))
    np.testing.assert_array_equal(got["a"], obj["a"])
    np.testing.assert_array_equal(got["b"][0], obj["b"][0])
    np.testing.assert_array_equal(got["c"]["d"], obj["c"]["d"])
    assert got["b"][1:] == ["x", 3]


def test_channel_counts_bytes_and_doorbell():
    ch = PCIeChannel(buf_size=1 << 16)
    pkt = serialize({"x": np.arange(100)})
    ch.push(pkt)
    out = ch.pull()
    assert out == pkt
    assert ch.stats.packets == 1
    assert ch.stats.bytes_moved == len(pkt)


def test_rpc_error_propagation():
    class Svc:
        def boom(self):
            raise ValueError("nope")

        def ok(self, x):
            return x + 1

    client = RPCClient(RPCServer(Svc()))
    assert client.call("ok", x=41) == 42
    try:
        client.call("boom")
        assert False
    except RuntimeError as e:
        assert "nope" in str(e)
