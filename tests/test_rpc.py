"""RoP transport: serialization round-trips (hypothesis), channel mechanics,
multi-queue submission/completion rings, rolling per-method server stats."""
import threading

import numpy as np
import pytest

from _hyp import given, settings, st

from repro.rpc import (serialize, deserialize, PCIeChannel, RPCServer,
                       RPCClient, MultiQueueRoP, AsyncRPCClient,
                       QueueFullError)
from repro.rpc.server import _RECENT_WINDOW


prims = st.one_of(st.none(), st.booleans(), st.integers(-2**31, 2**31 - 1),
                  st.floats(allow_nan=False, allow_infinity=False),
                  st.text(max_size=20))
nested = st.recursive(
    prims, lambda c: st.one_of(
        st.lists(c, max_size=4),
        st.dictionaries(st.text(min_size=1, max_size=8), c, max_size=4)),
    max_leaves=12)


@settings(max_examples=50, deadline=None)
@given(nested)
def test_roundtrip_json_like(obj):
    got = deserialize(serialize(obj))
    assert got == obj or (obj != obj)


def test_roundtrip_ndarrays():
    rng = np.random.default_rng(0)
    obj = {"a": rng.standard_normal((3, 4)).astype(np.float32),
           "b": [rng.integers(0, 10, 5), "x", 3],
           "c": {"d": rng.standard_normal(7)}}
    got = deserialize(serialize(obj))
    np.testing.assert_array_equal(got["a"], obj["a"])
    np.testing.assert_array_equal(got["b"][0], obj["b"][0])
    np.testing.assert_array_equal(got["c"]["d"], obj["c"]["d"])
    assert got["b"][1:] == ["x", 3]


_DTYPES = ["<i4", ">i4", "<i8", ">i8", "<f4", ">f4", "<f8", ">f8",
           "<u2", ">u2", "|b1", "|i1"]


@settings(max_examples=60, deadline=None)
@given(st.sampled_from(_DTYPES),
       st.lists(st.integers(0, 5), min_size=0, max_size=3),
       st.sampled_from(["contig", "sliced", "reversed", "transposed"]),
       st.integers(0, 2**31 - 1))
def test_roundtrip_hardened_ndarrays(dtype, shape, layout, seed):
    """Any ndarray — non-native byte order, non-contiguous views (slices,
    negative strides, transposes), zero-size, 0-d — must round-trip the
    RoP packet format with identical values, dtype, and shape."""
    rng = np.random.default_rng(seed)
    arr = (rng.integers(0, 100, size=shape)
           .astype(np.dtype(dtype))).reshape(shape)
    if layout == "sliced" and arr.ndim and arr.shape[0] > 1:
        arr = arr[::2]
    elif layout == "reversed" and arr.ndim:
        arr = arr[::-1]
    elif layout == "transposed" and arr.ndim >= 2:
        arr = arr.T
    got = deserialize(serialize({"x": arr}))["x"]
    assert got.dtype == arr.dtype
    assert got.shape == arr.shape
    np.testing.assert_array_equal(got, arr)


def test_roundtrip_numpy_scalars_and_empty():
    got = deserialize(serialize({"b": np.bool_(True), "i": np.int64(-7),
                                 "f": np.float32(0.5),
                                 "e": np.empty(0, dtype=np.int32),
                                 "z": np.array(3.0)}))
    assert got["b"] is True and got["i"] == -7
    assert abs(got["f"] - 0.5) < 1e-9
    assert got["e"].shape == (0,) and got["e"].dtype == np.int32
    assert got["z"].shape == () and got["z"] == 3.0


def test_channel_counts_bytes_and_doorbell():
    ch = PCIeChannel(buf_size=1 << 16)
    pkt = serialize({"x": np.arange(100)})
    ch.push(pkt)
    out = ch.pull()
    assert out == pkt
    assert ch.stats.packets == 1
    assert ch.stats.bytes_moved == len(pkt)


class _Svc:
    def boom(self):
        raise ValueError("nope")

    def ok(self, x):
        return x + 1

    def stats(self):
        return {"custom": 1}


def test_rpc_error_propagation():
    client = RPCClient(RPCServer(_Svc()))
    assert client.call("ok", x=41) == 42
    try:
        client.call("boom")
        assert False
    except RuntimeError as e:
        assert "nope" in str(e)


def test_rpc_error_carries_device_traceback():
    client = RPCClient(RPCServer(_Svc()))
    with pytest.raises(RuntimeError) as ei:
        client.call("boom")
    msg = str(ei.value)
    assert "device traceback" in msg and "Traceback" in msg
    assert "ValueError" in msg                  # the device-side frame info


def test_method_stats_bounded_rolling():
    server = RPCServer(_Svc())
    client = RPCClient(server)
    for i in range(_RECENT_WINDOW + 40):
        client.call("ok", x=i)
    with pytest.raises(RuntimeError):
        client.call("boom")
    ms = server.method_stats["ok"]
    assert ms.calls == _RECENT_WINDOW + 40      # totals keep counting
    assert len(ms.recent_s) == _RECENT_WINDOW   # window stays bounded
    assert server.method_stats["boom"].errors == 1
    assert not hasattr(server, "call_log")      # the unbounded log is gone
    snap = server.stats_snapshot()
    assert snap["ok"]["recent_n"] == _RECENT_WINDOW
    assert snap["ok"]["total_s"] >= 0.0


def test_stats_rpc_injects_rolling_method_stats():
    client = RPCClient(RPCServer(_Svc()))
    client.call("ok", x=1)
    out = client.call("stats")
    assert out["custom"] == 1
    assert out["rpc"]["ok"]["calls"] == 1       # injected by the dispatcher


def test_sync_and_async_clients_share_error_and_stats_contract():
    """Both host-side stubs route replies through check_reply and keep the
    same per-method MethodStats shape, so local and RoP shard endpoints
    report identically in ``stats``."""
    server = RPCServer(_Svc())
    sync = RPCClient(server)
    rop = MultiQueueRoP(n_queues=1, depth=8)
    stop = threading.Event()

    def device():
        while not stop.is_set():
            got = rop.pop_submission(timeout=0.02)
            if got is not None:
                qid, cmd_id, packet = got
                rop.post_completion(qid, cmd_id, server.handle(packet))

    th = threading.Thread(target=device, daemon=True)
    th.start()
    try:
        async_ = AsyncRPCClient(rop, 0)
        for cl in (sync, async_):
            assert cl.call("ok", x=1) == 2
            with pytest.raises(RuntimeError) as ei:
                cl.call("boom")
            # unified error contract: method label + raw error type carried
            assert "RPC boom failed" in str(ei.value)
            assert ei.value.remote_error.startswith("ValueError")
        snaps = [cl.stats_snapshot() for cl in (sync, async_)]
        assert set(snaps[0]) == set(snaps[1]) == {"ok", "boom"}
        for snap in snaps:
            assert snap["ok"]["calls"] == 1 and snap["ok"]["errors"] == 0
            assert snap["boom"]["errors"] == 1
            assert set(snap["ok"]) == set(
                server.stats_snapshot()["ok"])       # same snapshot shape
    finally:
        stop.set()
        th.join(timeout=5)


# --------------------------------------------------------------- multi-queue
def test_multiqueue_out_of_order_completion_and_tracking():
    rop = MultiQueueRoP(n_queues=2, depth=8)
    a = rop.submit(0, b"pkt-a", method="x")
    b = rop.submit(1, b"pkt-b", method="y")
    assert rop.depth_in_flight == 2
    # device drains round-robin across queues
    got = [rop.pop_submission(timeout=0) for _ in range(2)]
    assert {g[1] for g in got} == {a, b}
    assert rop.pop_submission(timeout=0) is None
    # completions may land out of submission order
    rop.post_completion(1, b, b"done-b")
    rop.post_completion(0, a, b"done-a")
    assert rop.wait_completion(0, a) == b"done-a"
    assert rop.wait_completion(1, b) == b"done-b"
    assert rop.depth_in_flight == 0
    st = rop.stats_snapshot()
    assert st["queues"][0]["submitted"] == 1
    assert st["queues"][1]["completed"] == 1


def test_multiqueue_backpressure():
    rop = MultiQueueRoP(n_queues=1, depth=2)
    rop.submit(0, b"1")
    rop.submit(0, b"2")
    with pytest.raises(QueueFullError):
        rop.submit(0, b"3")
    assert rop.pairs[0].stats.rejected == 1


def test_async_client_against_device_thread():
    """Many concurrent logical clients against one device poll loop."""
    rop = MultiQueueRoP(n_queues=3, depth=16)
    server = RPCServer(_Svc())
    stop = threading.Event()

    def device():
        while not stop.is_set():
            got = rop.pop_submission(timeout=0.02)
            if got is not None:
                qid, cmd_id, packet = got
                rop.post_completion(qid, cmd_id, server.handle(packet))

    th = threading.Thread(target=device, daemon=True)
    th.start()
    try:
        clients = [AsyncRPCClient(rop, q) for q in range(3)]
        cmds = [(c, c.submit("ok", x=i * 10 + j))
                for j in range(4) for i, c in enumerate(clients)]
        results = [c.result(cid, timeout=30) for c, cid in cmds]
        assert results == [i * 10 + j + 1
                           for j in range(4) for i in range(3)]
        with pytest.raises(RuntimeError, match="device traceback"):
            clients[0].call("boom", timeout=30)
    finally:
        stop.set()
        th.join(timeout=5)
