"""Shared test configuration.

Forces 8 host CPU devices (``--xla_force_host_platform_device_count=8``)
*before any jax import* so the SPMD engine tests (``tests/test_spmd.py``)
can build real multi-device meshes on accelerator-less CI hosts.  pytest
imports this conftest before collecting any test module, which is the only
reliable pre-jax hook; if some plugin or sitecustomize imported jax first,
the flag cannot take effect — the ``spmd_devices`` fixture then skips the
mesh tests instead of failing them.

The flag is additive: an operator-supplied XLA_FLAGS that already pins a
device count is left untouched.
"""
from __future__ import annotations

import os
import sys

SPMD_HOST_DEVICES = 8

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + f" --xla_force_host_platform_device_count="
            f"{SPMD_HOST_DEVICES}").strip()

import pytest  # noqa: E402


@pytest.fixture
def spmd_devices() -> int:
    """Number of jax devices, skipping when the 8-device force didn't stick
    (jax initialized before this conftest could set XLA_FLAGS)."""
    import jax
    n = len(jax.devices())
    if n < SPMD_HOST_DEVICES:
        pytest.skip(f"needs {SPMD_HOST_DEVICES} forced host devices, "
                    f"found {n} (jax initialized before conftest?)")
    return n
