"""GraphRunner: DFG topo-sort/serialization, registry priority dispatch,
XBuilder program/unprogram semantics (Table 3 behaviour)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.dfg import DFG, Engine
from repro.core.registry import KernelRegistry
from repro.core.xbuilder import XBuilder, Bitstream
from repro.core import gnn
from repro.kernels.ops import program_config


def test_markup_roundtrip_and_topo():
    g = DFG()
    a = g.create_in("A")
    b = g.create_in("B")
    (c,) = g.create_op("Add", [a, b])
    (d,) = g.create_op("Mul", [c, a])
    g.create_out("Out", d)
    g2 = DFG.load(g.save())
    order = [n.op for n in g2.topo_nodes()]
    assert order == ["Add", "Mul"]

    reg = KernelRegistry()
    reg.register_device("cpu", 50)
    reg.register_op("Add", "cpu", lambda x, y: x + y)
    reg.register_op("Mul", "cpu", lambda x, y: x * y)
    out = Engine(reg).run(g2, {"A": 3.0, "B": 4.0})
    assert out["Out"] == 21.0


def test_cycle_detection():
    g = DFG()
    a = g.create_in("A")
    (b,) = g.create_op("Add", [a, "2_0"])       # forward ref -> cycle
    (c,) = g.create_op("Mul", [b, b])
    g._nodes[1].inputs = [str(b), "1_0"]        # self-loop
    with pytest.raises(ValueError):
        g.topo_nodes()


def test_priority_dispatch_and_reconfig():
    reg = KernelRegistry()
    xb = XBuilder(reg)                          # installs Shell (cpu, 50)
    calls = []

    def mk(dev):
        def f(a, b):
            calls.append(dev)
            return jnp.dot(a, b)
        return f

    xb.program(Bitstream("vector", 150, {"GEMM": mk("vector")}))
    xb.program(Bitstream("systolic", 300, {"GEMM": mk("systolic")}))
    dev, fn = reg.resolve("GEMM")
    assert dev == "systolic"                    # highest priority wins
    a = jnp.ones((4, 4))
    reg.dispatch("GEMM", a, a)
    assert calls == ["systolic"]

    xb.unprogram("systolic")                    # DFX decoupler
    dev, _ = reg.resolve("GEMM")
    assert dev == "vector"
    xb.unprogram("vector")
    dev, _ = reg.resolve("GEMM")
    assert dev == "cpu"                         # Shell always present
    with pytest.raises(ValueError):
        xb.unprogram("cpu")


def test_named_configs_match_shell():
    """Octa/Lsap/Hetero all compute the same GNN result (Fig. 16 setup)."""
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    nbr = jnp.asarray(rng.integers(0, 64, (16, 5)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (16, 5)), jnp.float32)

    results = {}
    for name in ("octa", "lsap", "hetero"):
        reg = KernelRegistry()
        xb = XBuilder(reg)
        program_config(xb, name)
        results[name] = np.asarray(reg.dispatch("SpMM_Mean", h, nbr, mask))
    np.testing.assert_allclose(results["octa"], results["lsap"],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(results["octa"], results["hetero"],
                               rtol=1e-5, atol=1e-5)


def test_gnn_dfg_equals_direct():
    from repro.core.service import HolisticGNNService, make_service_dfg
    import repro.store.sampler as S
    rng = np.random.default_rng(3)
    edges = np.stack([rng.integers(0, 80, 400), rng.integers(0, 80, 400)],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((80, 24)).astype(np.float32)
    svc = HolisticGNNService(h_threshold=8, pad_to=16)
    svc.update_graph(edges, emb)
    for model in ("gcn", "gin", "ngcf"):
        params = gnn.init_params(model, [24, 12, 8], seed=2)
        dfg = make_service_dfg(model, 2, [4, 4])
        weights = gnn.dfg_feeds(model, params, None, [])
        weights.pop("H")
        out = svc.run(dfg.save(), [1, 2], weights=weights)["Result"]
        b = S.sample_batch(svc.store, [1, 2], [4, 4],
                           rng=np.random.default_rng(0), pad_to=16)
        blocks = [(jnp.asarray(x.nbr), jnp.asarray(x.mask)) for x in b.layers]
        ref = gnn.FORWARD[model](params, jnp.asarray(b.embeddings), blocks)
        np.testing.assert_allclose(out[:2], np.asarray(ref)[:2],
                                   rtol=2e-5, atol=2e-5)
