"""Per-Pallas-kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU; same pallas_calls compile natively on TPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hyp import given, settings, st

from repro.kernels import (ref, gemm, spmm, sddmm, rmsnorm, flash_attention,
                           decode_attention)

RNG = np.random.default_rng(0)


def _r(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 130, 70), (128, 128, 128),
                                   (257, 64, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(m, k, n, dtype):
    a, b = _r(m, k, dtype=dtype), _r(k, n, dtype=dtype)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(gemm(a, b), np.float32),
        np.asarray(ref.gemm_ref(a, b), np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,f,d,kk", [(50, 32, 10, 4), (300, 96, 64, 7),
                                      (128, 256, 128, 16)])
@pytest.mark.parametrize("mode", ["mean", "sum"])
def test_spmm_sweep(n, f, d, kk, mode):
    h = _r(n, f)
    nbr = jnp.asarray(RNG.integers(0, n, (d, kk)), jnp.int32)
    mask = jnp.asarray(RNG.integers(0, 2, (d, kk)), jnp.float32)
    np.testing.assert_allclose(spmm(h, nbr, mask, mode=mode),
                               ref.spmm_ref(h, nbr, mask, mode=mode),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,f,d,kk", [(60, 32, 20, 3), (130, 64, 40, 8)])
def test_sddmm_sweep(n, f, d, kk):
    h = _r(n, f)
    nbr = jnp.asarray(RNG.integers(0, n, (d, kk)), jnp.int32)
    mask = jnp.asarray(RNG.integers(0, 2, (d, kk)), jnp.float32)
    np.testing.assert_allclose(sddmm(h, nbr, mask),
                               ref.sddmm_ref(h, nbr, mask),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,f", [(3, 64), (17, 256), (64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(b, f, dtype):
    x, w = _r(b, f, dtype=dtype), _r(f)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, w.astype(dtype)), np.float32),
        np.asarray(ref.rmsnorm_ref(x, w.astype(dtype)), np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hq,hkv,t,d", [(1, 2, 2, 64, 32), (2, 4, 2, 100, 64),
                                          (1, 8, 1, 33, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, hq, hkv, t, d, causal):
    q, k, v = _r(b, hq, t, d), _r(b, hkv, t, d), _r(b, hkv, t, d)
    out = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v, causal=causal),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,hq,hkv,d,ps,pp", [(2, 4, 2, 32, 8, 4),
                                              (3, 8, 2, 64, 16, 6),
                                              (1, 4, 4, 128, 32, 3)])
def test_decode_attention_sweep(b, hq, hkv, d, ps, pp):
    p_total = b * pp + 2
    q = _r(b, hq, d)
    kp, vp = _r(p_total, ps, hkv, d), _r(p_total, ps, hkv, d)
    pt = jnp.asarray(RNG.permutation(p_total)[: b * pp].reshape(b, pp),
                     jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, ps * pp, b), jnp.int32)
    out = decode_attention(q, kp, vp, pt, lengths)
    want = ref.decode_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 48),
       st.integers(1, 24), st.integers(1, 8))
def test_spmm_property(db, kb, n, d, kk):
    """Property: SpMM(sum) == dense one-hot matmul for any shape."""
    h = _r(n, 8)
    nbr = jnp.asarray(RNG.integers(0, n, (d, kk)), jnp.int32)
    mask = jnp.asarray(RNG.integers(0, 2, (d, kk)), jnp.float32)
    got = spmm(h, nbr, mask, mode="sum", bd=8 * db, bf=128)
    dense = (jax.nn.one_hot(nbr, n) * mask[..., None]).sum(1) @ h
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)
