"""Host-mesh construction: the pure (data, model) shape-selection policy
(no devices needed) and real mesh building over the forced host pool."""
import pytest

from repro.launch.mesh import host_mesh_shape, make_host_mesh


def test_shape_policy_prefers_widest_dividing_model_axis():
    assert host_mesh_shape(8) == (2, 4)
    assert host_mesh_shape(4) == (1, 4)
    assert host_mesh_shape(2) == (1, 2)
    assert host_mesh_shape(12) == (3, 4)


def test_shape_policy_odd_counts_never_drop_devices():
    # counts not divisible by 4 (or 2) fall through the 4/2/1 ladder
    assert host_mesh_shape(6) == (3, 2)
    assert host_mesh_shape(7) == (7, 1)
    assert host_mesh_shape(3) == (3, 1)
    assert host_mesh_shape(1) == (1, 1)
    for n in range(1, 33):
        d, m = host_mesh_shape(n)
        assert d * m == n                     # every device is in the mesh


def test_shape_policy_model_override():
    assert host_mesh_shape(8, model=2) == (4, 2)
    assert host_mesh_shape(8, model=8) == (1, 8)
    assert host_mesh_shape(6, model=3) == (2, 3)
    with pytest.raises(ValueError):
        host_mesh_shape(8, model=3)           # must divide
    with pytest.raises(ValueError):
        host_mesh_shape(8, model=0)
    with pytest.raises(ValueError):
        host_mesh_shape(0)


def test_make_host_mesh_builds_submeshes(spmd_devices):
    mesh = make_host_mesh()                   # all devices, policy shape
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == spmd_devices
    sub = make_host_mesh(2)                   # submesh of the pool
    assert sub.devices.shape == (1, 2)
    pinned = make_host_mesh(8, model=2)
    assert pinned.devices.shape == (4, 2)
    explicit = make_host_mesh(4, shape=(2, 2))
    assert explicit.devices.shape == (2, 2)
    with pytest.raises(ValueError):
        make_host_mesh(4, shape=(1, 2))       # shape must cover n
    with pytest.raises(ValueError):
        make_host_mesh(10 ** 6)               # more than exist
