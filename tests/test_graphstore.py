"""GraphStore behaviour: bulk/unit ops vs an adjacency-dict oracle,
H/L-type mapping invariants, and a hypothesis property test driving random
mutable-op sequences."""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.store.blockdev import BlockDevice, SLOTS_PER_PAGE
from repro.store.graphstore import GraphStore, preprocess_edges


def _mk_graph(n=200, e=1200, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.zipf(1.4, e) % n
    dst = rng.integers(0, n, e)
    return np.stack([dst, src], axis=1).astype(np.int64)


def _oracle(edges, n):
    adj = {v: {v} for v in range(n)}           # self loops
    for d, s in edges:
        adj[int(d)].add(int(s))
        adj[int(s)].add(int(d))
    return adj


def test_preprocess_edges_csr():
    edges = _mk_graph()
    indptr, indices = preprocess_edges(edges)
    n = int(edges.max()) + 1
    adj = _oracle(edges, n)
    for v in range(n):
        got = set(int(x) for x in indices[indptr[v]:indptr[v + 1]])
        assert got == adj[v], v
    # sorted within rows
    for v in range(n):
        row = indices[indptr[v]:indptr[v + 1]]
        assert np.all(np.diff(row) > 0)


def test_bulk_load_matches_oracle():
    edges = _mk_graph()
    n = int(edges.max()) + 1
    gs = GraphStore(BlockDevice(), h_threshold=8)
    gs.update_graph(edges)
    adj = _oracle(edges, n)
    for v in range(n):
        assert set(int(x) for x in gs.get_neighbors(v)) == adj[v], v
    # power-law: some vertices must be H-type, most L-type
    kinds = set(gs.gmap.values())
    assert kinds == {"H", "L"}


def test_bulk_overlap_timeline():
    edges = _mk_graph(500, 4000)
    emb = np.random.default_rng(0).standard_normal(
        (int(edges.max()) + 1, 64)).astype(np.float32)
    gs = GraphStore(BlockDevice(1 << 12), h_threshold=16)
    tl = gs.update_graph(edges, emb)
    # user-visible latency excludes (overlapped) graph preprocessing
    assert tl.user_visible <= tl.total
    assert tl.write_feature[1] > 0


def test_embeddings_roundtrip_and_update():
    edges = _mk_graph(100, 400)
    n = int(edges.max()) + 1
    emb = np.random.default_rng(1).standard_normal((n, 48)).astype(np.float32)
    gs = GraphStore(BlockDevice(), h_threshold=8)
    gs.update_graph(edges, emb)
    for v in (0, 1, n // 2, n - 1):
        np.testing.assert_array_equal(gs.get_embed(v), emb[v])
    new_row = np.full(48, 3.25, np.float32)
    gs.update_embed(5, new_row)
    np.testing.assert_array_equal(gs.get_embed(5), new_row)
    np.testing.assert_array_equal(gs.get_embed(4), emb[4])  # page RMW safe
    np.testing.assert_array_equal(gs.get_embed(6), emb[6])


def test_unit_ops_and_promotion():
    gs = GraphStore(BlockDevice(), h_threshold=4)
    edges = np.array([[0, 1], [1, 2], [2, 3]], np.int64)
    gs.update_graph(edges)
    # vertex addition (ascending VIDs -> appended to last L page)
    gs.add_vertex(10)
    assert set(gs.get_neighbors(10)) == {10}
    # adding many edges promotes 0 from L to H
    for u in range(4, 10):
        gs.add_edge(0, u)
    assert gs.gmap[0] == "H"
    assert set(gs.get_neighbors(0)) == {0, 1} | set(range(4, 10))
    # delete edge both directions
    gs.delete_edge(0, 4)
    assert 4 not in gs.get_neighbors(0)
    assert 0 not in gs.get_neighbors(4)
    # delete vertex scrubs it from neighbors
    gs.delete_vertex(0)
    for u in range(1, 10):
        assert 0 not in gs.get_neighbors(u)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["add_e", "del_e", "add_v"]),
                          st.integers(0, 24), st.integers(0, 24)),
                min_size=1, max_size=60))
def test_property_random_mutations(ops):
    gs = GraphStore(BlockDevice(), h_threshold=4)
    base = np.array([[0, 1], [1, 2]], np.int64)
    gs.update_graph(base)
    adj = _oracle(base, 3)
    next_vid = 25
    for op, a, b in ops:
        if op == "add_v":
            gs.add_vertex(next_vid)
            adj[next_vid] = {next_vid}
            next_vid += 1
        elif op == "add_e":
            a2, b2 = sorted((a, b))
            gs.add_edge(b2, a2)
            for v in (a2, b2):
                adj.setdefault(v, {v}).add(v)
            adj[a2].add(b2)
            adj[b2].add(a2)
        else:
            if a in adj and b in adj[a] and a != b:
                gs.delete_edge(a, b)
                adj[a].discard(b)
                adj[b].discard(a)
    store_adj = gs.to_adjacency()
    for v, want in adj.items():
        assert store_adj.get(v, set()) == want, (v, store_adj.get(v), want)


def test_write_amplification_unit_ops():
    """Mutable updates touch O(1) pages (the paper's WA argument)."""
    gs = GraphStore(BlockDevice(), h_threshold=64)
    gs.update_graph(_mk_graph(300, 2000))
    w0 = gs.dev.stats.written_pages
    gs.add_edge(5, 7)
    assert gs.dev.stats.written_pages - w0 <= 4
