"""Autonomic array runtime: supervisor state machine (suspect/decay,
burst-drain policy, refused drains, auto-rebuild), chaos kills with NO
operator involvement (auto-detect -> auto-drain -> auto-rebuild ->
bit-identical), failover races against in-flight fetches and streaming
rebuilds, and the end-to-end flow-control path (queue-full reap+retry,
in-flight window shedding, typed backpressure reasons, reasoned
admission errors)."""
import threading
import time

import numpy as np
import pytest

from repro.core import gnn
from repro.core.service import HolisticGNNService, make_service_dfg
from repro.rpc.queues import BackpressureError, QueueFullError
from repro.serve import (AdmissionError, BatchScheduler, HealthPolicy,
                         ServingRuntime, ShardSupervisor)
from repro.store import (BlockDevice, DeviceFailedError, GraphStore,
                         ReplicatedGraphStore, ShardedGraphStore,
                         make_local_endpoints, make_rop_endpoints,
                         sample_batch)
from repro.store.sharded import FlowControl


def _graph(n=240, e=1600, feat=12, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _pair(n_shards=3, replication=2, *, remote=False, flow=None,
          h_threshold=16, n=240):
    edges, emb = _graph(n)
    single = GraphStore(BlockDevice(), h_threshold=h_threshold)
    single.update_graph(edges, emb)
    eps = (make_rop_endpoints(n_shards, h_threshold=h_threshold) if remote
           else None)
    rep = ReplicatedGraphStore(n_shards=None if eps else n_shards,
                               endpoints=eps, replication=replication,
                               h_threshold=h_threshold, flow=flow)
    rep.update_graph(edges, emb)
    return single, rep, n


def _kill_device(rep, s):
    """Kill the shard's DEVICE directly — the chaos path: no fail_shard
    operator RPC, the array must notice on its own."""
    ep = rep.endpoints[s]
    if hasattr(ep, "local_store"):
        ep.local_store.dev.fail()
    else:
        ep.host.service.store.dev.fail()


def _ref_samples(single, n, k=6):
    out = []
    for i in range(k):
        rng = np.random.default_rng(100 + i)
        t = rng.integers(0, n, 8)
        b = sample_batch(single, t, [4, 4], rng=np.random.default_rng(i))
        out.append((t, i, b))
    return out


def _assert_batch_equal(a, b):
    np.testing.assert_array_equal(a.node_vids, b.node_vids)
    for la, lb in zip(a.layers, b.layers):
        np.testing.assert_array_equal(la.nbr, lb.nbr)
        np.testing.assert_array_equal(la.mask, lb.mask)
    np.testing.assert_array_equal(a.embeddings, b.embeddings)


def _wait_healthy(sup, rep, deadline_s=20.0):
    t_end = time.monotonic() + deadline_s
    while time.monotonic() < t_end:
        snap = sup.snapshot()
        if (snap["incidents"] and not any(rep.failed_shards)
                and all(st == "healthy" for st in snap["states"])):
            return snap
        time.sleep(0.01)
    raise AssertionError(f"array did not heal: {sup.snapshot()}")


# ------------------------------------------------------- policy state machine
def test_one_error_is_suspect_not_drain():
    _, rep, _ = _pair()
    sup = ShardSupervisor(rep, HealthPolicy(auto_rebuild=False))
    rep.health = sup                       # attach without the monitor
    sup.record_error(1, DeviceFailedError("blip"))
    assert sup.state_of(1) == "suspect"
    assert not rep.failed_shards[1]        # a single blip never drains
    assert sup.suspect_shards() == [1]
    rep.close()


def test_error_burst_drains_within_policy_window():
    _, rep, _ = _pair()
    sup = ShardSupervisor(rep, HealthPolicy(error_threshold=3, window_s=1.0,
                                            auto_rebuild=False))
    rep.health = sup
    for _ in range(2):
        sup.record_error(1, DeviceFailedError("x"))
        assert sup.state_of(1) == "suspect"
    sup.record_error(1, DeviceFailedError("x"))    # 3rd inside the window
    assert sup.state_of(1) == "failed"
    assert rep.failed_shards[1]
    snap = sup.snapshot()
    assert snap["incidents"] == 1
    inc = snap["last_incident"]
    assert inc["cause"] == "error_burst" and inc["drained"] is True
    assert inc["refused"] is None and inc["degraded_classes"]
    # further errors against a failed shard are no-ops, not new incidents
    sup.record_error(1, DeviceFailedError("x"))
    assert sup.snapshot()["incidents"] == 1
    rep.close()


def test_suspect_decays_back_to_healthy():
    _, rep, _ = _pair()
    sup = ShardSupervisor(rep, HealthPolicy(suspect_decay_s=0.05,
                                            probe_interval_s=0.01,
                                            auto_rebuild=False)).start()
    try:
        sup.record_error(0, DeviceFailedError("blip"))
        assert sup.state_of(0) == "suspect"
        t_end = time.monotonic() + 5.0
        while sup.state_of(0) != "healthy" and time.monotonic() < t_end:
            time.sleep(0.01)
        assert sup.state_of(0) == "healthy"
        assert sup.suspect_shards() == []
    finally:
        sup.stop()
        rep.close()


def test_refused_drain_is_terminal_not_a_loop():
    """Draining the LAST live replica of a class is data loss: the
    supervisor records the refusal and does NOT schedule a rebuild."""
    _, rep, _ = _pair()
    rep.fail_shard(0)                      # operator predecessor
    sup = ShardSupervisor(rep, HealthPolicy(error_threshold=2,
                                            auto_rebuild=False))
    rep.health = sup
    assert sup.state_of(0) == "failed"     # adopted at attach
    for _ in range(2):
        sup.record_error(1, DeviceFailedError("x"))
    snap = sup.snapshot()
    assert snap["states"][1] == "failed"
    assert snap["drained"][1] is False     # refused: not actually drained
    assert snap["last_incident"]["refused"] is not None
    assert not rep.failed_shards[1]        # store still serves from it
    rep.close()


def test_suspect_shard_steered_away_from():
    """Replica selection must avoid a supervisor-suspect shard while every
    class still has another live candidate."""
    _, rep, n = _pair(3, 2)
    sup = ShardSupervisor(rep, HealthPolicy(auto_rebuild=False))
    rep.health = sup
    sup.record_error(1, DeviceFailedError("blip"))
    reads0 = rep.shards[1].dev.stats.read_pages
    rng = np.random.default_rng(5)
    for _ in range(6):
        rep.get_embeds(rng.integers(0, n, 40))
    assert rep.shards[1].dev.stats.read_pages == reads0
    rep.close()


# ------------------------------------------------------------ chaos, no hands
@pytest.mark.parametrize("remote", [False, True])
def test_chaos_kill_auto_detect_drain_rebuild_bit_identical(remote):
    """Device dies with NO operator call: degraded reads stay bit-identical
    immediately, the supervisor detects + drains + rebuilds on its own,
    and post-rebuild reads are bit-identical at full redundancy."""
    single, rep, n = _pair(remote=remote)
    refs = _ref_samples(single, n)
    sup = ShardSupervisor(rep, HealthPolicy(probe_interval_s=0.005,
                                            rebuild_retry_s=0.05)).start()
    try:
        _kill_device(rep, 2)
        t, seed, ref = refs[0]             # in-flight-era degraded read
        _assert_batch_equal(ref, sample_batch(
            rep, t, [4, 4], rng=np.random.default_rng(seed)))
        snap = _wait_healthy(sup, rep)
        inc = snap["last_incident"]
        assert inc["shard"] == 2
        assert inc["cause"] in ("probe", "error_burst", "observed_drained")
        assert inc["drained"] is True and inc["refused"] is None
        assert inc["detect_s"] <= 5.0 and "restore_s" in inc
        for t, seed, ref in refs:          # full-redundancy reads
            _assert_batch_equal(ref, sample_batch(
                rep, t, [4, 4], rng=np.random.default_rng(seed)))
    finally:
        sup.stop()
        rep.close()


@pytest.mark.parametrize("remote", [False, True])
def test_kill_while_fetches_in_flight(remote):
    """Reader threads keep fetching while a device dies underneath them:
    every read stays bit-identical (failover) and the array heals."""
    single, rep, n = _pair(remote=remote)
    refs = _ref_samples(single, n, k=4)
    sup = ShardSupervisor(rep, HealthPolicy(probe_interval_s=0.005,
                                            rebuild_retry_s=0.05)).start()
    stop, errs = threading.Event(), []

    def reader(tid):
        while not stop.is_set():
            for t, seed, ref in refs:
                try:
                    _assert_batch_equal(ref, sample_batch(
                        rep, t, [4, 4], rng=np.random.default_rng(seed)))
                except Exception as e:  # noqa: BLE001 — collected
                    errs.append(f"reader{tid}: {type(e).__name__}: {e}")
                    return

    threads = [threading.Thread(target=reader, args=(i,)) for i in range(2)]
    for th in threads:
        th.start()
    try:
        time.sleep(0.05)                   # fetches in flight
        _kill_device(rep, 1)
        _wait_healthy(sup, rep)
    finally:
        stop.set()
        for th in threads:
            th.join(timeout=30.0)
        sup.stop()
    assert not errs, errs
    rep.close()


def test_kill_bystander_during_paced_rebuild_single_fault():
    """N=4 R=2: while shard 0's paced rebuild streams, a shard that is
    neither rebuild target nor donor dies.  The error-path detection
    (record_error -> suspect steering -> burst drain) keeps reads
    bit-identical throughout — ``fail_shard`` runs under the mutate lock
    only, so the drain lands WHILE the rebuild holds the maintenance
    gate — the rebuild completes, and the second fault rebuilds cleanly
    afterwards."""
    single, rep, n = _pair(4, 2)
    refs = _ref_samples(single, n, k=3)
    rep.fail_shard(0)
    sup = ShardSupervisor(rep, HealthPolicy(auto_rebuild=False))
    rep.health = sup                       # error path only, no monitor
    out = {}

    def run_rebuild():
        out["info"] = rep.rebuild_shard(0, pacing_s=0.03)

    th = threading.Thread(target=run_rebuild)
    th.start()
    time.sleep(0.02)                       # rebuild mid-stream
    _kill_device(rep, 2)                   # classes {1, 2}: survivors live
    for t, seed, ref in refs:              # reads flow during the stream
        _assert_batch_equal(ref, sample_batch(
            rep, t, [4, 4], rng=np.random.default_rng(seed)))
    th.join(timeout=60.0)
    assert out["info"]["pages_written"] > 0
    # one error marked the shard suspect and steering kept every later
    # read off it — exactly the blip policy: no burst, no drain yet
    assert sup.state_of(2) in ("suspect", "failed")
    if not rep.failed_shards[2]:
        rep.fail_shard(2)                  # drain (monitor would, via probe)
    rep.rebuild_shard(2)
    assert not any(rep.failed_shards)
    for t, seed, ref in refs:
        _assert_batch_equal(ref, sample_batch(
            rep, t, [4, 4], rng=np.random.default_rng(seed)))
    rep.close()


def test_kill_donor_during_rebuild_double_fault_raises_cleanly():
    """N=3 R=2: the rebuild's donor dies mid-stream — that class has lost
    both replicas.  The rebuild fails with an exception (no wedge, no
    silent partial state) and reads of the lost class raise
    ``DeviceFailedError`` instead of returning wrong data."""
    _, rep, n = _pair(3, 2)
    rep.fail_shard(0)
    out = {}

    def run_rebuild():
        try:
            out["info"] = rep.rebuild_shard(0, pacing_s=0.05)
        except Exception as e:  # noqa: BLE001 — the expected double fault
            out["err"] = e

    th = threading.Thread(target=run_rebuild)
    th.start()
    time.sleep(0.02)
    _kill_device(rep, 1)                   # donor for class 0 dies
    th.join(timeout=60.0)
    assert "err" in out, f"double-fault rebuild returned {out.get('info')}"
    assert rep.failed_shards[0]            # target still marked failed
    with pytest.raises(DeviceFailedError):
        rep.get_embeds(np.arange(60))      # lost class: clean error
    rep.close()


# --------------------------------------------------------------- idempotency
def test_fault_rpcs_idempotent_status_dicts():
    _, rep, _ = _pair()
    assert rep.rebuild_shard(1) == {"shard": 1, "already_live": True}
    info = rep.fail_shard(1)
    assert info["shard"] == 1 and info["degraded_classes"]
    assert rep.fail_shard(1) == {"shard": 1, "already_failed": True}
    out = {}

    def run_rebuild():
        out["info"] = rep.rebuild_shard(1, pacing_s=0.05)

    th = threading.Thread(target=run_rebuild)
    th.start()
    time.sleep(0.02)                       # stream in progress
    assert rep.rebuild_shard(1) == {"shard": 1, "rebuild_in_progress": True}
    th.join(timeout=60.0)
    assert out["info"]["pages_written"] > 0
    assert not any(rep.failed_shards)
    rep.close()


# -------------------------------------------------------------- flow control
class _CountingEp:
    """Wrapper asserting handle hygiene: every submitted call handle must
    be consumed (result or reap) — no completions left in the CQ."""

    def __init__(self, inner):
        self._inner = inner
        self.submitted = 0
        self.consumed = 0

    def call_submit(self, method, **kw):
        h = self._inner.call_submit(method, **kw)
        self.submitted += 1
        return h

    def call_result(self, h):
        self.consumed += 1
        return self._inner.call_result(h)

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _FlakyEp(_CountingEp):
    """Raises ``QueueFullError`` for the first ``fail_submits`` call
    submits, then behaves."""

    def __init__(self, inner, fail_submits):
        super().__init__(inner)
        self._fail_left = fail_submits

    def call_submit(self, method, **kw):
        if self._fail_left > 0:
            self._fail_left -= 1
            raise QueueFullError("synthetic SQ full", qid=0, depth=64)
        return super().call_submit(method, **kw)


def _flaky_store(fail_submits, retries=2):
    edges, emb = _graph()
    st = ShardedGraphStore(
        n_shards=3, h_threshold=16,
        flow=FlowControl(submit_retries=retries, backoff_base_s=1e-4,
                         backoff_max_s=1e-3))
    st.update_graph(edges, emb)
    st.endpoints = [_CountingEp(st.endpoints[0]),
                    _FlakyEp(st.endpoints[1], fail_submits),
                    _CountingEp(st.endpoints[2])]
    return st


def test_submit_round_queue_full_reaps_and_retries():
    """A QueueFullError part-way through a multi-shard round: handles
    already issued are reaped, the FULL set retries after backoff, and
    the round completes — with zero leaked completions."""
    st = _flaky_store(fail_submits=2)
    outs = st._submit_round([(s, "counters", {}) for s in range(3)])
    assert len(outs) == 3 and all("read_pages" in o for o in outs)
    assert st.backpressure_retries == 2 and st.backpressure_events == 0
    for ep in st.endpoints:
        assert ep.submitted == ep.consumed, \
            f"leaked call handles: {ep.submitted} != {ep.consumed}"
    # shard 0 was submitted on every attempt: 2 aborted + 1 good
    assert st.endpoints[0].submitted == 3
    st.close()


def test_submit_round_queue_full_exhausted_sheds_typed():
    st = _flaky_store(fail_submits=99, retries=2)
    with pytest.raises(BackpressureError) as ei:
        st._submit_round([(s, "counters", {}) for s in range(3)])
    r = ei.value.reason
    assert r["source"] == "queue_full" and r["shard"] == 1
    assert r["attempts"] == 3 and r["qid"] == 0
    assert st.backpressure_events == 1 and st.backpressure_retries == 2
    for ep in st.endpoints:
        assert ep.submitted == ep.consumed
    st.close()


def test_inflight_window_sheds_typed_backpressure():
    edges, emb = _graph()
    st = ShardedGraphStore(
        n_shards=2, h_threshold=16,
        flow=FlowControl(max_inflight_per_shard=1, window_timeout_s=0.02))
    st.update_graph(edges, emb)
    taken = st._acquire_windows([0])           # hold shard 0's only slot
    # semaphore OBJECTS come back (reshard may remap _windows mid-round)
    assert taken == [st._windows[0]]
    with pytest.raises(BackpressureError) as ei:
        st.get_embeds(np.arange(40))           # fans out onto shard 0
    r = ei.value.reason
    assert r["source"] == "inflight_window" and r["limit"] == 1
    assert st.backpressure_events == 1
    st._release_windows(taken)
    st.get_embeds(np.arange(40))               # recovers once released
    st.close()


def test_fetch_queue_full_reaps_and_recovers():
    """Same reap+retry contract on the fetch rings (fetch_submit)."""
    edges, emb = _graph()
    st = ShardedGraphStore(
        n_shards=2, h_threshold=16,
        flow=FlowControl(submit_retries=3, backoff_base_s=1e-4,
                         backoff_max_s=1e-3))
    st.update_graph(edges, emb)
    ref = st.get_embeds(np.arange(50))

    class _FlakyFetch:
        def __init__(self, inner, fail_submits):
            self._inner = inner
            self._fail_left = fail_submits

        def fetch_submit(self, **kw):
            if self._fail_left > 0:
                self._fail_left -= 1
                raise QueueFullError("synthetic SQ full", qid=1, depth=64)
            return self._inner.fetch_submit(**kw)

        def __getattr__(self, name):
            return getattr(self._inner, name)

    st.endpoints[1] = _FlakyFetch(st.endpoints[1], fail_submits=2)
    np.testing.assert_array_equal(ref, st.get_embeds(np.arange(50)))
    assert st.backpressure_retries == 2
    st.close()


# --------------------------------------------- reasoned rejections at the top
def test_admission_error_carries_reason_and_health():
    svc = HolisticGNNService(h_threshold=16)
    edges, emb = _graph()
    svc.store.update_graph(edges, emb)
    sched = BatchScheduler(svc, max_pending=1)
    sched.health_provider = lambda: {"failed_shards": [2],
                                     "states": ["healthy"] * 3}
    dfg = make_service_dfg("gcn", 2, [4, 4]).save()
    params = gnn.init_params("gcn", [12, 8, 4], seed=1)
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    sched.submit(dfg=dfg, batch=[1], weights=weights, on_done=lambda r: None)
    with pytest.raises(AdmissionError) as ei:
        sched.submit(dfg=dfg, batch=[2], weights=weights,
                     on_done=lambda r: None)
    r = ei.value.reason
    assert r["source"] == "admission"
    assert r["queue_depth"] == 1 and r["max_pending"] == 1
    assert r["shard_health"]["failed_shards"] == [2]
    assert sched.qos.rejected == 1
    assert sched.qos.snapshot()["last_reject_reason"]["source"] == "admission"


def test_scheduler_turns_backpressure_into_typed_completion():
    svc = HolisticGNNService(h_threshold=16)
    edges, emb = _graph()
    svc.store.update_graph(edges, emb)
    reason = {"source": "inflight_window", "shard": 1, "limit": 2}

    def run_batch(*a, **kw):
        raise BackpressureError("window full", reason=reason)

    svc.run_batch = run_batch
    sched = BatchScheduler(svc)
    dfg = make_service_dfg("gcn", 2, [4, 4]).save()
    params = gnn.init_params("gcn", [12, 8, 4], seed=1)
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    got = []
    sched.submit(dfg=dfg, batch=[1], weights=weights, on_done=got.append)
    sched.drain()
    assert len(got) == 1
    assert got[0]["ok"] is False and got[0]["backpressure"] is True
    assert got[0]["reason"] == reason
    assert sched.qos.backpressured == 1
    assert sched.qos.snapshot()["last_reject_reason"] == reason


def test_stats_rpc_carries_health_and_flow_blocks():
    edges, emb = _graph()
    svc = HolisticGNNService(n_shards=3, replication=2, h_threshold=16,
                             flow=FlowControl(max_inflight_per_shard=4))
    svc.store.update_graph(edges, emb)
    sup = ShardSupervisor(svc.store, HealthPolicy(auto_rebuild=False))
    svc.store.health = sup
    with ServingRuntime(svc) as rt:
        st = rt.client().call("stats", timeout=30)
    assert st["health"]["states"] == ["healthy"] * 3
    assert st["flow"]["max_inflight_per_shard"] == 4
    assert st["flow"]["backpressure_events"] == 0
    assert "backpressure" in st["qos"] and "health" in st["qos"]
    svc.close()
