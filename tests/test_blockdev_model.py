"""BlockDevice fault flag + busy-until command serialization (the honest
one-command-pipeline model) + embedding-space growth relocation."""
import threading
import time

import numpy as np
import pytest

from repro.store import BlockDevice, DeviceFailedError, GraphStore


# ------------------------------------------------------------- fault flag
def test_failed_device_rejects_every_command():
    dev = BlockDevice(64)
    page = np.zeros(1024, dtype=np.int32)
    dev.write_page(0, page)
    dev.fail()
    for fn in (lambda: dev.read_page(0),
               lambda: dev.read_pages([0]),
               lambda: dev.read_span(0, 1),
               lambda: dev.write_page(0, page),
               lambda: dev.write_span(0, page),
               lambda: dev.alloc_front(),
               lambda: dev.alloc_back(1),
               lambda: dev.free_page(0)):
        with pytest.raises(DeviceFailedError):
            fn()
    # data is unreachable, not erased — attribute access still works
    assert dev.stats.written_pages == 1


# ------------------------------------------------------ busy-until queueing
def test_concurrent_commands_on_one_device_serialize():
    """Two clients issuing a 15 ms command at the same instant must take
    ~30 ms wall — the device has ONE command pipeline.  (The old model
    slept in each calling thread independently, so overlapping commands
    finished in ~15 ms total: silent infinite command concurrency.)"""
    dev = BlockDevice(64, simulate_latency=True, command_latency_us=15000)

    t0 = time.perf_counter()
    dev.read_page(0)
    single = time.perf_counter() - t0

    start = threading.Barrier(2)

    def client():
        start.wait()
        dev.read_page(0)

    ths = [threading.Thread(target=client) for _ in range(2)]
    t0 = time.perf_counter()
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    concurrent = time.perf_counter() - t0
    assert concurrent >= 1.7 * single, (single, concurrent)


def test_serial_commands_unaffected_by_busy_model():
    dev = BlockDevice(64, simulate_latency=True, command_latency_us=2000)
    t0 = time.perf_counter()
    dev.read_page(0)
    dev.read_page(1)
    wall = time.perf_counter() - t0
    assert 0.004 <= wall < 0.1


def test_defer_latency_accumulates_without_sleeping():
    dev = BlockDevice(64, simulate_latency=True, command_latency_us=50000)
    t0 = time.perf_counter()
    with dev.defer_latency() as acct:
        dev.read_page(0)
        dev.read_page(1)
    assert time.perf_counter() - t0 < 0.040        # no inline sleep paid
    assert acct.us == pytest.approx(100000)
    assert dev._busy_until <= time.perf_counter()  # pipeline not reserved


# ----------------------------------------------------- growth relocation
def test_grow_relocation_keeps_embedding_reads_valid():
    """Neighbor-space growth AFTER bulk ingest relocates the embedding
    span; the store's base pointer must follow or embedding reads return
    the zeroed old span."""
    store = GraphStore(BlockDevice(num_pages=64), h_threshold=8)
    rng = np.random.default_rng(0)
    n, feat = 40, 64
    edges = np.stack([rng.integers(0, n, 200), rng.integers(0, n, 200)],
                     axis=1)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    store.update_graph(edges, emb)
    np.testing.assert_array_equal(store.get_embeds(np.arange(n)), emb)
    pages0 = store.dev.num_pages
    v = n
    while store.dev.num_pages == pages0:           # force a front-space grow
        store.add_vertex(v)
        store.add_edge(v, int(rng.integers(0, n)))
        v += 1
    np.testing.assert_array_equal(store.get_embeds(np.arange(n)), emb)
    np.testing.assert_array_equal(store.get_embed(3), emb[3])
