"""Device-DRAM page cache: hit/miss accounting, LRU eviction, and — the
part that matters for mutable-graph serving — write-hook invalidation
across every mutation path (unit updates, embedding RMWs, page splits,
device growth/relocation)."""
import numpy as np

from repro.store.blockdev import BlockDevice
from repro.store.embcache import EmbeddingPageCache
from repro.store.graphstore import GraphStore


def _twin_stores(seed=0, n=300, e=2500, feat=24, h_threshold=8,
                 cache_pages=4096, num_pages=1 << 14):
    """Two identical stores; only the first gets a page cache."""
    rng = np.random.default_rng(seed)
    src = rng.zipf(1.4, e) % n
    dst = rng.integers(0, n, e)
    edges = np.stack([dst, src], axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    stores = []
    for _ in range(2):
        gs = GraphStore(BlockDevice(num_pages), h_threshold=h_threshold)
        gs.update_graph(edges, emb)
        stores.append(gs)
    cached, plain = stores
    cached.attach_cache(EmbeddingPageCache(cache_pages))
    return cached, plain, n


def test_cached_reads_match_and_hit_counters_advance():
    cached, plain, n = _twin_stores()
    rng = np.random.default_rng(1)
    vids = rng.integers(0, n, 64)
    np.testing.assert_array_equal(cached.get_embeds(vids),
                                  plain.get_embeds(vids))
    st = cached.cache.stats
    assert st.misses > 0 and st.hits == 0          # cold pass: all misses
    miss0 = st.misses
    np.testing.assert_array_equal(cached.get_embeds(vids),
                                  plain.get_embeds(vids))
    assert st.misses == miss0 and st.hits > 0      # warm pass: all hits
    assert st.bytes_from_cache > 0
    assert cached.stats.cache is st                # surfaced via store stats


def test_graph_pages_cached_and_batch_reads_match():
    cached, plain, n = _twin_stores()
    vids = list(range(n))
    for _ in range(2):                             # cold then warm
        got = cached.get_neighbors_batch(vids)
        want = plain.get_neighbors_batch(vids)
        for v, (g, w) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(g, w, err_msg=str(v))
    assert cached.cache.stats.hits > 0


def test_update_embed_invalidates():
    cached, plain, n = _twin_stores()
    vids = np.arange(32)
    cached.get_embeds(vids)                        # warm the cache
    new_row = np.full(cached.feature_dim, 7.5, np.float32)
    for gs in (cached, plain):
        gs.update_embed(5, new_row)
    inv0 = cached.cache.stats.invalidations
    assert inv0 > 0                                # RMW dropped its pages
    np.testing.assert_array_equal(cached.get_embeds(vids),
                                  plain.get_embeds(vids))
    np.testing.assert_array_equal(cached.get_embed(5), new_row)


def test_mutation_sequence_stays_coherent():
    """add_edge / delete_edge / delete_vertex / add_vertex interleaved with
    cached reads: the cached store tracks the plain one exactly."""
    cached, plain, n = _twin_stores(h_threshold=4)
    rng = np.random.default_rng(2)
    vids = list(range(n))
    for step in range(30):
        a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
        op = step % 4
        for gs in (cached, plain):
            if op == 0:
                gs.add_edge(a, b)
            elif op == 1:
                gs.delete_edge(a, b)
            elif op == 2:
                gs.delete_vertex(a)
            else:
                gs.add_vertex(n + step)
        got = cached.get_neighbors_batch(vids)
        want = plain.get_neighbors_batch(vids)
        for v, (g, w) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(g, w,
                                          err_msg=f"step {step} vid {v}")
        np.testing.assert_array_equal(cached.get_embeds(np.arange(16)),
                                      plain.get_embeds(np.arange(16)))


def test_lru_eviction_bounded_and_correct():
    cached, plain, n = _twin_stores(cache_pages=4)
    rng = np.random.default_rng(3)
    for _ in range(6):
        vids = rng.integers(0, n, 40)
        np.testing.assert_array_equal(cached.get_embeds(vids),
                                      plain.get_embeds(vids))
    assert len(cached.cache) <= 4
    assert cached.cache.stats.evictions > 0


def test_device_grow_relocation_invalidates_everything():
    """_grow relocates the embedding span to new LPNs; stale cached pages
    must not survive it."""
    cached, plain, n = _twin_stores(num_pages=16, n=40, e=200, feat=24)
    vids = np.arange(20)
    cached.get_embeds(vids)                        # populate the cache
    pages0 = cached.dev.num_pages
    k = 0
    while cached.dev.num_pages == pages0:          # force front-alloc growth
        for gs in (cached, plain):
            gs.add_vertex(1000 + k)
        k += 1
        assert k < 20000, "device never grew"
    np.testing.assert_array_equal(cached.get_embeds(vids),
                                  plain.get_embeds(vids))
