"""Optional-hypothesis shim (satellite of the fast-path PR).

``pytest.importorskip("hypothesis")`` at module scope would skip *every*
test in a file, including the plain oracle tests; this shim instead keeps
those running everywhere and skips only the property tests when hypothesis
is absent.  When hypothesis is installed the real decorators are re-exported
unchanged, so the property tests run exactly as before.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                           # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any strategy construction at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)
