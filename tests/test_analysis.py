"""Static-analysis toolchain: the fixture corpus (every seeded
violation flagged with the right rule id, zero false positives on the
known-good file), the runtime lock-order witness, and the repo-wide
acceptance gate (``tools/analyze.py`` must be clean on this tree)."""
import re
import sys
import threading
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from analysis import core, guarded, lockorder, rpcsurface, threads  # noqa: E402
from repro import concurrency as conc                               # noqa: E402

FIXTURES = REPO / "tools" / "analysis" / "fixtures"

_FX_SPECS = (
    conc.LockSpec("fx.a", 10, "lock",
                  (("fx_good", "_a"), ("fx_bad_lockorder", "_a"))),
    conc.LockSpec("fx.b", 20, "lock",
                  (("fx_good", "_b"), ("fx_bad_lockorder", "_b"))),
    conc.LockSpec("fx.r", 25, "rlock", (("fx_good", "_r"),)),
    conc.LockSpec("fx.leaf", 30, "lock",
                  (("fx_good", "_leaf"), ("fx_bad_lockorder", "_leaf")),
                  leaf=True),
    conc.LockSpec("fx.mu", 40, "lock",
                  (("fx_good", "_mu"), ("fx_bad_guarded", "_mu"))),
    conc.LockSpec("fx.x", 50, "lock", (("fx_bad_lockorder", "_x"),)),
    conc.LockSpec("fx.y", 60, "lock", (("fx_bad_lockorder", "_y"),)),
)


def _fx_cfg():
    return core.AnalysisConfig(
        specs=_FX_SPECS, sanctioned={}, same_name_ok={},
        never_together={frozenset({"fx.x", "fx.y"}): "fixture pair"},
        with_funcs={}, attr_types={})


def _fx_modules(*names):
    by_name = {m.modname: m for m in core.load_package(FIXTURES, REPO)}
    return [by_name[n] for n in names]


def _expected(mod):
    """(rule, line) pairs parsed from ``# expect: R1[, R2]`` markers."""
    out = set()
    for i, text in enumerate(mod.source.splitlines(), start=1):
        m = re.search(r"#\s*expect:\s*([A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)",
                      text)
        if m:
            for rule in m.group(1).split(","):
                out.add((rule.strip(), i))
    return out


def _run_fixture_passes(mods):
    cfg = _fx_cfg()
    out = []
    out += lockorder.run(cfg, mods)
    out += guarded.run(cfg, mods)
    out += threads.run(cfg, mods)
    out += rpcsurface.run(cfg, mods)
    return out


# ------------------------------------------------------------- fixtures
def test_good_fixture_is_clean():
    mods = _fx_modules("fx_good")
    findings = [f for f in _run_fixture_passes(mods) if not f.suppressed]
    assert findings == [], [f.render() for f in findings]


@pytest.mark.parametrize("name", ["fx_bad_lockorder", "fx_bad_guarded",
                                  "fx_bad_threads", "fx_rpc"])
def test_bad_fixture_exact_findings(name):
    mods = _fx_modules(name)
    expected = _expected(mods[0])
    assert expected, f"{name} has no expect markers"
    active = [f for f in _run_fixture_passes(mods) if not f.suppressed]
    got = {(f.rule, f.line) for f in active}
    missing = expected - got
    extra = got - expected
    assert not missing, f"seeded violations not flagged: {sorted(missing)}"
    assert not extra, \
        "false positives: " + "; ".join(
            f.render() for f in active if (f.rule, f.line) not in expected)


def test_inline_suppressions_are_recorded_not_active():
    mods = _fx_modules("fx_bad_lockorder", "fx_bad_guarded")
    findings = _run_fixture_passes(mods)
    sup = [f for f in findings if f.suppressed]
    # one reviewed inversion + one reviewed unguarded read
    assert {f.rule for f in sup} == {"LO001", "GB002"}


def test_baseline_round_trip(tmp_path):
    mods = _fx_modules("fx_bad_guarded")
    gb = [f for f in guarded.run(_fx_cfg(), mods) if not f.suppressed]
    assert gb
    bl = tmp_path / "baseline.txt"
    bl.write_text("# justification line\n"
                  + "\n".join(f.key() for f in gb) + "\n")
    core.apply_baseline(gb, core.load_baseline(bl))
    assert all(f.suppressed for f in gb)


# ------------------------------------------------------ registry sanity
def test_registry_ranks_strictly_ascending_and_sites_unique():
    ranks = [s.rank for s in conc.LOCK_ORDER]
    assert ranks == sorted(ranks) and len(set(ranks)) == len(ranks)
    sites = [site for s in conc.LOCK_ORDER for site in s.sites]
    assert len(sites) == len(set(sites))


def test_lock_table_matches_docs():
    table = conc.render_lock_table()
    doc = (REPO / "docs" / "concurrency.md").read_text()
    assert table in doc, \
        "docs/concurrency.md lock table drifted: run " \
        "`python tools/analyze.py --write-docs`"


# ------------------------------------------------------------ acceptance
def test_repo_is_clean_under_full_analysis():
    """The tree itself must carry zero unsuppressed findings — the same
    gate the static-analysis CI job enforces."""
    import analyze
    findings = analyze.run_all()
    active = [f for f in findings if not f.suppressed]
    assert active == [], [f.render() for f in active]


def test_every_registered_site_is_witness_wrapped():
    """Each registry site whose module creates the primitive must route
    it through witness_lock/witness_condition (else the runtime witness
    silently skips it).  _mig_cv shares _mutate's wrapped RLock and the
    _windows semaphores are counted, not order-checked."""
    exempt = {("sharded", "_mig_cv"), ("sharded", "_windows"),
              ("endpoint", "_lock")}   # alias site: created in graphstore
    src = {m.modname: m for m in core.load_package(REPO / "src" / "repro",
                                                   REPO)}
    for spec in conc.LOCK_ORDER:
        for modname, attr in spec.sites:
            if (modname, attr) in exempt:
                continue
            text = src[modname].source
            pat = rf"self\.{re.escape(attr)}\s*=\s*witness_"
            assert re.search(pat, text), \
                f"{modname}.{attr} ({spec.name}) is not witness-wrapped"


# --------------------------------------------------------------- witness
@pytest.fixture
def witness():
    conc.set_witness(True)
    conc.reset_witness()
    yield conc
    conc.reset_witness()
    conc.set_witness(False)


def test_witness_clean_nesting_records_edges_only(witness):
    outer = conc.witness_lock("graphstore._lock", threading.RLock())
    inner = conc.witness_lock("blockdev._lock", threading.Lock())
    with outer:
        with inner:
            pass
    rep = conc.witness_report()
    assert rep["violations"] == []
    assert ("graphstore._lock", "blockdev._lock") in [
        tuple(e) for e in rep["edges"]]
    conc.assert_clean()


def test_witness_trips_on_deliberate_inversion(witness):
    outer = conc.witness_lock("blockdev._lock", threading.Lock())
    inner = conc.witness_lock("graphstore._lock", threading.RLock())
    with outer:              # rank 70 first...
        with inner:          # ...then rank 60: inversion
            pass
    with pytest.raises(AssertionError, match="inversion"):
        conc.assert_clean()


def test_witness_trips_under_leaf_and_exclusion(witness):
    leaf = conc.witness_lock("supervisor._lock", threading.Lock())
    other = conc.witness_lock("queues._work", threading.Condition())
    with leaf:
        with other:
            pass
    kinds = {v["kind"] for v in conc.witness_report()["violations"]}
    assert "leaf" in kinds

    conc.reset_witness()
    rd = conc.witness_condition(
        "sharded._rd_cv", threading.Condition(threading.Lock()))
    mut = conc.witness_lock("sharded._mutate", threading.RLock())
    with rd:
        with mut:
            pass
    kinds = {v["kind"] for v in conc.witness_report()["violations"]}
    assert "exclusion" in kinds


def test_witness_reentrant_same_instance_is_silent(witness):
    r = conc.witness_lock("sharded._mutate", threading.RLock())
    with r:
        with r:
            pass
    assert conc.witness_report()["violations"] == []


def test_witness_off_returns_raw_objects():
    conc.set_witness(False)
    raw = threading.Lock()
    assert conc.witness_lock("supervisor._lock", raw) is raw
    rawc = threading.Condition()
    assert conc.witness_condition("queues.cv", rawc) is rawc
