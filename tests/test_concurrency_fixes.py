"""Regression tests for the concurrency defects the static analyzer
surfaced: callbacks deferred out of leaf critical sections, RPC rounds
moved off the gossip lock, counters put behind their guards, and
thread-lifecycle hygiene (names + joins)."""
import threading
import time

import numpy as np
import pytest

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import Pipeline
from repro.serve.scheduler import QoSTelemetry
from repro.serve.supervisor import ShardSupervisor
from repro.store.blockdev import BlockDevice
from repro.store.sharded import ReplicatedGraphStore
from repro.train.checkpoint import Checkpointer


def _lock_free(lock) -> bool:
    """True iff ``lock`` is not currently held (probe-and-release)."""
    ok = lock.acquire(blocking=False)
    if ok:
        lock.release()
    return ok


# ------------------------------------------------------------ supervisor
class _StubStore:
    """Duck-typed seam ShardSupervisor attaches to."""

    def __init__(self, n_shards=2):
        self.n_shards = n_shards
        self.failed_shards = [False] * n_shards
        self.health = None

    def probe_shards(self):
        return [{"shard": s} for s in range(self.n_shards)]


def test_supervisor_transition_hook_runs_without_lock():
    seen = []

    def hook(s, old, new, info):
        # the defect: hooks used to fire inside _transition_locked with
        # the LEAF supervisor lock held — any hook touching a lock
        # deadlocked or inverted the order
        seen.append((s, old, new, _lock_free(sup._lock)))

    sup = ShardSupervisor(_StubStore(), on_transition=hook)
    sup.record_error(0, RuntimeError("boom"))
    assert seen == [(0, "healthy", "suspect", True)]


def test_supervisor_hook_exception_does_not_break_later_hooks():
    seen = []

    def hook(s, old, new, info):
        seen.append(s)
        raise RuntimeError("telemetry crash")

    sup = ShardSupervisor(_StubStore(n_shards=3), on_transition=hook)
    sup.record_error(0, RuntimeError("a"))
    sup.record_error(1, RuntimeError("b"))
    assert seen == [0, 1]
    assert [e["shard"] for e in sup.events] == [0, 1]


# -------------------------------------------------------------- blockdev
def test_blockdev_grow_hooks_fire_with_lock_released():
    dev = BlockDevice(num_pages=8)
    calls = []
    dev.on_grow = lambda extra: calls.append(
        ("grow", extra, _lock_free(dev._lock)))
    dev.on_write = lambda lpn0, n: calls.append(
        ("write", (lpn0, n), _lock_free(dev._lock)))
    base = dev.alloc_back(16)              # must grow: 16 > 8 pages
    assert base >= 0
    kinds = [c[0] for c in calls]
    assert "grow" in kinds and "write" in kinds
    assert all(free for _, _, free in calls), \
        "grow/write observers ran under blockdev._lock"


def test_blockdev_alloc_front_grow_hook_outside_lock():
    dev = BlockDevice(num_pages=4)
    dev.alloc_back(4)                      # embedding space eats the device
    calls = []
    dev.on_grow = lambda extra: calls.append(_lock_free(dev._lock))
    dev.alloc_front()                      # front meets back -> grow
    assert calls and all(calls)


# ------------------------------------------------------------------- qos
def test_qos_locked_mutators_reflected_in_snapshot():
    qos = QoSTelemetry()
    qos.note_rejected({"why": "queue_full"})
    qos.note_expired(2)
    qos.note_backpressured()
    qos.note_errors(3)
    qos.note_group(4)
    qos.record(0.001)
    snap = qos.snapshot()
    assert snap["rejected"] == 1
    assert snap["expired"] == 2
    assert snap["backpressured"] == 1
    assert snap["errors"] == 3
    assert snap["groups"] == 1 and snap["avg_group_size"] == 4.0
    assert snap["completed"] == 1
    assert snap["last_reject_reason"] == {"why": "queue_full"}


# ---------------------------------------------------------------- gossip
def _rep_store():
    rng = np.random.default_rng(0)
    n = 64
    edges = rng.integers(0, n, size=(256, 2), dtype=np.int64)
    emb = rng.standard_normal((n, 8)).astype(np.float32)
    st = ReplicatedGraphStore(n_shards=2, replication=2, h_threshold=16)
    st.update_graph(edges, emb)
    return st


def test_gossip_round_runs_outside_gossip_lock():
    st = _rep_store()
    observed = []
    orig = st._submit_round

    def spy(items):
        observed.append(_lock_free(st._gossip_lock))
        return orig(items)

    st._submit_round = spy
    pulls0 = st.gossip_pulls
    st._refresh_gossip(force=True)
    assert observed == [True], \
        "counters RPC round ran under the leaf sharded._gossip_lock"
    # published under the lock after the round
    assert st.gossip_pulls == pulls0 + 1
    assert st._gossip_reads.shape == (st.n_shards,)


def test_gossip_inflight_flag_clears_on_rpc_failure():
    st = _rep_store()
    st._submit_round = lambda items: (_ for _ in ()).throw(
        RuntimeError("net down"))
    with pytest.raises(RuntimeError):
        st._refresh_gossip(force=True)
    assert st._gossip_inflight is False      # next pull not wedged


# -------------------------------------------------------- thread hygiene
def test_pipeline_close_joins_named_prefetch_thread():
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=8,
                      num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=32)
    shape = ShapeConfig(name="t", kind="train", seq_len=8, global_batch=2)
    pipe = Pipeline(cfg, shape, prefetch=1, host_index=0, host_count=1)
    assert pipe._thread.name == "pipeline-prefetch"
    pipe.next()
    pipe.close()
    assert not pipe._thread.is_alive(), \
        "close() left the prefetch worker running"


def test_checkpoint_writer_thread_is_named(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": np.ones((2, 2), np.float32)})
    assert ck._thread is not None and ck._thread.name == "checkpoint-writer"
    ck.wait()
    assert ck.latest_step() == 1


# ------------------------------------------------------- ingest counters
def test_firehose_snapshot_counters_consistent_after_windows():
    from repro.store.ingest import MutationFirehose
    st = _rep_store()
    fh = MutationFirehose(st)
    for v in range(8):
        fh.add_edge(1000 + v, v)
    fh.flush()
    snap = fh.snapshot()
    assert snap["windows"] >= 1
    assert snap["applied"] >= 1
    assert snap["submitted"] == 8
