"""End-to-end system behaviour: the full HolisticGNN service over RPC —
bulk ingest, DFG inference via priority-dispatched kernels, hardware
reconfiguration mid-service (the paper's headline flow)."""
import numpy as np
import jax.numpy as jnp

from repro.core.service import HolisticGNNService, make_service_dfg
from repro.core import gnn
from repro.kernels.ops import program_config
from repro.rpc import RPCServer, RPCClient


def test_end_to_end_inference_service():
    rng = np.random.default_rng(0)
    n, e = 300, 2000
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.5, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, 32)).astype(np.float32)

    svc = HolisticGNNService(h_threshold=16, pad_to=32)
    client = RPCClient(RPCServer(svc))

    # bulk ingest over RoP
    r = client.call("update_graph", edge_array=edges, embeddings=emb)
    assert r["user_visible_s"] <= r["total_s"] + 1e-6

    # GCN inference through the service DFG (BatchPre runs near storage)
    params = gnn.init_params("gcn", [32, 16, 8], seed=1)
    dfg = make_service_dfg("gcn", 2, [5, 5])
    weights = gnn.dfg_feeds("gcn", params, None, [])
    weights.pop("H")
    out1 = client.call("run", dfg=dfg.save(), batch=[1, 2, 3],
                       weights=weights)["Result"]
    assert out1.shape[1] == 8 and np.isfinite(out1).all()

    # reconfigure User logic (Hetero bitstreams) and re-run: same result
    dt = program_config(svc.xbuilder, "hetero")
    assert dt >= 0
    out2 = client.call("run", dfg=dfg.save(), batch=[1, 2, 3],
                       weights=weights)["Result"]
    np.testing.assert_allclose(out1[:3], out2[:3], rtol=1e-4, atol=1e-4)
    # the engine really dispatched to the accelerator devices
    devices = {d for _, d in svc.engine.trace}
    assert "systolic" in devices or "vector" in devices

    # mutable graph ops through the same service
    client.call("add_edge", dst=3, src=250)
    assert 250 in client.call("get_neighbors", vid=3)
    client.call("delete_edge", dst=3, src=250)
    assert 250 not in client.call("get_neighbors", vid=3)
