"""ShardEndpoint protocol: a remote (RoP) array is bit-identical to the
in-process array — healthy, degraded, and post-rebuild — per-shard RPC
count stays O(1) per batched read, rebuild streams shard-to-shard without
coordinator-side page materialization, and the replica-selection feedback
consumes a gossiped, staleness-bounded counter snapshot."""
import numpy as np
import pytest

from repro.core import gnn
from repro.core.service import HolisticGNNService, make_service_dfg
from repro.store import (BlockDevice, DeviceFailedError, GraphStore,
                         ReplicatedGraphStore, ShardedGraphStore,
                         make_rop_endpoints, sample_batch)
from repro.store.endpoint import pack_plan, unpack_plan


def _graph(n=400, e=3000, feat=24, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _single(h_threshold=16, **kw):
    edges, emb = _graph(**kw)
    single = GraphStore(BlockDevice(), h_threshold=h_threshold)
    single.update_graph(edges, emb)
    return single, edges, emb


def _remote(n_shards, replication=1, *, h_threshold=16, edges=None,
            emb=None, **store_kw):
    eps = make_rop_endpoints(n_shards, h_threshold=h_threshold)
    if replication > 1:
        store = ReplicatedGraphStore(endpoints=eps, replication=replication,
                                     h_threshold=h_threshold, **store_kw)
    else:
        store = ShardedGraphStore(endpoints=eps, h_threshold=h_threshold,
                                  **store_kw)
    if edges is not None:
        store.update_graph(edges, emb)
    return store


def _assert_reads_match(single, store, n, seed=3):
    rng = np.random.default_rng(seed)
    vids = rng.integers(0, n + 20, 70)           # includes unknown vids
    for a, b in zip(single.get_neighbors_batch(vids),
                    store.get_neighbors_batch(vids)):
        np.testing.assert_array_equal(a, b)
    known = vids[vids < n]
    np.testing.assert_array_equal(single.get_embeds(known),
                                  store.get_embeds(known))
    targets = rng.integers(0, n, 12)
    a = sample_batch(single, targets, [5, 5], rng=np.random.default_rng(9))
    b = sample_batch(store, targets, [5, 5], rng=np.random.default_rng(9))
    np.testing.assert_array_equal(a.node_vids, b.node_vids)
    for la, lb in zip(a.layers, b.layers):
        np.testing.assert_array_equal(la.nbr, lb.nbr)
        np.testing.assert_array_equal(la.mask, lb.mask)
    np.testing.assert_array_equal(a.embeddings, b.embeddings)


# ------------------------------------------------------------ plan packing
def test_pack_unpack_plan_roundtrip():
    desc = [None,
            ("L", 3, 0, 17),
            ("H", np.array([5, 9, 2]), np.array([100, 100, 7])),
            None,
            ("L", 0, 4, 4)]
    got = unpack_plan(pack_plan(desc))
    assert got[0] is None and got[3] is None
    assert got[1] == ("L", 3, 0, 17) and got[4] == ("L", 0, 4, 4)
    assert got[2][0] == "H"
    np.testing.assert_array_equal(got[2][1], desc[2][1])
    np.testing.assert_array_equal(got[2][2], desc[2][2])


# ------------------------------------------------------- remote bit-identity
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_remote_bit_identical_healthy(n_shards):
    single, edges, emb = _single()
    store = _remote(n_shards, edges=edges, emb=emb)
    try:
        _assert_reads_match(single, store, 400)
    finally:
        store.close()


@pytest.mark.parametrize("n_shards", [2, 4])
def test_remote_replicated_degraded_and_rebuilt(n_shards):
    """R=2 remote array: healthy, degraded under every single-shard
    failure, and post-rebuild reads all bit-identical to one device."""
    single, edges, emb = _single()
    store = _remote(n_shards, replication=2, edges=edges, emb=emb)
    try:
        _assert_reads_match(single, store, 400)
        for s in range(n_shards):
            store.fail_shard(s)
            _assert_reads_match(single, store, 400, seed=10 + s)
            info = store.rebuild_shard(s)
            assert info["pages_written"] > 0
            assert not any(store.failed_shards)
            _assert_reads_match(single, store, 400, seed=20 + s)
    finally:
        store.close()


def test_remote_mutations_match_single_device_twin():
    single, edges, emb = _single()
    store = _remote(3, replication=2, edges=edges, emb=emb)
    n = 400
    try:
        rng = np.random.default_rng(11)
        for _ in range(60):
            op = rng.integers(0, 5)
            a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
            if op == 0:
                single.add_edge(a, b), store.add_edge(a, b)
            elif op == 1:
                single.delete_edge(a, b), store.delete_edge(a, b)
            elif op == 2:
                v = n + int(rng.integers(0, 40))
                single.add_vertex(v), store.add_vertex(v)
            elif op == 3:
                row = rng.standard_normal(24).astype(np.float32)
                single.update_embed(a, row), store.update_embed(a, row)
            else:
                single.delete_vertex(a), store.delete_vertex(a)
        assert single.to_adjacency() == store.to_adjacency()
        _assert_reads_match(single, store, n, seed=40)
    finally:
        store.close()


def test_remote_run_bit_identical_service():
    edges, emb = _graph(n=600, e=5000, feat=32)
    ref = HolisticGNNService(h_threshold=16, pad_to=32)
    ref.store.update_graph(edges, emb)
    svc = HolisticGNNService(h_threshold=16, pad_to=32,
                             endpoints=make_rop_endpoints(2, h_threshold=16),
                             cache_pages=512)
    try:
        svc.store.update_graph(edges, emb)
        dfg = make_service_dfg("gcn", 2, [5, 5]).save()
        params = gnn.init_params("gcn", [32, 16, 8], seed=1)
        weights = {k: v for k, v in
                   gnn.dfg_feeds("gcn", params, None, []).items()
                   if k != "H"}
        want = ref.run(dfg, [3, 7, 11, 200], weights=weights,
                       seed=42)["Result"]
        got = svc.run(dfg, [3, 7, 11, 200], weights=weights,
                      seed=42)["Result"]
        np.testing.assert_array_equal(want, got)
        reqs = [{"targets": [3, 7], "seed": 1},
                {"targets": [9, 20, 31], "seed": 2}]
        for a, b in zip(ref.run_batch(dfg, reqs, weights=weights),
                        svc.run_batch(dfg, reqs, weights=weights)):
            np.testing.assert_array_equal(a["Result"], b["Result"])
    finally:
        svc.close()


# ----------------------------------------------------------- RPC accounting
def test_rpc_count_o1_per_batched_read():
    """One ``fetch`` command per shard per batched read — independent of
    how many vids (and pages) the read covers."""
    _, edges, emb = _single()
    store = _remote(2, edges=edges, emb=emb)
    try:
        def fetch_calls():
            return [ep.client.method_stats["fetch"].calls
                    if "fetch" in ep.client.method_stats else 0
                    for ep in store.endpoints]

        per_batch = []
        for b in (8, 64, 256):
            vids = np.random.default_rng(1).integers(0, 400, b)
            calls0 = fetch_calls()
            store.get_neighbors_batch(vids)
            store.get_embeds(vids % 400)
            calls1 = fetch_calls()
            per_batch.append([c1 - c0 for c0, c1 in zip(calls0, calls1)])
        # 2 batched reads -> exactly 2 fetch commands per shard, at any size
        assert all(all(c == 2 for c in row) for row in per_batch), per_batch
    finally:
        store.close()


def test_rebuild_streams_shard_to_shard():
    """The coordinator link carries plan + summary only; survivor pages
    move over the peer links straight into the replacement shard."""
    _, edges, emb = _single()
    store = _remote(3, replication=2, edges=edges, emb=emb)
    try:
        victim = 1
        store.fail_shard(victim)
        coord0 = store.endpoints[victim].channel_bytes()
        info = store.rebuild_shard(victim)
        coord_bytes = store.endpoints[victim].channel_bytes() - coord0
        page_bytes = int(info["pages_written"]) * 4096
        assert page_bytes > 0
        assert coord_bytes < 65536, coord_bytes
        assert page_bytes > 4 * coord_bytes, (coord_bytes, page_bytes)
    finally:
        store.close()


def test_failed_fetch_reaps_outstanding_handles():
    """When one shard's fetch fails mid-await (the drain path), the
    other shards' completions must still be reaped — otherwise every
    failover retry leaks full reply payloads in the RoP CQs."""
    _, edges, emb = _single()
    store = _remote(2, replication=2, edges=edges, emb=emb)
    try:
        store.endpoints[0].call("fail")      # device dies under the array
        with pytest.raises(DeviceFailedError):
            # shard 0 first: its result raises; shard 1's completion is
            # outstanding at that moment and must be drained
            store._endpoint_fetch([(0, {"emb_rows": np.arange(8)}),
                                   (1, {"emb_rows": np.arange(8)})])
        for ep in store.endpoints:
            assert not ep.client._pending, ep.client._pending
            for pair in ep.host.rop.pairs:
                assert not pair.cq, pair.cq
    finally:
        store.close()


# ------------------------------------------------------------ gossip loop
def test_gossip_staleness_bounds_counter_pulls():
    _, edges, emb = _single()
    # staleness 0: every selection refreshes the counter snapshot
    eager = ReplicatedGraphStore(n_shards=2, replication=2, h_threshold=16,
                                 stats_staleness_s=0.0)
    eager.update_graph(edges, emb)
    p0 = eager.gossip_pulls
    for _ in range(5):
        eager.get_embeds(np.arange(40))
    assert eager.gossip_pulls - p0 >= 5
    # large staleness bound: the cached snapshot serves every selection
    lazy = ReplicatedGraphStore(n_shards=2, replication=2, h_threshold=16,
                                stats_staleness_s=60.0)
    lazy.update_graph(edges, emb)
    p0 = lazy.gossip_pulls
    for _ in range(5):
        lazy.get_embeds(np.arange(40))
    assert lazy.gossip_pulls - p0 == 0
    # and the stale view never changes results, only device attribution
    np.testing.assert_array_equal(eager.get_embeds(np.arange(60)),
                                  lazy.get_embeds(np.arange(60)))


# ------------------------------------------------------------ error mapping
def test_device_failure_maps_to_typed_error_across_rop():
    _, edges, emb = _single()
    store = _remote(2, replication=2, edges=edges, emb=emb)
    try:
        store.fail_shard(0)
        with pytest.raises(DeviceFailedError):
            store.endpoints[0].call("get_neighbors", vid=0)
        # reads keep working through the failover path
        assert len(store.get_neighbors(0)) >= 0
    finally:
        store.close()


# ------------------------------------------------------------- stats parity
def test_stats_report_identical_shape_local_vs_remote():
    edges, emb = _graph(n=300, e=2000, feat=24)
    local = HolisticGNNService(h_threshold=16, pad_to=32, n_shards=2,
                               cache_pages=256)
    local.store.update_graph(edges, emb)
    remote = HolisticGNNService(h_threshold=16, pad_to=32,
                                endpoints=make_rop_endpoints(
                                    2, h_threshold=16),
                                cache_pages=256)
    try:
        remote.store.update_graph(edges, emb)
        vids = np.arange(24)
        local.store.get_embeds(vids)
        remote.store.get_embeds(vids)
        a, b = local.stats(), remote.stats()
        assert set(a) == set(b)
        assert set(a["store"]) == set(b["store"])
        assert a["store"]["pages_l"] == b["store"]["pages_l"]
        assert a["store"]["pages_h"] == b["store"]["pages_h"]
        assert a["device"]["read_pages"] == b["device"]["read_pages"]
        for sa, sb in zip(a["shards"], b["shards"]):
            assert set(sa) == set(sb)
            assert sa["device"] == sb["device"]
            # both endpoint flavours report per-method RPC stats
            assert set(sa["rpc"]) == set(sb["rpc"])
            assert sa["embcache"]["hits"] == sb["embcache"]["hits"]
        assert a["embcache"] == b["embcache"]
    finally:
        remote.close()
