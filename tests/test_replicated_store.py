"""ReplicatedGraphStore: R-way replica parity with the single-device
store, replica-spread selection balance, degraded-mode bit-identity under
every single-shard failure, write-fan-out coherence, and the
fail/rebuild/restore cycle — through the raw store and the service RPCs."""
import numpy as np
import pytest

from repro.core import gnn
from repro.core.service import HolisticGNNService, make_service_dfg
from repro.store import (BlockDevice, DeviceFailedError, GraphStore,
                         ReplicatedGraphStore, sample_batch)


def _graph(n=420, e=3200, feat=24, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _pair(n_shards, replication, *, h_threshold=16, n=420, e=3200, feat=24):
    edges, emb = _graph(n, e, feat)
    single = GraphStore(BlockDevice(), h_threshold=h_threshold)
    single.update_graph(edges, emb)
    rep = ReplicatedGraphStore(n_shards=n_shards, replication=replication,
                               h_threshold=h_threshold)
    rep.update_graph(edges, emb)
    return single, rep, n


def _assert_batches_equal(a, b, msg=""):
    np.testing.assert_array_equal(a.node_vids, b.node_vids, err_msg=msg)
    for la, lb in zip(a.layers, b.layers):
        np.testing.assert_array_equal(la.nbr, lb.nbr, err_msg=msg)
        np.testing.assert_array_equal(la.mask, lb.mask, err_msg=msg)
    np.testing.assert_array_equal(a.embeddings, b.embeddings, err_msg=msg)


def _assert_reads_match(single, rep, n, seed=3):
    rng = np.random.default_rng(seed)
    vids = rng.integers(0, n + 20, 70)           # includes unknown vids
    for a, b in zip(single.get_neighbors_batch(vids),
                    rep.get_neighbors_batch(vids)):
        np.testing.assert_array_equal(a, b)
    known = vids[vids < n]
    np.testing.assert_array_equal(single.get_embeds(known),
                                  rep.get_embeds(known))
    targets = rng.integers(0, n, 12)
    _assert_batches_equal(
        sample_batch(single, targets, [5, 5], rng=np.random.default_rng(9)),
        sample_batch(rep, targets, [5, 5], rng=np.random.default_rng(9)))


# ----------------------------------------------------------- healthy parity
@pytest.mark.parametrize("n_shards,replication",
                         [(3, 2), (4, 2), (4, 3), (4, 1)])
def test_replicated_bit_identical_healthy(n_shards, replication):
    single, rep, n = _pair(n_shards, replication)
    _assert_reads_match(single, rep, n)


def test_bad_replication_factor_rejected():
    with pytest.raises(ValueError):
        ReplicatedGraphStore(n_shards=2, replication=3)
    with pytest.raises(ValueError):
        ReplicatedGraphStore(n_shards=2, replication=0)


# ---------------------------------------------------------- degraded reads
def test_kill_each_shard_in_turn_stays_bit_identical():
    """R=2, N=3: fail every shard in turn; sample_batch / get_embeds /
    get_neighbors_batch must stay bit-identical to the healthy single
    device, and rebuild must restore full redundancy each time."""
    single, rep, n = _pair(3, 2)
    for s in range(3):
        info = rep.fail_shard(s)
        assert s not in [i for i, f in enumerate(rep.failed_shards) if not f]
        assert sorted(info["degraded_classes"]) == sorted(
            {(s - r) % 3 for r in range(2)})
        _assert_reads_match(single, rep, n, seed=10 + s)
        # reads must not touch the dead device
        with pytest.raises(DeviceFailedError):
            rep.shards[s].dev.read_page(0)
        info = rep.rebuild_shard(s)
        assert info["pages_written"] > 0
        assert not any(rep.failed_shards)
        assert rep.shards[s].dev.stats.written_pages == info["pages_written"]
        assert (rep.shards[s].stats.pages_l
                + rep.shards[s].stats.pages_h) > 0
        _assert_reads_match(single, rep, n, seed=20 + s)


def test_degraded_reads_avoid_failed_device():
    _, rep, n = _pair(4, 2)
    rep.fail_shard(1)
    reads0 = rep.shards[1].dev.stats.read_pages
    rep.get_embeds(np.arange(60))
    rep.get_neighbors_batch(np.arange(60))
    assert rep.shards[1].dev.stats.read_pages == reads0


def test_fail_validation_refuses_data_loss():
    _, rep, _ = _pair(3, 2)
    rep.fail_shard(0)
    # class c's owners are shards {c, c+1}.  With shard 0 dead, killing
    # shard 1 would lose class 0 (owners {0, 1} both dead) and killing
    # shard 2 would lose class 2 (owners {2, 0} both dead)
    with pytest.raises(DeviceFailedError):
        rep.fail_shard(1)
    with pytest.raises(DeviceFailedError):
        rep.fail_shard(2)
    rep.rebuild_shard(0)
    rep.fail_shard(1)                      # fine again after rebuild


def test_r1_cannot_fail_anything():
    _, rep, _ = _pair(3, 1)
    with pytest.raises(DeviceFailedError):
        rep.fail_shard(0)


def test_out_of_table_embedding_rows_rejected():
    """A row beyond the ingested table would land in ANOTHER role's
    stripe under the replica layout — silent cross-vertex corruption —
    so embed reads/writes bound-check the vid."""
    _, rep, n = _pair(2, 2)
    row = np.zeros(24, dtype=np.float32)
    before = rep.get_embeds(np.arange(n))
    for bad in (n, n + 7):
        with pytest.raises(KeyError):
            rep.update_embed(bad, row)
        with pytest.raises(KeyError):
            rep.get_embed(bad)
        with pytest.raises(KeyError):
            rep.get_embeds(np.array([0, bad]))
        rep.add_vertex(bad)                    # adjacency-only: fine
        with pytest.raises(KeyError):
            rep.add_vertex(bad + 100, embed=row)
    np.testing.assert_array_equal(rep.get_embeds(np.arange(n)), before)


# ------------------------------------------------------ write fan-out paths
def _mutate_both(single, rep, n, rounds=120, seed=11, feat=24):
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        op = rng.integers(0, 5)
        a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
        if op == 0:
            single.add_edge(a, b), rep.add_edge(a, b)
        elif op == 1:
            single.delete_edge(a, b), rep.delete_edge(a, b)
        elif op == 2:
            v = n + int(rng.integers(0, 40))
            single.add_vertex(v), rep.add_vertex(v)
        elif op == 3:
            row = rng.standard_normal(feat).astype(np.float32)
            single.update_embed(a, row), rep.update_embed(a, row)
        else:
            single.delete_vertex(a), rep.delete_vertex(a)


def test_write_fanout_coherence_mutate_fail_read_survivor():
    """Mutations fan out to every replica: mutate, fail each shard in
    turn, and the survivors must serve the mutated state bit-identically."""
    single, rep, n = _pair(3, 2)
    _mutate_both(single, rep, n)
    assert single.to_adjacency() == rep.to_adjacency()
    for s in range(3):
        rep.fail_shard(s)
        _assert_reads_match(single, rep, n, seed=30 + s)
        rep.rebuild_shard(s)


def test_degraded_writes_then_rebuild_then_other_failure():
    """Writes while degraded land on the survivors; rebuild folds them in;
    failing ANOTHER shard afterwards forces reads through the rebuilt
    replica, which must hold the degraded-era mutations."""
    single, rep, n = _pair(3, 2)
    rep.fail_shard(0)
    _mutate_both(single, rep, n, rounds=60, seed=13)
    _assert_reads_match(single, rep, n, seed=40)
    rep.rebuild_shard(0)
    # kill shard 1: class 0 (owners {0, 1}) must now be served by the
    # REBUILT shard 0 exclusively
    rep.fail_shard(1)
    _assert_reads_match(single, rep, n, seed=41)
    rep.rebuild_shard(1)
    _assert_reads_match(single, rep, n, seed=42)


# -------------------------------------------------------- replica selection
def test_select_replicas_balances_feasible_skew():
    """A class-skewed (but feasible) weight mix must spread to near-equal
    per-shard load; repeated selections drive cumulative balance to ~1."""
    _, rep, n = _pair(4, 2, n=800, e=6000)
    rng = np.random.default_rng(0)
    for _ in range(6):
        hot = 1 + 4 * rng.integers(0, 200, 120)      # class-1 heavy
        cold = rng.integers(0, 800, 240)
        rep.get_embeds(np.concatenate([hot, cold]) % 800)
    reads = [d.stats.read_pages for d in rep.devs]
    assert min(reads) / max(reads) >= 0.9, reads


def test_selection_only_targets_live_owners():
    _, rep, n = _pair(4, 2)
    vids = np.arange(160, dtype=np.int64)
    owner = rep._select_replicas(vids)
    for v, s in zip(vids.tolist(), owner.tolist()):
        assert s in rep.replica_shards(v)
    rep.fail_shard(2)
    owner = rep._select_replicas(vids)
    assert 2 not in set(owner.tolist())


# --------------------------------------------------------- service surface
def test_service_replicated_run_and_fault_rpcs():
    edges, emb = _graph(n=600, e=5000, feat=32)
    ref = HolisticGNNService(h_threshold=16, pad_to=32)
    ref.store.update_graph(edges, emb)
    svc = HolisticGNNService(h_threshold=16, pad_to=32, n_shards=3,
                             replication=2, cache_pages=600)
    svc.store.update_graph(edges, emb)
    dfg = make_service_dfg("gcn", 2, [5, 5]).save()
    params = gnn.init_params("gcn", [32, 16, 8], seed=1)
    weights = {k: v for k, v in
               gnn.dfg_feeds("gcn", params, None, []).items() if k != "H"}
    want = ref.run(dfg, [3, 7, 11, 200], weights=weights, seed=42)["Result"]
    got = svc.run(dfg, [3, 7, 11, 200], weights=weights, seed=42)["Result"]
    np.testing.assert_array_equal(want, got)

    st = svc.stats()
    assert st["replication"] == {"r": 2, "failed_shards": []}
    assert all(not s["failed"] for s in st["shards"])

    svc.fail_shard(1)
    got = svc.run(dfg, [3, 7, 11, 200], weights=weights, seed=42)["Result"]
    np.testing.assert_array_equal(want, got)
    st = svc.stats()
    assert st["replication"]["failed_shards"] == [1]
    assert st["shards"][1]["failed"]

    info = svc.rebuild_shard(1)
    assert info["pages_written"] > 0
    st = svc.stats()
    assert st["replication"]["failed_shards"] == []
    assert st["shards"][1]["pages_l"] + st["shards"][1]["pages_h"] > 0
    got = svc.run(dfg, [3, 7, 11, 200], weights=weights, seed=42)["Result"]
    np.testing.assert_array_equal(want, got)


def test_service_fault_rpcs_need_replication():
    svc = HolisticGNNService(n_shards=2)
    with pytest.raises(RuntimeError):
        svc.fail_shard(0)
