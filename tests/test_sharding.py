"""Distribution: dev-mesh dry-run cells compile (subprocess owns XLA_FLAGS),
ZeRO-1 spec derivation, compressed collective numerics, paged serving engine
equals the dense decode path."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


@pytest.mark.parametrize("arch,shape", [
    ("llama3.2-3b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "decode_32k"),
    ("jamba-v0.1-52b", "train_4k"),
])
def test_dryrun_dev_cells(arch, shape):
    r = _run_dryrun(["--arch", arch, "--shape", shape, "--dev", "--smoke",
                     "--both-meshes", "--out", "/tmp/dryrun_test"])
    assert "ALL 2 dry-run cells compiled OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-2000:]


def test_zero1_spec_adds_dp_axis():
    from repro.train.optimizer import opt_pspecs
    from repro.models import build, layers as L
    from repro.configs import SMOKES
    api = build(SMOKES["llama3.2-3b"], tp=4)
    specs = opt_pspecs(api.param_defs(), zero1=True, dp_axes=("data",),
                       dp_size=2)
    flat = [s for s in __import__("jax").tree.leaves(
        specs["m"], is_leaf=lambda x: hasattr(x, "_normalized_spec") or
        isinstance(x, tuple))]
    # at least one moment spec gained a "data" entry
    assert any("data" in str(s) for s in flat)


def test_compressed_psum_mean_matches_fp32():
    """int8-EF compressed all-reduce ~= true mean (single shard exactness)."""
    from repro.train.collectives import compressed_psum_mean
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    f = compressed_psum_mean(mesh, "data")
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((1, 64)), jnp.float32)}
    e = {"w": jnp.zeros((1, 64), jnp.float32)}
    mean, err = f(g, e)
    np.testing.assert_allclose(mean["w"], g["w"], rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(mean["w"]) + np.asarray(err["w"]),
                               g["w"], rtol=1e-6, atol=1e-6)


def test_paged_serving_matches_dense_decode():
    """The paged engine (GraphStore pages) must reproduce the dense-cache
    decode path token for token."""
    from repro.configs import SMOKES
    from repro.models import build, layers as L
    from repro.launch.serve import PagedLM
    from repro.store.pagedkv import PagePool

    cfg = SMOKES["llama3.2-3b"]
    api = build(cfg, tp=1)
    params = api.init_params(0)
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(0, cfg.vocab_size, 9))

    pool = PagePool(num_pages=32, page_size=4, num_layers=cfg.num_layers,
                    num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim)
    eng = PagedLM(cfg, params, pool)
    seq = eng.mgr.add_sequence(0, prompt)
    first = eng.prefill(seq)
    seq.generated.append(first)
    paged_tokens = [first]
    for _ in range(5):
        t = eng.decode_step([seq])[0]
        seq.generated.append(t)
        paged_tokens.append(t)

    # dense reference
    caches = L.init_tree(api.cache_defs(1, 64))
    toks = jnp.asarray([prompt], jnp.int32)
    lg, caches = api.prefill(params, {"tokens": toks}, caches)
    dense_tokens = [int(jnp.argmax(lg[0, -1]))]
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    cur = dense_tokens[0]
    for _ in range(5):
        lg, caches = api.decode(params, {"tokens": jnp.asarray([[cur]]),
                                         "lengths": lengths}, caches)
        cur = int(jnp.argmax(lg[0, 0]))
        dense_tokens.append(cur)
        lengths = lengths + 1
    assert paged_tokens == dense_tokens
