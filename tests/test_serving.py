"""Concurrent serving runtime: fused-group bit-exactness vs serial
execution, scheduler QoS mechanics (priorities, deadlines, admission
backpressure), and mutable-ops-under-load cache coherence."""
import threading

import numpy as np
import pytest

from repro.core.service import HolisticGNNService, make_service_dfg
from repro.core.dfg import DFG
from repro.core import gnn
from repro.serve import ServingRuntime, BatchScheduler, AdmissionError
from repro.serve.batcher import split_service_dfg, sample_group, pad_group
from repro.store.sampler import sample_batch


def _service(seed=0, n=600, e=5000, feat=32, cache_pages=2048):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    svc = HolisticGNNService(h_threshold=16, pad_to=32,
                             cache_pages=cache_pages)
    svc.store.update_graph(edges, emb)
    return svc, n


def _model_setup(model, feat=32):
    params = gnn.init_params(model, [feat, 16, 8], seed=1)
    dfg = make_service_dfg(model, 2, [5, 5]).save()
    weights = {k: v for k, v in
               gnn.dfg_feeds(model, params, None, []).items() if k != "H"}
    return dfg, weights


# ------------------------------------------------------------------ batcher
def test_split_service_dfg():
    dfg = make_service_dfg("gcn", 2, [5, 5])
    prog = split_service_dfg(dfg)
    assert prog is not None
    assert prog.fanouts == [5, 5]
    assert len(prog.feed_refs) == 5                  # H + 2 * (nbr, mask)
    assert "Batch" not in prog.model._ins and "Seed" not in prog.model._ins
    assert all(r in prog.model._ins for r in prog.feed_refs)
    # model-only DFG (no BatchPre) is not batchable
    assert split_service_dfg(gnn.build_gcn_dfg(2)) is None


def test_sample_group_matches_solo_sampling():
    svc, n = _service()
    rng = np.random.default_rng(3)
    targets = [rng.integers(0, n, s) for s in (8, 3, 1, 8)]
    seeds = [11, 12, 13, 14]
    grp, slices = sample_group(svc.store, targets, seeds, [5, 5])
    assert [s[1] for s in slices] == [8, 3, 1, 8]
    for r, (t, s) in enumerate(zip(targets, seeds)):
        solo = sample_batch(svc.store, t, [5, 5],
                            rng=np.random.default_rng(s))
        off, nt = slices[r]
        # target-level rows of the composed deepest-to-shallowest stack
        np.testing.assert_array_equal(
            solo.layers[-1].mask, grp.layers[-1].mask[off: off + nt])
        # per-request node vids survive composition (scattered, not reordered)
        assert set(solo.node_vids.tolist()) <= set(grp.node_vids.tolist())


def test_pad_group_buckets_are_geometric():
    from repro.serve.batcher import _bucket
    svc, n = _service()
    grp, _ = sample_group(svc.store, [np.arange(8)], [0], [5, 5])
    padded = pad_group(grp, 32)
    for dim in ([padded.num_nodes] +
                [b.nbr.shape[0] for b in padded.layers]):
        assert dim >= 32 and _bucket(dim, 32) == dim   # a bucket fixed point
    # half-octave ladder: bounded signatures, bounded (<= 33%) waste
    assert [_bucket(x, 32) for x in (1, 32, 33, 48, 49, 64, 97, 130)] == \
        [32, 32, 48, 48, 64, 64, 128, 192]


# -------------------------------------------------------- fused == serial
@pytest.mark.parametrize("model", ["gcn", "gin", "ngcf"])
def test_run_batch_bit_identical_to_serial(model):
    svc, n = _service()
    dfg, weights = _model_setup(model)
    rng = np.random.default_rng(5)
    reqs = [{"targets": rng.integers(0, n, sz).tolist(), "seed": 50 + i}
            for i, sz in enumerate([8, 3, 8, 1, 16])]
    fused = svc.run_batch(dfg, reqs, weights=weights, jit=True)
    for r, f in zip(reqs, fused):
        nt = len(r["targets"])
        serial = svc.run(dfg, r["targets"], weights=weights,
                         seed=r["seed"], jit=True)
        for k in serial:
            np.testing.assert_array_equal(serial[k][:nt], f[k][:nt])


def test_scheduled_runtime_bit_identical_to_serial():
    """The acceptance-criteria check at runtime level: a seeded scheduler
    run produces bit-identical per-request outputs to serial execution."""
    svc, n = _service()
    dfg, weights = _model_setup("gcn")
    rt = ServingRuntime(svc, n_queues=3, max_group=8)
    rng = np.random.default_rng(6)
    cmds = []
    for i in range(6):
        c = rt.client()
        targets = rng.integers(0, n, 8).tolist()
        cmds.append((c, c.submit("run", dfg=dfg, batch=targets,
                                 weights=weights, seed=i), targets, i))
    assert rt.pump() == 6
    assert rt.scheduler.qos.groups >= 1
    assert rt.scheduler.qos.grouped_requests == 6
    for c, cid, targets, i in cmds:
        got = c.result(cid)["Result"]
        want = svc.run(dfg, targets, weights=weights, seed=i)["Result"]
        np.testing.assert_array_equal(want[:8], got[:8], err_msg=f"req {i}")


def test_scheduler_priorities_schedule_first():
    svc, n = _service()
    dfg_a, weights = _model_setup("gcn")
    dfg_b = make_service_dfg("gcn", 2, [4, 4]).save()   # different program
    sched = BatchScheduler(svc, max_group=8, batch_window_s=0)
    order = []
    def done(tag):
        return lambda resp: order.append(tag)
    for i in range(3):
        sched.submit(dfg=dfg_a, batch=[i], weights=weights, seed=i,
                     priority=0, on_done=done(f"bulk{i}"))
    sched.submit(dfg=dfg_b, batch=[0], weights=weights, seed=9,
                 priority=5, on_done=done("urgent"))
    assert sched.step() == 1                  # high-priority singleton first
    assert order == ["urgent"]
    assert sched.step() == 3                  # bulk group coalesces after
    assert len(order) == 4


def test_scheduler_deadline_expiry():
    svc, n = _service()
    dfg, weights = _model_setup("gcn")
    sched = BatchScheduler(svc, batch_window_s=0)
    got = []
    sched.submit(dfg=dfg, batch=[1, 2], weights=weights, deadline_s=-0.001,
                 on_done=got.append)
    assert sched.step() == 0                  # expired, nothing executed
    assert len(got) == 1 and not got[0]["ok"]
    assert "DeadlineExceeded" in got[0]["error"]
    assert sched.qos.expired == 1


def test_admission_backpressure():
    svc, n = _service()
    dfg, weights = _model_setup("gcn")
    sched = BatchScheduler(svc, max_pending=2)
    for i in range(2):
        sched.submit(dfg=dfg, batch=[i], weights=weights,
                     on_done=lambda r: None)
    with pytest.raises(AdmissionError):
        sched.submit(dfg=dfg, batch=[9], weights=weights,
                     on_done=lambda r: None)
    assert sched.qos.rejected == 1
    # through the runtime the rejection becomes an error completion
    rt = ServingRuntime(svc, max_pending=1)
    c = rt.client()
    ids = [c.submit("run", dfg=dfg, batch=[i], weights=weights, seed=i)
           for i in range(3)]
    rt.pump()
    outcomes = []
    for cid in ids:
        try:
            c.result(cid)
            outcomes.append("ok")
        except RuntimeError as e:
            assert "AdmissionError" in str(e)
            outcomes.append("rejected")
    assert outcomes.count("rejected") == 2 and outcomes.count("ok") == 1


def test_scheduler_error_fans_out_with_traceback():
    svc, n = _service()
    dfg, _ = _model_setup("gcn")
    rt = ServingRuntime(svc)
    c = rt.client()
    cid = c.submit("run", dfg=dfg, batch=[1], weights={}, seed=0)  # no weights
    rt.pump()
    with pytest.raises(RuntimeError, match="device traceback"):
        c.result(cid)


def test_weights_fingerprint_prevents_wrong_coalescing():
    svc, n = _service()
    dfg, weights = _model_setup("gcn")
    params2 = gnn.init_params("gcn", [32, 16, 8], seed=99)
    weights2 = {k: v for k, v in
                gnn.dfg_feeds("gcn", params2, None, []).items() if k != "H"}
    rt = ServingRuntime(svc, max_group=8)
    c = rt.client()
    t = [1, 2, 3]
    c1 = c.submit("run", dfg=dfg, batch=t, weights=weights, seed=0)
    c2 = c.submit("run", dfg=dfg, batch=t, weights=weights2, seed=0)
    rt.pump()
    assert rt.scheduler.qos.groups == 2       # two groups, not one
    out1, out2 = c.result(c1)["Result"], c.result(c2)["Result"]
    np.testing.assert_array_equal(
        out1[:3], svc.run(dfg, t, weights=weights, seed=0)["Result"][:3])
    np.testing.assert_array_equal(
        out2[:3], svc.run(dfg, t, weights=weights2, seed=0)["Result"][:3])


def test_weights_registry_equivalence_and_coalescing():
    """put_weights + weights_ref: device-resident weights give the same
    results as shipping weights per request, and requests coalesce on ref."""
    svc, n = _service()
    dfg, weights = _model_setup("gcn")
    info = svc.put_weights("m1", weights)
    assert info["tensors"] == len(weights) and info["bytes"] > 0
    t = [1, 2, 3]
    a = svc.run(dfg, t, weights=weights, seed=3)["Result"]
    b = svc.run(dfg, t, weights_ref="m1", seed=3)["Result"]
    np.testing.assert_array_equal(a, b)
    fused = svc.run_batch(dfg, [{"targets": t, "seed": 3}],
                          weights_ref="m1")[0]["Result"]
    np.testing.assert_array_equal(a[:3], fused[:3])
    with pytest.raises(KeyError):
        svc.run(dfg, t, weights_ref="unregistered")
    rt = ServingRuntime(svc, max_group=8)
    c = rt.client()
    ids = [c.submit("run", dfg=dfg, batch=t, weights_ref="m1", seed=s)
           for s in range(3)]
    rt.pump()
    assert rt.scheduler.qos.groups == 1       # one fused group via the ref
    for s, cid in enumerate(ids):
        np.testing.assert_array_equal(
            c.result(cid)["Result"][:3],
            svc.run(dfg, t, weights_ref="m1", seed=s)["Result"][:3])


def test_qos_telemetry_via_stats_rpc():
    svc, n = _service()
    dfg, weights = _model_setup("gcn")
    rt = ServingRuntime(svc)
    c = rt.client()
    for i in range(5):
        c.submit("run", dfg=dfg, batch=[i, i + 1], weights=weights, seed=i)
    rt.pump()
    cid = c.submit("stats")
    rt.pump()
    st = c.result(cid)
    qos = st["qos"]
    assert qos["completed"] == 5 and qos["queue_depth"] == 0
    assert qos["p99_latency_s"] >= qos["p50_latency_s"] > 0
    assert qos["throughput_rps"] > 0 and qos["groups"] >= 1
    assert st["embcache"]["hits"] + st["embcache"]["misses"] > 0
    assert "run" in st["rpc"] or "stats" in st["rpc"]
    # the stats command itself is still in flight while snapshotting
    assert st["qos"]["transport"]["in_flight"] <= 1


# ------------------------------------------------- mutable ops under load
def test_mutable_ops_under_load_match_serial_reference():
    """Interleave unit mutations with scheduled run groups (deterministic
    stepping) and assert every scheduled output is bit-identical to a serial
    reference service receiving the same operation sequence — the cache
    invalidation correctness check."""
    svc, n = _service(cache_pages=512)
    ref, _ = _service(cache_pages=None)       # twin without cache, serial
    dfg, weights = _model_setup("gcn")
    rt = ServingRuntime(svc, n_queues=2, max_group=8)
    mut_client = rt.client()
    rng = np.random.default_rng(7)
    seed_ctr = 0
    for round_ in range(6):
        # a batch of concurrent runs...
        cmds = []
        cl = rt.client()
        for _ in range(4):
            t = rng.integers(0, n, 6).tolist()
            cmds.append((t, seed_ctr,
                         cl.submit("run", dfg=dfg, batch=t, weights=weights,
                                   seed=seed_ctr)))
            seed_ctr += 1
        rt.pump()
        for t, s, cid in cmds:
            got = cl.result(cid)["Result"]
            want = ref.run(dfg, t, weights=weights, seed=s)["Result"]
            np.testing.assert_array_equal(want[:6], got[:6],
                                          err_msg=f"round {round_}")
        # ...then mutations through the SAME runtime (sync dispatch path),
        # mirrored onto the reference store
        a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
        row = rng.standard_normal(32).astype(np.float32)
        mids = [mut_client.submit("add_edge", dst=a, src=b),
                mut_client.submit("update_embed", vid=a, embed=row),
                mut_client.submit("delete_vertex", vid=(a + 1) % n)]
        rt.pump()
        for mid in mids:
            mut_client.result(mid)
        ref.store.add_edge(a, b)
        ref.store.update_embed(a, row)
        ref.store.delete_vertex((a + 1) % n)
    assert svc.store.cache.stats.invalidations > 0
    assert svc.store.cache.stats.hits > 0


def test_mutable_ops_threaded_stress_cache_coherent():
    """Threaded mode: concurrent clients + live mutations; after quiescing,
    cached reads must equal device truth."""
    svc, n = _service(cache_pages=512)
    dfg, weights = _model_setup("gcn")
    rt = ServingRuntime(svc, n_queues=4, max_group=8)
    rt.start()
    errors = []

    def runner(i):
        try:
            cl = rt.client()
            rng = np.random.default_rng(100 + i)
            for j in range(4):
                out = cl.call("run", dfg=dfg,
                              batch=rng.integers(0, n, 6).tolist(),
                              weights=weights, seed=i * 10 + j, timeout=120)
                assert np.isfinite(out["Result"]).all()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    def mutator():
        try:
            cl = rt.client()
            rng = np.random.default_rng(999)
            for _ in range(12):
                cl.call("add_edge", dst=int(rng.integers(0, n)),
                        src=int(rng.integers(0, n)), timeout=120)
                cl.call("update_embed", vid=int(rng.integers(0, n)),
                        embed=rng.standard_normal(32).astype(np.float32),
                        timeout=120)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=runner, args=(i,)) for i in range(4)]
    threads.append(threading.Thread(target=mutator))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rt.stop()
    assert not errors, errors
    # quiesced: cache contents must agree with the device
    vids = np.arange(min(n, 128))
    warm = svc.store.get_embeds(vids)
    svc.store.cache.clear()
    truth = svc.store.get_embeds(vids)
    np.testing.assert_array_equal(warm, truth)
