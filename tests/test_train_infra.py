"""Training substrate: optimizer convergence, checkpoint atomicity +
corruption detection, fault-tolerant resume determinism, straggler monitor,
paged-KV manager."""
import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import optimizer as O
from repro.train.checkpoint import Checkpointer
from repro.train.trainer import Trainer, StragglerMonitor
from repro.models import build
from repro.configs import SMOKES
from repro.configs.base import ShapeConfig
from repro.store.pagedkv import PagePool, PagedKVManager


def test_adamw_converges_quadratic():
    c = O.AdamWConfig(lr=0.1, warmup_steps=1, decay_steps=1000,
                      weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = O.init_state(params)
    target = jnp.asarray([1.0, 2.0])

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum((q["x"] - target) ** 2))(p)
        return O.apply_updates(c, p, g, s)

    for _ in range(300):
        params, state, m = step(params, state)
    np.testing.assert_allclose(params["x"], target, atol=1e-2)


def test_schedule_warmup_and_decay():
    c = O.AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100)
    assert float(O.schedule(c, jnp.asarray(1))) < 0.2
    assert float(O.schedule(c, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(O.schedule(c, jnp.asarray(100))) == pytest.approx(0.1)


def test_int8_ef_compression_reduces_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    acc_ref = jnp.zeros_like(g)
    for i in range(20):                       # repeated steps: EF compensates
        q, scale, err = O.compress_int8(g, err)
        acc = acc + O.decompress_int8(q, scale)
        acc_ref = acc_ref + g
    rel = float(jnp.linalg.norm(acc - acc_ref) / jnp.linalg.norm(acc_ref))
    assert rel < 1e-2, rel


def test_checkpoint_roundtrip_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))},
            "stack": (jnp.zeros(4), jnp.full(2, 7.0))}
    ck.save(3, tree, blocking=True)
    ck.save(7, tree, blocking=True)
    ck.save(11, tree, blocking=True)
    assert ck.committed_steps() == [7, 11]     # keep=2 GC'd step 3
    got, step = ck.restore(tree)
    assert step == 11
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(a, b)

    # a step without COMMIT is invisible
    os.remove(tmp_path / "step_11" / "COMMIT")
    assert ck.committed_steps() == [7]

    # corruption detection
    leaf = next(f for f in os.listdir(tmp_path / "step_7")
                if f.endswith(".npy"))
    arr = np.load(tmp_path / "step_7" / leaf)
    np.save(tmp_path / "step_7" / leaf, arr + 1)
    with pytest.raises(IOError):
        ck.restore(tree, step=7)


def test_trainer_fault_resume_is_deterministic(tmp_path):
    cfg = SMOKES["llama3.2-3b"]
    shape = ShapeConfig("t", "train", seq_len=16, global_batch=2)
    api = build(cfg, tp=1)

    def mk():
        return Trainer(api, shape, opt_cfg=None, ckpt_dir=str(tmp_path),
                       ckpt_every=5, seed=3)

    # uninterrupted run of 10 steps
    t1 = mk()
    t1.run(10)
    losses_ref = [m["loss"] for m in t1.metrics_log]
    shutil.rmtree(tmp_path)
    os.makedirs(tmp_path)

    # run that "fails" at step 7 and resumes from the step-5 checkpoint
    t2 = mk()
    with pytest.raises(RuntimeError):
        t2.run(10, fault_hook=lambda s: s == 7)
    t2.ckpt.wait()           # flush the in-flight async writer (the step-5
    #                          commit races the injected fault otherwise)
    assert [m["step"] for m in t2.metrics_log] == list(range(7))
    np.testing.assert_allclose([m["loss"] for m in t2.metrics_log][:5],
                               losses_ref[:5], rtol=1e-5)
    t3 = mk()
    assert t3.ckpt.latest_step() == 5
    t3.run(5)                                   # deterministic replay 5..10
    assert [m["step"] for m in t3.metrics_log] == list(range(5, 10))
    np.testing.assert_allclose([m["loss"] for m in t3.metrics_log],
                               losses_ref[5:10], rtol=1e-5)


def test_straggler_monitor():
    m = StragglerMonitor(factor=2.0)
    for _ in range(5):
        m.observe(0, 1.0)
    assert not m.flagged
    assert m.observe(6, 5.0) is True
    assert len(m.flagged) == 1


def test_paged_kv_manager_chains_and_reuse():
    pool = PagePool(num_pages=8, page_size=4, num_layers=1,
                    num_kv_heads=2, head_dim=8)
    mgr = PagedKVManager(pool)
    s1 = mgr.add_sequence(0, [1, 2, 3])
    k = np.arange(6 * 2 * 8, dtype=np.float32).reshape(6, 2, 8)
    mgr.write_kv(s1, 0, k, k, 0)               # 6 slots -> 2 pages (H-chain)
    assert len(s1.pages) == 2
    pt = mgr.page_table([s1], 2)
    got = np.concatenate([pool.k[0, pt[0, 0]], pool.k[0, pt[0, 1]]])[:6]
    np.testing.assert_array_equal(got, k)
    # release returns pages to the free list (paper's VID reuse)
    free_before = pool.free_pages
    mgr.release(s1)
    assert pool.free_pages == free_before + 2
    with pytest.raises(MemoryError):
        s2 = mgr.add_sequence(1, [1])
        mgr.ensure_capacity(s2, 9 * 4)          # exceed pool
