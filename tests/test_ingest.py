"""Distributed device-side ingest: chunked bulk load bit-identity with
the monolithic path (pages AND reads, across N x R), BulkTimeline phase
accounting, raw-chunk-only coordinator traffic over real RoP links, and
the mutation firehose — windowed device-side batches whose reads are
bit-identical to serial unit-mutation replay, with typed write-side
admission control."""
import threading
import time

import numpy as np
import pytest

from repro.core.service import HolisticGNNService
from repro.rpc.queues import BackpressureError
from repro.store import (BlockDevice, GraphStore, MutationFirehose,
                         ReplicatedGraphStore, ShardedGraphStore,
                         make_rop_endpoints)
from repro.store.blockdev import DeviceFailedError


def _graph(n=400, e=3000, feat=24, seed=0):
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.zipf(1.4, e) % n],
                     axis=1).astype(np.int64)
    emb = rng.standard_normal((n, feat)).astype(np.float32)
    return edges, emb


def _mk(n_shards, replication, **kw):
    kw.setdefault("h_threshold", 16)
    if replication == 1:
        return ShardedGraphStore(n_shards=n_shards, **kw)
    return ReplicatedGraphStore(n_shards=n_shards,
                                replication=replication, **kw)


def _shard_devs(store):
    return [ep.service.store.dev for ep in store.endpoints] \
        if hasattr(store.endpoints[0], "service") else None


# ------------------------------------------------------- bulk bit-identity
@pytest.mark.parametrize("n_shards,replication",
                         [(1, 1), (2, 1), (2, 2), (4, 1), (4, 2)])
def test_chunked_ingest_bit_identical_pages_and_reads(n_shards, replication):
    """Chunked distributed ingest lays the SAME pages as the monolithic
    coordinator-side preprocess — device arrays compare equal — and every
    read matches."""
    edges, emb = _graph()
    n = len(emb)
    a = _mk(n_shards, replication)
    b = _mk(n_shards, replication)
    a.update_graph(edges, emb)
    tl = b.update_graph_chunked(edges, emb, chunk_edges=500,
                                emb_chunk_rows=64)
    assert a.num_vertices == b.num_vertices == n
    for s in range(n_shards):
        pa = a.endpoints[s].service.store.dev._pages
        pb = b.endpoints[s].service.store.dev._pages
        np.testing.assert_array_equal(pa, pb, err_msg=f"shard {s}")
    rng = np.random.default_rng(3)
    vids = rng.integers(0, n + 10, 80)
    for va, vb in zip(a.get_neighbors_batch(vids),
                      b.get_neighbors_batch(vids)):
        np.testing.assert_array_equal(va, vb)
    known = vids[vids < n]
    np.testing.assert_array_equal(a.get_embeds(known), b.get_embeds(known))
    assert tl.total > 0.0


def test_chunked_ingest_no_embeddings_and_already_undirected():
    edges, _ = _graph(e=1200)
    mirrored = np.concatenate([edges, edges[:, ::-1]])
    a = _mk(2, 1)
    b = _mk(2, 1)
    a.update_graph(edges)
    b.update_graph_chunked(mirrored, already_undirected=True,
                           chunk_edges=300)
    assert a.to_adjacency() == b.to_adjacency()


def test_bulk_timeline_phases_populated():
    edges, emb = _graph()
    st = _mk(2, 1)
    tl = st.update_graph_chunked(edges, emb, chunk_edges=500,
                                 emb_chunk_rows=64)
    # transfer starts the load; graph_pre (exchange + device sort) follows;
    # the commit bursts close it out; user-visible excludes the graph tail
    assert tl.transfer[0] == 0.0 and tl.transfer[1] > 0.0
    assert tl.transfer[1] <= tl.graph_pre[0] <= tl.graph_pre[1]
    assert tl.write_feature[1] > tl.write_feature[0] >= tl.graph_pre[0]
    assert tl.write_graph[1] >= tl.write_feature[0]
    assert tl.total >= tl.user_visible > 0.0
    assert st._bulk is tl


def test_chunked_ingest_over_rop_links_raw_chunks_only():
    """Over real RoP endpoints the coordinator ships only raw edge chunks
    and embedding stripes: zero preprocessed write_adjacency /
    write_embedding_table commands, yet the pages are bit-identical to a
    local monolithic load."""
    edges, emb = _graph(n=256, e=1500, feat=8)
    ref = ShardedGraphStore(n_shards=2, h_threshold=16)
    ref.update_graph(edges, emb)
    eps = make_rop_endpoints(2, h_threshold=16, feature_dim=8)
    try:
        st = ShardedGraphStore(endpoints=eps)
        st.update_graph_chunked(edges, emb, chunk_edges=400,
                                emb_chunk_rows=64)
        for s, ep in enumerate(eps):
            np.testing.assert_array_equal(
                ref.endpoints[s].service.store.dev._pages,
                ep.host.service.store.dev._pages, err_msg=f"shard {s}")
            sent = ep.method_stats
            assert "write_adjacency" not in sent
            assert "write_embedding_table" not in sent
            assert sent["ingest_edges"].calls > 0
            assert sent["ingest_commit"].calls == 1
            assert ep.channel_bytes() > 0
        vids = np.arange(0, 256, 7)
        for va, vb in zip(ref.get_neighbors_batch(vids),
                          st.get_neighbors_batch(vids)):
            np.testing.assert_array_equal(va, vb)
    finally:
        for ep in eps:
            ep.close()


def test_chunked_ingest_rejects_failed_shard_and_aborts_sessions():
    edges, emb = _graph(e=1000)
    st = _mk(3, 2)
    st.update_graph(edges, emb)
    st.fail_shard(1)
    with pytest.raises(DeviceFailedError):
        st.update_graph_chunked(edges, emb)
    # sessions on the survivors were never opened / were aborted: a fresh
    # load on a healthy twin still works
    st2 = _mk(3, 2)
    st2.update_graph_chunked(edges, emb, chunk_edges=250)
    assert st2.num_vertices == len(emb)


def test_ingest_begin_rejects_nested_session():
    st = _mk(2, 1)
    ep = st.endpoints[0]
    ep.call("ingest_begin", shard=0, n_shards=2)
    with pytest.raises(RuntimeError):
        ep.call("ingest_begin", shard=0, n_shards=2)
    ep.call("ingest_abort")


# ------------------------------------------------------------- firehose
def _mixed_ops(n, feat, count, seed=1):
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(count):
        k = int(rng.integers(0, 5))
        if k == 0:
            ops.append(("add_edge", int(rng.integers(0, n)),
                        int(rng.integers(0, n))))
        elif k == 1:
            ops.append(("delete_edge", int(rng.integers(0, n)),
                        int(rng.integers(0, n))))
        elif k == 2:
            ops.append(("update_embed", int(rng.integers(0, n)),
                        rng.standard_normal(feat).astype(np.float32)))
        elif k == 3:
            ops.append(("add_vertex", int(rng.integers(0, n)),
                        rng.standard_normal(feat).astype(np.float32)))
        else:
            ops.append(("delete_vertex", int(rng.integers(0, n))))
    return ops


@pytest.mark.parametrize("replication", [1, 2])
def test_firehose_reads_bit_identical_to_serial_replay(replication):
    """Mid-stream reads at any flush boundary match a twin store applying
    the identical ops one unit mutation at a time — including the
    delete_vertex barrier and replica fan-out accounting."""
    edges, emb = _graph(n=300, e=2000, feat=16)
    n = 300
    a = _mk(3, replication)     # serial unit-mutation replay
    b = _mk(3, replication)     # firehose windows
    a.update_graph(edges, emb)
    b.update_graph(edges, emb)
    fh = MutationFirehose(b, max_window_ops=32)
    rng = np.random.default_rng(7)
    for i, op in enumerate(_mixed_ops(n, 16, 260)):
        getattr(a, op[0])(*op[1:])
        getattr(fh, op[0])(*op[1:])
        if i % 57 == 0:
            fh.flush()
            vids = rng.integers(0, n, 32)
            for va, vb in zip(a.get_neighbors_batch(vids),
                              b.get_neighbors_batch(vids)):
                np.testing.assert_array_equal(va, vb)
            np.testing.assert_array_equal(a.get_embeds(vids),
                                          b.get_embeds(vids))
    snap = fh.close()
    assert a.to_adjacency() == b.to_adjacency()
    assert a.num_vertices == b.num_vertices
    assert a.stats.unit_updates == b.stats.unit_updates
    assert snap["applied"] == snap["submitted"] == 260
    assert snap["log_depth"] == 0
    assert snap["windows"] > 1 and snap["barriers"] > 0
    assert snap["subops"] >= snap["applied"]


def test_firehose_single_device_serial_fallback():
    edges, emb = _graph(n=200, e=1200, feat=8)
    a = GraphStore(BlockDevice(), h_threshold=16)
    b = GraphStore(BlockDevice(), h_threshold=16)
    a.update_graph(edges, emb)
    b.update_graph(edges, emb)
    fh = MutationFirehose(b, max_window_ops=16)
    for op in _mixed_ops(200, 8, 120, seed=5):
        getattr(a, op[0])(*op[1:])
        getattr(fh, op[0])(*op[1:])
    fh.close()
    assert a.to_adjacency() == b.to_adjacency()
    np.testing.assert_array_equal(a.dev._pages, b.dev._pages)


def test_firehose_sheds_typed_backpressure_when_log_full():
    edges, emb = _graph(e=500)
    st = _mk(2, 1)
    st.update_graph(edges, emb)
    fh = MutationFirehose(st, max_log_ops=4)
    for i in range(4):
        fh.add_edge(i, i + 1)
    with pytest.raises(BackpressureError) as ei:
        fh.add_edge(9, 9)
    assert ei.value.reason["source"] == "firehose_log"
    assert ei.value.reason["limit"] == 4
    assert fh.snapshot()["shed"] == 1
    fh.flush()                  # drains, admission recovers
    fh.add_edge(9, 9)
    fh.close()


def test_firehose_window_timer_applies_in_background():
    edges, emb = _graph(e=800)
    st = _mk(2, 1)
    st.update_graph(edges, emb)
    fh = st.firehose(window_s=0.01).start()
    try:
        for i in range(40):
            fh.add_edge(i % 50, (i * 7) % 50)
        deadline = time.monotonic() + 5.0
        while fh.snapshot()["applied"] < 40:
            assert time.monotonic() < deadline, fh.snapshot()
            time.sleep(0.01)
        assert fh.last_error is None
    finally:
        snap = fh.close()
    assert snap["applied"] == 40 and snap["log_depth"] == 0


def test_firehose_rejects_bad_embed_row_at_submission():
    edges, emb = _graph(e=500)
    st = _mk(2, 2)
    st.update_graph(edges, emb)
    fh = MutationFirehose(st)
    with pytest.raises(KeyError):
        fh.update_embed(len(emb) + 100,
                        np.zeros(emb.shape[1], dtype=np.float32))
    assert fh.snapshot()["submitted"] == 0     # nothing poisoned the log
    fh.close()


def test_firehose_concurrent_readers_see_consistent_windows():
    """Reads racing the window timer always observe a window boundary:
    every observed neighbor list is one the serial-replay twin passes
    through."""
    edges, emb = _graph(n=200, e=1500, feat=8)
    st = _mk(2, 1)
    st.update_graph(edges, emb)
    fh = st.firehose(window_s=0.002, max_window_ops=8).start()
    stop = threading.Event()
    errs = []

    def reader():
        rng = np.random.default_rng(11)
        while not stop.is_set():
            vids = rng.integers(0, 200, 16)
            try:
                outs = st.get_neighbors_batch(vids)
                assert len(outs) == 16
            except Exception as e:  # noqa: BLE001
                errs.append(e)
                return

    th = threading.Thread(target=reader, daemon=True)
    th.start()
    try:
        for i in range(200):
            fh.add_edge(i % 200, (i * 13) % 200)
            if i % 50 == 0:
                time.sleep(0.005)
    finally:
        snap = fh.close()
        stop.set()
        th.join(timeout=5.0)
    assert not errs
    assert snap["applied"] == 200


# ------------------------------------------------------- service plumbing
def test_service_update_graph_chunked_and_already_undirected():
    edges, emb = _graph(n=300, e=2000, feat=16)
    ref = HolisticGNNService(h_threshold=16, n_shards=2)
    ref.update_graph(edges, emb)
    svc = HolisticGNNService(h_threshold=16, n_shards=2)
    out = svc.update_graph(edges, emb, chunked=True, chunk_edges=400)
    assert out["total_s"] > 0
    assert ref.store.to_adjacency() == svc.store.to_adjacency()
    # pre-mirrored input with already_undirected=True lands identically
    mirrored = np.concatenate([edges, edges[:, ::-1]])
    svc2 = HolisticGNNService(h_threshold=16, n_shards=2)
    svc2.update_graph(mirrored, emb, already_undirected=True,
                      chunked=True, chunk_edges=400)
    assert ref.store.to_adjacency() == svc2.store.to_adjacency()
    # single-device stores fall back to the monolithic path
    solo = HolisticGNNService(h_threshold=16)
    solo.update_graph(edges, emb, chunked=True)
    assert ref.store.to_adjacency() == solo.store.to_adjacency()


def test_service_firehose_rpcs_route_unit_mutations():
    edges, emb = _graph(n=200, e=1200, feat=8)
    svc = HolisticGNNService(h_threshold=16, n_shards=2)
    svc.update_graph(edges, emb)
    ref = HolisticGNNService(h_threshold=16, n_shards=2)
    ref.update_graph(edges, emb)
    svc.open_firehose(window_s=60.0)       # timer effectively off
    with pytest.raises(RuntimeError):
        svc.open_firehose()
    ops = _mixed_ops(200, 8, 60, seed=9)
    for op in ops:
        getattr(ref, op[0])(*op[1:])
        getattr(svc, op[0])(*op[1:])
    st = svc.stats()
    assert st["firehose"]["submitted"] == 60
    out = svc.flush_firehose()
    assert out["applied_now"] + out["barriers"] >= 0
    snap = svc.close_firehose()
    assert snap["applied"] == 60
    assert svc.firehose is None
    assert ref.store.to_adjacency() == svc.store.to_adjacency()
    # after close, unit mutations hit the store directly again
    svc.add_edge(1, 2)
    ref.add_edge(1, 2)
    assert ref.store.to_adjacency() == svc.store.to_adjacency()
